//! Quickstart: train a small classifier with WASGD+ on the tiny synthetic
//! workload and print the loss curve. Hermetic — the default `Auto`
//! backend falls back to the native engine, so no artifacts are needed:
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::synth::DatasetKind;

fn main() -> Result<()> {
    // Paper preset for the tiny workload: p=4 workers, τ=50, β=0.9, T=1.
    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.epochs = 4.0;
    cfg.eval_every = 32;

    println!(
        "WASGD+ quickstart: dataset={} variant={} p={} τ={} β={} ã={}",
        cfg.dataset.name(),
        cfg.variant,
        cfg.p,
        cfg.tau,
        cfg.beta,
        cfg.a_tilde
    );

    let out = run_experiment_full(&cfg)?;
    println!("iter      sim_time  train_loss  test_loss  test_err");
    for r in &out.log.records {
        println!(
            "{:>6}  {:>9.3}s  {:>10.4}  {:>9.4}  {:>8.3}",
            r.iteration, r.sim_time_s, r.train_loss, r.test_loss, r.test_error
        );
    }

    let first = out.log.records.first().unwrap().train_loss;
    let last = out.log.records.last().unwrap().train_loss;
    println!(
        "\ntrain loss {first:.4} → {last:.4}  ({} kernel executions, \
         comm {:.3}s sim, orders kept/redrawn {}/{})",
        out.exec_count, out.comm_time_s, out.orders_kept, out.orders_redrawn
    );
    assert!(last < first, "training should reduce the loss");
    Ok(())
}
