//! Real-thread WASGD+ launcher: p OS threads, each with its own
//! execution backend, blocking all-gather at every τ — the deployment-shaped
//! topology (the simulation used by the figures replaces only *time*,
//! this replaces nothing).
//!
//! ```bash
//! cargo run --release --example threaded_workers -- [p] [steps]
//! ```

use anyhow::Result;
use wasgd::cluster::threads::run_wasgd_plus_threaded;
use wasgd::config::ExperimentConfig;
use wasgd::data::synth::DatasetKind;

fn main() -> Result<()> {
    let p: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(256);

    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
    cfg.p = p;

    println!(
        "threaded WASGD+: {} real workers × {steps} steps (τ={}, β={}, ã={}) on {}",
        cfg.p, cfg.tau, cfg.beta, cfg.a_tilde, cfg.dataset.name()
    );
    let out = run_wasgd_plus_threaded(&cfg, steps)?;
    println!(
        "wall {:.2}s — final per-worker mean batch loss: {:?}",
        out.wall_time_s,
        out.final_energies.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    println!("worker-0 param vector: D={} (‖x‖₂ = {:.4})", out.params.len(),
        wasgd::linalg::norm2(&out.params));
    assert!(out.final_energies.iter().all(|&e| e.is_finite() && e < 1.0),
        "threaded cohort should have learned the tiny task");
    println!("threaded run OK");
    Ok(())
}
