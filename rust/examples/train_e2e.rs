//! End-to-end driver (EXPERIMENTS.md §E2E): train the MNIST-analogue
//! MLP (~235k params) for a few hundred steps with WASGD+ over p=4
//! workers, against sequential SGD under the same budget, proving the
//! full stack composes: synthetic data → rust coordinator → backend
//! kernel execution (native MLP engine by default; the Pallas-backed
//! PJRT artifacts with `--features pjrt` + artifacts on disk) → weighted
//! aggregation → metrics.
//!
//! ```bash
//! cargo run --release --example train_e2e
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::synth::DatasetKind;
use wasgd::metrics::write_csv;

fn main() -> Result<()> {
    let epochs = std::env::args()
        .nth(1)
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0);

    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::MnistLike);
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 50;
    cfg.m = 10;
    cfg.c = 2;
    cfg.epochs = epochs;
    cfg.eval_every = 32;
    cfg.eval_batches = 8;

    println!(
        "end-to-end: {} on {} | p={} τ={} β={} ã={} η={} | epochs={}",
        cfg.algo.name(),
        cfg.dataset.name(),
        cfg.p,
        cfg.tau,
        cfg.beta,
        cfg.a_tilde,
        cfg.lr,
        cfg.epochs
    );

    let plus = run_experiment_full(&cfg)?;
    println!("\n-- WASGD+ loss curve --");
    println!("{:>7} {:>8} {:>11} {:>11} {:>10} {:>10}", "iter", "epoch", "sim_time_s", "train_loss", "train_err", "test_err");
    for r in &plus.log.records {
        println!(
            "{:>7} {:>8.2} {:>11.3} {:>11.4} {:>10.3} {:>10.3}",
            r.iteration, r.epoch, r.sim_time_s, r.train_loss, r.train_error, r.test_error
        );
    }

    let mut seq_cfg = cfg.clone();
    seq_cfg.algo = AlgoKind::Sequential;
    let seq = run_experiment_full(&seq_cfg)?;

    let p_final = plus.log.records.last().unwrap();
    let s_final = seq.log.records.last().unwrap();
    println!("\n-- same-epoch-budget comparison --");
    println!(
        "WASGD+ p=4 : train_loss {:.4}  test_err {:.3}  sim_time {:.2}s",
        p_final.train_loss, p_final.test_error, p_final.sim_time_s
    );
    println!(
        "seq SGD    : train_loss {:.4}  test_err {:.3}  sim_time {:.2}s",
        s_final.train_loss, s_final.test_error, s_final.sim_time_s
    );
    // Time-to-loss speedup at a common target.
    let target = s_final.train_loss.max(p_final.train_loss) * 1.05;
    if let (Some(tp), Some(ts)) = (plus.log.time_to_loss(target), seq.log.time_to_loss(target)) {
        println!("time-to-loss({target:.3}): wasgd+ {tp:.2}s vs sgd {ts:.2}s → {:.2}× speedup", ts / tp);
    }
    println!(
        "kernel execs: {} | comm {:.3}s sim | wait {:.3}s sim | orders kept/redrawn {}/{}",
        plus.exec_count, plus.comm_time_s, plus.wait_time_s, plus.orders_kept, plus.orders_redrawn
    );

    write_csv("results/e2e_mnist.csv", &[plus.log, seq.log])?;
    println!("wrote results/e2e_mnist.csv");
    Ok(())
}
