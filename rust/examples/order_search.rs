//! §3.4 sample-order search demo: watch WASGD+ retain good shuffling
//! seeds (Judge score ≤ −1) and redraw bad ones, and compare against
//! forced δ-blocked orders (the Fig. 3 pathology).

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::synth::DatasetKind;

fn main() -> Result<()> {
    let base = {
        let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
        cfg.algo = AlgoKind::WasgdPlus;
        cfg.p = 4;
        cfg.epochs = 6.0;
        cfg.eval_every = 64;
        cfg
    };

    // 1) Order search on (normal WASGD+).
    let searched = run_experiment_full(&base)?;
    println!(
        "order search: kept {} / redrawn {} parts; final train loss {:.4}",
        searched.orders_kept,
        searched.orders_redrawn,
        searched.log.final_train_loss()
    );

    // 2) Forced δ-blocked orders — the paper's Fig. 3 degradation.
    println!("\nforced δ-label-blocked orders (no search):");
    println!("{:>6}  {:>12}  {:>10}", "δ", "final loss", "final err");
    let mut last_loss = 0.0;
    for delta in [1usize, 10, 100] {
        let mut cfg = base.clone();
        cfg.force_delta_order = Some(delta);
        let out = run_experiment_full(&cfg)?;
        let r = out.log.records.last().unwrap();
        println!("{delta:>6}  {:>12.4}  {:>10.3}", r.train_loss, r.train_error);
        last_loss = r.train_loss;
    }
    let _ = last_loss;

    println!(
        "\nsearched order beat or matched blocked orders: {:.4} (searched)",
        searched.log.final_train_loss()
    );
    Ok(())
}
