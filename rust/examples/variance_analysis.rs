//! Lemma 2 / Eq. (35) validation (DESIGN.md experiment E10).
//!
//! The paper derives the asymptotic variance of the weighted-aggregating
//! iterate on the quadratic F(x) = ½cx² with noisy gradients
//! g(x) = cx − b̃x − h̃ (b̃, h̃ zero-mean, variances σ_b², σ_h²) and
//! communication probability ζ per step:
//!
//!   lim Var(Σθᵢxᵢ) = η σ_h² ω (2c − ηc² − ησ_b²(1+δω)/(1+δ))⁻¹
//!   with ω = Σθᵢ², δ = ζ / ((1−ζ)η(2c−ηc²)).
//!
//! This driver runs the actual stochastic recursion (pure rust — no PJRT
//! needed: the lemma is about the update rule, not the model) for a grid
//! of (p, ζ, weighting) and compares the empirical variance with the
//! closed form. It also exercises Lemma 3's boundary: ζ=1 equal-weights
//! ≡ mini-batch SGD.

use wasgd::linalg;
use wasgd::rng::Rng;

/// Closed-form Eq. (35).
fn predicted_variance(eta: f64, c: f64, sb2: f64, sh2: f64, omega: f64, zeta: f64) -> f64 {
    let rho = 2.0 * c - eta * c * c;
    let delta = if zeta >= 1.0 {
        f64::INFINITY
    } else {
        zeta / ((1.0 - zeta) * eta * rho)
    };
    let frac = if delta.is_infinite() {
        omega
    } else {
        (1.0 + delta * omega) / (1.0 + delta)
    };
    eta * sh2 * omega / (rho - eta * sb2 * frac)
}

/// Simulate the recursion and measure lim Var(Σθᵢxᵢ).
fn empirical_variance(
    p: usize,
    theta: &[f32],
    eta: f64,
    c: f64,
    sb: f64,
    sh: f64,
    zeta: f64,
    steps: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f64; p];
    let burn = steps / 4;
    let mut acc = 0.0;
    let mut acc2 = 0.0;
    let mut n = 0usize;
    for t in 0..steps {
        for xi in x.iter_mut() {
            let b = rng.normal() * sb;
            let h = rng.normal() * sh;
            // x ← x − η g(x),  g(x) = c x − b̃ x − h̃
            *xi = (1.0 - eta * c) * *xi + eta * (b * *xi + h);
        }
        if rng.uniform() < zeta {
            // Communication: everyone adopts the weighted aggregate (β=1).
            let agg: f64 = x
                .iter()
                .zip(theta.iter())
                .map(|(&xi, &th)| th as f64 * xi)
                .sum();
            for xi in x.iter_mut() {
                *xi = agg;
            }
        }
        if t >= burn {
            let agg: f64 = x
                .iter()
                .zip(theta.iter())
                .map(|(&xi, &th)| th as f64 * xi)
                .sum();
            acc += agg;
            acc2 += agg * agg;
            n += 1;
        }
    }
    let mean = acc / n as f64;
    acc2 / n as f64 - mean * mean
}

fn main() {
    let eta = 0.05;
    let c = 1.0;
    let sb = 0.2;
    let sh = 1.0;
    let steps = 400_000;

    println!("Lemma 2 (Eq. 35): predicted vs empirical asymptotic variance");
    println!("{:<28} {:>6} {:>6} {:>12} {:>12} {:>8}", "weighting", "p", "ζ", "predicted", "empirical", "ratio");

    let mut worst_ratio: f64 = 1.0;
    for &p in &[2usize, 4, 8] {
        for &zeta in &[0.1f64, 0.5, 0.9] {
            for (name, theta) in [
                ("equal", vec![1.0 / p as f32; p]),
                (
                    "boltzmann(ã=1, spread h)",
                    linalg::boltzmann_weights(
                        &(0..p).map(|i| 0.5 + i as f32 * 0.5).collect::<Vec<_>>(),
                        1.0,
                    ),
                ),
            ] {
                let omega: f64 = theta.iter().map(|&t| (t as f64).powi(2)).sum();
                let pred = predicted_variance(eta, c, sb * sb, sh * sh, omega, zeta);
                let emp = empirical_variance(
                    p, &theta, eta, c, sb, sh, zeta, steps, 1234 + p as u64,
                );
                let ratio = emp / pred;
                worst_ratio = worst_ratio.max(ratio.max(1.0 / ratio));
                println!(
                    "{name:<28} {p:>6} {zeta:>6.1} {pred:>12.6} {emp:>12.6} {ratio:>8.3}"
                );
            }
        }
    }
    println!("\nworst predicted/empirical mismatch: {worst_ratio:.3}×");
    assert!(
        worst_ratio < 1.35,
        "empirical variance should track Eq. (35) within ~35% at this budget"
    );

    // Lemma 3: ζ=1 equal weights ≡ mini-batch SGD with batch p.
    println!("\nLemma 3 boundary: ζ=1 equal-weight vs mini-batch (p=4)");
    let p = 4;
    let theta = vec![1.0 / p as f32; p];
    let emp = empirical_variance(p, &theta, eta, c, sb, sh, 1.0, steps, 99);
    // Mini-batch of p gradients: variance of noise term shrinks by p.
    let mut rng = Rng::new(100);
    let mut x = 0.0f64;
    let (mut acc, mut acc2, mut n) = (0.0, 0.0, 0usize);
    for t in 0..steps {
        let mut g = 0.0;
        for _ in 0..p {
            let b = rng.normal() * sb;
            let h = rng.normal() * sh;
            g += c * x - b * x - h;
        }
        x -= eta * g / p as f64;
        if t >= steps / 4 {
            acc += x;
            acc2 += x * x;
            n += 1;
        }
    }
    let mb = acc2 / n as f64 - (acc / n as f64).powi(2);
    println!("aggregated ζ=1: {emp:.6}   mini-batch: {mb:.6}   ratio {:.3}", emp / mb);
    assert!((emp / mb - 1.0).abs() < 0.25, "Lemma 3 equivalence violated");
    println!("\nvariance analysis OK");
}
