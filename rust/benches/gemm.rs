//! Micro-bench: the blocked GEMM kernel subsystem vs its naive
//! reference — the anchor entry of the `BENCH_native.json` perf
//! trajectory. The acceptance bar for the kernel work is measured here:
//! blocked at `threads=2` must clear ≥2× the naive reference median on
//! a 256×256×256 GEMM — recorded precisely in the JSON, and asserted
//! *loosely* (≥1.3×) in `--quick` mode so CI's bench-smoke job catches
//! outright regressions without flaking on noisy shared runners.
//!
//! Covers the forward product (`matmul_bias`), both backward products
//! (`matmul_tn_acc`, `matmul_nt`) and an im2col-shaped panel (the conv
//! hot path: many rows, tiny K); the aggregation row-combine boundary
//! lives in `benches/aggregation.rs`.

use wasgd::bench::{self, black_box, Bencher};
use wasgd::kernels::{reference, Gemm};
use wasgd::rng::Rng;
use wasgd::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    args.accept("bench"); // cargo appends --bench to harness=false bins
    let quick = args.bool_flag("quick") || Bencher::env_quick();
    let max_threads = args.num_flag("max-threads", 4usize)?;
    args.finish()?;
    let mut b = Bencher::with_quick(quick);
    let mut rng = Rng::new(7);

    // The acceptance shape: 256³.
    let (m, k, n) = (256usize, 256usize, 256usize);
    let mut a = vec![0.0f32; m * k];
    let mut w = vec![0.0f32; k * n];
    let mut bias = vec![0.0f32; n];
    rng.fill_normal(&mut a, 0.0, 1.0);
    rng.fill_normal(&mut w, 0.0, 1.0);
    rng.fill_normal(&mut bias, 0.0, 1.0);
    let mut z = vec![0.0f32; m * n];

    let naive_s = b
        .bench("gemm naive 256x256x256", || {
            reference::matmul_bias(&a, &w, &bias, m, k, n, &mut z);
            black_box(z[0]);
        })
        .median_s;

    let mut blocked_t2_s = f64::NAN;
    for t in [1usize, 2, 4] {
        if t > max_threads.max(1) {
            continue;
        }
        let g = Gemm::new(t);
        let s = b
            .bench_with_threads(&format!("gemm blocked 256x256x256 t={t}"), t, || {
                g.matmul_bias(&a, &w, &bias, m, k, n, &mut z);
                black_box(z[0]);
            })
            .median_s;
        if t == 2 {
            blocked_t2_s = s;
        }
    }

    // Backward products at the same shape (threads = 2).
    {
        let g = Gemm::new(2.min(max_threads.max(1)));
        let t = g.threads();
        let mut gw = vec![0.0f32; k * n];
        b.bench_with_threads(&format!("gemm tn_acc 256x256x256 t={t}"), t, || {
            g.matmul_tn_acc(&a, &z, m, k, n, &mut gw);
            black_box(gw[0]);
        });
        let mut da = vec![0.0f32; m * k];
        b.bench_with_threads(&format!("gemm nt 256x256x256 t={t}"), t, || {
            g.matmul_nt(&z, &w, m, n, k, &mut da);
            black_box(da[0]);
        });
    }

    // im2col-shaped panel: rows = B·H·W of a 32×32 conv layer, K = 9·cin.
    {
        let (rows, kk, cc) = (8192usize, 27usize, 32usize);
        let mut patches = vec![0.0f32; rows * kk];
        let mut cw = vec![0.0f32; kk * cc];
        let cb = vec![0.1f32; cc];
        rng.fill_normal(&mut patches, 0.0, 1.0);
        rng.fill_normal(&mut cw, 0.0, 1.0);
        let mut cz = vec![0.0f32; rows * cc];
        b.bench("gemm naive im2col 8192x27x32", || {
            reference::matmul_bias(&patches, &cw, &cb, rows, kk, cc, &mut cz);
            black_box(cz[0]);
        });
        for t in [1usize, 2] {
            if t > max_threads.max(1) {
                continue;
            }
            let g = Gemm::new(t);
            b.bench_with_threads(&format!("gemm blocked im2col 8192x27x32 t={t}"), t, || {
                g.matmul_bias(&patches, &cw, &cb, rows, kk, cc, &mut cz);
                black_box(cz[0]);
            });
        }
    }

    // (The aggregation row-combine boundary is benched by
    // `benches/aggregation.rs`, which owns that suite.)

    let speedup = naive_s / blocked_t2_s;
    println!("\nblocked t=2 speedup over naive on 256³: {speedup:.2}× (acceptance bar: ≥2×)");
    if quick && max_threads >= 2 {
        // Loose smoke gate — quick mode measures from a handful of
        // iterations on shared CI cores, so only an outright regression
        // (blocked barely beating naive) should fail the job. The ≥2×
        // acceptance bar is read off the precise medians recorded in
        // BENCH_native.json by a full `cargo bench --bench gemm`.
        assert!(
            speedup >= 1.3,
            "blocked t=2 must clearly beat the naive reference on 256³ (≥1.3× smoke gate, \
             ≥2× acceptance bar), got {speedup:.2}× (naive {naive_s:.5}s, blocked \
             {blocked_t2_s:.5}s)"
        );
    }

    b.summary("gemm kernels");
    let path = bench::bench_json_path();
    bench::append_bench_json(&path, "gemm", quick, b.results())?;
    println!("perf trajectory → {}", path.display());
    Ok(())
}
