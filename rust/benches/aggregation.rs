//! Micro-bench: the weighted-aggregation boundary (the paper's hot
//! communication step) — the backend kernel (native panel kernel, or the
//! PJRT Pallas artifact when built with `--features pjrt` and artifacts
//! exist) vs the host fallback — plus the weight evaluation itself and
//! the kernel-subsystem row-combine it is built on. Informs the
//! DESIGN.md §Perf choice of when each path pays off; stats land in the
//! `BENCH_native.json` perf trajectory.

use wasgd::algorithms::host_aggregate;
use wasgd::bench::{self, black_box, Bencher};
use wasgd::config::BackendKind;
use wasgd::kernels::Gemm;
use wasgd::linalg;
use wasgd::rng::Rng;
use wasgd::runtime::{backend_for_variant, Backend as _};
use wasgd::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    args.accept("bench");
    let quick = args.bool_flag("quick") || Bencher::env_quick();
    // Resolve 0 = all cores up front so entry tags record the real count.
    let threads = Gemm::new(args.num_flag("threads", 2usize)?).threads();
    args.finish()?;
    let mut b = Bencher::with_quick(quick);
    let mut rng = Rng::new(1);

    // Host weight evaluation.
    for p in [4usize, 16] {
        let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.1, 2.0)).collect();
        b.bench(&format!("boltzmann_weights p={p}"), || {
            black_box(linalg::boltzmann_weights(black_box(&h), 1.0));
        });
    }

    // Host aggregation across parameter sizes (D of tiny ≈ 154, mnist ≈ 235k).
    for (dname, d) in [("tiny", 154usize), ("mnist_mlp", 235_146)] {
        for p in [2usize, 4, 8] {
            let mut params: Vec<Vec<f32>> = (0..p)
                .map(|_| {
                    let mut v = vec![0.0f32; d];
                    rng.fill_normal(&mut v, 0.0, 1.0);
                    v
                })
                .collect();
            let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.1, 2.0)).collect();
            let theta = linalg::boltzmann_weights(&h, 1.0);
            b.bench(&format!("host_aggregate {dname} p={p} (D={d})"), || {
                host_aggregate(black_box(&mut params), black_box(&theta), 0.9);
            });
        }
    }

    // The row-combine the aggregation is built on, single vs threaded.
    {
        let d = 235_146usize;
        let p = 4usize;
        let rows_flat: Vec<Vec<f32>> = (0..p)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let refs: Vec<&[f32]> = rows_flat.iter().map(|r| r.as_slice()).collect();
        let wts = [0.3f32, 0.2, 0.4, 0.1];
        let mut agg = vec![0.0f32; d];
        let single = Gemm::single();
        b.bench_with_threads(&format!("combine_rows mnist_mlp p={p} t=1"), 1, || {
            single.combine_rows(&mut agg, &refs, &wts);
            black_box(agg[0]);
        });
        if threads > 1 {
            let g = Gemm::new(threads);
            b.bench_with_threads(&format!("combine_rows mnist_mlp p={p} t={threads}"), threads, || {
                g.combine_rows(&mut agg, &refs, &wts);
                black_box(agg[0]);
            });
        }
    }

    // Backend kernel path: native always works; with `--features pjrt`
    // and artifacts on disk, Auto picks the Pallas artifact instead.
    let root = std::path::Path::new("artifacts");
    for variant in ["tiny_mlp", "mnist_mlp"] {
        match backend_for_variant(root, variant, BackendKind::Auto, threads) {
            Ok(engine) => {
                let d = engine.manifest().param_count;
                for p in [2usize, 4, 8] {
                    if !engine.has_aggregate(p) {
                        continue;
                    }
                    let mut stacked = vec![0.0f32; p * d];
                    rng.fill_normal(&mut stacked, 0.0, 1.0);
                    let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.1, 2.0)).collect();
                    // Warm the executable cache.
                    let _ = engine.aggregate(&stacked, &h, 1.0, 0.9).unwrap();
                    let name = engine.name();
                    b.bench_with_threads(
                        &format!("{name}_aggregate {variant} p={p} (D={d})"),
                        threads,
                        || {
                            black_box(
                                engine
                                    .aggregate(black_box(&stacked), black_box(&h), 1.0, 0.9)
                                    .unwrap(),
                            );
                        },
                    );
                }
            }
            Err(e) => eprintln!("skipping {variant}: {e}"),
        }
    }

    b.summary("aggregation boundary");
    let path = bench::bench_json_path();
    bench::append_bench_json(&path, "aggregation", quick, b.results())?;
    println!("perf trajectory → {}", path.display());
    Ok(())
}
