//! Micro-bench: simulated-cluster substrate (clock advance, collectives)
//! and the deterministic PRNG — the coordinator's non-PJRT hot loop.
//! These must stay negligible next to a PJRT step (~ms): the simulation
//! layer may not become the bottleneck (DESIGN.md §Perf L3 target).
//! Appends its stats to the `BENCH_native.json` perf trajectory.

use wasgd::bench::{self, black_box, Bencher};
use wasgd::cluster::{ComputeModel, FabricConfig, SimCluster};
use wasgd::data::order::{delta_blocked_order, OrderState, RecordWindow};
use wasgd::rng::Rng;
use wasgd::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    args.accept("bench");
    let quick = args.bool_flag("quick") || Bencher::env_quick();
    args.finish()?;
    let mut b = Bencher::with_quick(quick);

    // PRNG primitives.
    let mut rng = Rng::new(1);
    b.bench("rng next_u64", || {
        black_box(rng.next_u64());
    });
    b.bench("rng normal", || {
        black_box(rng.normal());
    });
    b.bench("rng permutation n=8192", || {
        black_box(rng.permutation(8192));
    });

    // Cluster ops.
    for p in [4usize, 16] {
        let mut c = SimCluster::new(p, FabricConfig::default(), ComputeModel::default(), 7);
        b.bench(&format!("advance_compute p={p} (1 step each)"), || {
            for i in 0..p {
                c.advance_compute(i, 1);
            }
        });
        b.bench(&format!("sync_allgather p={p} 1MiB"), || {
            black_box(c.sync_allgather(1 << 20));
        });
        b.bench(&format!("async_gather p={p} quorum={}", p - 1), || {
            black_box(c.async_gather(0, p - 1, 1 << 20));
        });
    }

    // Order machinery.
    let labels: Vec<i32> = (0..8192).map(|i| (i % 10) as i32).collect();
    let mut orng = Rng::new(3);
    b.bench("delta_blocked_order n=8192 δ=10", || {
        black_box(delta_blocked_order(&labels, 10, &mut orng));
    });
    let mut st = OrderState::new(8192, 4, 5);
    b.bench("order_for_part n=8192/4", || {
        st.record_score(0, 0.5);
        black_box(st.order_for_part(0));
    });
    let w = RecordWindow::new(1000, 100, 4);
    let mut k = 0usize;
    b.bench("record_window is_recorded", || {
        k = (k + 1) % 1000;
        black_box(w.is_recorded(k));
    });

    b.summary("fabric & substrates");
    let path = bench::bench_json_path();
    bench::append_bench_json(&path, "fabric", quick, b.results())?;
    println!("perf trajectory → {}", path.display());
    Ok(())
}
