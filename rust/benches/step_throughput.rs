//! Micro-bench: the engine hot path — train_step / eval_batch per
//! variant, and one full coordinator iteration per algorithm (the
//! end-to-end step cost that every figure's wall-time depends on).
//! §Perf L3: the coordinator overhead around `train_step` must stay in
//! the noise. Runs on whichever backend Auto resolves to (native without
//! artifacts; PJRT with `--features pjrt` + artifacts). `cifar_cnn10`
//! exercises the native conv path (im2col GEMMs through the blocked
//! kernel subsystem — `--threads N` sets the intra-op budget). Appends
//! its stats to the `BENCH_native.json` perf trajectory.

use wasgd::bench::{self, black_box, Bencher};
use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::synth::DatasetKind;
use wasgd::rng::Rng;
use wasgd::runtime::{backend_for_variant, Backend as _};
use wasgd::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    args.accept("bench");
    let quick = args.bool_flag("quick") || Bencher::env_quick();
    // Resolve 0 = all cores up front so entry tags record the real count.
    let threads = wasgd::kernels::Gemm::new(args.num_flag("threads", 2usize)?).threads();
    args.finish()?;
    let mut b = Bencher::with_quick(quick);
    let root = std::path::Path::new("artifacts");
    let mut rng = Rng::new(1);

    for variant in ["tiny_mlp", "mnist_mlp", "cifar_cnn10"] {
        let engine = match backend_for_variant(root, variant, BackendKind::Auto, threads) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("skipping {variant}: {e}");
                continue;
            }
        };
        let m = engine.manifest();
        let mut params = m.init_params(1);
        let mut x = vec![0.0f32; m.batch * m.input_dim];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y: Vec<i32> = (0..m.batch).map(|_| rng.below(m.num_classes) as i32).collect();
        // Warm-up/compile.
        let _ = engine.train_step(&params, &x, &y, 0.01).unwrap();
        b.bench_with_threads(
            &format!("train_step {variant} (D={})", m.param_count),
            threads,
            || {
                let (next, out) = engine
                    .train_step(black_box(&params), black_box(&x), black_box(&y), 0.01)
                    .unwrap();
                params = next;
                black_box(out.loss);
            },
        );
        b.bench_with_threads(&format!("eval_batch {variant}"), threads, || {
            black_box(engine.eval_batch(black_box(&params), &x, &y).unwrap());
        });
    }

    // End-to-end: one full (short) coordinator run per algorithm on tiny.
    for algo in [
        AlgoKind::Sequential,
        AlgoKind::Easgd,
        AlgoKind::Wasgd,
        AlgoKind::WasgdPlus,
    ] {
        let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
        cfg.algo = algo;
        cfg.p = 4;
        cfg.epochs = 0.5;
        cfg.eval_every = 1_000_000; // suppress eval inside the bench
        cfg.backups = 1;
        b.bench(&format!("short run {} (0.5 epoch, p=4)", algo.name()), || {
            black_box(run_experiment_full(black_box(&cfg)).unwrap());
        });
    }

    b.summary("step throughput");
    let path = bench::bench_json_path();
    bench::append_bench_json(&path, "step_throughput", quick, b.results())?;
    println!("perf trajectory → {}", path.display());
    Ok(())
}
