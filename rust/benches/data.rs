//! Micro-bench: the data pipeline — batch gather throughput, IDX and
//! CIFAR parse throughput (in-memory, format-conformant buffers), and
//! the streaming batch-planner overhead. §Perf: the planner + gather
//! work sits on every local SGD step of every worker, so it must stay
//! in the noise next to `train_step`; the parsers bound how fast a
//! `--data-dir` run can come up. Appends its stats to the
//! `BENCH_native.json` perf trajectory (suite `data`).

use wasgd::bench::{self, black_box, Bencher};
use wasgd::data::synth::{DatasetKind, SynthConfig};
use wasgd::data::{cifar, idx, BatchPlanner};
use wasgd::rng::Rng;
use wasgd::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()?;
    args.accept("bench"); // cargo appends --bench to harness=false bins
    let quick = args.bool_flag("quick") || Bencher::env_quick();
    args.finish()?;
    let mut b = Bencher::with_quick(quick);
    let mut rng = Rng::new(13);

    // Gather throughput: one 32-example batch from an MNIST-shaped
    // split — the per-step hot path of every worker.
    {
        let ds = SynthConfig::preset(DatasetKind::MnistLike).with_sizes(8192, 512).build(1);
        let batch = 32usize;
        let mut x = Vec::new();
        let mut y = Vec::new();
        let mut idx_buf: Vec<u32> = Vec::with_capacity(batch);
        b.bench("gather_train mnist 32x784", || {
            idx_buf.clear();
            for _ in 0..batch {
                idx_buf.push(rng.below(ds.n_train()) as u32);
            }
            ds.gather_train(&idx_buf, &mut x, &mut y);
            black_box(x[0]);
        });
        b.bench("gather_test mnist 32x784", || {
            idx_buf.clear();
            for _ in 0..batch {
                idx_buf.push(rng.below(ds.n_test()) as u32);
            }
            ds.gather_test(&idx_buf, &mut x, &mut y);
            black_box(x[0]);
        });
    }

    // IDX parse throughput: 2048 MNIST-geometry images (~1.6 MB).
    {
        let (n, rows, cols) = (2048usize, 28usize, 28usize);
        let pixels: Vec<u8> = (0..n * rows * cols).map(|i| (i % 256) as u8).collect();
        let bytes = idx::encode_images(n, rows, cols, &pixels);
        b.bench("idx parse 2048x28x28", || {
            black_box(idx::parse_images(black_box(&bytes)).unwrap().pixels.len());
        });
    }

    // CIFAR parse throughput: 256 records (~768 KB) of each flavour.
    {
        let n = 256usize;
        let file = cifar::CifarFile {
            labels: (0..n).map(|k| (k % 10) as u8).collect(),
            coarse: Vec::new(),
            pixels_chw: (0..n * cifar::PIXELS_PER_RECORD).map(|i| (i % 256) as u8).collect(),
        };
        let bytes = cifar::encode(&file, cifar::CifarFormat::C10);
        b.bench("cifar10 parse 256 records", || {
            black_box(cifar::parse(black_box(&bytes), cifar::CifarFormat::C10).unwrap().n());
        });
    }

    // Planner overhead: one next_batch_into over an order-searched
    // 8192-sample split — the exact per-step planner cost, epoch
    // regenerations amortised in.
    {
        let n = 8192usize;
        let labels: Vec<i32> = (0..n).map(|i| (i % 10) as i32).collect();
        let mut planner =
            BatchPlanner::new(0, Rng::new(5), n, 32, None, true, 4, None, labels.clone());
        let mut out: Vec<u32> = Vec::with_capacity(32);
        b.bench("planner next_batch order-search 8192/b32", || {
            planner.next_batch_into(&mut out);
            black_box(out[0]);
        });
        let mut delta =
            BatchPlanner::new(0, Rng::new(5), n, 32, None, false, 4, Some(50), labels);
        b.bench("planner next_batch delta-blocked 8192/b32", || {
            delta.next_batch_into(&mut out);
            black_box(out[0]);
        });
    }

    b.summary("data pipeline");
    let path = bench::bench_json_path();
    bench::append_bench_json(&path, "data", quick, b.results())?;
    println!("perf trajectory → {}", path.display());
    Ok(())
}
