//! Property tests for the journal's record framing.
//!
//! The contract under test, for *arbitrary* events (including NaN and
//! ±∞ losses, empty strings, and odd resume geometries):
//!
//! * encode → parse is a bitwise round-trip, consuming exactly the
//!   encoded length;
//! * a stream of records parses completely; every strict prefix either
//!   yields fewer events (boundary cut) or reports the truncation
//!   offset and record index (mid-record cut) — never a clean
//!   full-length parse, and never a spurious hard error;
//! * corrupting any framing field (magic, version, kind, reserved,
//!   length, CRC) or flipping any payload bit is rejected with a
//!   pointed error, and oversized lengths are rejected *before* any
//!   allocation could happen.

use proptest::prelude::*;

use wasgd::cluster::wire::WireEncoding;
use wasgd::journal::{
    encode_record, parse_record, rank_journal_path, read_events_bytes, Event, MembershipChange,
    JOURNAL_VERSION, MAX_RECORD_LEN, RECORD_HEADER_LEN,
};

fn arb_f32_bits() -> impl Strategy<Value = f32> {
    any::<u32>().prop_map(f32::from_bits)
}

fn arb_resume() -> impl Strategy<Value = Vec<Vec<f32>>> {
    prop::collection::vec(prop::collection::vec(arb_f32_bits(), 0..6), 0..3)
}

fn arb_change() -> impl Strategy<Value = MembershipChange> {
    prop_oneof![
        Just(MembershipChange::Joined),
        Just(MembershipChange::Left),
        Just(MembershipChange::Crashed),
        Just(MembershipChange::Finished),
    ]
}

fn arb_event() -> impl Strategy<Value = Event> {
    prop_oneof![
        (
            (any::<u32>(), any::<u32>(), any::<u64>(), any::<bool>()),
            "[ -~]{0,24}",
            "[ -~]{0,64}",
            arb_resume(),
        )
            .prop_map(|((rank, p, seed, qi8), git_rev, config_json, resume)| {
                Event::RunStarted {
                    rank,
                    p,
                    seed,
                    encoding: if qi8 { WireEncoding::Qi8 } else { WireEncoding::F32 },
                    git_rev,
                    config_json,
                    resume,
                }
            }),
        (any::<u64>(), any::<u32>(), any::<u64>(), arb_f32_bits(), any::<u64>()).prop_map(
            |(round, rank, digest, loss, comm_bytes)| Event::PanelDigest {
                round,
                rank,
                digest,
                loss,
                comm_bytes,
            }
        ),
        (any::<u64>(), any::<u64>(), "[ -~]{0,32}").prop_map(|(steps, digest, path)| {
            Event::CheckpointWritten { steps, digest, path }
        }),
        (any::<u64>(), any::<u32>(), arb_change()).prop_map(|(epoch, rank, change)| {
            Event::Membership { epoch, rank, change }
        }),
        (any::<u64>(), any::<u64>(), any::<u64>()).prop_map(|(steps, rounds, final_digest)| {
            Event::RunFinished { steps, rounds, final_digest }
        }),
        (
            any::<u64>(),
            any::<u64>(),
            prop::collection::vec(any::<u32>(), 0..5),
            any::<u64>(),
            "[ -~]{0,24}",
        )
            .prop_map(|(epoch, round, members, anchor_digest, reason)| {
                Event::EpochCommitted { epoch, round, members, anchor_digest, reason }
            }),
    ]
}

proptest! {
    #[test]
    fn encode_parse_is_a_bitwise_roundtrip(ev in arb_event()) {
        let buf = encode_record(&ev);
        let (back, consumed) = parse_record(&buf).unwrap().expect("complete record");
        prop_assert_eq!(consumed, buf.len());
        // Event's PartialEq compares f32 payloads by bit pattern, so
        // this holds for NaN and ±∞ losses too.
        prop_assert_eq!(back, ev);
    }

    #[test]
    fn stream_prefixes_never_parse_clean(
        evs in prop::collection::vec(arb_event(), 1..5),
        cut_frac in 0.0f64..1.0,
    ) {
        let mut stream = Vec::new();
        let mut boundaries = vec![0usize];
        for ev in &evs {
            stream.extend_from_slice(&encode_record(ev));
            boundaries.push(stream.len());
        }
        let (full, trunc) = read_events_bytes(&stream).unwrap();
        prop_assert!(trunc.is_none());
        prop_assert_eq!(full.len(), evs.len());

        let cut = (stream.len() as f64 * cut_frac) as usize;
        prop_assume!(cut < stream.len()); // strict prefixes only
        let (pre, trunc) = read_events_bytes(&stream[..cut]).unwrap();
        if boundaries.contains(&cut) {
            // A record-boundary cut is a well-formed shorter stream;
            // the missing RunFinished is the replay layer's to flag.
            prop_assert!(trunc.is_none());
            prop_assert!(pre.len() < evs.len());
        } else {
            let t = trunc.expect("mid-record cut must report a truncation");
            let start_of_cut_record = *boundaries.iter().filter(|b| **b <= cut).max().unwrap();
            prop_assert_eq!(t.offset as usize, start_of_cut_record);
            prop_assert_eq!(t.record as usize, pre.len());
        }
    }

    #[test]
    fn framing_field_corruption_is_rejected(ev in arb_event(), field in 0usize..6) {
        let mut buf = encode_record(&ev);
        let expect = match field {
            0 => {
                buf[0] ^= 0xFF; // magic
                "magic"
            }
            1 => {
                buf[4] = buf[4].wrapping_add(1); // version
                "schema"
            }
            2 => {
                buf[6] = 99; // kind outside 1..=6
                "kind"
            }
            3 => {
                buf[7] = 7; // reserved must be 0
                "reserved"
            }
            4 => {
                buf[8..12].copy_from_slice(&u32::MAX.to_le_bytes()); // len
                "cap"
            }
            _ => {
                let n = buf.len();
                buf[n - 1] ^= 0x01; // stored CRC
                "CRC"
            }
        };
        let err = parse_record(&buf).expect_err("corrupt framing must error");
        let msg = format!("{err:#}");
        prop_assert!(msg.contains(expect), "wanted {:?} in: {}", expect, msg);
    }

    #[test]
    fn any_payload_bitflip_fails_the_crc(
        ev in arb_event(),
        sel in any::<prop::sample::Index>(),
        bit in 0u8..8,
    ) {
        let buf = encode_record(&ev);
        let payload_len = buf.len() - RECORD_HEADER_LEN - 4;
        prop_assume!(payload_len > 0);
        let mut bad = buf;
        bad[RECORD_HEADER_LEN + sel.index(payload_len)] ^= 1 << bit;
        let err = parse_record(&bad).expect_err("payload flip must fail the CRC");
        prop_assert!(format!("{err:#}").contains("CRC"));
    }
}

#[test]
fn oversized_length_is_rejected_before_any_allocation() {
    // A header alone claiming a huge payload: with validation-last this
    // would be Ok(None) forever (or worse, an attempted allocation).
    // The cap check runs on the 12 header bytes, so it errors here.
    let mut buf = Vec::new();
    buf.extend_from_slice(b"WSGJ");
    buf.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
    buf.push(2); // PanelDigest
    buf.push(0);
    buf.extend_from_slice(&(MAX_RECORD_LEN + 1).to_le_bytes());
    let err = parse_record(&buf).expect_err("oversized len must be rejected from the header");
    assert!(format!("{err:#}").contains("cap"));
}

#[test]
fn nan_and_infinite_losses_roundtrip_bit_exactly() {
    for loss in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0f32] {
        let ev = Event::PanelDigest { round: 3, rank: 1, digest: 7, loss, comm_bytes: 9 };
        let buf = encode_record(&ev);
        let (back, _) = parse_record(&buf).unwrap().unwrap();
        match back {
            Event::PanelDigest { loss: l, .. } => assert_eq!(l.to_bits(), loss.to_bits()),
            other => panic!("wrong event back: {other:?}"),
        }
        assert_eq!(back, ev, "bitwise PartialEq must treat NaN as equal to itself");
    }
}

#[test]
fn membership_and_epoch_commit_records_roundtrip_populated() {
    // The elastic path writes these with real payloads (not the empty
    // defaults the generators favour) — pin one populated instance of
    // each so the encoding of every field is exercised deterministically.
    let evs = [
        Event::Membership { epoch: 4, rank: 2, change: MembershipChange::Crashed },
        Event::EpochCommitted {
            epoch: 5,
            round: 17,
            members: vec![0, 2, 3],
            anchor_digest: 0xdead_beef_cafe_f00d,
            reason: "rank 1 missed its heartbeats (silent for 400ms) after round 17".to_string(),
        },
    ];
    for ev in evs {
        let buf = encode_record(&ev);
        let (back, consumed) = parse_record(&buf).unwrap().expect("complete record");
        assert_eq!(consumed, buf.len());
        assert_eq!(back, ev);
    }
}

#[test]
fn rank_journal_paths_are_distinct_suffixes() {
    let base = std::path::Path::new("/tmp/run.jrn");
    let p0 = rank_journal_path(base, 0);
    let p3 = rank_journal_path(base, 3);
    assert_ne!(p0, p3);
    assert!(p0.to_string_lossy().ends_with("rank0"));
    assert!(p3.to_string_lossy().ends_with("rank3"));
}
