//! Golden data-fixture pinning — the `data-fixtures` CI check.
//!
//! The four committed files under `tests/fixtures/data/` are tiny,
//! format-conformant IDX and CIFAR files produced by the deterministic
//! generators below (pure pixel formulas through the public
//! `data::idx` / `data::cifar` encoders). The tests:
//!
//! 1. re-generate each fixture and compare **byte-for-byte** against
//!    the committed file, so any drift in the encoders or the formats
//!    fails CI;
//! 2. decode the committed bytes and assert known pixel/label values,
//!    so the parsers are pinned against the on-disk representation
//!    (not merely against the encoders' own output).
//!
//! To regenerate after an intentional format change, run with
//! `WASGD_REGEN_FIXTURES=1` and commit the rewritten files.

use std::path::PathBuf;

use wasgd::data::{cifar, idx};

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/data")
}

/// Golden IDX images: 4 images of 6×6, pixel `i = (i·31 + 7) mod 251`.
fn golden_idx_images() -> Vec<u8> {
    let px: Vec<u8> = (0..4 * 6 * 6).map(|i| ((i * 31 + 7) % 251) as u8).collect();
    idx::encode_images(4, 6, 6, &px)
}

/// Golden IDX labels: `[3, 1, 4, 1]`.
fn golden_idx_labels() -> Vec<u8> {
    idx::encode_labels(&[3, 1, 4, 1])
}

/// Golden CIFAR-10: 2 records, labels `[7, 2]`, pixel `j` of record `k`
/// `= (j·31 + k·7 + 3) mod 256`.
fn golden_cifar10() -> Vec<u8> {
    let file = cifar::CifarFile {
        labels: vec![7, 2],
        coarse: Vec::new(),
        pixels_chw: (0..2 * cifar::PIXELS_PER_RECORD)
            .map(|i| {
                let (k, j) = (i / cifar::PIXELS_PER_RECORD, i % cifar::PIXELS_PER_RECORD);
                ((j * 31 + k * 7 + 3) % 256) as u8
            })
            .collect(),
    };
    cifar::encode(&file, cifar::CifarFormat::C10)
}

/// Golden CIFAR-100: 2 records, coarse `[1, 0]`, fine `[42, 99]`,
/// pixel `j` of record `k` `= (j·37 + k·11 + 5) mod 256`.
fn golden_cifar100() -> Vec<u8> {
    let file = cifar::CifarFile {
        labels: vec![42, 99],
        coarse: vec![1, 0],
        pixels_chw: (0..2 * cifar::PIXELS_PER_RECORD)
            .map(|i| {
                let (k, j) = (i / cifar::PIXELS_PER_RECORD, i % cifar::PIXELS_PER_RECORD);
                ((j * 37 + k * 11 + 5) % 256) as u8
            })
            .collect(),
    };
    cifar::encode(&file, cifar::CifarFormat::C100)
}

/// Compare (or, under `WASGD_REGEN_FIXTURES`, rewrite) one fixture.
fn check_fixture(name: &str, generated: Vec<u8>) {
    let path = fixture_dir().join(name);
    if std::env::var_os("WASGD_REGEN_FIXTURES").is_some() {
        std::fs::write(&path, &generated).unwrap();
        eprintln!("regenerated {}", path.display());
        return;
    }
    let committed = std::fs::read(&path).unwrap_or_else(|e| {
        panic!("reading {}: {e} (run with WASGD_REGEN_FIXTURES=1?)", path.display())
    });
    assert!(
        generated.len() <= 10 * 1024,
        "{name}: golden fixtures must stay ≤ 10 KB, got {}",
        generated.len()
    );
    assert_eq!(
        committed, generated,
        "{name}: committed fixture drifted from the generator — if the format change is \
         intentional, regenerate with WASGD_REGEN_FIXTURES=1 and commit"
    );
}

#[test]
fn golden_idx_fixtures_match_generators_byte_for_byte() {
    check_fixture("golden-images-idx3-ubyte", golden_idx_images());
    check_fixture("golden-labels-idx1-ubyte", golden_idx_labels());
}

#[test]
fn golden_cifar_fixtures_match_generators_byte_for_byte() {
    check_fixture("golden_cifar10.bin", golden_cifar10());
    check_fixture("golden_cifar100.bin", golden_cifar100());
}

#[test]
fn committed_idx_fixtures_decode_to_known_values() {
    let bytes = std::fs::read(fixture_dir().join("golden-images-idx3-ubyte")).unwrap();
    let img = idx::parse_images(&bytes).unwrap();
    assert_eq!((img.n, img.rows, img.cols), (4, 6, 6));
    // Spot pixels from the generator formula (i·31 + 7) mod 251.
    assert_eq!(img.pixels[0], 7);
    assert_eq!(img.pixels[50], 51);
    assert_eq!(img.pixels[143], 173);

    let label_bytes = std::fs::read(fixture_dir().join("golden-labels-idx1-ubyte")).unwrap();
    assert_eq!(idx::parse_labels(&label_bytes).unwrap(), vec![3, 1, 4, 1]);
}

#[test]
fn committed_cifar_fixtures_decode_to_known_values() {
    let bytes = std::fs::read(fixture_dir().join("golden_cifar10.bin")).unwrap();
    let c10 = cifar::parse(&bytes, cifar::CifarFormat::C10).unwrap();
    assert_eq!(c10.n(), 2);
    assert_eq!(c10.labels, vec![7, 2]);
    assert!(c10.coarse.is_empty());
    // Spot pixels from (j·31 + k·7 + 3) mod 256.
    assert_eq!(c10.pixels_chw[5], 158, "record 0, byte 5");
    assert_eq!(c10.pixels_chw[cifar::PIXELS_PER_RECORD + 100], 38, "record 1, byte 100");

    let bytes = std::fs::read(fixture_dir().join("golden_cifar100.bin")).unwrap();
    let c100 = cifar::parse(&bytes, cifar::CifarFormat::C100).unwrap();
    assert_eq!(c100.n(), 2);
    assert_eq!(c100.coarse, vec![1, 0]);
    assert_eq!(c100.labels, vec![42, 99]);
    // Spot pixels from (j·37 + k·11 + 5) mod 256.
    assert_eq!(c100.pixels_chw[5], 190, "record 0, byte 5");
    assert_eq!(c100.pixels_chw[cifar::PIXELS_PER_RECORD + 100], 132, "record 1, byte 100");
}
