//! Property suites over the communication-efficiency layer
//! (`cluster::fabric::PanelCodec` + the exchange topologies): the
//! error-feedback residual partitions the compensated panel exactly (no
//! gradient mass is ever lost, bit for bit), a fixed residual drains to
//! zero under repeated encoding, and the ring topology with the
//! lossless f32 encoding is bit-identical to the full gather — the
//! invariants `docs/FABRIC.md` files under "Lossy modes and the two
//! test tiers".

use proptest::prelude::*;

use wasgd::cluster::fabric::{PanelCodec, Topology};
use wasgd::cluster::threads::run_wasgd_plus_threaded;
use wasgd::cluster::wire::{topk_indices, topk_k, WireEncoding};
use wasgd::config::{BackendKind, ExperimentConfig};
use wasgd::data::synth::DatasetKind;

fn finite_f32() -> impl Strategy<Value = f32> {
    prop_oneof![
        -1e20f32..1e20f32,
        -1.0f32..1.0f32,
        Just(0.0f32),
        Just(-0.0f32),
        Just(f32::MIN_POSITIVE),
    ]
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(64))]

    /// The error-feedback invariant, for any keep-rate: `committed`
    /// splits the compensated outgoing panel into (decoded, residual)
    /// with *disjoint support and raw bits* — kept coordinates carry
    /// the outgoing value in the decoded panel and exactly +0.0 in the
    /// residual, dropped coordinates the reverse. Nothing is subtracted
    /// in floating point, so decoded + residual reconstructs the
    /// outgoing panel bit for bit and no gradient mass is ever lost.
    #[test]
    fn error_feedback_partitions_the_compensated_panel(
        theta in prop::collection::vec(finite_f32(), 0..200),
        prior in prop::collection::vec(finite_f32(), 0..200),
        k_ppm in 0u32..=1_000_000,
    ) {
        let d = theta.len().min(prior.len());
        let (theta, prior) = (&theta[..d], &prior[..d]);
        let enc = WireEncoding::TopK { k_ppm };
        let mut codec = PanelCodec::new(enc, d);
        // Seed a non-trivial residual state: commit one round first.
        let first = codec.outgoing(prior);
        codec.committed(&first);

        let outgoing = codec.outgoing(theta);
        let decoded = codec.committed(&outgoing);
        let residual = codec.residual();
        prop_assert_eq!(decoded.len(), d);
        prop_assert_eq!(residual.len(), d);

        let kept = topk_indices(&outgoing, k_ppm);
        prop_assert_eq!(kept.len(), topk_k(d, k_ppm));
        let mut is_kept = vec![false; d];
        for &i in &kept {
            is_kept[i as usize] = true;
        }
        for i in 0..d {
            if is_kept[i] {
                prop_assert_eq!(decoded[i].to_bits(), outgoing[i].to_bits());
                prop_assert_eq!(residual[i].to_bits(), 0.0f32.to_bits());
            } else {
                prop_assert_eq!(decoded[i].to_bits(), 0.0f32.to_bits());
                prop_assert_eq!(residual[i].to_bits(), outgoing[i].to_bits());
            }
            // The merge form of the same fact: whichever side holds the
            // coordinate holds the outgoing panel's raw bits.
            let merged = if is_kept[i] { decoded[i] } else { residual[i] };
            prop_assert_eq!(merged.to_bits(), outgoing[i].to_bits());
        }
    }

    /// Feeding the codec the zero panel transmits pure residual each
    /// round: every round drains the top-k remaining coordinates and
    /// adds nothing back, so the residual hits exactly zero within
    /// ⌈d/k⌉ rounds and stays there — dropped coordinates are delayed,
    /// never lost.
    #[test]
    fn residual_drains_to_zero_under_repeated_encoding(
        theta in prop::collection::vec(finite_f32(), 1..120),
        k_ppm in 1u32..=1_000_000,
    ) {
        let d = theta.len();
        let k = topk_k(d, k_ppm);
        let mut codec = PanelCodec::new(WireEncoding::TopK { k_ppm }, d);
        let out = codec.outgoing(&theta);
        codec.committed(&out);

        let zero = vec![0.0f32; d];
        let rounds = d.div_ceil(k);
        for _ in 0..rounds {
            let out = codec.outgoing(&zero);
            codec.committed(&out);
        }
        prop_assert!(
            codec.residual().iter().all(|r| r.abs() == 0.0),
            "residual not drained after {} rounds: {:?}", rounds, codec.residual()
        );
        // And stays drained: one more zero round transmits nothing new.
        let out = codec.outgoing(&zero);
        codec.committed(&out);
        prop_assert!(codec.residual().iter().all(|r| r.abs() == 0.0));
    }
}

/// The ring topology delivers the same cohort content as the full
/// gather, one neighbour hop at a time — with the lossless f32 encoding
/// the threaded fabric's final parameters must be bit-identical at
/// every cohort size, odd and even.
#[test]
fn ring_f32_matches_full_gather_bit_for_bit() {
    for p in [2usize, 3, 5] {
        let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
        cfg.backend = BackendKind::Native;
        cfg.p = p;
        cfg.tau = 16;
        cfg.m = 4;
        cfg.c = 2;
        let full = run_wasgd_plus_threaded(&cfg, 64).unwrap();
        cfg.topology = Topology::Ring;
        let ring = run_wasgd_plus_threaded(&cfg, 64).unwrap();
        assert_eq!(full.final_energies.len(), p);
        for (a, b) in full.final_energies.iter().zip(ring.final_energies.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "p={p}: final energies diverged");
        }
        let fa: Vec<u32> = full.params.iter().map(|v| v.to_bits()).collect();
        let ra: Vec<u32> = ring.params.iter().map(|v| v.to_bits()).collect();
        assert_eq!(fa, ra, "p={p}: ring f32 must match full f32 bit for bit");
        assert!(ring.comm_bytes > 0);
    }
}
