//! Stress properties for `cluster::fabric::PanelExchange` — the
//! in-process collective the worker fabrics barrier on (the threaded
//! substrate directly, and the TCP rendezvous relay on the serve side).
//!
//! Extends the fixed-shape unit tests with a proptest sweep over the
//! cohort size `p ∈ 2..8` and *controlled* per-round deposit orderings: a
//! shared turn counter forces workers to enter `exchange` in a random
//! permutation each round, exploring schedules (including a round-`r`
//! waiter still asleep while a fast worker already deposits for round
//! `r+1`) that free-running threads rarely hit. Invariants: no lost
//! generation (every worker observes every round exactly once), all
//! workers observe identical published vectors in slot order, and a
//! poison injected *after* the last publication never corrupts a
//! completed round (the rendezvous poisons on worker departure, so this
//! is the normal termination schedule).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;

use proptest::prelude::*;
use proptest::test_runner::TestCaseError;

use wasgd::cluster::fabric::PanelExchange;

/// Run `p` workers for `orders.len()` rounds, forcing round `r`'s deposits
/// to happen in the order `orders[r]`; verify every worker saw every
/// round's full, identical vector. The last depositor of the final round
/// immediately poisons the exchange — as the TCP relay does when a
/// worker delivers its final panel — which must not disturb any
/// already-published round.
fn run_case(p: usize, orders: Vec<Vec<usize>>) -> Result<(), TestCaseError> {
    let rounds = orders.len();
    let ex: Arc<PanelExchange<(usize, usize)>> = Arc::new(PanelExchange::new(p));
    let turn = Arc::new(AtomicUsize::new(0));
    let orders = Arc::new(orders);

    let mut handles = Vec::new();
    for i in 0..p {
        let ex = Arc::clone(&ex);
        let turn = Arc::clone(&turn);
        let orders = Arc::clone(&orders);
        handles.push(thread::spawn(move || {
            let mut seen: Vec<Vec<(usize, usize)>> = Vec::with_capacity(rounds);
            for (r, order) in orders.iter().enumerate() {
                let pos = order.iter().position(|&w| w == i).unwrap();
                // Spin until this worker's scheduled deposit slot.
                while turn.load(Ordering::SeqCst) != r * p + pos {
                    thread::yield_now();
                }
                turn.fetch_add(1, Ordering::SeqCst);
                let vals = ex.exchange(i, (i, r)).expect("round poisoned early");
                if r + 1 == rounds && pos + 1 == p {
                    // Final round's last depositor "departs" at once.
                    ex.poison(&format!("worker {i} departed"));
                }
                seen.push(vals.to_vec());
            }
            seen
        }));
    }

    let results: Vec<Vec<Vec<(usize, usize)>>> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();

    for (i, res) in results.iter().enumerate() {
        prop_assert_eq!(res.len(), rounds, "worker {} lost a generation", i);
        for (r, vals) in res.iter().enumerate() {
            // Published vector is in slot order and carries round r's
            // value from *every* worker, whatever the deposit order was.
            let expect: Vec<(usize, usize)> = (0..p).map(|w| (w, r)).collect();
            prop_assert_eq!(vals, &expect, "worker {} round {}", i, r);
        }
    }
    // And identical across workers.
    for res in &results[1..] {
        prop_assert_eq!(res, &results[0]);
    }
    Ok(())
}

proptest! {
    // Each case spawns p threads for several rounds; keep the case count
    // modest so the suite stays in the hundreds of milliseconds.
    #![proptest_config(ProptestConfig::with_cases(16))]

    #[test]
    fn panel_exchange_survives_random_deposit_orderings(
        (p, orders) in (2usize..8).prop_flat_map(|p| {
            let idx: Vec<usize> = (0..p).collect();
            (Just(p), prop::collection::vec(Just(idx).prop_shuffle(), 3..10))
        })
    ) {
        run_case(p, orders)?;
    }
}

#[test]
fn deposits_after_a_departure_poison_error_out() {
    let ex: Arc<PanelExchange<u8>> = Arc::new(PanelExchange::new(2));
    ex.poison("worker 1 departed");
    assert!(ex.exchange(0, 7).is_err());
}
