//! Property suites over the host `linalg` kernels — the numerical core
//! every aggregation path (native, PJRT host-fallback, threaded cluster)
//! leans on. Driven by `proptest` so the shapes, magnitudes and
//! temperatures sweep far wider than the fixed-case unit tests.

use proptest::prelude::*;

use wasgd::linalg;

/// Non-degenerate per-worker loss energies for cohorts of 2..16.
fn energies() -> impl Strategy<Value = Vec<f32>> {
    prop::collection::vec(1e-3f32..10.0, 2..16)
}

/// Energies plus a random permutation of their indices.
fn energies_with_perm() -> impl Strategy<Value = (Vec<f32>, Vec<usize>)> {
    energies().prop_flat_map(|h| {
        let idx: Vec<usize> = (0..h.len()).collect();
        (Just(h), Just(idx).prop_shuffle())
    })
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(256))]

    /// Eq. 13 weights are a point on the probability simplex.
    #[test]
    fn boltzmann_is_simplex_point(h in energies(), a_tilde in 0.0f32..100.0) {
        let th = linalg::boltzmann_weights(&h, a_tilde);
        prop_assert_eq!(th.len(), h.len());
        let sum: f32 = th.iter().sum();
        prop_assert!((sum - 1.0).abs() < 1e-4, "Σθ = {}", sum);
        prop_assert!(th.iter().all(|&t| (0.0..=1.0).contains(&t)), "{:?}", th);
    }

    /// Relabelling workers relabels weights identically: θ(h∘π) = θ(h)∘π.
    #[test]
    fn boltzmann_is_permutation_equivariant(
        (h, perm) in energies_with_perm(),
        a_tilde in 0.0f32..50.0,
    ) {
        let permuted: Vec<f32> = perm.iter().map(|&j| h[j]).collect();
        let th = linalg::boltzmann_weights(&h, a_tilde);
        let th_p = linalg::boltzmann_weights(&permuted, a_tilde);
        for (i, &j) in perm.iter().enumerate() {
            prop_assert!(
                (th_p[i] - th[j]).abs() < 1e-6,
                "π({i})={j}: {} vs {}", th_p[i], th[j]
            );
        }
    }

    /// Lower loss energy never gets a smaller weight (monotone in h).
    #[test]
    fn boltzmann_weights_monotone_decreasing_in_h(
        h in energies(),
        a_tilde in 0.01f32..50.0,
    ) {
        let th = linalg::boltzmann_weights(&h, a_tilde);
        for i in 0..h.len() {
            for j in 0..h.len() {
                if h[i] < h[j] {
                    prop_assert!(
                        th[i] >= th[j] - 1e-6,
                        "h[{i}]={} < h[{j}]={} but θ {} < {}", h[i], h[j], th[i], th[j]
                    );
                }
            }
        }
    }

    /// WASGD's inverse-loss weights against an independent f64 scalar
    /// implementation.
    #[test]
    fn inverse_loss_weights_match_scalar_reference(h in energies()) {
        let got = linalg::inverse_loss_weights(&h);
        let inv: Vec<f64> = h.iter().map(|&v| 1.0 / v as f64).collect();
        let denom: f64 = inv.iter().sum();
        for (i, &g) in got.iter().enumerate() {
            let want = inv[i] / denom;
            prop_assert!((g as f64 - want).abs() < 1e-5, "i={i}: {g} vs {want}");
        }
    }

    /// Σθⱼ·rowⱼ against a per-column f64 scalar reference.
    #[test]
    fn weighted_sum_matches_scalar_reference(
        rows in prop::collection::vec(
            prop::collection::vec(-5.0f32..5.0, 1..48),
            1..8,
        ),
        seed_w in prop::collection::vec(0.01f32..1.0, 8),
    ) {
        let d = rows[0].len();
        let rows: Vec<Vec<f32>> = rows.into_iter().map(|mut r| { r.resize(d, 0.0); r }).collect();
        let w = &seed_w[..rows.len()];
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let mut out = vec![0.0f32; d];
        linalg::weighted_sum(&mut out, &refs, w);
        for k in 0..d {
            let want: f64 = rows
                .iter()
                .zip(w.iter())
                .map(|(r, &wi)| r[k] as f64 * wi as f64)
                .sum();
            prop_assert!((out[k] as f64 - want).abs() < 1e-3, "col {k}: {} vs {want}", out[k]);
        }
    }

    /// Eq. 10's β-mix against the scalar formula, including endpoints.
    #[test]
    fn lerp_into_matches_scalar_reference(
        y0 in prop::collection::vec(-10.0f32..10.0, 1..64),
        x_seed in prop::collection::vec(-10.0f32..10.0, 64),
        t in 0.0f32..=1.0,
    ) {
        let x = &x_seed[..y0.len()];
        let mut y = y0.clone();
        linalg::lerp_into(&mut y, t, x);
        for k in 0..y0.len() {
            let want = (1.0 - t) * y0[k] + t * x[k];
            prop_assert!((y[k] - want).abs() < 1e-5, "col {k}: {} vs {want}", y[k]);
        }
        if t == 0.0 {
            prop_assert_eq!(&y, &y0);
        }
    }
}
