//! Property tests for epoch-anchor checkpoints.
//!
//! The elastic rendezvous snapshots the committed cohort to
//! `<dir>/epoch_NNNN/` at every membership boundary and journals the
//! cohort digest in the matching `EpochCommitted.anchor_digest`. The
//! resume contract, for arbitrary cohorts (NaN and ±∞ parameters
//! included):
//!
//! * anchor save → reload is a bit-exact round trip, so the reloaded
//!   rows' [`digest_cohort`] equals the digest the journal committed —
//!   which is exactly what lets `wasgd replay --verify` chain a resumed
//!   session back onto the anchor it restarted from;
//! * `latest_epoch_anchor` picks the highest-numbered anchor regardless
//!   of save order, terminal anchors included;
//! * a plain root checkpoint (a completed run) wins over any anchor.

use std::sync::atomic::{AtomicUsize, Ordering};

use proptest::prelude::*;

use wasgd::checkpoint::{latest_epoch_anchor, load_resume_dir, Checkpoint};
use wasgd::journal::digest_cohort;

static CASE: AtomicUsize = AtomicUsize::new(0);

/// A fresh scratch directory per proptest case, so shrinking never
/// replays onto a dirty tree.
fn case_dir() -> std::path::PathBuf {
    let n = CASE.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("wasgd_ckpt_props_{}_{}", std::process::id(), n))
}

fn arb_f32_bits() -> impl Strategy<Value = f32> {
    any::<u32>().prop_map(f32::from_bits)
}

/// A cohort: p equal-length rows. The loader derives d from `state.json`
/// and insists every worker file matches it, as every real cohort does.
fn arb_cohort() -> impl Strategy<Value = Vec<Vec<f32>>> {
    (1usize..5, 1usize..33).prop_flat_map(|(p, d)| {
        prop::collection::vec(prop::collection::vec(arb_f32_bits(), d), p)
    })
}

/// An anchor checkpoint shaped the way the rendezvous writes them: the
/// boundary label for a live commit, the terminal label for a finale.
fn anchor(index: u64, terminal: bool, workers: Vec<Vec<f32>>, steps: u64) -> Checkpoint {
    Checkpoint {
        label: if terminal {
            "wasgd+ terminal anchor (partial finale)".to_string()
        } else {
            format!("wasgd+ epoch {index} anchor")
        },
        iteration: steps,
        epoch: steps as f64 / 128.0,
        sim_time_s: steps as f64 * 1e-3,
        workers,
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(64))]

    #[test]
    fn anchor_roundtrip_preserves_the_committed_cohort_digest(
        cohorts in prop::collection::vec(arb_cohort(), 1..4),
        base in 0u64..40,
        stride in 1u64..5,
        terminal_last in any::<bool>(),
        steps in 0u64..10_000,
    ) {
        let dir = case_dir();
        // Anchors land at strictly increasing indices but are saved in
        // reverse, to prove the scan does not lean on write order.
        let indexed: Vec<(u64, &Vec<Vec<f32>>)> = cohorts
            .iter()
            .enumerate()
            .map(|(i, rows)| (base + stride * i as u64, rows))
            .collect();
        for (k, (idx, rows)) in indexed.iter().enumerate().rev() {
            let terminal = terminal_last && k == indexed.len() - 1;
            let ck = anchor(*idx, terminal, (*rows).clone(), steps + idx);
            ck.save(&dir.join(format!("epoch_{idx:04}"))).unwrap();
        }
        let (latest_idx, latest_path) =
            latest_epoch_anchor(&dir).unwrap().expect("anchors were saved");
        let (want_idx, want_rows) = indexed.last().unwrap();
        prop_assert_eq!(latest_idx, *want_idx);

        // The journaled `anchor_digest` is `digest_cohort` over the
        // committed rows; the reloaded anchor must land on the identical
        // value — bit-exact through the `.f32` files, NaN rows included.
        let want_digest = digest_cohort(want_rows.iter().map(|r| r.as_slice()));
        let direct = Checkpoint::load(&latest_path).unwrap();
        prop_assert_eq!(
            digest_cohort(direct.workers.iter().map(|r| r.as_slice())),
            want_digest
        );

        let resumed = load_resume_dir(&dir).unwrap();
        prop_assert_eq!(
            digest_cohort(resumed.workers.iter().map(|r| r.as_slice())),
            want_digest
        );
        prop_assert_eq!(resumed.iteration, steps + *want_idx);
        if terminal_last {
            prop_assert!(
                resumed.label.contains("terminal anchor"),
                "terminal label lost: {:?}",
                resumed.label
            );
        } else {
            prop_assert!(resumed.label.contains("anchor"));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn a_root_checkpoint_beats_every_anchor(
        root_rows in arb_cohort(),
        anchor_rows in arb_cohort(),
        idx in 0u64..99,
    ) {
        let dir = case_dir();
        anchor(idx, false, anchor_rows, 7)
            .save(&dir.join(format!("epoch_{idx:04}")))
            .unwrap();
        let root = Checkpoint {
            label: "wasgd+ tiny_cnn p=2 (completed)".to_string(),
            iteration: 256,
            epoch: 2.0,
            sim_time_s: 1.0,
            workers: root_rows.clone(),
        };
        root.save(&dir).unwrap();
        // A completed run's own state.json outranks any boundary anchor:
        // resuming a finished session must restart from its final rows.
        let resumed = load_resume_dir(&dir).unwrap();
        prop_assert_eq!(resumed.iteration, 256);
        prop_assert_eq!(
            digest_cohort(resumed.workers.iter().map(|r| r.as_slice())),
            digest_cohort(root_rows.iter().map(|r| r.as_slice()))
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
