//! Property suites over the blocked GEMM kernel subsystem: the
//! blocked/threaded paths must agree with the naive `reference` loops to
//! ≤1e-5 across arbitrary shapes — ragged tails included — and must be
//! *bit-deterministic* across thread counts (the row-panel partitioning
//! keeps every element's accumulation order fixed, so `--threads` can
//! never silently change the science).

use proptest::prelude::*;

use wasgd::kernels::{reference, Gemm};
use wasgd::rng::Rng;

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];

fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.0, 1.0);
    v
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// The three matmul entry points vs reference at one shape + seed, over
/// every thread count, with cross-thread bit equality pinned against the
/// first thread count's outputs.
fn check_shape(m: usize, k: usize, n: usize, seed: u64, tol: f32) {
    let mut rng = Rng::new(seed);
    let a = fill(&mut rng, m * k);
    let w = fill(&mut rng, k * n);
    let bias = fill(&mut rng, n);
    let gw_seed = fill(&mut rng, k * n);

    let mut z_want = vec![0.0f32; m * n];
    reference::matmul_bias(&a, &w, &bias, m, k, n, &mut z_want);
    let mut gw_want = gw_seed.clone();
    reference::matmul_tn_acc(&a, &z_want, m, k, n, &mut gw_want);
    let mut da_want = vec![0.0f32; m * k];
    reference::matmul_nt(&z_want, &w, m, n, k, &mut da_want);

    let mut first: Option<(Vec<f32>, Vec<f32>, Vec<f32>)> = None;
    for &t in &THREAD_COUNTS {
        let g = Gemm::new(t);
        let mut z = vec![0.0f32; m * n];
        g.matmul_bias(&a, &w, &bias, m, k, n, &mut z);
        assert!(
            max_abs_diff(&z, &z_want) <= tol,
            "matmul_bias {m}x{k}x{n} t={t}: diff {} > {tol}",
            max_abs_diff(&z, &z_want)
        );
        // Backward products reuse the forward output as dz so the whole
        // layer adjoint is exercised at the same ragged shape.
        let mut gw = gw_seed.clone();
        g.matmul_tn_acc(&a, &z_want, m, k, n, &mut gw);
        assert!(
            max_abs_diff(&gw, &gw_want) <= tol,
            "matmul_tn_acc {m}x{k}x{n} t={t}"
        );
        let mut da = vec![0.0f32; m * k];
        g.matmul_nt(&z_want, &w, m, n, k, &mut da);
        assert!(max_abs_diff(&da, &da_want) <= tol, "matmul_nt {m}x{k}x{n} t={t}");

        if let Some((z1, gw1, da1)) = &first {
            assert!(bits_equal(&z, z1), "matmul_bias bits differ at t={t} ({m}x{k}x{n})");
            assert!(bits_equal(&gw, gw1), "matmul_tn_acc bits differ at t={t} ({m}x{k}x{n})");
            assert!(bits_equal(&da, da1), "matmul_nt bits differ at t={t} ({m}x{k}x{n})");
        } else {
            first = Some((z, gw, da));
        }
    }
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(48))]

    /// Kernel ≡ naive within 1e-5 and bit-identical across threads, on
    /// random small shapes — empty dims and every ragged-tail combination
    /// included. (Shapes under the small-GEMM cut dispatch to the
    /// reference loops by design; the suite below pins the blocked path.)
    #[test]
    fn blocked_matches_reference_on_random_shapes(
        m in 0usize..34,
        k in 0usize..41,
        n in 0usize..38,
        seed in 0u64..1_000_000,
    ) {
        check_shape(m, k, n, seed, 1e-5);
    }

    /// Same properties on shapes that are guaranteed to clear the
    /// small-GEMM cut: the packed-panel blocked machinery itself, with
    /// ragged MR/NR/MC tails, across every thread count.
    #[test]
    fn blocked_path_matches_reference_on_larger_shapes(
        m in 32usize..80,
        k in 32usize..80,
        n in 32usize..80,
        seed in 0u64..1_000_000,
    ) {
        // 32³ = 2^15 = the small-GEMM cut, so every case takes the
        // blocked path.
        check_shape(m, k, n, seed, 1e-5);
    }

    /// The aggregation row-combine: threaded column partitioning agrees
    /// with the reference and is bit-stable across thread counts.
    #[test]
    fn combine_rows_matches_reference(
        p in 1usize..9,
        d in 1usize..600,
        seed in 0u64..1_000_000,
    ) {
        let mut rng = Rng::new(seed);
        let rows: Vec<Vec<f32>> = (0..p).map(|_| fill(&mut rng, d)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let wts = fill(&mut rng, p);
        let mut want = vec![0.0f32; d];
        reference::combine_rows(&mut want, &refs, &wts);
        let mut first: Option<Vec<f32>> = None;
        for &t in &THREAD_COUNTS {
            let mut got = vec![0.0f32; d];
            Gemm::new(t).combine_rows(&mut got, &refs, &wts);
            prop_assert!(max_abs_diff(&got, &want) <= 1e-5, "p={p} d={d} t={t}");
            if let Some(g1) = &first {
                prop_assert!(bits_equal(&got, g1), "combine bits differ t={t}");
            } else {
                first = Some(got);
            }
        }
    }
}

/// Shapes deliberately straddling every block boundary: the KC=256 and
/// NC=256 cache blocks, the MC=64 row block, and the MR=4/NR=16
/// micro-tiles — plus minimum sizes. Proptest's small shapes cover the
/// micro-tile tails; these cover the macro-tile tails.
#[test]
fn tile_boundary_shapes_match_reference() {
    for &(m, k, n) in &[
        (1usize, 1usize, 1usize),
        (4, 256, 256),    // exact KC/NC, single row panel
        (65, 257, 17),    // MC+1, KC+1, ragged NR tail
        (70, 300, 130),   // straddles MC and KC mid-block
        (129, 64, 256),   // two MC blocks + 1 row
        (33, 40, 300),    // straddles NC
        (300, 17, 40),    // many row panels, tiny K
    ] {
        check_shape(m, k, n, 0xC0FFEE ^ (m * 31 + k * 7 + n) as u64, 1e-5);
    }
}

#[test]
fn empty_dims_match_reference() {
    for &(m, k, n) in &[(0usize, 5usize, 3usize), (5, 0, 3), (5, 3, 0), (0, 0, 0)] {
        check_shape(m, k, n, 42, 1e-5);
    }
}

/// Same inputs, thread counts {1,2,4,8}, identical output bits — run on
/// a shape big enough that the parallel path genuinely engages (the
/// small proptest shapes fall below the single-thread work threshold).
#[test]
fn bit_determinism_on_parallel_sized_shapes() {
    check_shape(256, 80, 96, 7, 1e-5);
    check_shape(211, 113, 67, 9, 1e-5);
}
