//! Property suites over the data pipeline (mirroring the
//! `wire_props.rs` idioms): IDX and CIFAR encodings round-trip exactly,
//! every malformed input — truncated, oversized, bad-magic,
//! dimension-lying — is rejected with an error (never a panic or a huge
//! allocation), and the worker shards partition the train split exactly
//! and rank-stably.

use proptest::prelude::*;

use wasgd::data::{cifar, idx, shard_range};

fn pixels(max_images: usize, side: usize) -> impl Strategy<Value = (usize, usize, usize, Vec<u8>)> {
    (0..=max_images, 1..=side, 1..=side).prop_flat_map(|(n, r, c)| {
        prop::collection::vec(any::<u8>(), n * r * c).prop_map(move |px| (n, r, c, px))
    })
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(64))]

    /// IDX image tensors round-trip exactly for arbitrary geometry and
    /// pixel content (including zero images).
    #[test]
    fn idx_images_roundtrip((n, rows, cols, px) in pixels(6, 12)) {
        let bytes = idx::encode_images(n, rows, cols, &px);
        prop_assert_eq!(bytes.len(), 16 + px.len());
        let back = idx::parse_images(&bytes).unwrap();
        prop_assert_eq!(back.n, n);
        prop_assert_eq!(back.rows, rows);
        prop_assert_eq!(back.cols, cols);
        prop_assert_eq!(back.pixels, px);
    }

    /// IDX label vectors round-trip exactly.
    #[test]
    fn idx_labels_roundtrip(labels in prop::collection::vec(any::<u8>(), 0..200)) {
        let bytes = idx::encode_labels(&labels);
        prop_assert_eq!(idx::parse_labels(&bytes).unwrap(), labels);
    }

    /// Every strict prefix of a valid IDX image file is rejected, and so
    /// is every padded extension — byte length must match the declared
    /// dims exactly.
    #[test]
    fn idx_truncations_and_extensions_rejected((n, rows, cols, px) in pixels(3, 6)) {
        let bytes = idx::encode_images(n, rows, cols, &px);
        for cut in 0..bytes.len() {
            prop_assert!(idx::parse_images(&bytes[..cut]).is_err(), "prefix of {} bytes", cut);
        }
        let mut fat = bytes.clone();
        fat.push(0);
        prop_assert!(idx::parse_images(&fat).is_err());
    }

    /// Corrupting any header byte of the magic/dtype/rank prelude to a
    /// different value is rejected.
    #[test]
    fn idx_bad_magic_rejected(
        (n, rows, cols, px) in pixels(3, 6),
        at in 0usize..4,
        val in any::<u8>(),
    ) {
        let mut bytes = idx::encode_images(n, rows, cols, &px);
        prop_assume!(bytes[at] != val);
        bytes[at] = val;
        // A corrupted prelude must never parse as the same tensor. (A
        // rank byte of 1 can legitimately re-parse as a label file —
        // images-vs-labels confusion is covered by the rank check.)
        prop_assert!(idx::parse_images(&bytes).is_err());
    }

    /// Dimension-lying headers (declared product ≠ payload, up to
    /// overflowing u32 products) error out before allocating.
    #[test]
    fn idx_lying_dims_rejected(
        (n, rows, cols, px) in pixels(3, 6),
        lie in prop_oneof![Just(u32::MAX), 0u32..64],
    ) {
        // Overwrite the image-count dim: any value other than the truth
        // makes the declared product disagree with the payload length
        // (or overflow), and must be rejected before allocation.
        prop_assume!(lie as usize != n);
        let mut bytes = idx::encode_images(n, rows, cols, &px);
        bytes[4..8].copy_from_slice(&lie.to_be_bytes());
        prop_assert!(idx::parse_images(&bytes).is_err());
    }

    /// CIFAR files round-trip exactly under both flavours.
    #[test]
    fn cifar_roundtrip(
        n in 0usize..4,
        c100 in any::<bool>(),
        seed in any::<u32>(),
    ) {
        let format = if c100 { cifar::CifarFormat::C100 } else { cifar::CifarFormat::C10 };
        let file = cifar::CifarFile {
            labels: (0..n).map(|k| ((k as u32 + seed) % format.classes() as u32) as u8).collect(),
            coarse: if c100 {
                (0..n).map(|k| ((k as u32 ^ seed) % 20) as u8).collect()
            } else {
                Vec::new()
            },
            pixels_chw: (0..n * cifar::PIXELS_PER_RECORD)
                .map(|i| ((i as u32).wrapping_mul(2654435761).wrapping_add(seed) >> 8) as u8)
                .collect(),
        };
        let bytes = cifar::encode(&file, format);
        prop_assert_eq!(bytes.len(), n * format.record_len());
        if n == 0 {
            // Empty files are rejected (a dataset needs examples).
            prop_assert!(cifar::parse(&bytes, format).is_err());
        } else {
            prop_assert_eq!(cifar::parse(&bytes, format).unwrap(), file);
        }
    }

    /// Any byte length that is not a whole number of records is
    /// rejected, truncated or padded alike.
    #[test]
    fn cifar_ragged_lengths_rejected(n in 1usize..3, cut in 1usize..3072) {
        let format = cifar::CifarFormat::C10;
        let file = cifar::CifarFile {
            labels: vec![0; n],
            coarse: Vec::new(),
            pixels_chw: vec![7; n * cifar::PIXELS_PER_RECORD],
        };
        let bytes = cifar::encode(&file, format);
        prop_assert!(cifar::parse(&bytes[..bytes.len() - cut], format).is_err());
        let mut fat = bytes.clone();
        fat.extend(std::iter::repeat(0u8).take(cut));
        prop_assert!(cifar::parse(&fat, format).is_err());
    }

    /// Out-of-range fine labels are rejected with the record named.
    #[test]
    fn cifar_bad_labels_rejected(n in 1usize..4, bad_at in 0usize..4, excess in 0u8..100) {
        let bad_at = bad_at % n;
        let format = cifar::CifarFormat::C10;
        let mut file = cifar::CifarFile {
            labels: vec![1; n],
            coarse: Vec::new(),
            pixels_chw: vec![0; n * cifar::PIXELS_PER_RECORD],
        };
        file.labels[bad_at] = 10 + excess; // ≥ classes
        let bytes = cifar::encode(&file, format);
        let err = cifar::parse(&bytes, format).unwrap_err();
        prop_assert!(format!("{err}").contains(&format!("record {bad_at}")));
    }

    /// The p worker shards partition `[0, n)` exactly — no gap, no
    /// overlap, rank order — and re-deriving any shard yields the same
    /// bounds (rank-stability under re-runs).
    #[test]
    fn shards_partition_exactly_and_rank_stably(n in 0usize..10_000, p in 1usize..64) {
        let mut cursor = 0usize;
        for rank in 0..p {
            let (lo, hi) = shard_range(n, rank, p);
            prop_assert_eq!(lo, cursor, "rank {} must start where its predecessor ended", rank);
            prop_assert!(hi >= lo);
            let again = shard_range(n, rank, p);
            prop_assert_eq!((lo, hi), again, "rank {} bounds must be stable", rank);
            cursor = hi;
        }
        prop_assert_eq!(cursor, n, "shards must cover the whole split");
        // Balance: every shard is ⌊n/p⌋ except the last, which absorbs
        // the remainder.
        let base = n / p;
        for rank in 0..p.saturating_sub(1) {
            let (lo, hi) = shard_range(n, rank, p);
            prop_assert_eq!(hi - lo, base);
        }
    }
}
