//! Loopback end-to-end equivalence of the worker fabrics.
//!
//! The acceptance bar for the TCP fabric: tiny_cnn WASGD+ at p=4 under
//! `--fabric tcp` — four genuine OS processes exchanging (θ, h) panels
//! over loopback TCP — must reproduce the `--fabric sim` (simulated
//! `Trainer`) final parameters **bit for bit**. The in-process threaded
//! substrate is pinned to the same bar across every fabric-capable
//! scheme, which is what makes the claim structural (one worker loop,
//! one `CommPolicy` code path) rather than coincidental.
//!
//! Everything here is hermetic: native backend, synthetic data (plus a
//! tiny generated IDX dataset for the real-file leg), no artifacts,
//! loopback sockets only.

use std::net::TcpListener;
use std::process::{Command, Stdio};
use std::thread;
use std::time::Duration;

use wasgd::checkpoint::load_resume_dir;
use wasgd::cluster::fabric::{planned_steps, run_decentralized_threaded, Collective, Topology};
use wasgd::cluster::tcp::{serve, ElasticOptions, RemoteCluster, ServeOptions};
use wasgd::cluster::threads::run_wasgd_plus_threaded;
use wasgd::cluster::wire::WireEncoding;
use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig};
use wasgd::coordinator::Trainer;
use wasgd::data::{idx, DataPipeline, Dataset, SourceKind};
use wasgd::journal::replay::{self, ReplayOptions};
use wasgd::journal::{rank_journal_path, read_events, Event, MembershipChange};
use wasgd::runtime::load_backend;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Poll a journal the rendezvous is still appending to until `pred`
/// holds on its event stream. A torn tail record (`Truncation`) is
/// expected while the writer is live, so it is tolerated here — only
/// the parsed prefix feeds the predicate.
fn wait_for_journal(path: &std::path::Path, what: &str, pred: impl Fn(&[Event]) -> bool) {
    for _ in 0..12_000 {
        if let Ok((events, _trunc)) = read_events(path) {
            if pred(&events) {
                return;
            }
        }
        thread::sleep(Duration::from_millis(1));
    }
    panic!("timed out waiting for {what} in {}", path.display());
}

/// Every `PanelDigest` row of a journal, loss bit-compared.
fn digest_rows(path: &std::path::Path) -> Vec<(u64, u32, u64, u32, u64)> {
    let (events, trunc) = read_events(path).unwrap();
    assert!(trunc.is_none(), "journal {} is truncated", path.display());
    events
        .iter()
        .filter_map(|ev| match ev {
            Event::PanelDigest { round, rank, digest, loss, comm_bytes } => {
                Some((*round, *rank, *digest, loss.to_bits(), *comm_bytes))
            }
            _ => None,
        })
        .collect()
}

/// tiny_cnn WASGD+ p=4: the acceptance configuration. 0.25 epochs of
/// the 512-sample tiny split at batch 4 → 32 local steps, τ=8 → 4
/// aggregation boundaries per worker.
fn tiny_cnn_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_preset(wasgd::data::synth::DatasetKind::Tiny);
    cfg.backend = BackendKind::Native;
    cfg.variant = "tiny_cnn".to_string();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 8;
    cfg.m = 2;
    cfg.c = 1;
    cfg.lr = 0.05;
    cfg.seed = 17;
    cfg.threads = 1;
    cfg.epochs = 0.25;
    cfg.eval_every = 16;
    cfg.eval_batches = 2;
    // Fixed compute model: step-time calibration measures real time and
    // is irrelevant to the numerics, but keeping it fixed is cheaper.
    cfg.compute.step_time_s = 1e-3;
    cfg
}

/// Run the simulated trainer (`--fabric sim`) on the pipeline's dataset
/// and return every worker's final parameters.
fn sim_final_workers(cfg: &ExperimentConfig) -> (Vec<Vec<f32>>, Dataset, usize) {
    let engine = load_backend(cfg).unwrap();
    let dataset = DataPipeline::from_config(cfg).unwrap().load(engine.manifest()).unwrap();
    let steps = planned_steps(cfg, dataset.n_train(), engine.manifest().batch);
    let mut trainer = Trainer::new(cfg.clone(), engine.as_ref(), &dataset).unwrap();
    let out = trainer.run().unwrap();
    (out.final_workers, dataset, steps)
}

#[test]
fn threaded_fabric_matches_simulated_trainer_bit_exactly() {
    // The in-process substrate of the decentralized loop vs the
    // centralized simulated trainer: same θ bits for every worker.
    let cfg = tiny_cnn_cfg();
    let (sim, _dataset, steps) = sim_final_workers(&cfg);
    assert_eq!(steps, 32, "budget arithmetic drifted from the test's premise");

    let threaded = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    assert_eq!(
        bits(&threaded.params),
        bits(&sim[0]),
        "threaded fabric diverged from the simulated trainer"
    );
    assert!(threaded.comm_bytes > 0);
}

#[test]
fn every_fabric_capable_scheme_matches_the_trainer() {
    // The equivalence is structural — the fabric loop drives the same
    // CommPolicy code — so it must hold for every scheme the fabric
    // accepts, not just the headline WASGD+.
    for algo in [
        AlgoKind::WasgdPlus,
        AlgoKind::Wasgd,
        AlgoKind::Mmwu,
        AlgoKind::Spsgd,
        AlgoKind::Easgd,
    ] {
        let mut cfg = tiny_cnn_cfg();
        cfg.variant = "tiny_mlp".to_string(); // fast: the claim is per-scheme
        cfg.algo = algo;
        cfg.seed = 29;
        let (sim, _dataset, steps) = sim_final_workers(&cfg);
        let outs = run_decentralized_threaded(&cfg, steps).unwrap();
        assert_eq!(outs.len(), cfg.p);
        for (rank, out) in outs.iter().enumerate() {
            assert_eq!(
                bits(&out.params),
                bits(&sim[rank]),
                "{}: rank {rank} diverged from the trainer",
                algo.name()
            );
        }
    }
}

#[test]
fn acceptance_tcp_four_processes_match_sim_bit_exactly() {
    // THE acceptance criterion: tiny_cnn WASGD+ p=4 as 4 OS processes
    // over loopback TCP (lossless f32 panels) vs `--fabric sim` — final
    // θ bits AND the per-round journal digest streams from every vantage
    // point (sim trainer, tcp rendezvous, each of the 4 worker ranks).
    let cfg = tiny_cnn_cfg();
    let jdir = std::env::temp_dir().join(format!("wasgd_jrn_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&jdir).unwrap();
    let sim_jrn = jdir.join("sim.jrn");
    let serve_jrn = jdir.join("serve.jrn");
    let worker_base = jdir.join("worker.jrn");

    let mut sim_cfg = cfg.clone();
    sim_cfg.journal = Some(sim_jrn.clone());
    let (sim, _dataset, _steps) = sim_final_workers(&sim_cfg);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: None,
        journal: Some(serve_jrn.clone()),
        elastic: None,
    };
    let server = thread::spawn(move || serve(listener, &opts));

    let exe = env!("CARGO_BIN_EXE_wasgd");
    let worker_base_s = worker_base.to_str().unwrap().to_string();
    let children: Vec<_> = (0..cfg.p)
        .map(|_| {
            Command::new(exe)
                .args(["worker", "--connect", &addr, "--journal", &worker_base_s])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a wasgd worker process")
        })
        .collect();

    let outcome = server.join().unwrap().expect("rendezvous session");
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a worker process failed");
    }

    assert_eq!(outcome.finals.len(), 4);
    assert_eq!(outcome.rounds, 4, "32 steps at τ=8 are 4 boundaries");
    assert_eq!(outcome.steps, 32, "finals carry the true step budget");
    for (rank, (h, theta)) in outcome.finals.iter().enumerate() {
        assert!(h.is_finite());
        assert_eq!(
            bits(theta),
            bits(&sim[rank]),
            "tcp rank {rank} diverged from --fabric sim"
        );
    }
    // The relay fans every panel back out p ways.
    assert!(outcome.comm.total_sent() > outcome.comm.total_received());
    assert!(outcome.comm.peers.iter().all(|peer| peer.sent > 0 && peer.received > 0));

    // Satellite: every vantage point journals the SAME digest stream.
    // 4 rounds × p=4 rows, (round, rank, θ digest, loss bits,
    // comm_bytes) identical across sim, rendezvous, and all 4 ranks.
    let serve_rows = digest_rows(&serve_jrn);
    assert_eq!(serve_rows.len(), 16, "4 rounds × p=4 digests");
    assert_eq!(digest_rows(&sim_jrn), serve_rows, "sim journal != tcp rendezvous journal");
    for rank in 0..cfg.p {
        assert_eq!(
            digest_rows(&rank_journal_path(&worker_base, rank)),
            serve_rows,
            "rank {rank} worker journal diverged from the rendezvous stream"
        );
    }
    let _ = std::fs::remove_dir_all(&jdir);
}

#[test]
fn idx_backed_tcp_four_processes_match_sim_bit_exactly() {
    // The data-pipeline acceptance criterion: the same sim ≡ tcp
    // equivalence on a NON-synth source. A tiny generated IDX dataset
    // (64 train / 16 test 8×8 images — real files on disk, parsed and
    // normalised by the idx provider) drives tiny_cnn WASGD+ p=4 as 4
    // OS processes over loopback TCP; final θ must match `--fabric sim`
    // bit for bit. The `--data-dir` + resolved source ride the wire
    // config to every worker process.
    let dir = std::env::temp_dir().join(format!("wasgd_idx_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let train_px: Vec<u8> = (0..64 * 8 * 8).map(|i| ((i * 37 + 11) % 256) as u8).collect();
    let test_px: Vec<u8> = (0..16 * 8 * 8).map(|i| ((i * 53 + 29) % 256) as u8).collect();
    let train_y: Vec<u8> = (0..64).map(|i| (i % 2) as u8).collect();
    let test_y: Vec<u8> = (0..16).map(|i| ((i + 1) % 2) as u8).collect();
    std::fs::write(dir.join(idx::FILE_NAMES[0]), idx::encode_images(64, 8, 8, &train_px)).unwrap();
    std::fs::write(dir.join(idx::FILE_NAMES[1]), idx::encode_labels(&train_y)).unwrap();
    std::fs::write(dir.join(idx::FILE_NAMES[2]), idx::encode_images(16, 8, 8, &test_px)).unwrap();
    std::fs::write(dir.join(idx::FILE_NAMES[3]), idx::encode_labels(&test_y)).unwrap();

    let mut cfg = tiny_cnn_cfg();
    cfg.data_dir = Some(dir.clone());
    cfg.seed = 23;
    cfg.tau = 4;
    cfg.epochs = 0.5; // 64 samples / batch 4 → 16 spe → 8 steps, 2 boundaries

    // `auto` must pick the files up, and the sim trainer must genuinely
    // be running on them.
    let pipeline = DataPipeline::from_config(&cfg).unwrap();
    assert_eq!(pipeline.source_kind(), SourceKind::Idx, "auto resolution missed the files");
    let (sim, dataset, steps) = sim_final_workers(&cfg);
    assert_eq!(dataset.dim, 64, "8×8 IDX images through the tiny_cnn geometry");
    assert_eq!(dataset.n_train(), 64);
    assert_eq!(steps, 8);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: None,
        journal: None,
        elastic: None,
    };
    let server = thread::spawn(move || serve(listener, &opts));

    let exe = env!("CARGO_BIN_EXE_wasgd");
    let children: Vec<_> = (0..cfg.p)
        .map(|_| {
            Command::new(exe)
                .args(["worker", "--connect", &addr])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a wasgd worker process")
        })
        .collect();

    let outcome = server.join().unwrap().expect("rendezvous session");
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a worker process failed");
    }

    assert_eq!(outcome.finals.len(), 4);
    assert_eq!(outcome.rounds, 2, "8 steps at τ=4 are 2 boundaries");
    assert_eq!(outcome.steps, 8);
    for (rank, (h, theta)) in outcome.finals.iter().enumerate() {
        assert!(h.is_finite());
        assert_eq!(
            bits(theta),
            bits(&sim[rank]),
            "idx-backed tcp rank {rank} diverged from --fabric sim"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The convergence-quality tier (docs/FABRIC.md, "Lossy modes and the
/// two test tiers"). Top-k panels cannot meet the bit-exact oracle by
/// design, so they are accepted statistically instead: a seeded
/// mnist_cnn short run under `--encoding topk:0.01` must land within a
/// documented ε of the lossless run's final windowed loss, and the
/// *measured* comm counters — not an estimate — must show the sparse
/// panels cost under 10% of the dense f32 bytes.
///
/// Ignored under the default (bit-exact) tier and run by the CI
/// `comm-quality` job in release mode, so a statistical band can never
/// mask a determinism regression — and a flaky seed never blocks the
/// deterministic jobs.
#[test]
#[ignore = "statistical tier: run by the comm-quality CI job (release mode, fixed seed)"]
fn topk_converges_within_epsilon_of_lossless() {
    // The acceptance band for seed 41 at this 32-step budget. The
    // lossless run only descends modestly in 32 steps (≈0.2–0.5 below
    // the ln(10) start), so an absolute band this wide still catches
    // divergence, a codec that corrupts panels, or error feedback
    // failing to re-inject dropped mass — while tolerating the real
    // (bounded) sparsification lag of a 1% keep-rate, whose aggregate
    // re-sparsifies every worker's panel at each boundary.
    const EPSILON: f32 = 0.75;
    // 10-class uniform-prediction baseline ln(10) ≈ 2.3026 plus batch
    // noise: the lossy run must at minimum never do *worse* than an
    // untrained model.
    const UNIFORM_BASELINE: f32 = 2.6;

    let mut cfg = ExperimentConfig::paper_preset(wasgd::data::synth::DatasetKind::MnistLike);
    cfg.backend = BackendKind::Native;
    cfg.variant = "mnist_cnn".to_string();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 8;
    cfg.m = 2;
    cfg.c = 1;
    cfg.lr = 0.02;
    cfg.seed = 41;
    cfg.threads = 1;
    cfg.compute.step_time_s = 1e-3;
    let steps = 32; // 4 collective rounds at τ=8

    let lossless = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    cfg.encoding = WireEncoding::TopK { k_ppm: 10_000 };
    let lossy = run_wasgd_plus_threaded(&cfg, steps).unwrap();

    let mean = |e: &[f32]| e.iter().sum::<f32>() / e.len() as f32;
    let base = mean(&lossless.final_energies);
    let sparse = mean(&lossy.final_energies);
    assert!(base.is_finite() && sparse.is_finite(), "windowed losses must stay finite");
    assert!(base < 2.45, "the lossless oracle itself failed to train: {base}");
    assert!(
        sparse < UNIFORM_BASELINE,
        "topk:0.01 diverged past the uniform-prediction baseline: {sparse}"
    );
    assert!(
        sparse - base <= EPSILON,
        "topk:0.01 final loss {sparse} strayed more than ε={EPSILON} from lossless {base}"
    );

    // The bytes claim is pinned by the counters the fabric actually
    // measured. mnist_cnn has 20 490 parameters: a dense f32 body is
    // 81 960 B while topk:0.01 ships 205 index/value pairs ≈ 1 648 B,
    // so 10× headroom holds with the frame overhead included.
    assert!(lossless.comm_bytes > 0 && lossy.comm_bytes > 0);
    assert!(
        lossy.comm_bytes * 10 < lossless.comm_bytes,
        "topk bytes {} must be <10% of f32 bytes {}",
        lossy.comm_bytes,
        lossless.comm_bytes
    );
}

#[test]
fn acceptance_lossy_tcp_four_processes_ring_and_topk() {
    // The lossy-mode acceptance criterion, at the same 4-OS-process
    // rigor as the f32 acceptance test above: (1) `--topology ring`
    // with f32 is bit-identical to the full gather — same finals, same
    // journal digest stream — because the ring delivers the identical
    // cohort content one hop at a time; (2) a `--encoding topk:0.01
    // --topology ring` session completes, its journal replay-verifies
    // bit for bit (top-k is deterministically lossy), and its measured
    // relay traffic is under 10% of the dense f32 session's.
    let mut cfg = tiny_cnn_cfg();
    cfg.tau = 2; // 16 rounds: panel traffic dwarfs the fixed handshake bytes
    let jdir = std::env::temp_dir().join(format!("wasgd_lossy_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&jdir).unwrap();

    let exe = env!("CARGO_BIN_EXE_wasgd");
    let run_session = |cfg: &ExperimentConfig, jrn: &std::path::Path| {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: cfg.encoding,
            resume: None,
            journal: Some(jrn.to_path_buf()),
            elastic: None,
        };
        let server = thread::spawn(move || serve(listener, &opts));
        let children: Vec<_> = (0..cfg.p)
            .map(|_| {
                Command::new(exe)
                    .args(["worker", "--connect", &addr])
                    .stdout(Stdio::null())
                    .stderr(Stdio::null())
                    .spawn()
                    .expect("spawning a wasgd worker process")
            })
            .collect();
        let outcome = server.join().unwrap().expect("rendezvous session");
        for mut child in children {
            assert!(child.wait().unwrap().success(), "a worker process failed");
        }
        outcome
    };

    let full_jrn = jdir.join("full_f32.jrn");
    let ring_jrn = jdir.join("ring_f32.jrn");
    let topk_jrn = jdir.join("ring_topk.jrn");

    let full = run_session(&cfg, &full_jrn);
    cfg.topology = Topology::Ring;
    let ring = run_session(&cfg, &ring_jrn);
    cfg.encoding = WireEncoding::TopK { k_ppm: 10_000 }; // --encoding topk:0.01
    let topk = run_session(&cfg, &topk_jrn);

    // (1) ring + f32 ≡ full + f32, bit for bit, at p=4.
    assert_eq!(full.rounds, 16, "32 steps at τ=2 are 16 boundaries");
    assert_eq!(ring.rounds, 16);
    assert_eq!(full.finals.len(), 4);
    assert_eq!(ring.finals.len(), 4);
    for (rank, ((fh, ft), (rh, rt))) in full.finals.iter().zip(ring.finals.iter()).enumerate() {
        assert_eq!(fh.to_bits(), rh.to_bits(), "rank {rank}: ring final energy diverged");
        assert_eq!(bits(ft), bits(rt), "rank {rank}: ring f32 θ must match full f32 bit for bit");
    }
    assert_eq!(
        digest_rows(&ring_jrn),
        digest_rows(&full_jrn),
        "the ring session's journal must carry the full gather's digest stream"
    );
    replay::verify(&ring_jrn, &ReplayOptions::default())
        .expect("the ring+f32 journal replay-verifies");

    // (2) topk:0.01 + ring completes, and its deterministic journal
    // replay-verifies bit for bit — the digests are over the *decoded*
    // panels every rank actually aggregated.
    assert_eq!(topk.finals.len(), 4);
    assert_eq!(topk.rounds, 16);
    assert_eq!(topk.steps, 32);
    for (h, theta) in &topk.finals {
        assert!(h.is_finite());
        assert_eq!(theta.len(), full.finals[0].1.len(), "finals always ride f32, full-width");
    }
    replay::verify(&topk_jrn, &ReplayOptions::default())
        .expect("the topk+ring journal replay-verifies");

    // (3) the measured relay counters — not an estimate — show the
    // sparse session under 10% of the dense one.
    assert!(
        topk.comm.total_sent() * 10 < full.comm.total_sent(),
        "topk relay traffic {} must be <10% of f32 {}",
        topk.comm.total_sent(),
        full.comm.total_sent()
    );
    let _ = std::fs::remove_dir_all(&jdir);
}

#[test]
fn elastic_tcp_survives_a_sigkilled_worker() {
    // Elastic acceptance #1: 4 OS worker processes, one SIGKILLed
    // mid-run — no Leave frame, no TCP FIN courtesy; the rendezvous
    // only learns from the silence. It must cut the epoch, commit with
    // the 3 survivors, re-form at p=3 from the anchor checkpoint, and
    // drain the full step budget — with the loss still decreasing and
    // the stitched journal replay-verifiable across the membership
    // change.
    let mut cfg = tiny_cnn_cfg();
    cfg.tau = 2; // many cheap rounds: the kill lands mid-run, not post-run
    cfg.epochs = 2.0; // 256 local steps → 128 boundaries
    cfg.elastic = true;
    cfg.heartbeat_ms = 100;
    cfg.min_workers = 2;
    let jdir = std::env::temp_dir().join(format!("wasgd_elastic_kill_{}", std::process::id()));
    std::fs::create_dir_all(&jdir).unwrap();
    let serve_jrn = jdir.join("serve.jrn");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: None,
        journal: Some(serve_jrn.clone()),
        elastic: Some(ElasticOptions {
            min_workers: 2,
            max_workers: 4,
            heartbeat_ms: 100,
            anchor_dir: None,
        }),
    };
    let server = thread::spawn(move || serve(listener, &opts));

    let exe = env!("CARGO_BIN_EXE_wasgd");
    let mut children: Vec<_> = (0..cfg.p)
        .map(|_| {
            Command::new(exe)
                .args(["worker", "--connect", &addr])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a wasgd worker process")
        })
        .collect();

    // Let the cohort publish at least one full round, then kill.
    wait_for_journal(&serve_jrn, "the first collective round", |events| {
        events.iter().filter(|ev| matches!(ev, Event::PanelDigest { .. })).count() >= 4
    });
    children[1].kill().expect("SIGKILL the victim worker");
    let mut victim = children.remove(1);

    let outcome = server.join().unwrap().expect("elastic rendezvous session");
    assert_eq!(outcome.finals.len(), 3, "the session must finish at p=3");
    assert_eq!(outcome.steps, 256, "the survivors absorb the full step budget");
    assert!(!victim.wait().unwrap().success(), "the victim was SIGKILLed");
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a surviving worker process failed");
    }

    // The loss keeps decreasing across the membership change: the mean
    // over the first round's 4 digests beats the final round's 3.
    let rows = digest_rows(&serve_jrn);
    let mean = |r: &[(u64, u32, u64, u32, u64)]| {
        r.iter().map(|&(_, _, _, lb, _)| f64::from(f32::from_bits(lb))).sum::<f64>()
            / r.len() as f64
    };
    let first = mean(&rows[..4]);
    let last = mean(&rows[rows.len() - 3..]);
    assert!(
        last < first,
        "loss must keep decreasing across the kill: round 1 mean {first}, final mean {last}"
    );

    // The stitched journal — epoch 0 at p=4, the boundary, epoch 1 at
    // p=3 — replays bit-exactly, anchor chain included.
    let report = replay::verify(&serve_jrn, &ReplayOptions::default())
        .expect("replay across the membership change");
    assert!(report.segments >= 2, "the kill must split the run into epochs");
    assert!(report.commits >= 1, "the epoch boundary must be chained");
    let _ = std::fs::remove_dir_all(&jdir);
}

#[test]
fn elastic_tcp_absorbs_a_late_joiner() {
    // Elastic acceptance #2: a p=2 session is under way when a third
    // worker connects. The rendezvous parks it, cuts the epoch at the
    // next boundary, and re-forms at p=3 with the joiner seated and
    // seeded from the anchor — and the whole stitched journal still
    // replay-verifies.
    let mut cfg = tiny_cnn_cfg();
    cfg.p = 2;
    cfg.tau = 2;
    cfg.epochs = 4.0; // 512 local steps → 256 boundaries: room to join mid-run
    cfg.elastic = true;
    cfg.heartbeat_ms = 100;
    cfg.min_workers = 1;
    let jdir = std::env::temp_dir().join(format!("wasgd_elastic_join_{}", std::process::id()));
    std::fs::create_dir_all(&jdir).unwrap();
    let serve_jrn = jdir.join("serve.jrn");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: None,
        journal: Some(serve_jrn.clone()),
        elastic: Some(ElasticOptions {
            min_workers: 1,
            max_workers: 3,
            heartbeat_ms: 100,
            anchor_dir: None,
        }),
    };
    let server = thread::spawn(move || serve(listener, &opts));

    let exe = env!("CARGO_BIN_EXE_wasgd");
    let spawn_worker = || {
        Command::new(exe)
            .args(["worker", "--connect", &addr])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning a wasgd worker process")
    };
    let mut children: Vec<_> = (0..cfg.p).map(|_| spawn_worker()).collect();

    // Once the p=2 cohort has a round on the books, the latecomer knocks.
    wait_for_journal(&serve_jrn, "the first collective round", |events| {
        events.iter().filter(|ev| matches!(ev, Event::PanelDigest { .. })).count() >= 2
    });
    children.push(spawn_worker());

    let outcome = server.join().unwrap().expect("elastic rendezvous session");
    assert_eq!(outcome.finals.len(), 3, "the joiner must be seated by the finale");
    assert_eq!(outcome.steps, 512, "the budget is conserved across the re-form");
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a worker process failed");
    }

    let (events, trunc) = read_events(&serve_jrn).unwrap();
    assert!(trunc.is_none(), "the finished serve journal must be whole");
    assert!(
        events.iter().any(|ev| matches!(
            ev,
            Event::EpochCommitted { reason, .. } if reason.contains("joiner")
        )),
        "the boundary reason must name the queued joiner"
    );
    let segs = replay::segments(&events).unwrap();
    assert!(segs.len() >= 2, "the join must open a new epoch segment");
    assert_eq!(segs[1].header.p, 3, "the second epoch runs at p=3");

    let report = replay::verify(&serve_jrn, &ReplayOptions::default())
        .expect("replay across the join");
    assert!(report.commits >= 1, "the absorption boundary must be chained");
    let _ = std::fs::remove_dir_all(&jdir);
}

#[test]
fn elastic_tcp_resumes_from_epoch_anchors() {
    // Elastic acceptance #3: the whole rendezvous — process, sockets,
    // journal writer — is SIGKILLed mid-epoch, then revived with
    // `--resume DIR`. The revival loads the latest `epoch_NNNN/` anchor,
    // seeds its first formation from the anchor's rows, stitches a
    // round-0 resume commit onto the torn journal, and drains the rest
    // of the budget — with the loss still decreasing end to end and the
    // stitched journal replay-verifying across the resume boundary.
    let dir = std::env::temp_dir().join(format!("wasgd_elastic_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let serve_jrn = dir.join("serve.jrn");
    let anchors = dir.join("anchors");

    // Phase 1: a p=3 elastic session as a genuine OS process, so the
    // kill takes the acceptor, the relays, and the journal file handle
    // with it. `--listen :0` + the machine-parseable first stdout line
    // avoid any port race.
    let exe = env!("CARGO_BIN_EXE_wasgd");
    let mut serve_child = Command::new(exe)
        .args([
            "serve", "--listen", "127.0.0.1:0", "--backend", "native", "--variant", "tiny_cnn",
            "--algo", "wasgd+", "--p", "3", "--tau", "2", "--m", "2", "--c", "1", "--lr", "0.05",
            "--seed", "17", "--epochs", "2.0", "--eval-every", "16", "--elastic",
            "--heartbeat-ms", "100", "--min-workers", "1", "--max-workers", "3",
        ])
        .arg("--journal")
        .arg(&serve_jrn)
        .arg("--save-checkpoint")
        .arg(&anchors)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning the rendezvous process");
    let addr = {
        use std::io::BufRead;
        let mut line = String::new();
        std::io::BufReader::new(serve_child.stdout.take().unwrap()).read_line(&mut line).unwrap();
        line.trim()
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected rendezvous banner: {line:?}"))
            .to_string()
    };
    let spawn_worker = |addr: &str| {
        Command::new(exe)
            .args(["worker", "--connect", addr])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning a wasgd worker process")
    };
    let mut children: Vec<_> = (0..3).map(|_| spawn_worker(&addr)).collect();

    // A worker dies once the p=3 cohort has rounds on the books, so a
    // live boundary commits and writes an epoch anchor for the two
    // survivors before the rendezvous itself is killed.
    wait_for_journal(&serve_jrn, "the first rounds at p=3", |events| {
        events.iter().filter(|ev| matches!(ev, Event::PanelDigest { .. })).count() >= 6
    });
    children[0].kill().expect("SIGKILL the victim worker");
    wait_for_journal(&serve_jrn, "post-boundary progress at p=2", |events| {
        let anchored = events.iter().any(|ev| matches!(ev, Event::CheckpointWritten { .. }));
        let starts: Vec<usize> = events
            .iter()
            .enumerate()
            .filter(|(_, ev)| matches!(ev, Event::RunStarted { .. }))
            .map(|(i, _)| i)
            .collect();
        anchored
            && starts.len() >= 2
            && events[starts[1]..]
                .iter()
                .filter(|ev| matches!(ev, Event::PanelDigest { .. }))
                .count()
                >= 4
    });
    serve_child.kill().expect("SIGKILL the rendezvous mid-epoch");
    let _ = serve_child.wait();
    for mut child in children.drain(..) {
        let _ = child.kill();
        let _ = child.wait();
    }

    // Phase 2: revive from the anchor root. The latest anchor carries
    // the two survivors' committed rows; the revived base config is
    // sized to match, but the step budget must name the original run's.
    let ck = load_resume_dir(&anchors).expect("the anchor root must resolve to a checkpoint");
    assert!(ck.label.contains("anchor"), "phase 1 must leave an epoch anchor, got {:?}", ck.label);
    let survivors = ck.workers.len();
    assert_eq!(survivors, 2, "the live boundary committed two survivors");
    assert!(ck.iteration > 0, "the anchor records the committed steps");

    let mut cfg = tiny_cnn_cfg();
    cfg.p = survivors;
    cfg.tau = 2;
    cfg.epochs = 2.0; // same 256-step budget as phase 1 (budget is p-independent)
    cfg.elastic = true;
    cfg.heartbeat_ms = 100;
    cfg.min_workers = 1;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr2 = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: Some(ck),
        journal: Some(serve_jrn.clone()),
        elastic: Some(ElasticOptions {
            min_workers: 1,
            max_workers: 3,
            heartbeat_ms: 100,
            anchor_dir: Some(anchors.clone()),
        }),
    };
    let server = thread::spawn(move || serve(listener, &opts));
    let children: Vec<_> = (0..survivors).map(|_| spawn_worker(&addr2)).collect();

    let outcome = server.join().unwrap().expect("the revived session completes");
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a revived worker process failed");
    }
    assert_eq!(outcome.finals.len(), survivors);
    assert_eq!(outcome.steps, 256, "kill + resume must still drain the full step budget");
    assert!(
        outcome.commit_reasons.first().is_some_and(|r| r.contains("resumed from the epoch anchor")),
        "the revived session's first boundary is the resume commit: {:?}",
        outcome.commit_reasons
    );

    // The loss keeps decreasing from the original run's first round
    // (p=3) through the revived run's finale.
    let rows = digest_rows(&serve_jrn);
    let mean = |r: &[(u64, u32, u64, u32, u64)]| {
        r.iter().map(|&(_, _, _, lb, _)| f64::from(f32::from_bits(lb))).sum::<f64>()
            / r.len() as f64
    };
    let first = mean(&rows[..3]);
    let last = mean(&rows[rows.len() - survivors..]);
    assert!(
        last < first,
        "loss must keep decreasing across the kill + resume: round 1 mean {first}, final {last}"
    );

    // The stitched journal — the p=3 epoch, the live boundary, the
    // killed epoch's torn tail terminated by the round-0 resume commit,
    // the revived segments — replays bit-exactly end to end.
    let report = replay::verify(&serve_jrn, &ReplayOptions::default())
        .expect("replay across the resume boundary");
    assert!(report.segments >= 3, "kill + resume must leave >= 3 segments, got {}", report.segments);
    assert!(report.commits >= 2, "live boundary + resume boundary must chain, got {}", report.commits);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn elastic_tcp_reforms_through_a_finale_death() {
    // Elastic acceptance #4: a worker dies AFTER the cohort's last
    // collective round, while `Final` panels are in flight. The session
    // must bank the finals that arrived, re-form the survivors into a
    // zero-step epilogue instead of erroring with a partial finale,
    // complete from the bank, and name the dead rank and its last
    // completed round in the commit reason.
    let mut cfg = tiny_cnn_cfg();
    cfg.p = 3; // 32 steps at tau=8 → exactly 4 rounds, then the finale
    cfg.elastic = true;
    cfg.heartbeat_ms = 100;
    cfg.min_workers = 1;
    let dir = std::env::temp_dir().join(format!("wasgd_finale_death_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let serve_jrn = dir.join("serve.jrn");
    let anchors = dir.join("anchors");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: None,
        journal: Some(serve_jrn.clone()),
        elastic: Some(ElasticOptions {
            min_workers: 1,
            max_workers: 3,
            heartbeat_ms: 100,
            anchor_dir: Some(anchors.clone()),
        }),
    };
    let server = thread::spawn(move || serve(listener, &opts));

    // The mole connects first (arrival order is seating order → rank 0),
    // heartbeats dutifully, joins all four collective rounds — and then
    // hangs up without ever sending its Final. Because a relay can only
    // commit from inside the Panel arm, a worker that heartbeats through
    // its last round and closes its socket is deterministically reported
    // dead "after completing round 4", never silently committed.
    let mole_addr = addr.clone();
    let mole = thread::spawn(move || {
        let (mut fabric, welcome) = RemoteCluster::connect(&mole_addr).unwrap();
        assert_eq!(fabric.rank(), 0, "the mole connected first, so it is seated as rank 0");
        let mcfg = ExperimentConfig::from_wire_json(&welcome.config_json).unwrap();
        assert!(mcfg.elastic, "the wire config must announce the elastic session");
        fabric.start_heartbeats(Duration::from_millis(100));
        let d = {
            let engine = load_backend(&mcfg).unwrap();
            engine.manifest().init_params(mcfg.seed ^ 0x9a9a).len()
        };
        for _ in 0..4 {
            fabric.all_gather(1.0, &vec![0.5f32; d]).unwrap();
        }
        drop(fabric); // the socket dies with the cohort's finals in flight
    });
    // The mole's handshake lands before the real pair connects, pinning
    // its rank-0 seat.
    thread::sleep(Duration::from_millis(300));
    let exe = env!("CARGO_BIN_EXE_wasgd");
    let children: Vec<_> = (0..2)
        .map(|_| {
            Command::new(exe)
                .args(["worker", "--connect", &addr])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a wasgd worker process")
        })
        .collect();

    let outcome = server.join().unwrap().expect("the session completes from banked finals");
    mole.join().unwrap();
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a surviving worker process failed");
    }

    assert_eq!(outcome.finals.len(), 2, "both survivors' finals are delivered");
    assert_eq!(outcome.steps, 32, "the banked finals carry the full budget");
    assert_eq!(outcome.rounds, 4, "every collective round completed before the death");
    let reason = outcome.commit_reasons.last().expect("the finale boundary records a reason");
    assert!(
        reason.contains("rank 0") && reason.contains("round 4"),
        "the commit reason must name the dead rank and its last completed round: {reason:?}"
    );

    // Journal shape: both survivors' Finished memberships, rank 0's
    // crash, and a RunFinished carrying the partial-finale sentinel
    // (final_digest 0 — there is no full-cohort final to digest).
    // No replay::verify here: the mole's junk panels are in the digest
    // stream by design; the resume test above covers verification.
    let (events, trunc) = read_events(&serve_jrn).unwrap();
    assert!(trunc.is_none(), "the finished serve journal must be whole");
    let finished = events
        .iter()
        .filter(|ev| matches!(ev, Event::Membership { change: MembershipChange::Finished, .. }))
        .count();
    assert_eq!(finished, 2, "both survivors' finals were journaled as Finished");
    assert!(
        events.iter().any(|ev| matches!(
            ev,
            Event::Membership { rank: 0, change: MembershipChange::Crashed, .. }
        )),
        "rank 0's finale death must be journaled as Crashed"
    );
    assert!(
        events.iter().any(|ev| matches!(ev, Event::RunFinished { final_digest: 0, .. })),
        "a banked-finals completion journals the final_digest sentinel"
    );

    // Even this session leaves a terminal anchor, so it is resumable.
    let ck = load_resume_dir(&anchors).expect("the terminal anchor must resolve");
    assert!(ck.label.contains("terminal anchor"), "unexpected anchor label {:?}", ck.label);
    assert_eq!(ck.workers.len(), 2, "the terminal anchor holds the survivors' rows");
    assert_eq!(ck.iteration, 32);
    let _ = std::fs::remove_dir_all(&dir);
}
