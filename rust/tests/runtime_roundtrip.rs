//! Runtime round-trip: AOT artifacts → PJRT → numbers.
//!
//! Compiled only with `--features pjrt`. At run time the artifacts are
//! located through the `WASGD_ARTIFACTS` env var (falling back to
//! `<crate>/artifacts`); when none are present, every test skips with a
//! note instead of panicking — the hermetic native-backend suites carry
//! the default `cargo test` signal.
//!
//! These tests pin the python↔rust ABI: manifest consistency, literal
//! packing, tuple unpacking, and — most importantly — that the Pallas
//! aggregation artifact agrees with the host implementation of Eq. 10+13.
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use wasgd::linalg;
use wasgd::rng::Rng;
use wasgd::runtime::{Backend as _, Engine};

fn artifacts_root() -> PathBuf {
    std::env::var_os("WASGD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Load the tiny variant, or `None` (→ the test skips) when no artifacts
/// are on disk.
fn tiny_engine() -> Option<Engine> {
    let root = artifacts_root();
    if !root.join("tiny_mlp").join("manifest.json").exists() {
        eprintln!(
            "no artifacts under {} — set WASGD_ARTIFACTS (and run `python -m compile.aot`); \
             skipping",
            root.display()
        );
        return None;
    }
    Some(Engine::load(&root, "tiny_mlp").expect("artifacts present but failed to load"))
}

#[test]
fn manifest_is_consistent() {
    let Some(e) = tiny_engine() else { return };
    let m = &e.manifest;
    assert_eq!(m.name, "tiny_mlp");
    assert!(m.param_count > 0);
    assert_eq!(m.input_dim, 16);
    assert_eq!(m.num_classes, 2);
    assert!(m.check().is_ok());
    let total: usize = m.param_layout.iter().map(|p| p.numel()).sum();
    assert_eq!(total, m.param_count);
}

#[test]
fn train_step_runs_and_learns() {
    let Some(e) = tiny_engine() else { return };
    let m = &e.manifest;
    let mut params = m.init_params(3);
    let mut rng = Rng::new(1);
    let mut x = vec![0.0f32; m.batch * m.input_dim];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<i32> = (0..m.batch).map(|_| rng.below(m.num_classes) as i32).collect();

    let (_, first) = e.train_step(&params, &x, &y, 0.0).unwrap();
    assert_eq!(first.per_example.len(), m.batch);
    assert!(first.loss.is_finite());
    // mean(per_example) == loss (the coordinator's estimator relies on it).
    let mean: f32 = first.per_example.iter().sum::<f32>() / m.batch as f32;
    assert!((mean - first.loss).abs() < 1e-4);

    let mut last = first.loss;
    for _ in 0..60 {
        let (next, out) = e.train_step(&params, &x, &y, 0.1).unwrap();
        params = next;
        last = out.loss;
    }
    assert!(
        last < first.loss * 0.7,
        "overfitting one batch must reduce loss: {} → {last}",
        first.loss
    );
}

#[test]
fn train_step_lr_zero_is_identity() {
    let Some(e) = tiny_engine() else { return };
    let m = &e.manifest;
    let params = m.init_params(5);
    let x = vec![0.25f32; m.batch * m.input_dim];
    let y = vec![0i32; m.batch];
    let (next, _) = e.train_step(&params, &x, &y, 0.0).unwrap();
    assert_eq!(next.len(), params.len());
    for (a, b) in next.iter().zip(params.iter()) {
        assert!((a - b).abs() < 1e-7);
    }
}

#[test]
fn train_step_rejects_bad_shapes() {
    let Some(e) = tiny_engine() else { return };
    let m = &e.manifest;
    let params = m.init_params(0);
    let x = vec![0.0f32; m.batch * m.input_dim];
    let y = vec![0i32; m.batch];
    assert!(e.train_step(&params[..10], &x, &y, 0.1).is_err());
    assert!(e.train_step(&params, &x[..4], &y, 0.1).is_err());
    assert!(e.train_step(&params, &x, &y[..1], 0.1).is_err());
}

#[test]
fn eval_batch_counts_are_sane() {
    let Some(e) = tiny_engine() else { return };
    let m = &e.manifest;
    let params = m.init_params(0);
    let mut rng = Rng::new(2);
    let mut x = vec![0.0f32; m.batch * m.input_dim];
    rng.fill_normal(&mut x, 0.0, 1.0);
    let y: Vec<i32> = (0..m.batch).map(|_| rng.below(m.num_classes) as i32).collect();
    let out = e.eval_batch(&params, &x, &y).unwrap();
    assert!(out.sum_loss.is_finite() && out.sum_loss > 0.0);
    assert!(out.correct >= 0.0 && out.correct <= m.batch as f32);
}

#[test]
fn aggregate_artifact_matches_host_math() {
    let Some(e) = tiny_engine() else { return };
    let d = e.manifest.param_count;
    let mut rng = Rng::new(7);
    for &p in &[2usize, 4, 8] {
        assert!(e.has_aggregate(p), "aggregate_p{p} artifact missing");
        let mut stacked = vec![0.0f32; p * d];
        rng.fill_normal(&mut stacked, 0.0, 0.5);
        let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.05, 2.0)).collect();
        for &(a_tilde, beta) in &[(0.0f32, 1.0f32), (1.0, 0.9), (10.0, 0.5), (0.5, 0.0)] {
            let got = e.aggregate(&stacked, &h, a_tilde, beta).unwrap();
            // Host twin of Eq. 10+13.
            let theta = linalg::boltzmann_weights(&h, a_tilde);
            let rows: Vec<&[f32]> = stacked.chunks(d).collect();
            let mut agg = vec![0.0f32; d];
            linalg::weighted_sum(&mut agg, &rows, &theta);
            for i in 0..p {
                for k in (0..d).step_by(7) {
                    let want = (1.0 - beta) * stacked[i * d + k] + beta * agg[k];
                    let diff = (got[i * d + k] - want).abs();
                    assert!(
                        diff < 1e-4,
                        "p={p} ã={a_tilde} β={beta} row {i} col {k}: {} vs {want}",
                        got[i * d + k]
                    );
                }
            }
        }
    }
}

#[test]
fn aggregate_beta1_reaches_consensus() {
    let Some(e) = tiny_engine() else { return };
    let d = e.manifest.param_count;
    let p = 4;
    let mut rng = Rng::new(9);
    let mut stacked = vec![0.0f32; p * d];
    rng.fill_normal(&mut stacked, 0.0, 1.0);
    let h = vec![0.3f32, 0.9, 0.5, 1.5];
    let out = e.aggregate(&stacked, &h, 1.0, 1.0).unwrap();
    for i in 1..p {
        for k in 0..d {
            assert!((out[i * d + k] - out[k]).abs() < 1e-5);
        }
    }
}

/// Regression test for the input-buffer leak in the xla crate's
/// `execute` C shim (it `release()`s every input device buffer). The
/// engine must use `execute_b` with rust-owned buffers; RSS over many
/// steps must stay flat.
#[test]
fn memory_stable_over_many_steps() {
    fn rss_pages() -> usize {
        std::fs::read_to_string("/proc/self/statm")
            .ok()
            .and_then(|s| s.split_whitespace().nth(1).map(|v| v.parse().unwrap_or(0)))
            .unwrap_or(0)
    }
    let Some(e) = tiny_engine() else { return };
    let m = &e.manifest;
    let mut params = m.init_params(1);
    let x = vec![0.1f32; m.batch * m.input_dim];
    let y = vec![0i32; m.batch];
    // Warm-up so allocator pools stabilise.
    for _ in 0..500 {
        let (p2, _) = e.train_step(&params, &x, &y, 0.01).unwrap();
        params = p2;
    }
    let before = rss_pages();
    for _ in 0..4000 {
        let (p2, _) = e.train_step(&params, &x, &y, 0.01).unwrap();
        params = p2;
    }
    let after = rss_pages();
    let grown = after.saturating_sub(before);
    // The old leak grew ~0.75 pages/step here (≈3000 pages); allow slack.
    assert!(grown < 600, "RSS grew by {grown} pages over 4000 steps");
}

#[test]
fn calibrate_step_time_positive() {
    let Some(e) = tiny_engine() else { return };
    let t = e.calibrate_step_time(3).unwrap();
    assert!(t > 0.0 && t < 1.0, "step time {t}");
}

#[test]
fn mnist_variant_loads_too() {
    let root = artifacts_root();
    if !root.join("mnist_mlp").join("manifest.json").exists() {
        eprintln!("no mnist_mlp artifacts — skipping");
        return;
    }
    let e = Engine::load(&root, "mnist_mlp").expect("mnist_mlp artifacts");
    assert_eq!(e.manifest.input_dim, 784);
    assert_eq!(e.manifest.num_classes, 10);
    assert!(e.manifest.param_count > 200_000);
}
