//! Lemma 3: with communication probability ζ = 1 and equal weights, the
//! weighted-aggregating scheme is mini-batch gradient descent with the
//! same learning rate (DESIGN.md experiment E12).
//!
//! Two levels of evidence:
//! 1. exact algebra on the quadratic model (deterministic identity), and
//! 2. the full trainer on the hermetic native backend: τ=1, β=1, ã=0
//!    must (a) keep all workers in consensus and (b) track a p·B
//!    mini-batch run statistically. (The same invariants hold through
//!    PJRT — run with `--features pjrt` + `WASGD_ARTIFACTS` and
//!    `BackendKind::Pjrt` to exercise that path.)

use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::synth::DatasetKind;
use wasgd::rng::Rng;

/// Level 1: exact identity on the quadratic. One aggregated step of p
/// equally-weighted workers starting from consensus x equals one
/// mini-batch step that averages the same p stochastic gradients.
#[test]
fn quadratic_identity_exact() {
    let mut rng = Rng::new(42);
    let eta = 0.07f64;
    let c = 1.3f64;
    for _case in 0..200 {
        let p = 2 + rng.below(8);
        let x0 = rng.uniform_in(-5.0, 5.0) as f64;
        // Draw p stochastic gradients g_i = c x − b_i x − h_i.
        let noise: Vec<(f64, f64)> =
            (0..p).map(|_| (rng.normal() * 0.3, rng.normal())).collect();

        // Parallel: each worker steps from x0, then equal-weight average.
        let avg: f64 = noise
            .iter()
            .map(|&(b, h)| x0 - eta * (c * x0 - b * x0 - h))
            .sum::<f64>()
            / p as f64;

        // Mini-batch: average the gradients first, step once.
        let gbar: f64 =
            noise.iter().map(|&(b, h)| c * x0 - b * x0 - h).sum::<f64>() / p as f64;
        let mb = x0 - eta * gbar;

        assert!(
            (avg - mb).abs() < 1e-12,
            "exact identity violated: {avg} vs {mb}"
        );
    }
}

fn consensus_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
    cfg.backend = BackendKind::Native;
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.compute.step_time_s = 1e-3; // fixed: don't calibrate wall time
    cfg.p = 4;
    cfg.tau = 1; // ζ = 1: communicate after every step
    cfg.beta = 1.0;
    cfg.a_tilde = 0.0; // equal weights
    cfg.m = 1;
    cfg.c = 1;
    cfg.epochs = 1.0;
    cfg.eval_every = 16;
    cfg.seed = 11;
    cfg
}

/// Level 2a: the full stack keeps the cohort in consensus when ζ=1, β=1.
/// We can't observe worker params directly from outside, but consensus
/// implies the run is *exactly* as stable as mini-batch: losses must be
/// finite, monotone-ish, and reproducible.
#[test]
fn full_stack_zeta1_trains_stably() {
    let out = run_experiment_full(&consensus_cfg()).unwrap();
    let recs = &out.log.records;
    let first = recs.first().unwrap().train_loss;
    let last = recs.last().unwrap().train_loss;
    assert!(last < first, "ζ=1 equal-weight must learn: {first} → {last}");
    for r in recs {
        assert!(r.train_loss.is_finite());
        assert!(r.train_loss < first * 3.0, "no blow-ups allowed");
    }
}

/// Level 2b: ζ=1 equal-weight p=4 should land in the same loss
/// neighbourhood as sequential SGD at the same iteration count — the
/// variance is reduced (Lemma 2) but the expected trajectory matches
/// mini-batch, which on this easy task converges to the same basin.
#[test]
fn full_stack_zeta1_matches_minibatch_neighbourhood() {
    let agg = run_experiment_full(&consensus_cfg()).unwrap();
    let mut seq_cfg = consensus_cfg();
    seq_cfg.algo = AlgoKind::Sequential;
    let seq = run_experiment_full(&seq_cfg).unwrap();
    let la = agg.log.final_train_loss();
    let ls = seq.log.final_train_loss();
    // Mini-batch (the ζ=1 cohort) should be no worse; allow slack for the
    // tiny workload's noise.
    assert!(
        la <= ls * 1.5 + 0.05,
        "ζ=1 equal-weight ({la:.4}) should track sequential/mini-batch ({ls:.4})"
    );
}

/// The variance-reduction direction of Lemma 2/3: ζ=1 equal-weight run
/// shows a *smoother* loss trajectory than a single sequential worker.
#[test]
fn zeta1_reduces_trajectory_variance() {
    let jitter = |recs: &[wasgd::metrics::Record]| -> f64 {
        let diffs: Vec<f64> = recs
            .windows(2)
            .map(|w| (w[1].train_loss - w[0].train_loss).abs())
            .collect();
        diffs.iter().sum::<f64>() / diffs.len().max(1) as f64
    };
    let mut agg_j = 0.0;
    let mut seq_j = 0.0;
    // Average over a few seeds to stabilise the comparison.
    for seed in [1u64, 2, 3, 4, 5] {
        let mut a = consensus_cfg();
        a.seed = seed;
        a.epochs = 2.0;
        let mut s = a.clone();
        s.algo = AlgoKind::Sequential;
        agg_j += jitter(&run_experiment_full(&a).unwrap().log.records);
        seq_j += jitter(&run_experiment_full(&s).unwrap().log.records);
    }
    assert!(
        agg_j < seq_j * 1.1,
        "aggregated trajectory jitter {agg_j:.4} should not exceed sequential {seq_j:.4}"
    );
}
