//! End-to-end acceptance for the event-sourced run journal and
//! `wasgd replay`.
//!
//! The bar, per the determinism contract the fabrics already pin: a
//! journaled tiny_cnn WASGD+ p=4 run — both as the simulated trainer
//! and as 4 genuine OS worker processes over loopback TCP — must
//! replay **bit for bit** from nothing but the journal file. And any
//! injected single-bit corruption must be rejected with a pointed
//! error naming the offending record, never silently absorbed.

use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::thread;

use wasgd::checkpoint::Checkpoint;
use wasgd::cluster::tcp::{run_remote_worker, serve, ServeOptions};
use wasgd::cluster::wire::WireEncoding;
use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::journal::replay::{self, ReplayOptions};
use wasgd::journal::{parse_record, rank_journal_path, read_events_bytes};

/// tiny_cnn WASGD+ p=4 — the acceptance configuration (32 local steps,
/// τ=8 → 4 collective rounds), identical to `tests/fabric_e2e.rs`.
fn tiny_cnn_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_preset(wasgd::data::synth::DatasetKind::Tiny);
    cfg.backend = BackendKind::Native;
    cfg.variant = "tiny_cnn".to_string();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 8;
    cfg.m = 2;
    cfg.c = 1;
    cfg.lr = 0.05;
    cfg.seed = 17;
    cfg.threads = 1;
    cfg.epochs = 0.25;
    cfg.eval_every = 16;
    cfg.eval_batches = 2;
    cfg.compute.step_time_s = 1e-3;
    cfg
}

/// A cheaper journal source for the framing-level fault-injection
/// sweeps: tiny_mlp WASGD+ p=2, 16 steps (batch 8 → 64 steps/epoch at
/// 0.25 epochs), τ=4 → 4 rounds.
fn tiny_mlp_cfg() -> ExperimentConfig {
    let mut cfg = tiny_cnn_cfg();
    cfg.variant = "tiny_mlp".to_string();
    cfg.p = 2;
    cfg.tau = 4;
    cfg.seed = 29;
    cfg
}

fn temp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("wasgd_replay_{name}_{}", std::process::id()))
}

/// Byte offsets of every record boundary in a clean journal (including
/// the end-of-file offset), for surgical truncation.
fn record_offsets(buf: &[u8]) -> Vec<usize> {
    let mut offs = vec![0usize];
    let mut pos = 0usize;
    while pos < buf.len() {
        match parse_record(&buf[pos..]).expect("clean journal") {
            Some((_, consumed)) => {
                pos += consumed;
                offs.push(pos);
            }
            None => break,
        }
    }
    assert_eq!(pos, buf.len(), "clean journal must parse to the last byte");
    offs
}

#[test]
fn sim_journal_replays_bit_exactly() {
    // Acceptance leg 1: journal a `--fabric sim` tiny_cnn WASGD+ p=4
    // run, then re-execute it from nothing but the journal.
    let jrn = temp_path("sim.jrn");
    let mut cfg = tiny_cnn_cfg();
    cfg.journal = Some(jrn.clone());
    run_experiment_full(&cfg).unwrap();

    let report = replay::verify(&jrn, &ReplayOptions::default()).unwrap();
    assert_eq!(report.segments, 1);
    assert_eq!(report.rounds, 4, "32 steps at τ=8 are 4 rounds");
    assert_eq!(report.digests, 16, "4 rounds × p=4 digests");
    assert_eq!(report.steps, 32);

    let timeline = replay::inspect(&jrn).unwrap();
    assert!(timeline.contains("RunStarted"), "inspect lists the header:\n{timeline}");
    assert!(timeline.contains("PanelDigest"), "inspect lists digests:\n{timeline}");
    assert!(timeline.contains("RunFinished"), "inspect lists the finish:\n{timeline}");
    let _ = std::fs::remove_file(&jrn);
}

#[test]
fn acceptance_tcp_four_process_journal_replays_bit_exactly() {
    // Acceptance leg 2: the SAME configuration as 4 real OS worker
    // processes over loopback TCP. The rendezvous journal (and a worker
    // rank's own journal) must replay bit for bit through the simulated
    // trainer — the fabrics' determinism contract, made durable.
    let cfg = tiny_cnn_cfg();
    let serve_jrn = temp_path("tcp_serve.jrn");
    let worker_base = temp_path("tcp_worker.jrn");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let opts = ServeOptions {
        cfg: cfg.clone(),
        encoding: WireEncoding::F32,
        resume: None,
        journal: Some(serve_jrn.clone()),
        elastic: None,
    };
    let server = thread::spawn(move || serve(listener, &opts));

    let exe = env!("CARGO_BIN_EXE_wasgd");
    let worker_base_s = worker_base.to_str().unwrap().to_string();
    let children: Vec<_> = (0..cfg.p)
        .map(|_| {
            Command::new(exe)
                .args(["worker", "--connect", &addr, "--journal", &worker_base_s])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a wasgd worker process")
        })
        .collect();
    let outcome = server.join().unwrap().expect("rendezvous session");
    for mut child in children {
        assert!(child.wait().unwrap().success(), "a worker process failed");
    }
    assert_eq!(outcome.steps, 32);

    let report = replay::verify(&serve_jrn, &ReplayOptions::default()).unwrap();
    assert_eq!(report.segments, 1);
    assert_eq!(report.digests, 16);
    assert_eq!(report.steps, 32);

    // A worker's own journal is a fresh-session vantage point on the
    // same stream — also self-contained, also verifiable.
    let rank0 = rank_journal_path(&worker_base, 0);
    let wreport = replay::verify(&rank0, &ReplayOptions::default()).unwrap();
    assert_eq!(wreport.digests, 16);

    let _ = std::fs::remove_file(&serve_jrn);
    for r in 0..cfg.p {
        let _ = std::fs::remove_file(rank_journal_path(&worker_base, r));
    }
}

#[test]
fn every_single_bit_corruption_is_rejected_with_a_pointed_error() {
    // Fault injection, exhaustively: flip every bit of every byte of a
    // clean journal. Each flip must either fail the parse with an error
    // naming the offending record, or (a flip in a length field) turn
    // into a reported truncation — never a clean full parse.
    let jrn = temp_path("corrupt.jrn");
    let mut cfg = tiny_mlp_cfg();
    cfg.journal = Some(jrn.clone());
    run_experiment_full(&cfg).unwrap();
    let clean = std::fs::read(&jrn).unwrap();
    let (baseline, trunc) = read_events_bytes(&clean).unwrap();
    assert!(trunc.is_none());
    assert!(baseline.len() >= 8, "journal should hold header + digests + finish");

    for i in 0..clean.len() {
        for bit in 0..8u8 {
            let mut bad = clean.clone();
            bad[i] ^= 1 << bit;
            match read_events_bytes(&bad) {
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(
                        msg.contains("record #"),
                        "flip byte {i} bit {bit}: error must name the record, got: {msg}"
                    );
                }
                Ok((evs, t)) => {
                    // Only a length-field flip can land here: the CRC
                    // now spans a window past EOF, surfacing as a
                    // truncation that names the record and offset.
                    assert!(
                        t.is_some(),
                        "flip byte {i} bit {bit} parsed clean ({} events)",
                        evs.len()
                    );
                }
            }
        }
    }

    // The same contract through the full user-facing verify path.
    for (i, label) in [(1usize, "header"), (clean.len() / 2, "mid"), (clean.len() - 2, "tail")] {
        let mut bad = clean.clone();
        bad[i] ^= 0x10;
        let bad_path = temp_path(&format!("corrupt_{label}.jrn"));
        std::fs::write(&bad_path, &bad).unwrap();
        let err = replay::verify(&bad_path, &ReplayOptions::default())
            .expect_err("corrupted journal must not verify");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("record #") || msg.contains("truncated"),
            "{label}: error must point at the damage, got: {msg}"
        );
        let _ = std::fs::remove_file(&bad_path);
    }
    let _ = std::fs::remove_file(&jrn);
}

#[test]
fn truncated_journals_replay_the_complete_prefix_then_report_the_cut() {
    let jrn = temp_path("trunc.jrn");
    let mut cfg = tiny_mlp_cfg();
    cfg.journal = Some(jrn.clone());
    run_experiment_full(&cfg).unwrap();
    let clean = std::fs::read(&jrn).unwrap();
    let offs = record_offsets(&clean);
    assert!(offs.len() > 4);

    // Cut mid-record inside the final record: every complete round
    // before the cut verifies, then the truncation is reported with its
    // byte offset.
    let mid_cut = temp_path("trunc_mid.jrn");
    std::fs::write(&mid_cut, &clean[..clean.len() - 3]).unwrap();
    let err = replay::verify(&mid_cut, &ReplayOptions::default())
        .expect_err("mid-record truncation must not verify clean");
    let msg = format!("{err:#}");
    assert!(msg.contains("truncated mid-record"), "got: {msg}");
    assert!(msg.contains("complete round(s)"), "got: {msg}");

    // Cut exactly at the last record boundary: the RunFinished seal is
    // gone, so the journal is a strict prefix — all recorded digests
    // still verify first, then the missing seal is the error.
    let seal_cut = temp_path("trunc_seal.jrn");
    std::fs::write(&seal_cut, &clean[..offs[offs.len() - 2]]).unwrap();
    let err = replay::verify(&seal_cut, &ReplayOptions::default())
        .expect_err("a sealless prefix must not verify clean");
    let msg = format!("{err:#}");
    assert!(msg.contains("RunFinished"), "got: {msg}");
    assert!(msg.contains("complete round(s)"), "got: {msg}");

    for p in [&jrn, &mid_cut, &seal_cut] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn resumed_tcp_session_stitches_and_replays_end_to_end() {
    // Checkpoint/resume regression: session 1 journals to PATH, its
    // finals become a checkpoint (pinning ServeOutcome.steps as the
    // resume iteration and the f32 resume vectors from PR 4's wire
    // format), session 2 resumes from it and APPENDS to the same
    // journal. `wasgd replay` then verifies both stitched segments
    // independently, end to end.
    let cfg = tiny_mlp_cfg();
    let jrn = temp_path("stitch.jrn");

    let run_session = |resume: Option<Checkpoint>| {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: WireEncoding::F32,
            resume,
            journal: Some(jrn.clone()),
            elastic: None,
        };
        let server = thread::spawn(move || serve(listener, &opts));
        let workers: Vec<_> = (0..cfg.p)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || run_remote_worker(&addr, None, None, None, None))
            })
            .collect();
        for w in workers {
            w.join().unwrap().expect("in-process worker");
        }
        server.join().unwrap().expect("rendezvous session")
    };

    let first = run_session(None);
    assert_eq!(first.steps, 16);
    let ck = Checkpoint {
        label: "replay-e2e stitch".into(),
        iteration: first.steps,
        epoch: cfg.epochs,
        sim_time_s: 0.0,
        workers: first.finals.iter().map(|(_, theta)| theta.clone()).collect(),
    };
    let second = run_session(Some(ck));
    assert_eq!(second.steps, 16);

    let report = replay::verify(&jrn, &ReplayOptions::default()).unwrap();
    assert_eq!(report.segments, 2, "resume must append a second segment");
    assert_eq!(report.rounds, 8, "4 rounds per session");
    assert_eq!(report.digests, 16, "4 rounds × p=2, twice");
    assert_eq!(report.steps, 32, "16 local steps per session");
    let _ = std::fs::remove_file(&jrn);
}
