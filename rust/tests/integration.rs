//! End-to-end integration: every scheme through the full coordinator on
//! the tiny workload, plus cross-scheme invariants. Runs hermetically on
//! the native backend — no Python, no JAX, no HLO artifacts. The PJRT
//! twin lives at the bottom behind `--features pjrt` + `WASGD_ARTIFACTS`.

use wasgd::cluster::threads::run_wasgd_plus_threaded;
use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig};
use wasgd::coordinator::{run_experiment_full, RunOutput, Trainer};
use wasgd::data::synth::{DatasetKind, SynthConfig};
use wasgd::runtime::{load_backend, Backend as _};

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
    cfg.backend = BackendKind::Native;
    cfg.p = 4;
    cfg.epochs = 3.0;
    cfg.eval_every = 32;
    cfg.eval_batches = 4;
    cfg.seed = 7;
    // Fixed compute model: step-time calibration measures *real* time and
    // would break bitwise determinism of the simulated clocks.
    cfg.compute.step_time_s = 1e-3;
    cfg
}

fn run(algo: AlgoKind) -> RunOutput {
    let mut cfg = base_cfg();
    cfg.algo = algo;
    if algo == AlgoKind::WasgdPlusAsync {
        cfg.backups = 1;
    }
    run_experiment_full(&cfg).unwrap_or_else(|e| panic!("{}: {e:#}", algo.name()))
}

#[test]
fn every_algorithm_trains_and_stays_finite() {
    for algo in AlgoKind::ALL {
        let out = run(algo);
        let recs = &out.log.records;
        assert!(recs.len() >= 3, "{}: too few records", algo.name());
        for r in recs {
            assert!(r.train_loss.is_finite(), "{}: non-finite loss", algo.name());
            assert!(r.sim_time_s >= 0.0);
            assert!((0.0..=1.0).contains(&r.train_error));
            assert!((0.0..=1.0).contains(&r.test_error));
        }
        // Sim time strictly increases across records (after step 0).
        for w in recs.windows(2) {
            assert!(
                w[1].sim_time_s >= w[0].sim_time_s,
                "{}: sim time must be monotone",
                algo.name()
            );
        }
        let first = recs.first().unwrap().train_loss;
        let last = recs.last().unwrap().train_loss;
        assert!(
            last < first,
            "{}: training should reduce loss ({first:.4} → {last:.4})",
            algo.name()
        );
    }
}

#[test]
fn parallel_schemes_charge_communication() {
    for algo in [AlgoKind::Spsgd, AlgoKind::Easgd, AlgoKind::Wasgd, AlgoKind::WasgdPlus] {
        let out = run(algo);
        assert!(out.comm_time_s > 0.0, "{} should pay comm time", algo.name());
    }
    let seq = run(AlgoKind::Sequential);
    assert_eq!(seq.comm_time_s, 0.0, "sequential pays no comm");
}

#[test]
fn wasgd_plus_uses_engine_aggregation_and_order_search() {
    let out = run(AlgoKind::WasgdPlus);
    // Order search ran: some parts were scored and regenerated or kept.
    assert!(out.orders_kept + out.orders_redrawn > 0);
    // Aggregation went through the backend (exec count ≫ steps means
    // boundaries executed extra kernels; just check it's substantial).
    assert!(out.exec_count > 100);
}

#[test]
fn acceptance_wasgd_plus_reduces_loss_on_native_backend() {
    // The PR's acceptance criterion, pinned as a test: DatasetKind::Tiny +
    // AlgoKind::WasgdPlus on the native backend must reduce train loss
    // across 3 epochs with zero artifacts present.
    let out = run(AlgoKind::WasgdPlus);
    let first = out.log.records.first().unwrap().train_loss;
    let last = out.log.records.last().unwrap().train_loss;
    assert!(
        last < first * 0.9,
        "3 native epochs must make real progress: {first:.4} → {last:.4}"
    );
}

#[test]
fn omwu_pays_more_sim_time_than_mmwu() {
    // Same iteration budget; OMWU's full-dataset weight evaluation is
    // charged to the virtual clock (that's the paper's point in §5.5).
    let omwu = run(AlgoKind::Omwu);
    let mmwu = run(AlgoKind::Mmwu);
    let t_omwu = omwu.log.records.last().unwrap().sim_time_s;
    let t_mmwu = mmwu.log.records.last().unwrap().sim_time_s;
    assert!(
        t_omwu > t_mmwu * 1.2,
        "OMWU {t_omwu:.3}s should be noticeably slower than MMWU {t_mmwu:.3}s"
    );
}

#[test]
fn deterministic_across_reruns() {
    let mut cfg = base_cfg();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.epochs = 1.0;
    let a = run_experiment_full(&cfg).unwrap();
    let b = run_experiment_full(&cfg).unwrap();
    assert_eq!(a.log.records.len(), b.log.records.len());
    for (ra, rb) in a.log.records.iter().zip(b.log.records.iter()) {
        assert_eq!(ra.iteration, rb.iteration);
        assert!((ra.train_loss - rb.train_loss).abs() < 1e-9);
        assert!((ra.sim_time_s - rb.sim_time_s).abs() < 1e-12);
    }
}

#[test]
fn seed_changes_the_run() {
    let mut cfg = base_cfg();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.epochs = 1.0;
    let a = run_experiment_full(&cfg).unwrap();
    cfg.seed = 1234;
    let b = run_experiment_full(&cfg).unwrap();
    let la = a.log.records.last().unwrap().train_loss;
    let lb = b.log.records.last().unwrap().train_loss;
    assert!((la - lb).abs() > 1e-9, "different seeds should differ");
}

#[test]
fn estimation_error_probe_in_range() {
    let mut cfg = base_cfg();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.track_estimation_error = true;
    cfg.epochs = 2.0;
    let out = run_experiment_full(&cfg).unwrap();
    assert!(!out.estimation_errors.is_empty(), "probe should record boundaries");
    for &(iter, err) in &out.estimation_errors {
        assert!(iter > 0);
        // Eq. 27: error = Σ|θ−θ_true| ∈ [0, 2].
        assert!((0.0..=2.0).contains(&err), "error {err} out of range");
    }
}

#[test]
fn larger_m_estimates_weights_better() {
    // Fig. 6's mechanism: more recorded batches → lower Eq. 27 error.
    let err_for = |m: usize| {
        let mut cfg = base_cfg();
        cfg.algo = AlgoKind::WasgdPlus;
        cfg.track_estimation_error = true;
        cfg.m = m;
        cfg.c = 1;
        cfg.epochs = 3.0;
        let out = run_experiment_full(&cfg).unwrap();
        let errs = out.estimation_errors;
        errs.iter().map(|&(_, e)| e as f64).sum::<f64>() / errs.len().max(1) as f64
    };
    let e1 = err_for(1);
    let e16 = err_for(16);
    assert!(
        e16 <= e1 + 0.05,
        "m=16 estimation ({e16:.4}) should not be worse than m=1 ({e1:.4})"
    );
}

#[test]
fn forced_delta_order_degrades_large_delta() {
    // Fig. 3's shape: δ=1 (interleaved) should beat δ=64 (label-blocked)
    // on final loss for the tiny workload.
    let loss_for = |delta: usize| {
        let mut cfg = base_cfg();
        cfg.algo = AlgoKind::WasgdPlus;
        cfg.force_delta_order = Some(delta);
        cfg.epochs = 2.0;
        run_experiment_full(&cfg).unwrap().log.final_train_loss()
    };
    let l1 = loss_for(1);
    let l64 = loss_for(64);
    assert!(
        l1 < l64 * 1.5,
        "δ=1 ({l1:.4}) should not be much worse than δ=64 ({l64:.4})"
    );
}

#[test]
fn async_ignores_stragglers_in_sim_time() {
    // With heavy stragglers, async WASGD+ should finish its boundaries in
    // less simulated time than the synchronous variant.
    let mk = |algo: AlgoKind| {
        let mut cfg = base_cfg();
        cfg.algo = algo;
        cfg.backups = 2;
        cfg.epochs = 2.0;
        cfg.compute.step_time_s = 1e-3;
        cfg.compute.jitter_cv = 0.1;
        cfg.compute.straggler_prob = 0.05;
        cfg.compute.straggler_factor = 20.0;
        run_experiment_full(&cfg).unwrap()
    };
    let sync = mk(AlgoKind::WasgdPlus);
    let asyn = mk(AlgoKind::WasgdPlusAsync);
    let t_sync = sync.log.records.last().unwrap().sim_time_s;
    let t_async = asyn.log.records.last().unwrap().sim_time_s;
    assert!(
        t_async < t_sync,
        "async ({t_async:.3}s) should beat sync ({t_sync:.3}s) under stragglers"
    );
}

#[test]
fn acceptance_cifar10_cnn_wasgd_plus_trains_hermetically() {
    // The PR's acceptance criterion: the Cifar10Like paper preset (which
    // selects the `cifar_cnn10` conv variant) must run end to end on the
    // native backend — zero Python/JAX/artifacts — and reduce train loss
    // with WASGD+ at p=4. A small split + τ keeps the test quick while
    // still crossing several aggregation boundaries.
    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Cifar10Like);
    assert_eq!(cfg.variant, "cifar_cnn10");
    cfg.backend = BackendKind::Native;
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 4;
    cfg.m = 2;
    cfg.c = 1;
    cfg.lr = 0.02;
    cfg.epochs = 2.0;
    cfg.eval_every = 8;
    cfg.eval_batches = 2;
    cfg.seed = 11;
    cfg.compute.step_time_s = 1e-3; // skip wall-clock calibration
    let engine = load_backend(&cfg).expect("cifar_cnn10 must load natively");
    assert_eq!(engine.name(), "native");
    // 256 train samples at B=32 → 8 steps/epoch, 16 steps total/worker.
    let dataset = SynthConfig::preset(DatasetKind::Cifar10Like)
        .with_sizes(256, 64)
        .build(cfg.seed);
    let mut tr = Trainer::new(cfg, engine.as_ref(), &dataset).unwrap();
    let out = tr.run().unwrap();
    let recs = &out.log.records;
    assert!(recs.len() >= 3, "expected initial + ≥2 periodic evals");
    for r in recs {
        assert!(r.train_loss.is_finite() && r.test_loss.is_finite());
    }
    let first = recs.first().unwrap().train_loss;
    let last = recs.last().unwrap().train_loss;
    assert!(
        last < first * 0.7,
        "16 CNN steps × 4 workers must make real progress: {first:.4} → {last:.4}"
    );
    assert!(out.comm_time_s > 0.0, "τ boundaries must charge communication");
}

#[test]
fn cifar100_preset_loads_and_steps_natively() {
    // `wasgd run --dataset cifar100` out of the box: preset resolves,
    // backend loads, and one train step on synthetic data is finite.
    let cfg = ExperimentConfig::paper_preset(DatasetKind::Cifar100Like);
    assert_eq!(cfg.variant, "cifar_cnn100");
    let engine = load_backend(&cfg).expect("cifar_cnn100 must load natively");
    let m = engine.manifest();
    let dataset = SynthConfig::preset(DatasetKind::Cifar100Like)
        .with_sizes(m.batch, m.batch)
        .build(3);
    let params = m.init_params(3);
    let idx: Vec<u32> = (0..m.batch as u32).collect();
    let (mut x, mut y) = (Vec::new(), Vec::new());
    dataset.gather_train(&idx, &mut x, &mut y);
    let (next, out) = engine.train_step(&params, &x, &y, cfg.lr).unwrap();
    assert!(out.loss.is_finite());
    assert_eq!(next.len(), params.len());
    assert_ne!(next, params, "gradient step must move the parameters");
}

#[test]
fn threaded_wasgd_plus_is_bit_deterministic_across_runs_and_threads() {
    // End-to-end determinism of the *real-thread* launcher on the conv
    // variant: `run_wasgd_plus_threaded` on tiny_cnn at p=4 must produce
    // bit-identical final parameters (a) across two repeats and (b)
    // across `--threads 1` vs `--threads 4` — intra-op parallelism can
    // never silently change the science. (tiny_cnn's GEMMs sit below the
    // kernel's parallel-work threshold, so this leg pins the *dispatch*
    // stability; the mnist_cnn test below drives the genuinely threaded
    // path end to end.)
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();

    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
    cfg.backend = BackendKind::Native;
    cfg.variant = "tiny_cnn".to_string();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 8;
    cfg.m = 2;
    cfg.c = 1;
    cfg.lr = 0.05;
    cfg.seed = 17;
    cfg.threads = 1;
    let steps = 32; // 4 aggregation boundaries per worker

    let a = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    let b = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    assert!(!a.params.is_empty());
    assert!(a.final_energies.iter().all(|e| e.is_finite()));
    assert_eq!(bits(&a.params), bits(&b.params), "repeat runs must be bit-identical");

    cfg.threads = 4;
    let c = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    assert_eq!(
        bits(&a.params),
        bits(&c.params),
        "--threads 1 vs --threads 4 must produce identical parameter bits"
    );
    assert_eq!(a.final_energies, c.final_energies, "loss energies must match too");
}

#[test]
fn threaded_wasgd_plus_mnist_cnn_engages_parallel_gemms_bit_identically() {
    // The same guarantee where the threaded path genuinely runs: the
    // mnist_cnn conv GEMMs (25088×9×16 and 6272×144×32 per step, forward
    // and backward) sit far above the kernel's parallel-work threshold,
    // so at threads=4 every one of those products really is computed by
    // scoped row-panel threads — and the final parameters must still be
    // bit-identical to the single-threaded run.
    let mut cfg = ExperimentConfig::paper_preset(DatasetKind::MnistLike);
    cfg.backend = BackendKind::Native;
    cfg.variant = "mnist_cnn".to_string();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.p = 4;
    cfg.tau = 4;
    cfg.m = 2;
    cfg.c = 1;
    cfg.seed = 23;
    cfg.threads = 1;
    let steps = 8; // 2 aggregation boundaries per worker, conv-heavy

    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    let single = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    cfg.threads = 4;
    let threaded = run_wasgd_plus_threaded(&cfg, steps).unwrap();
    assert!(!single.params.is_empty());
    assert_eq!(
        bits(&single.params),
        bits(&threaded.params),
        "threaded mnist_cnn GEMMs changed the parameter bits"
    );
    assert_eq!(single.final_energies, threaded.final_energies);
}

#[test]
fn target_loss_stops_early() {
    let mut cfg = base_cfg();
    cfg.algo = AlgoKind::WasgdPlus;
    cfg.epochs = 50.0; // would be long…
    cfg.target_loss = Some(0.55);
    let out = run_experiment_full(&cfg).unwrap();
    let last = out.log.records.last().unwrap();
    assert!(last.train_loss <= 0.56, "should stop at/near the target");
    assert!(last.epoch < 50.0, "must stop before the full budget");
}

/// PJRT twin of the core invariants. Compiled only with `--features
/// pjrt`; at run time it additionally wants artifacts on disk, located
/// through the `WASGD_ARTIFACTS` env var — unset, the tests skip with a
/// note instead of panicking with "run `make artifacts` first".
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use std::path::PathBuf;

    fn pjrt_cfg() -> Option<ExperimentConfig> {
        let root = match std::env::var_os("WASGD_ARTIFACTS") {
            Some(v) => PathBuf::from(v),
            None => {
                eprintln!("WASGD_ARTIFACTS unset — skipping PJRT integration tests");
                return None;
            }
        };
        let mut cfg = base_cfg();
        cfg.backend = BackendKind::Pjrt;
        cfg.artifacts_root = root;
        Some(cfg)
    }

    #[test]
    fn pjrt_wasgd_plus_trains_and_stays_finite() {
        let Some(mut cfg) = pjrt_cfg() else { return };
        cfg.algo = AlgoKind::WasgdPlus;
        let out = run_experiment_full(&cfg).unwrap();
        let recs = &out.log.records;
        assert!(recs.last().unwrap().train_loss < recs.first().unwrap().train_loss);
        assert!(recs.iter().all(|r| r.train_loss.is_finite()));
    }

    #[test]
    fn pjrt_and_native_agree_on_aggregation() {
        use wasgd::linalg;
        use wasgd::runtime::{backend_for_variant, Backend as _};
        let Some(cfg) = pjrt_cfg() else { return };
        let pjrt = backend_for_variant(&cfg.artifacts_root, &cfg.variant, BackendKind::Pjrt, 1)
            .expect("artifacts under WASGD_ARTIFACTS");
        let native =
            backend_for_variant(&cfg.artifacts_root, &cfg.variant, BackendKind::Native, 1)
                .unwrap();
        let d = pjrt.manifest().param_count;
        assert_eq!(d, native.manifest().param_count, "manifests must agree");
        let p = 4;
        let mut rng = wasgd::rng::Rng::new(3);
        let mut stacked = vec![0.0f32; p * d];
        rng.fill_normal(&mut stacked, 0.0, 0.5);
        let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.05, 2.0)).collect();
        if !pjrt.has_aggregate(p) {
            eprintln!("no aggregate_p{p} artifact — skipping");
            return;
        }
        let a = pjrt.aggregate(&stacked, &h, 1.0, 0.9).unwrap();
        let b = native.aggregate(&stacked, &h, 1.0, 0.9).unwrap();
        let max_diff = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "backends disagree by {max_diff}");
        let _ = linalg::norm2(&a);
    }
}
