//! Property-based tests over the coordinator's invariants.
//!
//! The offline build has no `proptest`, so cases are generated with the
//! in-repo deterministic PRNG — same idea: hundreds of random instances
//! per property, with the failing seed printed on assert.

use wasgd::algorithms::host_aggregate;
use wasgd::cluster::{ComputeModel, FabricConfig, SimCluster};
use wasgd::config::AlgoKind;
use wasgd::coordinator::true_weights;
use wasgd::data::order::{delta_blocked_order, judge, OrderState, RecordWindow};
use wasgd::linalg;
use wasgd::rng::Rng;
use wasgd::util::Json;

const CASES: usize = 300;

fn rand_energies(rng: &mut Rng, p: usize) -> Vec<f32> {
    (0..p).map(|_| rng.uniform_in(1e-3, 10.0)).collect()
}

#[test]
fn prop_boltzmann_weights_form_a_simplex() {
    let mut rng = Rng::new(0xB017);
    for case in 0..CASES {
        let p = 2 + rng.below(15);
        let h = rand_energies(&mut rng, p);
        let a_tilde = rng.uniform_in(0.0, 100.0);
        let th = linalg::boltzmann_weights(&h, a_tilde);
        let sum: f32 = th.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "case {case}: Σθ = {sum}");
        assert!(th.iter().all(|&t| (0.0..=1.0).contains(&t)), "case {case}: {th:?}");
    }
}

#[test]
fn prop_boltzmann_monotone_lower_loss_higher_weight() {
    let mut rng = Rng::new(0xB018);
    for case in 0..CASES {
        let p = 2 + rng.below(10);
        let h = rand_energies(&mut rng, p);
        let a_tilde = rng.uniform_in(0.01, 50.0);
        let th = linalg::boltzmann_weights(&h, a_tilde);
        for i in 0..p {
            for j in 0..p {
                if h[i] < h[j] {
                    assert!(
                        th[i] >= th[j] - 1e-6,
                        "case {case}: h[{i}]={} < h[{j}]={} but θ {} < {}",
                        h[i],
                        h[j],
                        th[i],
                        th[j]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_inverse_weights_match_boltzmann_ordering() {
    // Both weight families must agree on the ranking of workers.
    let mut rng = Rng::new(0xB019);
    for _ in 0..CASES {
        let p = 2 + rng.below(8);
        let h = rand_energies(&mut rng, p);
        let inv = linalg::inverse_loss_weights(&h);
        let bol = linalg::boltzmann_weights(&h, 5.0);
        let rank = |w: &[f32]| {
            let mut idx: Vec<usize> = (0..w.len()).collect();
            idx.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());
            idx
        };
        assert_eq!(rank(&inv)[0], rank(&bol)[0], "best worker must agree");
    }
}

#[test]
fn prop_host_aggregate_is_convex_combination() {
    // Every output coordinate lies in the convex hull of the inputs.
    let mut rng = Rng::new(0xA66);
    for case in 0..CASES {
        let p = 2 + rng.below(6);
        let d = 1 + rng.below(64);
        let mut params: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..d).map(|_| rng.uniform_in(-5.0, 5.0)).collect())
            .collect();
        let h = rand_energies(&mut rng, p);
        let theta = linalg::boltzmann_weights(&h, rng.uniform_in(0.0, 10.0));
        let beta = rng.uniform_in(0.0, 1.0);
        let orig = params.clone();
        host_aggregate(&mut params, &theta, beta);
        for k in 0..d {
            let lo = orig.iter().map(|r| r[k]).fold(f32::INFINITY, f32::min);
            let hi = orig.iter().map(|r| r[k]).fold(f32::NEG_INFINITY, f32::max);
            for (i, row) in params.iter().enumerate() {
                assert!(
                    row[k] >= lo - 1e-4 && row[k] <= hi + 1e-4,
                    "case {case}: row {i} col {k}: {} outside [{lo}, {hi}]",
                    row[k]
                );
            }
        }
    }
}

#[test]
fn prop_host_aggregate_contracts_spread() {
    // β > 0 must not increase the cohort diameter (the contraction that
    // drives Theorem 1).
    let mut rng = Rng::new(0xA67);
    for case in 0..CASES {
        let p = 2 + rng.below(6);
        let d = 1 + rng.below(32);
        let mut params: Vec<Vec<f32>> = (0..p)
            .map(|_| (0..d).map(|_| rng.uniform_in(-3.0, 3.0)).collect())
            .collect();
        let theta = linalg::boltzmann_weights(&rand_energies(&mut rng, p), 1.0);
        let beta = rng.uniform_in(0.0, 1.0);
        let diam = |ps: &[Vec<f32>]| -> f64 {
            let mut m = 0.0f64;
            for i in 0..ps.len() {
                for j in i + 1..ps.len() {
                    m = m.max(linalg::dist2(&ps[i], &ps[j]));
                }
            }
            m
        };
        let before = diam(&params);
        host_aggregate(&mut params, &theta, beta);
        let after = diam(&params);
        assert!(
            after <= before + 1e-5,
            "case {case}: diameter grew {before} → {after} (β={beta})"
        );
        // And with β=1 the diameter is exactly 0.
        host_aggregate(&mut params, &theta, 1.0);
        assert!(diam(&params) < 1e-5, "case {case}: β=1 must reach consensus");
    }
}

#[test]
fn prop_record_window_counts_exactly_m() {
    // Σ_{k<τ} is_recorded(k) == m (the clamped value) for every (τ, m, c)
    // — the estimation windows must sample exactly the paper's m batches.
    let mut rng = Rng::new(0x3EC);
    for case in 0..CASES {
        let tau = 1 + rng.below(2000);
        let m = 1 + rng.below(300);
        let c = 1 + rng.below(16);
        let w = RecordWindow::new(tau, m, c);
        let count = w.count_per_period();
        assert_eq!(
            count, w.m,
            "case {case}: τ={tau} m={m} c={c} (clamped τ={} m={} c={}) recorded {count}",
            w.tau, w.m, w.c
        );
        assert_eq!(count, w.recorded_count(), "case {case}");
        // Periodicity: iteration k and k+τ agree.
        for _ in 0..8 {
            let k = rng.below(w.tau);
            assert_eq!(w.is_recorded(k), w.is_recorded(k + w.tau), "case {case} k={k}");
        }
    }
}

#[test]
fn prop_order_state_orders_are_permutations_of_parts() {
    let mut rng = Rng::new(0x02d3);
    for case in 0..120 {
        let n = 10 + rng.below(5000);
        let parts = 1 + rng.below(8);
        let mut st = OrderState::new(n, parts, rng.next_u64());
        let mut all: Vec<u32> = Vec::new();
        for part in 0..st.n_parts {
            // Randomly mark good/bad before regenerating.
            st.record_score(part, rng.uniform_in(-3.0, 3.0));
            all.extend(st.order_for_part(part));
        }
        all.sort_unstable();
        let want: Vec<u32> = (0..n as u32).collect();
        assert_eq!(all, want, "case {case}: n={n} parts={parts}");
    }
}

#[test]
fn prop_order_seed_survival_follows_judgment() {
    let mut rng = Rng::new(0x02d4);
    for _ in 0..CASES {
        let n = 50 + rng.below(500);
        let mut st = OrderState::new(n, 2, rng.next_u64());
        let _ = st.order_for_part(0);
        let seed = st.seed_of(0);
        let score = rng.uniform_in(-2.5, 2.5);
        st.record_score(0, score);
        let _ = st.order_for_part(0);
        if score <= -1.0 {
            assert_eq!(st.seed_of(0), seed, "good score must keep the seed");
        } else {
            assert_ne!(st.seed_of(0), seed, "bad score must redraw the seed");
        }
    }
}

#[test]
fn prop_judge_scores_are_zero_mean() {
    let mut rng = Rng::new(0x10d6);
    for case in 0..CASES {
        let p = 2 + rng.below(14);
        let h = rand_energies(&mut rng, p);
        let scores: Vec<f32> = (0..p).map(|i| judge(&h, i)).collect();
        let mean: f64 = scores.iter().map(|&s| s as f64).sum::<f64>() / p as f64;
        assert!(mean.abs() < 1e-3, "case {case}: mean z-score {mean}");
        // Best worker has the most negative score.
        let best = (0..p).min_by(|&a, &b| h[a].partial_cmp(&h[b]).unwrap()).unwrap();
        let min_score = scores.iter().cloned().fold(f32::INFINITY, f32::min);
        assert!((scores[best] - min_score).abs() < 1e-6, "case {case}");
    }
}

#[test]
fn prop_delta_blocked_orders_are_permutations() {
    let mut rng = Rng::new(0xDE17A);
    for case in 0..120 {
        let n = 20 + rng.below(2000);
        let classes = 2 + rng.below(20);
        let labels: Vec<i32> = (0..n).map(|_| rng.below(classes) as i32).collect();
        let delta = 1 + rng.below(200);
        let mut order = delta_blocked_order(&labels, delta, &mut rng);
        order.sort_unstable();
        assert_eq!(order, (0..n as u32).collect::<Vec<_>>(), "case {case}");
    }
}

#[test]
fn prop_sync_allgather_equalises_clocks_monotonically() {
    let mut rng = Rng::new(0x57A6);
    for case in 0..CASES {
        let p = 1 + rng.below(16);
        let mut c = SimCluster::new(
            p,
            FabricConfig::default(),
            ComputeModel { step_time_s: 1e-3, jitter_cv: 0.3, straggler_prob: 0.1, straggler_factor: 5.0 },
            rng.next_u64(),
        );
        for i in 0..p {
            c.advance_compute(i, rng.below(50));
        }
        let max_before = c.now();
        let after = c.sync_allgather(1 + rng.below(1 << 20));
        assert!(after >= max_before, "case {case}");
        for &t in &c.clocks {
            assert!((t - after).abs() < 1e-12, "case {case}: clocks not equal");
        }
    }
}

#[test]
fn prop_async_gather_never_exceeds_barrier_time() {
    let mut rng = Rng::new(0x57A7);
    for case in 0..CASES {
        let p = 3 + rng.below(12);
        let mut c = SimCluster::new(
            p,
            FabricConfig::default(),
            ComputeModel { step_time_s: 1e-3, jitter_cv: 0.5, straggler_prob: 0.2, straggler_factor: 10.0 },
            rng.next_u64(),
        );
        for i in 0..p {
            c.advance_compute(i, 1 + rng.below(100));
        }
        let barrier = c.now();
        let bytes = 1 + rng.below(1 << 16);
        let need = 1 + rng.below(p - 1);
        let mut c2 = c.clone();
        let resume = c2.async_gather(0, need, bytes);
        let full = c.sync_allgather(bytes);
        assert!(
            resume <= full + 1e-12,
            "case {case}: async quorum resume {resume} after full barrier {full} (barrier {barrier})"
        );
    }
}

#[test]
fn prop_true_weights_always_simplex() {
    let mut rng = Rng::new(0x7347);
    for _ in 0..CASES {
        let p = 2 + rng.below(10);
        let h = rand_energies(&mut rng, p);
        for algo in [AlgoKind::Wasgd, AlgoKind::WasgdPlus, AlgoKind::Mmwu] {
            let th = true_weights(algo, &h, rng.uniform_in(0.0, 20.0));
            let s: f32 = th.iter().sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }
}

#[test]
fn prop_json_roundtrips_random_manifest_shapes() {
    let mut rng = Rng::new(0x150);
    for case in 0..CASES {
        let n = rng.below(6);
        let arr: Vec<String> = (0..n).map(|i| format!("{}", i * 7)).collect();
        let text = format!(
            r#"{{"name":"v{case}","xs":[{}],"nested":{{"k":{} }},"f":{}}}"#,
            arr.join(","),
            rng.below(1000),
            rng.uniform()
        );
        let j = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(j.req_str("name").unwrap(), format!("v{case}"));
        assert_eq!(j.req_arr("xs").unwrap().len(), n);
        assert!(j.get("nested").unwrap().get("k").unwrap().as_usize().is_some());
        assert!(j.get("f").unwrap().as_f64().is_some());
    }
}

#[test]
fn prop_rng_permutation_bijective() {
    let mut rng = Rng::new(0x9e4);
    for _ in 0..60 {
        let n = 1 + rng.below(10_000);
        let mut p = rng.permutation(n);
        p.sort_unstable();
        assert_eq!(p, (0..n as u32).collect::<Vec<_>>());
    }
}
