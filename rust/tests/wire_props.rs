//! Property suites over the TCP fabric's wire protocol
//! (`cluster::wire`): framing round trips exactly (f32 panels are
//! bit-lossless, qi8 panels are bounded-error and smaller, top-k panels
//! decode to exactly `topk_apply` of the original), ragged cohort rows
//! survive, and every malformed input — truncated frames, corrupted
//! headers, lying inner lengths, lying sparse indices/counts — is
//! rejected with an error, never a panic or a bogus parse.

use std::io::Cursor;

use proptest::prelude::*;

use wasgd::cluster::wire::{
    topk_apply, topk_indices, topk_k, Cohort, EpochCommit, Frame, Heartbeat, JoinRequest, Leave,
    MsgKind, Panel, Welcome, WireEncoding,
};

fn frame_bytes(frame: &Frame) -> Vec<u8> {
    let mut bytes = Vec::new();
    frame.write_to(&mut bytes).unwrap();
    bytes
}

fn reread(frame: &Frame) -> Frame {
    let bytes = frame_bytes(frame);
    assert_eq!(bytes.len(), frame.encoded_len());
    Frame::read_from(&mut Cursor::new(&bytes)).unwrap()
}

fn finite_f32() -> impl Strategy<Value = f32> {
    prop_oneof![
        -1e30f32..1e30f32,
        -1.0f32..1.0f32,
        Just(0.0f32),
        Just(-0.0f32),
        Just(f32::MIN_POSITIVE),
    ]
}

fn theta_vec(max_len: usize) -> impl Strategy<Value = Vec<f32>> {
    prop::collection::vec(finite_f32(), 0..max_len)
}

proptest! {
    #![proptest_config(ProptestConfig::with_cases(64))]

    /// f32 panels round-trip bit-exactly for arbitrary rounds, h values
    /// and (ragged) vector lengths.
    #[test]
    fn panel_f32_roundtrip_bit_exact(
        round in any::<u64>(),
        h in finite_f32(),
        theta in theta_vec(300),
    ) {
        let frame = Panel::frame(MsgKind::Panel, round, h, &theta, WireEncoding::F32);
        prop_assert_eq!(frame.encoded_len(), Panel::wire_len(WireEncoding::F32, theta.len()));
        let back = Panel::parse(&reread(&frame)).unwrap();
        prop_assert_eq!(back.round, round);
        prop_assert_eq!(back.h.to_bits(), h.to_bits());
        prop_assert_eq!(back.theta.len(), theta.len());
        for (a, b) in back.theta.iter().zip(theta.iter()) {
            prop_assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// qi8 panels round-trip within the quantisation bound (scale/2 per
    /// element plus fp slack), stay the documented size, and never touch
    /// the raw h field.
    #[test]
    fn panel_qi8_roundtrip_bounded(
        round in any::<u64>(),
        h in finite_f32(),
        theta in theta_vec(300),
    ) {
        let frame = Panel::frame(MsgKind::Panel, round, h, &theta, WireEncoding::Qi8);
        prop_assert_eq!(frame.encoded_len(), Panel::wire_len(WireEncoding::Qi8, theta.len()));
        let back = Panel::parse(&reread(&frame)).unwrap();
        prop_assert_eq!(back.h.to_bits(), h.to_bits());
        prop_assert_eq!(back.theta.len(), theta.len());
        let max_abs = theta.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 0.0 };
        for (a, b) in back.theta.iter().zip(theta.iter()) {
            prop_assert!(
                (a - b).abs() <= scale * 0.5 + max_abs * 1e-5,
                "decoded {} vs {} (scale {})", a, b, scale
            );
        }
        // The quantised payload undercuts f32 once the vector dominates
        // the fixed overhead.
        if theta.len() >= 8 {
            prop_assert!(frame.encoded_len() < Panel::wire_len(WireEncoding::F32, theta.len()));
        }
    }

    /// Cohorts preserve rank order and per-row raggedness under both
    /// encodings (rows carry their own length prefix).
    #[test]
    fn cohort_roundtrip_ragged_rows(
        round in any::<u64>(),
        panels in prop::collection::vec((finite_f32(), theta_vec(40)), 0..6),
        qi8 in any::<bool>(),
    ) {
        let enc = if qi8 { WireEncoding::Qi8 } else { WireEncoding::F32 };
        let frame = Cohort::frame(round, &panels, enc);
        let back = Cohort::parse(&reread(&frame)).unwrap();
        prop_assert_eq!(back.round, round);
        prop_assert_eq!(back.panels.len(), panels.len());
        for ((bh, bt), (h, t)) in back.panels.iter().zip(panels.iter()) {
            prop_assert_eq!(bh.to_bits(), h.to_bits());
            prop_assert_eq!(bt.len(), t.len());
            if enc == WireEncoding::F32 {
                for (a, b) in bt.iter().zip(t.iter()) {
                    prop_assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    /// Top-k panels round-trip to exactly `topk_apply` of the original —
    /// kept coordinates carry raw bits, dropped ones decode to zero —
    /// and the kept index set is strictly increasing. Decoding needs no
    /// rate: `reread` rebuilds the encoding from the header, which only
    /// carries the family (the reconstructed rate field is 0).
    #[test]
    fn panel_topk_roundtrip_is_topk_apply(
        round in any::<u64>(),
        h in finite_f32(),
        theta in theta_vec(300),
        k_ppm in prop_oneof![Just(1u32), 1u32..1_000_000, Just(1_000_000u32)],
    ) {
        let enc = WireEncoding::TopK { k_ppm };
        let frame = Panel::frame(MsgKind::Panel, round, h, &theta, enc);
        prop_assert_eq!(frame.encoded_len(), Panel::wire_len(enc, theta.len()));
        let back = Panel::parse(&reread(&frame)).unwrap();
        prop_assert_eq!(back.round, round);
        prop_assert_eq!(back.h.to_bits(), h.to_bits());
        prop_assert_eq!(back.theta.len(), theta.len());
        let want = topk_apply(&theta, k_ppm);
        for (a, b) in back.theta.iter().zip(want.iter()) {
            prop_assert_eq!(a.to_bits(), b.to_bits());
        }
        let idx = topk_indices(&theta, k_ppm);
        prop_assert_eq!(idx.len(), topk_k(theta.len(), k_ppm));
        for w in idx.windows(2) {
            prop_assert!(w[0] < w[1], "kept indices must strictly increase");
        }
    }

    /// Every strict prefix of a top-k frame is rejected, like the other
    /// encodings — the sparse body never parses half-received.
    #[test]
    fn truncated_topk_frames_rejected(
        theta in theta_vec(24),
        k_ppm in 1u32..=1_000_000,
    ) {
        let enc = WireEncoding::TopK { k_ppm };
        let bytes = frame_bytes(&Panel::frame(MsgKind::Panel, 1, 0.5, &theta, enc));
        for k in 0..bytes.len() {
            prop_assert!(
                Frame::read_from(&mut Cursor::new(&bytes[..k])).is_err(),
                "prefix of {} bytes parsed", k
            );
        }
        prop_assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_ok());
    }

    /// Lying top-k metadata — an index past the dim, a duplicated or
    /// unsorted index pair, a count that disagrees with the byte length,
    /// a count above the dim — is rejected while only the length-checked
    /// input bytes are held (validate before the dense allocation).
    /// Body layout inside a Panel payload: round(8) h(4) len(4), then
    /// dim u32 | k u32 | k indices | k values.
    #[test]
    fn lying_topk_fields_rejected(theta in prop::collection::vec(finite_f32(), 2..40)) {
        let dim = theta.len() as u32;
        let enc = WireEncoding::TopK { k_ppm: 1_000_000 }; // k = dim ≥ 2
        let good = Panel::frame(MsgKind::Panel, 1, 0.0, &theta, enc);
        prop_assert!(Panel::parse(&good).is_ok());

        // Index out of range: the last index is rewritten to dim.
        let mut oob = good.clone();
        let last = 24 + 4 * (dim as usize - 1);
        oob.payload[last..last + 4].copy_from_slice(&dim.to_le_bytes());
        prop_assert!(Panel::parse(&oob).is_err(), "index == dim parsed");

        // Duplicate index: indices[1] = indices[0].
        let mut dup = good.clone();
        let (a, b) = (24, 28);
        let first: [u8; 4] = dup.payload[a..a + 4].try_into().unwrap();
        dup.payload[b..b + 4].copy_from_slice(&first);
        prop_assert!(Panel::parse(&dup).is_err(), "duplicate index parsed");

        // Unsorted pair: swap indices[0] and indices[1].
        let mut unsorted = good.clone();
        let (x, y): ([u8; 4], [u8; 4]) = (
            unsorted.payload[a..a + 4].try_into().unwrap(),
            unsorted.payload[b..b + 4].try_into().unwrap(),
        );
        unsorted.payload[a..a + 4].copy_from_slice(&y);
        unsorted.payload[b..b + 4].copy_from_slice(&x);
        prop_assert!(Panel::parse(&unsorted).is_err(), "unsorted indices parsed");

        // Count lying past the byte length (validated before allocation).
        let mut lying_k = good.clone();
        lying_k.payload[20..24].copy_from_slice(&(dim - 1).to_le_bytes());
        prop_assert!(Panel::parse(&lying_k).is_err(), "count/byte-length mismatch parsed");

        // Count above the dim — and an implausible dim is rejected
        // before the dense output vector exists.
        let mut huge = good.clone();
        huge.payload[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        prop_assert!(Panel::parse(&huge).is_err(), "implausible dim parsed");
    }

    /// Welcomes round-trip their rank/p/config/resume payloads.
    #[test]
    fn welcome_roundtrip(
        rank in 0u32..64,
        extra in 0u32..64,
        json in "[ -~]{0,120}",
        resume in prop::option::of(theta_vec(60)),
    ) {
        let w = Welcome { rank, p: rank + 1 + extra, config_json: json, resume };
        let back = Welcome::parse(&reread(&w.frame(WireEncoding::F32))).unwrap();
        prop_assert_eq!(back, w);
    }

    /// Every strict prefix of a valid frame is rejected — the
    /// length-prefixed header never lets a truncated stream parse.
    #[test]
    fn truncated_frames_rejected(
        h in finite_f32(),
        theta in theta_vec(24),
        qi8 in any::<bool>(),
    ) {
        let enc = if qi8 { WireEncoding::Qi8 } else { WireEncoding::F32 };
        let bytes = frame_bytes(&Panel::frame(MsgKind::Panel, 1, h, &theta, enc));
        for k in 0..bytes.len() {
            prop_assert!(
                Frame::read_from(&mut Cursor::new(&bytes[..k])).is_err(),
                "prefix of {} bytes parsed", k
            );
        }
        prop_assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_ok());
    }

    /// Corrupting any single header byte either still yields a valid
    /// header (flipping payload-length bits can alias) or is rejected —
    /// it must never panic. Magic corruption is always rejected.
    #[test]
    fn corrupted_magic_always_rejected(
        theta in theta_vec(24),
        pos in 0usize..4,
        xor in 1u8..=255,
    ) {
        let frame = Panel::frame(MsgKind::Panel, 1, 0.5, &theta, WireEncoding::F32);
        let mut bytes = frame_bytes(&frame);
        bytes[pos] ^= xor;
        prop_assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_err());
    }

    /// A payload whose inner vector length lies past the payload end is
    /// rejected by the typed parsers (no panic, no over-read).
    #[test]
    fn lying_inner_length_rejected(theta in theta_vec(24), lie in 25u32..10_000) {
        let mut frame = Panel::frame(MsgKind::Panel, 1, 0.0, &theta, WireEncoding::F32);
        // Overwrite the inner length prefix at round(8) + h(4).
        frame.payload[12..16].copy_from_slice(&(lie * 4).to_le_bytes());
        prop_assert!(Panel::parse(&frame).is_err());
    }

    /// The four elastic frames (heartbeat, join request, leave, epoch
    /// commit) round-trip exactly for arbitrary field values.
    #[test]
    fn elastic_frames_roundtrip(
        round in any::<u64>(),
        rejoin in prop::option::of(any::<u32>()),
        epoch in any::<u64>(),
        members in prop::collection::vec(any::<u32>(), 0..8),
        anchor in any::<u64>(),
        reason in "[ -~]{0,48}",
    ) {
        let hb = Heartbeat { round };
        prop_assert_eq!(Heartbeat::parse(&reread(&hb.frame())).unwrap(), hb);
        let jr = JoinRequest { prior_rank: rejoin };
        prop_assert_eq!(JoinRequest::parse(&reread(&jr.frame())).unwrap(), jr);
        let lv = Leave { round };
        prop_assert_eq!(Leave::parse(&reread(&lv.frame())).unwrap(), lv);
        let ec = EpochCommit { epoch, round, members, anchor_digest: anchor, reason };
        let back = EpochCommit::parse(&reread(&ec.frame())).unwrap();
        prop_assert_eq!(back, ec);
    }

    /// Every strict prefix of every elastic frame is rejected, just like
    /// the training frames — a half-received membership message never
    /// parses.
    #[test]
    fn truncated_elastic_frames_rejected(
        round in any::<u64>(),
        rejoin in prop::option::of(any::<u32>()),
        members in prop::collection::vec(any::<u32>(), 0..6),
        reason in "[ -~]{0,24}",
    ) {
        let frames = [
            Heartbeat { round }.frame(),
            JoinRequest { prior_rank: rejoin }.frame(),
            Leave { round }.frame(),
            EpochCommit { epoch: 3, round, members, anchor_digest: 7, reason }.frame(),
        ];
        for frame in &frames {
            let bytes = frame_bytes(frame);
            for k in 0..bytes.len() {
                prop_assert!(
                    Frame::read_from(&mut Cursor::new(&bytes[..k])).is_err(),
                    "prefix of {} / {} bytes parsed as {:?}", k, bytes.len(), frame.kind
                );
            }
            prop_assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_ok());
        }
    }

    /// Field-level corruption of the elastic frames is rejected before
    /// any allocation or over-read: a bad join marker byte, a member
    /// count lying past the payload end, an implausibly huge member
    /// count, and a reason length lying past the payload end.
    #[test]
    fn corrupted_elastic_fields_rejected(
        members in prop::collection::vec(any::<u32>(), 0..6),
        reason in "[ -~]{0,24}",
        marker in 2u8..=255,
    ) {
        let mut jr = JoinRequest { prior_rank: Some(3) }.frame();
        jr.payload[0] = marker;
        prop_assert!(JoinRequest::parse(&jr).is_err(), "join marker {} parsed", marker);

        let ec = EpochCommit {
            epoch: 1,
            round: 2,
            members: members.clone(),
            anchor_digest: 3,
            reason: reason.clone(),
        };

        // Member count lying past the payload end: validate, don't read.
        let mut lying_count = ec.frame();
        lying_count.payload[16..20]
            .copy_from_slice(&(members.len() as u32 + 1000).to_le_bytes());
        prop_assert!(EpochCommit::parse(&lying_count).is_err());

        // An implausible count is rejected before any allocation.
        let mut huge_count = ec.frame();
        huge_count.payload[16..20].copy_from_slice(&(1u32 << 21).to_le_bytes());
        prop_assert!(EpochCommit::parse(&huge_count).is_err());

        // Reason length lying past the payload end.
        let mut lying_reason = ec.frame();
        let at = 8 + 8 + 4 + 4 * members.len() + 8;
        lying_reason.payload[at..at + 4]
            .copy_from_slice(&(reason.len() as u32 + 1000).to_le_bytes());
        prop_assert!(EpochCommit::parse(&lying_reason).is_err());
    }
}

#[test]
fn topk_edge_rates_roundtrip() {
    let theta = vec![3.0f32, -1.0, 0.5, -4.0, 0.0, 2.0];

    // k = 0 (the zero rate is unreachable from the CLI, which demands
    // R > 0, but the codec itself must handle it): an empty kept set
    // decodes to the all-zero panel.
    let zero = WireEncoding::TopK { k_ppm: 0 };
    let frame = Panel::frame(MsgKind::Panel, 1, 0.25, &theta, zero);
    assert_eq!(frame.encoded_len(), Panel::wire_len(zero, theta.len()));
    let back = Panel::parse(&frame).unwrap();
    assert_eq!(back.theta, vec![0.0f32; theta.len()]);

    // k = dim: the full rate keeps everything, bit-exactly — top-k at
    // rate 1 degenerates to (a fatter) f32.
    let full = WireEncoding::TopK { k_ppm: 1_000_000 };
    let back = Panel::parse(&Panel::frame(MsgKind::Panel, 1, 0.25, &theta, full)).unwrap();
    for (a, b) in back.theta.iter().zip(theta.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // The empty vector is fine at any rate.
    let back = Panel::parse(&Panel::frame(MsgKind::Panel, 1, 0.0, &[], full)).unwrap();
    assert!(back.theta.is_empty());
}

#[test]
fn specials_survive_topk_framing_bit_exactly() {
    // Non-finite magnitudes rank deterministically (NaN above +∞ under
    // total_cmp) and kept values carry raw bits — a NaN coordinate
    // survives sparsification unmangled rather than poisoning the codec.
    let theta = vec![1.0f32, f32::NAN, -2.0, f32::INFINITY, f32::NEG_INFINITY, -0.0];
    let enc = WireEncoding::TopK { k_ppm: 500_000 }; // keep 3 of 6
    let idx = topk_indices(&theta, 500_000);
    assert_eq!(idx, vec![1, 3, 4], "NaN then ±∞ outrank every finite magnitude");
    let back = Panel::parse(&Panel::frame(MsgKind::Panel, 7, f32::NAN, &theta, enc)).unwrap();
    assert_eq!(back.h.to_bits(), f32::NAN.to_bits());
    let want = topk_apply(&theta, 500_000);
    for (a, b) in back.theta.iter().zip(want.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(back.theta[1].to_bits(), f32::NAN.to_bits());
    assert_eq!(back.theta[3], f32::INFINITY);
    assert_eq!(back.theta[4], f32::NEG_INFINITY);
}

#[test]
fn specials_survive_f32_framing_bit_exactly() {
    // NaN payloads, infinities and signed zeros are parameter-vector
    // edge cases the lossless encoding must carry untouched.
    let theta = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, f32::MIN_POSITIVE];
    let frame = Panel::frame(MsgKind::Final, 9, f32::NAN, &theta, WireEncoding::F32);
    let back = Panel::parse(&reread(&frame)).unwrap();
    assert_eq!(back.round, 9);
    assert_eq!(back.h.to_bits(), f32::NAN.to_bits());
    for (a, b) in back.theta.iter().zip(theta.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
