//! CIFAR-10/100 binary-record format: parser, encoder, and the
//! [`DataSource`] provider that materialises a [`Dataset`] from the
//! python-version `.bin` files.
//!
//! One record is `label bytes + 3072 pixel bytes`: CIFAR-10 carries one
//! label byte, CIFAR-100 two (coarse then fine — training uses the fine
//! label). The 3072 pixels are three 1024-byte CHW planes (R, then G,
//! then B), each a row-major 32×32 image. Our models consume NHWC, so
//! [`record_to_hwc`] interleaves the planes while applying the
//! per-channel normalisation.
//!
//! Hygiene mirrors `data/idx.rs`: the byte length must be a whole,
//! non-zero number of records and every (fine) label must be in range —
//! both checked *before* the pixel buffers are allocated. Round trips
//! and rejection paths are property-tested in `tests/data_props.rs`;
//! the committed golden fixtures are pinned byte-for-byte by
//! `tests/data_fixtures.rs`.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use super::source::{DataSource, Normalization};
use super::synth::DatasetKind;
use super::Dataset;

/// Image side length (CIFAR images are 32×32).
pub const HW: usize = 32;
/// Pixel bytes per record: three 32×32 CHW planes.
pub const PIXELS_PER_RECORD: usize = 3 * HW * HW;

/// Which CIFAR binary flavour a file uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CifarFormat {
    /// CIFAR-10: 1 label byte per record, 10 classes.
    C10,
    /// CIFAR-100: 2 label bytes (coarse, fine) per record, 100 fine classes.
    C100,
}

impl CifarFormat {
    /// Label bytes preceding the pixels in each record.
    pub fn label_bytes(self) -> usize {
        match self {
            CifarFormat::C10 => 1,
            CifarFormat::C100 => 2,
        }
    }

    /// Fine-label class count.
    pub fn classes(self) -> usize {
        match self {
            CifarFormat::C10 => 10,
            CifarFormat::C100 => 100,
        }
    }

    /// Total bytes per record.
    pub fn record_len(self) -> usize {
        self.label_bytes() + PIXELS_PER_RECORD
    }

    /// Human-readable flavour name.
    pub fn name(self) -> &'static str {
        match self {
            CifarFormat::C10 => "cifar-10",
            CifarFormat::C100 => "cifar-100",
        }
    }
}

/// A parsed CIFAR binary file: labels plus raw CHW pixel planes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CifarFile {
    /// Fine labels, one per record.
    pub labels: Vec<u8>,
    /// Coarse labels (CIFAR-100 only; empty for CIFAR-10).
    pub coarse: Vec<u8>,
    /// Raw CHW pixels, `n · 3072` bytes.
    pub pixels_chw: Vec<u8>,
}

impl CifarFile {
    /// Record count.
    pub fn n(&self) -> usize {
        self.labels.len()
    }
}

/// Parse a CIFAR binary file. Rejects empty files, byte lengths that
/// are not a whole number of records, and out-of-range fine labels —
/// all before the pixel buffer is allocated.
pub fn parse(bytes: &[u8], format: CifarFormat) -> Result<CifarFile> {
    let rec = format.record_len();
    ensure!(!bytes.is_empty(), "{}: empty file", format.name());
    ensure!(
        bytes.len() % rec == 0,
        "{}: {} bytes is not a whole number of {rec}-byte records",
        format.name(),
        bytes.len()
    );
    let n = bytes.len() / rec;
    let lb = format.label_bytes();
    // Validate every record's fine label before allocating pixels.
    for k in 0..n {
        let fine = bytes[k * rec + lb - 1];
        ensure!(
            (fine as usize) < format.classes(),
            "{}: record {k} has fine label {fine} ≥ {} classes",
            format.name(),
            format.classes()
        );
    }
    let mut labels = Vec::with_capacity(n);
    let mut coarse = Vec::with_capacity(if lb == 2 { n } else { 0 });
    let mut pixels_chw = Vec::with_capacity(n * PIXELS_PER_RECORD);
    for k in 0..n {
        let r = &bytes[k * rec..(k + 1) * rec];
        if lb == 2 {
            coarse.push(r[0]);
        }
        labels.push(r[lb - 1]);
        pixels_chw.extend_from_slice(&r[lb..]);
    }
    Ok(CifarFile { labels, coarse, pixels_chw })
}

/// Encode a CIFAR binary file — the exact inverse of [`parse`]
/// (round-trip property-tested), used by the fixture generators and the
/// hermetic test suites. For [`CifarFormat::C10`], `file.coarse` must be
/// empty; for [`CifarFormat::C100`] it must carry one byte per record.
pub fn encode(file: &CifarFile, format: CifarFormat) -> Vec<u8> {
    let n = file.n();
    assert_eq!(file.pixels_chw.len(), n * PIXELS_PER_RECORD, "pixel buffer ≠ n·3072");
    match format {
        CifarFormat::C10 => {
            assert!(file.coarse.is_empty(), "cifar-10 records have no coarse label")
        }
        CifarFormat::C100 => {
            assert_eq!(file.coarse.len(), n, "cifar-100 needs one coarse label per record")
        }
    }
    let mut out = Vec::with_capacity(n * format.record_len());
    for k in 0..n {
        if format == CifarFormat::C100 {
            out.push(file.coarse[k]);
        }
        out.push(file.labels[k]);
        out.extend_from_slice(&file.pixels_chw[k * PIXELS_PER_RECORD..(k + 1) * PIXELS_PER_RECORD]);
    }
    out
}

/// Interleave one record's CHW planes into normalised NHWC floats:
/// `out[(row·32+col)·3 + ch] = norm(ch, plane_ch[row·32+col])`.
pub fn record_to_hwc(chw: &[u8], norm: &Normalization, out: &mut [f32]) {
    assert_eq!(chw.len(), PIXELS_PER_RECORD, "record pixel slice ≠ 3072");
    assert_eq!(out.len(), PIXELS_PER_RECORD, "output slice ≠ 3072");
    for ch in 0..3 {
        let plane = &chw[ch * HW * HW..(ch + 1) * HW * HW];
        for (pos, &b) in plane.iter().enumerate() {
            out[pos * 3 + ch] = norm.apply(ch, b);
        }
    }
}

/// The CIFAR [`DataSource`]: the python-version train/test `.bin` files
/// of one flavour, normalised per channel and interleaved to NHWC.
pub struct CifarSource {
    kind: DatasetKind,
    format: CifarFormat,
    norm: Normalization,
    train_files: Vec<PathBuf>,
    test_file: PathBuf,
}

impl CifarSource {
    /// Probe `dir` (then `dir/<kind-name>/`) for the flavour's canonical
    /// file names: `data_batch_1.bin … data_batch_5.bin` + `test_batch.bin`
    /// for CIFAR-10, `train.bin` + `test.bin` for CIFAR-100. CIFAR-10
    /// accepts a **contiguous prefix** `data_batch_1..k` (so trimmed
    /// test sets work), but a gapped layout — a higher-numbered batch
    /// present with an earlier one missing — is ambiguous (half a
    /// download? different hosts holding different subsets would
    /// silently de-synchronise a tcp cohort) and is treated as no
    /// match. `None` when the kind is not a CIFAR family or no
    /// complete file set is found.
    pub fn locate(dir: &Path, kind: DatasetKind) -> Option<Self> {
        let format = match kind {
            DatasetKind::Cifar10Like => CifarFormat::C10,
            DatasetKind::Cifar100Like => CifarFormat::C100,
            _ => return None,
        };
        for base in [dir.to_path_buf(), dir.join(kind.name())] {
            let (train_files, test_file) = match format {
                CifarFormat::C10 => {
                    let batch = |i: usize| base.join(format!("data_batch_{i}.bin"));
                    let present: Vec<bool> = (1..=5).map(|i| batch(i).is_file()).collect();
                    let k = present.iter().take_while(|&&p| p).count();
                    let gapped = present[k..].iter().any(|&p| p);
                    let train: Vec<PathBuf> =
                        if gapped { Vec::new() } else { (1..=k).map(batch).collect() };
                    (train, base.join("test_batch.bin"))
                }
                CifarFormat::C100 => {
                    let t = base.join("train.bin");
                    (if t.is_file() { vec![t] } else { Vec::new() }, base.join("test.bin"))
                }
            };
            if !train_files.is_empty() && test_file.is_file() {
                return Some(Self {
                    kind,
                    format,
                    norm: Normalization::for_kind(kind),
                    train_files,
                    test_file,
                });
            }
        }
        None
    }

    /// Parse and concatenate one or more record files into normalised
    /// NHWC rows.
    fn load_files(&self, paths: &[PathBuf]) -> Result<(Vec<f32>, Vec<i32>)> {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for path in paths {
            let bytes =
                std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
            let file = parse(&bytes, self.format)
                .with_context(|| format!("parsing {}", path.display()))?;
            let base = x.len();
            x.resize(base + file.n() * PIXELS_PER_RECORD, 0.0);
            for k in 0..file.n() {
                record_to_hwc(
                    &file.pixels_chw[k * PIXELS_PER_RECORD..(k + 1) * PIXELS_PER_RECORD],
                    &self.norm,
                    &mut x[base + k * PIXELS_PER_RECORD..base + (k + 1) * PIXELS_PER_RECORD],
                );
            }
            y.extend(file.labels.iter().map(|&l| l as i32));
        }
        Ok((x, y))
    }
}

impl DataSource for CifarSource {
    fn provenance(&self) -> &'static str {
        "cifar"
    }

    fn materialise(&self) -> Result<Dataset> {
        let (train_x, train_y) = self.load_files(&self.train_files)?;
        let (test_x, test_y) = self.load_files(std::slice::from_ref(&self.test_file))?;
        Ok(Dataset {
            name: self.kind.name().to_string(),
            dim: PIXELS_PER_RECORD,
            classes: self.format.classes(),
            train_x,
            train_y,
            test_x,
            test_y,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_file(n: usize, format: CifarFormat, salt: usize) -> CifarFile {
        CifarFile {
            labels: (0..n).map(|k| ((k * 3 + salt) % format.classes()) as u8).collect(),
            coarse: match format {
                CifarFormat::C10 => Vec::new(),
                CifarFormat::C100 => (0..n).map(|k| ((k + salt) % 20) as u8).collect(),
            },
            pixels_chw: (0..n * PIXELS_PER_RECORD)
                .map(|i| ((i * 7 + salt) % 256) as u8)
                .collect(),
        }
    }

    #[test]
    fn roundtrip_both_formats() {
        for format in [CifarFormat::C10, CifarFormat::C100] {
            let file = demo_file(3, format, 5);
            let bytes = encode(&file, format);
            assert_eq!(bytes.len(), 3 * format.record_len());
            assert_eq!(parse(&bytes, format).unwrap(), file);
        }
    }

    #[test]
    fn ragged_and_empty_rejected() {
        let file = demo_file(2, CifarFormat::C10, 1);
        let bytes = encode(&file, CifarFormat::C10);
        assert!(parse(&[], CifarFormat::C10).is_err(), "empty");
        assert!(parse(&bytes[..bytes.len() - 1], CifarFormat::C10).is_err(), "truncated");
        let mut fat = bytes.clone();
        fat.push(0);
        assert!(parse(&fat, CifarFormat::C10).is_err(), "oversized");
        // A C10 file is not a whole number of C100 records.
        assert!(parse(&bytes, CifarFormat::C100).is_err());
    }

    #[test]
    fn out_of_range_label_rejected() {
        let mut file = demo_file(2, CifarFormat::C10, 0);
        file.labels[1] = 10;
        let bytes = encode(&file, CifarFormat::C10);
        let err = parse(&bytes, CifarFormat::C10).unwrap_err();
        assert!(format!("{err}").contains("record 1"), "{err}");
    }

    #[test]
    fn hwc_interleaves_planes_with_per_channel_norm() {
        let norm = Normalization::for_kind(DatasetKind::Cifar10Like);
        let chw: Vec<u8> = (0..PIXELS_PER_RECORD).map(|i| (i % 256) as u8).collect();
        let mut out = vec![0.0f32; PIXELS_PER_RECORD];
        record_to_hwc(&chw, &norm, &mut out);
        // Spatial position 5: R from plane 0, G from plane 1, B from plane 2.
        for ch in 0..3 {
            let want = norm.apply(ch, chw[ch * 1024 + 5]);
            assert_eq!(out[5 * 3 + ch].to_bits(), want.to_bits(), "channel {ch}");
        }
    }

    #[test]
    fn locate_and_materialise_from_dir() {
        let dir = std::env::temp_dir().join(format!("wasgd_cifar_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(CifarSource::locate(&dir, DatasetKind::Cifar10Like).is_none());
        assert!(CifarSource::locate(&dir, DatasetKind::Tiny).is_none(), "non-CIFAR kind");
        let train = demo_file(4, CifarFormat::C10, 2);
        let test = demo_file(2, CifarFormat::C10, 9);
        std::fs::write(dir.join("data_batch_1.bin"), encode(&train, CifarFormat::C10)).unwrap();
        std::fs::write(dir.join("test_batch.bin"), encode(&test, CifarFormat::C10)).unwrap();

        let src = CifarSource::locate(&dir, DatasetKind::Cifar10Like).expect("files present");
        assert_eq!(src.provenance(), "cifar");
        let ds = src.materialise().unwrap();
        assert_eq!(ds.dim, 3072);
        assert_eq!(ds.classes, 10);
        assert_eq!(ds.n_train(), 4);
        assert_eq!(ds.n_test(), 2);
        assert_eq!(ds.train_y[1], train.labels[1] as i32);
        // NHWC interleave of record 0, spatial 0, channel 1 (G plane).
        let norm = Normalization::for_kind(DatasetKind::Cifar10Like);
        let want = norm.apply(1, train.pixels_chw[1024]);
        assert_eq!(ds.train_x[1].to_bits(), want.to_bits());

        // A gapped batch layout (batch 3 present, batch 2 missing) is
        // ambiguous and must not match…
        std::fs::write(dir.join("data_batch_3.bin"), encode(&train, CifarFormat::C10)).unwrap();
        assert!(CifarSource::locate(&dir, DatasetKind::Cifar10Like).is_none(), "gapped layout");
        // …but the contiguous prefix 1..=3 concatenates in index order.
        std::fs::write(dir.join("data_batch_2.bin"), encode(&test, CifarFormat::C10)).unwrap();
        let src = CifarSource::locate(&dir, DatasetKind::Cifar10Like).unwrap();
        let ds = src.materialise().unwrap();
        assert_eq!(ds.n_train(), 4 + 2 + 4);
        assert_eq!(ds.train_y[4], test.labels[0] as i32, "batch 2 follows batch 1");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
