//! The pluggable data pipeline: sources, normalisation, sharding, and
//! the streaming batch planner.
//!
//! Three layers, bottom to top:
//!
//! * [`DataSource`] — the provider seam. Three implementations
//!   materialise a [`Dataset`]: the deterministic synthetic generator
//!   ([`SynthSource`] over [`SynthConfig`]), the IDX parser for
//!   MNIST/Fashion-MNIST ([`crate::data::idx::IdxSource`]), and the
//!   CIFAR-10/100 binary-record parser
//!   ([`crate::data::cifar::CifarSource`]).
//! * [`DataPipeline`] — resolves a [`DataSpec`] (dataset family ×
//!   source × `--data-dir`) to a concrete provider, owns the
//!   per-dataset normalisation constants ([`Normalization`]), and
//!   validates the materialised split against the model manifest's
//!   input geometry (replacing the old ad-hoc `fabric_dataset`
//!   dim-adaption). Resolution is a pure function of the spec and the
//!   filesystem, so every process of a fabric cohort materialises the
//!   identical split — the sim ≡ threads ≡ tcp bit-exactness contract
//!   (`tests/fabric_e2e.rs`) holds for every source.
//! * [`BatchPlanner`] — the streaming sample-index planner every worker
//!   walks: fresh uniform shuffles (baselines), rank-stable shard
//!   shuffles (SPSGD, via [`shard_range`]), δ-label-blocked orders (the
//!   Fig. 3 study, [`delta_blocked_order`]), or the §3.4 seeded
//!   per-part orders ([`OrderState`]) — identical machinery over synth
//!   and real data. `next_batch_into` refills a caller buffer, keeping
//!   the hot loop allocation-free.

use std::path::PathBuf;

use anyhow::{ensure, Result};

use crate::config::ExperimentConfig;
use crate::rng::Rng;
use crate::runtime::Manifest;

use super::cifar::CifarSource;
use super::idx::{self, IdxSource};
use super::order::{delta_blocked_order, OrderState};
use super::synth::{DatasetKind, SynthConfig};
use super::Dataset;

/// Which concrete provider materialises the dataset (`--source …`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SourceKind {
    /// Resolve automatically: real files when `--data-dir` holds them,
    /// the synthetic analogue otherwise (with a pointed message).
    #[default]
    Auto,
    /// Force the deterministic synthetic generator.
    Synth,
    /// Force the IDX loader (MNIST-family ubyte files).
    Idx,
    /// Force the CIFAR binary-record loader.
    Cifar,
}

impl SourceKind {
    /// Every source kind, in CLI listing order.
    pub const ALL: [SourceKind; 4] =
        [SourceKind::Auto, SourceKind::Synth, SourceKind::Idx, SourceKind::Cifar];

    /// CLI name (`--source auto|synth|idx|cifar`).
    pub fn name(&self) -> &'static str {
        match self {
            SourceKind::Auto => "auto",
            SourceKind::Synth => "synth",
            SourceKind::Idx => "idx",
            SourceKind::Cifar => "cifar",
        }
    }

    /// Parse a CLI name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "auto" => SourceKind::Auto,
            "synth" => SourceKind::Synth,
            "idx" => SourceKind::Idx,
            "cifar" => SourceKind::Cifar,
            _ => return None,
        })
    }
}

/// The config-level description of where training data comes from:
/// dataset family, provider selection, and the directory real files
/// live in. Rides the tcp fabric's wire JSON (with `source` already
/// resolved to a concrete provider by the rendezvous), so every worker
/// process loads the same data the simulated trainer would.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DataSpec {
    /// Dataset family (`--dataset`).
    pub kind: DatasetKind,
    /// Provider selection (`--source`, default auto).
    pub source: SourceKind,
    /// Directory holding real MNIST/Fashion-MNIST/CIFAR files
    /// (`--data-dir`); probed directly and under `<dir>/<kind-name>/`.
    pub data_dir: Option<PathBuf>,
}

impl DataSpec {
    /// The real-file format this family ships as: IDX for the
    /// MNIST-shaped kinds (including `tiny`, which hermetic tests feed
    /// with small IDX fixtures), CIFAR records for the CIFAR kinds.
    pub fn real_format(&self) -> SourceKind {
        match self.kind {
            DatasetKind::Cifar10Like | DatasetKind::Cifar100Like => SourceKind::Cifar,
            _ => SourceKind::Idx,
        }
    }

    /// Static consistency rules — no filesystem access, so this is
    /// cheap enough for `ExperimentConfig::validate` to delegate to
    /// (the one home of these rules): a forced real source must match
    /// the family's shipping format and needs a data dir.
    pub fn check(&self) -> Result<()> {
        if matches!(self.source, SourceKind::Idx | SourceKind::Cifar) {
            let real = self.real_format();
            ensure!(
                self.source == real,
                "dataset {} ships as {} files, not {} — use --source {} (or auto)",
                self.kind.name(),
                real.name(),
                self.source.name(),
                real.name()
            );
            ensure!(
                self.data_dir.is_some(),
                "--source {} needs --data-dir pointing at the downloaded files",
                self.source.name()
            );
        }
        Ok(())
    }

    /// Resolve `Auto` to a concrete provider: probe the data dir for
    /// the family's file set and fall back to synth when it is absent.
    /// Returns the concrete source plus an optional human-readable note
    /// (what was found, or why the fallback fired) for the CLI to
    /// surface. Forced `idx`/`cifar` selections are validated against
    /// the family's real format ([`DataSpec::check`]).
    pub fn resolve(&self) -> Result<(SourceKind, Option<String>)> {
        self.check()?;
        let real = self.real_format();
        match self.source {
            SourceKind::Synth => Ok((SourceKind::Synth, None)),
            SourceKind::Idx | SourceKind::Cifar => Ok((self.source, None)),
            SourceKind::Auto => {
                let Some(dir) = &self.data_dir else {
                    return Ok((SourceKind::Synth, None));
                };
                let found = match real {
                    SourceKind::Idx => IdxSource::locate(dir, self.kind).is_some(),
                    _ => CifarSource::locate(dir, self.kind).is_some(),
                };
                if found {
                    Ok((
                        real,
                        Some(format!(
                            "data: using real {} {} files from {}",
                            self.kind.name(),
                            real.name(),
                            dir.display()
                        )),
                    ))
                } else {
                    Ok((
                        SourceKind::Synth,
                        Some(format!(
                            "data: no {} {} files under {} (expected {}); \
                             falling back to the synthetic analogue",
                            self.kind.name(),
                            real.name(),
                            dir.display(),
                            expected_files(self.kind)
                        )),
                    ))
                }
            }
        }
    }
}

/// The canonical file names a real dataset directory must hold for one
/// family (the pointed-message and error-text helper).
pub fn expected_files(kind: DatasetKind) -> String {
    match kind {
        DatasetKind::Cifar10Like => {
            "data_batch_1.bin[…data_batch_5.bin] + test_batch.bin".to_string()
        }
        DatasetKind::Cifar100Like => "train.bin + test.bin".to_string(),
        _ => idx::FILE_NAMES.join(" + "),
    }
}

/// Per-dataset input normalisation: pixels map `u8 → (b/255 − mean)/std`
/// per channel. The constants are the standard published per-channel
/// statistics of each corpus (see `docs/DATA.md`); the synthetic
/// generator emits already-standardised features and bypasses this.
#[derive(Clone, Debug)]
pub struct Normalization {
    /// Per-channel mean of the `[0, 1]`-scaled pixels.
    pub mean: Vec<f32>,
    /// Per-channel standard deviation of the `[0, 1]`-scaled pixels.
    pub std: Vec<f32>,
}

impl Normalization {
    /// The constants for one dataset family (1 channel for the
    /// MNIST-shaped kinds, 3 for CIFAR).
    pub fn for_kind(kind: DatasetKind) -> Self {
        let (mean, std): (&[f32], &[f32]) = match kind {
            // No published statistics for the synthetic tiny family:
            // plain centring to [−1, 1].
            DatasetKind::Tiny => (&[0.5], &[0.5]),
            DatasetKind::MnistLike => (&[0.1307], &[0.3081]),
            DatasetKind::FashionLike => (&[0.2860], &[0.3530]),
            DatasetKind::Cifar10Like => {
                (&[0.4914, 0.4822, 0.4465], &[0.2470, 0.2435, 0.2616])
            }
            DatasetKind::Cifar100Like => {
                (&[0.5071, 0.4865, 0.4409], &[0.2673, 0.2564, 0.2762])
            }
        };
        Self { mean: mean.to_vec(), std: std.to_vec() }
    }

    /// Normalise one raw pixel byte of channel `ch`.
    #[inline]
    pub fn apply(&self, ch: usize, byte: u8) -> f32 {
        (byte as f32 / 255.0 - self.mean[ch]) / self.std[ch]
    }
}

/// A provider that can materialise a full train+test [`Dataset`] — the
/// pluggable seam under the [`DataPipeline`].
pub trait DataSource {
    /// Short provenance tag ("synth", "idx", "cifar") for logs/errors.
    fn provenance(&self) -> &'static str;

    /// Materialise the dataset (both splits, features normalised).
    fn materialise(&self) -> Result<Dataset>;
}

/// The synthetic-analogue [`DataSource`]: wraps [`SynthConfig::build`],
/// a pure function of the seed.
pub struct SynthSource {
    /// Generator parameters (dim already adapted to the model variant).
    pub cfg: SynthConfig,
    /// Generation seed.
    pub seed: u64,
}

impl DataSource for SynthSource {
    fn provenance(&self) -> &'static str {
        "synth"
    }

    fn materialise(&self) -> Result<Dataset> {
        Ok(self.cfg.build(self.seed))
    }
}

/// The resolved data pipeline: a concrete provider selection plus the
/// normalisation/validation that makes its output safe to train on.
/// Pure function of `(DataSpec, seed, filesystem)`, so the simulated
/// trainer, every fabric worker thread, and every `wasgd worker` OS
/// process materialise the identical split.
pub struct DataPipeline {
    spec: DataSpec,
    note: Option<String>,
    seed: u64,
}

impl DataPipeline {
    /// Build and resolve a pipeline from an explicit spec + seed.
    pub fn new(spec: DataSpec, seed: u64) -> Result<Self> {
        let (source, note) = spec.resolve()?;
        Ok(Self { spec: DataSpec { source, ..spec }, note, seed })
    }

    /// Build from an experiment config (`cfg.data_spec()`, `cfg.seed`).
    pub fn from_config(cfg: &ExperimentConfig) -> Result<Self> {
        Self::new(cfg.data_spec(), cfg.seed)
    }

    /// The concrete provider this pipeline resolved to (never `Auto`).
    pub fn source_kind(&self) -> SourceKind {
        self.spec.source
    }

    /// Human-readable resolution note (real files found / fallback
    /// fired), for the CLI to surface.
    pub fn note(&self) -> Option<&str> {
        self.note.as_deref()
    }

    /// Instantiate the resolved provider. The synth generator adapts
    /// its feature count to the variant's input geometry (e.g.
    /// `tiny_cnn`'s 8×8×1 = 64 against the tiny preset's 16 raw
    /// features); real sources carry the geometry their files declare
    /// and are validated against the manifest in [`DataPipeline::load`].
    pub fn provider(&self, manifest: &Manifest) -> Result<Box<dyn DataSource>> {
        let kind = self.spec.kind;
        match self.spec.source {
            SourceKind::Synth => {
                let mut synth = SynthConfig::preset(kind);
                synth.dim = manifest.input_dim;
                Ok(Box::new(SynthSource { cfg: synth, seed: self.seed }))
            }
            SourceKind::Idx => {
                let dir = self.spec.data_dir.as_deref().expect("resolve() requires data_dir");
                let src = IdxSource::locate(dir, kind).ok_or_else(|| {
                    anyhow::anyhow!(
                        "no {} idx files under {} (expected {})",
                        kind.name(),
                        dir.display(),
                        expected_files(kind)
                    )
                })?;
                Ok(Box::new(src))
            }
            SourceKind::Cifar => {
                let dir = self.spec.data_dir.as_deref().expect("resolve() requires data_dir");
                let src = CifarSource::locate(dir, kind).ok_or_else(|| {
                    anyhow::anyhow!(
                        "no {} cifar files under {} (expected {})",
                        kind.name(),
                        dir.display(),
                        expected_files(kind)
                    )
                })?;
                Ok(Box::new(src))
            }
            SourceKind::Auto => unreachable!("DataPipeline::new resolves Auto"),
        }
    }

    /// Materialise the dataset and validate it against the model
    /// variant's geometry: feature count must equal the manifest's
    /// input dim (real files cannot be dim-adapted — a mismatch names
    /// both sides), the label space must fit the model head, and both
    /// splits must be non-empty.
    pub fn load(&self, manifest: &Manifest) -> Result<Dataset> {
        let provider = self.provider(manifest)?;
        let provenance = provider.provenance();
        let ds = provider.materialise()?;
        ensure!(
            ds.dim == manifest.input_dim,
            "{provenance} dataset {} is {}-dimensional but variant {} wants {} input \
             features — pick a matching --variant or drop --data-dir",
            ds.name,
            ds.dim,
            manifest.name,
            manifest.input_dim
        );
        ensure!(
            ds.classes <= manifest.num_classes,
            "{provenance} dataset {} has {} classes but variant {} emits {} logits",
            ds.name,
            ds.classes,
            manifest.name,
            manifest.num_classes
        );
        ensure!(
            ds.n_train() >= 1 && ds.n_test() >= 1,
            "{provenance} dataset {} has an empty split ({} train / {} test examples)",
            ds.name,
            ds.n_train(),
            ds.n_test()
        );
        Ok(ds)
    }
}

/// Rank-stable shard of `[0, n)` for worker `rank` of `p`: `p` equal
/// `⌊n/p⌋`-sized ranges with the remainder absorbed by the last rank.
/// The shards partition the train split exactly and depend on nothing
/// but `(n, rank, p)` — property-tested in `tests/data_props.rs`. This
/// is the one sharding rule every execution layer (simulated trainer,
/// threaded fabric, tcp workers) uses.
pub fn shard_range(n: usize, rank: usize, p: usize) -> (usize, usize) {
    debug_assert!(p >= 1 && rank < p);
    let base = n / p;
    let lo = rank * base;
    let hi = if rank + 1 == p { n } else { lo + base };
    (lo, hi)
}

/// The streaming batch planner: one worker's walk over the training
/// set, `batch` indices at a time, regenerating its order each epoch
/// from whichever policy applies (see the module docs). Extracted from
/// the old `Worker` internals so the same machinery drives synth and
/// real data on every fabric.
pub struct BatchPlanner {
    n_samples: usize,
    batch: usize,
    /// SPSGD shard bounds `[lo, hi)` in sample-index space.
    shard: Option<(usize, usize)>,
    /// `Some` when the §3.4 order search is active.
    order_state: Option<OrderState>,
    /// Fig. 3: force δ-blocked orders instead of uniform shuffles.
    force_delta: Option<usize>,
    /// Training labels (needed to build δ-blocked orders).
    labels: Vec<i32>,
    rng: Rng,
    /// Current epoch order and cursor.
    epoch_order: Vec<u32>,
    pos: usize,
    epoch: u64,
}

impl BatchPlanner {
    /// Construct a planner and build its first epoch order. `id` is the
    /// worker rank (it salts the order-search seed exactly like the
    /// pre-refactor `Worker` did, preserving every pinned trajectory).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        rng: Rng,
        n_samples: usize,
        batch: usize,
        shard: Option<(usize, usize)>,
        order_search: bool,
        n_parts: usize,
        force_delta: Option<usize>,
        labels: Vec<i32>,
    ) -> Self {
        let order_state = if order_search && shard.is_none() {
            Some(OrderState::new(n_samples, n_parts, rng.clone().next_u64() ^ id as u64))
        } else {
            None
        };
        let mut planner = Self {
            n_samples,
            batch,
            shard,
            order_state,
            force_delta,
            labels,
            rng,
            epoch_order: Vec::new(),
            pos: 0,
            epoch: 0,
        };
        planner.new_epoch();
        planner
    }

    /// Build the next epoch's order.
    fn new_epoch(&mut self) {
        self.epoch_order.clear();
        self.pos = 0;
        if let Some(delta) = self.force_delta {
            self.epoch_order = delta_blocked_order(&self.labels, delta, &mut self.rng);
        } else if let Some(st) = self.order_state.as_mut() {
            // §3.4: per-part seeded permutations (keep-or-redraw applied
            // inside order_for_part based on recorded scores).
            for part in 0..st.n_parts {
                self.epoch_order.extend(st.order_for_part(part));
            }
        } else if let Some((lo, hi)) = self.shard {
            let mut idx: Vec<u32> = (lo as u32..hi as u32).collect();
            self.rng.shuffle(&mut idx);
            self.epoch_order = idx;
        } else {
            self.epoch_order = self.rng.permutation(self.n_samples);
        }
    }

    /// Refill `out` with the next `batch` sample indices (wrapping to a
    /// new epoch as needed) — the allocation-free hot-loop entry point.
    pub fn next_batch_into(&mut self, out: &mut Vec<u32>) {
        let b = self.batch;
        if (self.pos + 1) * b > self.epoch_order.len() {
            self.epoch += 1;
            self.new_epoch();
        }
        let lo = self.pos * b;
        self.pos += 1;
        out.clear();
        out.extend_from_slice(&self.epoch_order[lo..lo + b]);
    }

    /// Allocating convenience wrapper around
    /// [`BatchPlanner::next_batch_into`] (tests, examples).
    pub fn next_batch(&mut self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.batch);
        self.next_batch_into(&mut out);
        out
    }

    /// Record the cohort z-score from `Judge` (Algorithm 2, Function 3)
    /// against the order part the planner is currently inside, so the
    /// part's seed survives iff its *latest* score was good — exactly
    /// Algorithm 1's `Scores[l] = score`.
    pub fn record_score(&mut self, score: f32) {
        if let Some(st) = self.order_state.as_mut() {
            let part_len = (self.n_samples / st.n_parts).max(1);
            let sample_pos = self.pos * self.batch;
            let part = (sample_pos / part_len).min(st.n_parts - 1);
            st.record_score(part, score);
        }
    }

    /// Completed epochs (order regenerations).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Order parts that kept their seed so far (telemetry).
    pub fn orders_kept(&self) -> u64 {
        self.order_state.as_ref().map(|s| s.kept).unwrap_or(0)
    }

    /// Order parts that redrew their seed so far (telemetry).
    pub fn orders_redrawn(&self) -> u64 {
        self.order_state.as_ref().map(|s| s.redrawn).unwrap_or(0)
    }

    /// The live order-search state, when active (test hook).
    pub fn order_state_mut(&mut self) -> Option<&mut OrderState> {
        self.order_state.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn planner(order_search: bool, shard: Option<(usize, usize)>) -> BatchPlanner {
        let labels: Vec<i32> = (0..120).map(|i| (i % 4) as i32).collect();
        BatchPlanner::new(0, Rng::new(5), 120, 10, shard, order_search, 4, None, labels)
    }

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let mut pl = planner(false, None);
        let mut seen = Vec::new();
        for _ in 0..12 {
            seen.extend(pl.next_batch());
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..120u32).collect::<Vec<_>>());
        assert_eq!(pl.epoch(), 0);
        pl.next_batch();
        assert_eq!(pl.epoch(), 1);
    }

    #[test]
    fn shard_restricts_indices() {
        let mut pl = planner(false, Some((30, 60)));
        for _ in 0..6 {
            for i in pl.next_batch() {
                assert!((30..60).contains(&(i as usize)));
            }
        }
    }

    #[test]
    fn order_search_covers_epoch_too() {
        let mut pl = planner(true, None);
        let mut seen = Vec::new();
        for _ in 0..12 {
            seen.extend(pl.next_batch());
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..120u32).collect::<Vec<_>>());
    }

    #[test]
    fn good_score_preserves_epoch_order_part() {
        let mut pl = planner(true, None);
        let first: Vec<u32> = (0..12).flat_map(|_| pl.next_batch()).collect();
        for part in 0..4 {
            pl.order_state_mut().unwrap().record_score(part, -2.0);
        }
        let second: Vec<u32> = (0..12).flat_map(|_| pl.next_batch()).collect();
        assert_eq!(first, second, "good scores must keep all seeds");

        for part in 0..4 {
            pl.order_state_mut().unwrap().record_score(part, 2.0);
        }
        let third: Vec<u32> = (0..12).flat_map(|_| pl.next_batch()).collect();
        assert_ne!(second, third, "bad scores must reshuffle");
    }

    #[test]
    fn delta_forced_orders_have_blocks() {
        let labels: Vec<i32> = (0..120).map(|i| (i % 4) as i32).collect();
        let mut pl =
            BatchPlanner::new(0, Rng::new(9), 120, 10, None, false, 4, Some(30), labels.clone());
        let idx = pl.next_batch();
        let first_label = labels[idx[0] as usize];
        assert!(idx.iter().all(|&i| labels[i as usize] == first_label));
    }

    #[test]
    fn next_batch_into_is_stream_stable() {
        // The buffered entry point yields the same stream as a fresh
        // planner's allocating one.
        let mut a = planner(true, None);
        let mut b = planner(true, None);
        let mut buf = Vec::new();
        for _ in 0..30 {
            b.next_batch_into(&mut buf);
            assert_eq!(a.next_batch(), buf);
        }
    }

    #[test]
    fn shard_range_partitions_and_absorbs_remainder() {
        assert_eq!(shard_range(103, 0, 4), (0, 25));
        assert_eq!(shard_range(103, 3, 4), (75, 103));
        assert_eq!(shard_range(8, 0, 1), (0, 8));
        // p > n: leading shards are empty, the last takes everything.
        assert_eq!(shard_range(2, 0, 4), (0, 0));
        assert_eq!(shard_range(2, 3, 4), (0, 2));
    }

    #[test]
    fn spec_resolution_auto_and_forced() {
        let spec =
            DataSpec { kind: DatasetKind::MnistLike, source: SourceKind::Auto, data_dir: None };
        assert_eq!(spec.resolve().unwrap(), (SourceKind::Synth, None));

        let missing = std::env::temp_dir().join("wasgd_definitely_missing_data_dir");
        let spec = DataSpec {
            kind: DatasetKind::MnistLike,
            source: SourceKind::Auto,
            data_dir: Some(missing),
        };
        let (src, note) = spec.resolve().unwrap();
        assert_eq!(src, SourceKind::Synth);
        assert!(note.unwrap().contains("falling back"), "fallback must be pointed");

        // Forced sources must match the family's real format.
        let spec = DataSpec {
            kind: DatasetKind::Cifar10Like,
            source: SourceKind::Idx,
            data_dir: Some(PathBuf::from(".")),
        };
        assert!(spec.resolve().is_err());
        let spec = DataSpec {
            kind: DatasetKind::MnistLike,
            source: SourceKind::Cifar,
            data_dir: Some(PathBuf::from(".")),
        };
        assert!(spec.resolve().is_err());
        let spec =
            DataSpec { kind: DatasetKind::MnistLike, source: SourceKind::Idx, data_dir: None };
        assert!(spec.resolve().is_err(), "forced real source needs --data-dir");
    }

    #[test]
    fn pipeline_adapts_synth_dim_to_variant() {
        let mut cfg = ExperimentConfig::default();
        cfg.variant = "tiny_cnn".to_string();
        let manifest = Manifest::native_variant("tiny_cnn").unwrap();
        let pipeline = DataPipeline::from_config(&cfg).unwrap();
        assert_eq!(pipeline.source_kind(), SourceKind::Synth);
        let ds = pipeline.load(&manifest).unwrap();
        assert_eq!(ds.dim, 64); // 8×8×1, not the tiny preset's 16
        assert_eq!(ds.n_train(), 512);
        // Rebuilding yields the identical split (pure function of seed).
        let ds2 = DataPipeline::from_config(&cfg).unwrap().load(&manifest).unwrap();
        assert_eq!(ds.train_x, ds2.train_x);
        assert_eq!(ds.train_y, ds2.train_y);
    }

    #[test]
    fn pipeline_rejects_geometry_mismatch() {
        // Real IDX files cannot be dim-adapted: 4×4 images against the
        // 8×8×1 tiny_cnn manifest must fail with both sides named.
        let dir = std::env::temp_dir().join(format!("wasgd_geom_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let px: Vec<u8> = (0..4 * 16).map(|i| i as u8).collect();
        std::fs::write(dir.join(idx::FILE_NAMES[0]), idx::encode_images(4, 4, 4, &px)).unwrap();
        std::fs::write(dir.join(idx::FILE_NAMES[1]), idx::encode_labels(&[0, 1, 0, 1])).unwrap();
        std::fs::write(dir.join(idx::FILE_NAMES[2]), idx::encode_images(4, 4, 4, &px)).unwrap();
        std::fs::write(dir.join(idx::FILE_NAMES[3]), idx::encode_labels(&[1, 0, 1, 0])).unwrap();

        let mut cfg = ExperimentConfig::default();
        cfg.variant = "tiny_cnn".to_string();
        cfg.data_dir = Some(dir.clone());
        let manifest = Manifest::native_variant("tiny_cnn").unwrap();
        let pipeline = DataPipeline::from_config(&cfg).unwrap();
        assert_eq!(pipeline.source_kind(), SourceKind::Idx, "auto must pick the files up");
        let err = pipeline.load(&manifest).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("16") && msg.contains("64"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn normalization_constants_shapes() {
        for kind in [
            DatasetKind::Tiny,
            DatasetKind::MnistLike,
            DatasetKind::FashionLike,
            DatasetKind::Cifar10Like,
            DatasetKind::Cifar100Like,
        ] {
            let n = Normalization::for_kind(kind);
            let channels = match kind {
                DatasetKind::Cifar10Like | DatasetKind::Cifar100Like => 3,
                _ => 1,
            };
            assert_eq!(n.mean.len(), channels, "{}", kind.name());
            assert_eq!(n.std.len(), channels);
            assert!(n.std.iter().all(|&s| s > 0.0));
            // Mid-grey maps near zero, extremes stay bounded.
            assert!(n.apply(0, 128).abs() < 2.5);
            assert!(n.apply(0, 0) < n.apply(0, 255));
        }
    }

    #[test]
    fn source_kind_parse_roundtrip() {
        for s in SourceKind::ALL {
            assert_eq!(SourceKind::parse(s.name()), Some(s));
        }
        assert_eq!(SourceKind::parse("imagenet"), None);
        assert_eq!(SourceKind::default(), SourceKind::Auto);
    }
}
