//! Data substrate: the pluggable source pipeline, real-file parsers,
//! synthetic generators, and sample-order state.
//!
//! The paper evaluates on MNIST / Fashion-MNIST / CIFAR-10 / CIFAR-100.
//! Ingestion is a [`source::DataSource`] seam with three providers
//! behind one [`source::DataPipeline`]:
//!
//! * [`synth`] — deterministic synthetic analogues whose *relative
//!   difficulty* matches the paper's corpora (mnist < fashion <
//!   cifar10 < cifar100; DESIGN.md §3) — the hermetic default, since
//!   this environment has no network access;
//! * [`idx`] — the MNIST-family IDX ubyte parser, picking up real
//!   downloaded files via `wasgd run --data-dir <path>`;
//! * [`cifar`] — the CIFAR-10/100 binary-record parser (same flag).
//!
//! The pipeline owns per-dataset normalisation, geometry validation
//! against the model manifest, rank-stable worker sharding
//! ([`source::shard_range`]) and the streaming [`source::BatchPlanner`]
//! that composes with [`order::OrderState`] /
//! [`order::delta_blocked_order`] — so the §3.4 designed sample order
//! runs identically over synthetic and real data, on every fabric.

pub mod cifar;
pub mod idx;
pub mod order;
pub mod source;
pub mod synth;

pub use order::{delta_blocked_order, OrderState, RecordWindow};
pub use source::{
    shard_range, BatchPlanner, DataPipeline, DataSource, DataSpec, Normalization, SourceKind,
};
pub use synth::{DatasetKind, SynthConfig};

/// A fully materialised classification dataset (train + test split),
/// row-major `x` with `dim` features per example.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Dataset name (preset kind).
    pub name: String,
    /// Feature count per example.
    pub dim: usize,
    /// Number of classes.
    pub classes: usize,
    /// Training features, row-major `[n_train × dim]`.
    pub train_x: Vec<f32>,
    /// Training labels.
    pub train_y: Vec<i32>,
    /// Test features, row-major `[n_test × dim]`.
    pub test_x: Vec<f32>,
    /// Test labels.
    pub test_y: Vec<i32>,
}

impl Dataset {
    /// Number of training examples.
    pub fn n_train(&self) -> usize {
        self.train_y.len()
    }

    /// Number of test examples.
    pub fn n_test(&self) -> usize {
        self.test_y.len()
    }

    /// Row view of one training example.
    #[inline]
    pub fn train_row(&self, i: usize) -> &[f32] {
        &self.train_x[i * self.dim..(i + 1) * self.dim]
    }

    /// Gather a batch of training examples (by index) into the caller's
    /// reusable buffers — the hot-loop path, allocation-free.
    pub fn gather_train(&self, idx: &[u32], x_out: &mut Vec<f32>, y_out: &mut Vec<i32>) {
        x_out.clear();
        y_out.clear();
        x_out.reserve(idx.len() * self.dim);
        y_out.reserve(idx.len());
        for &i in idx {
            let i = i as usize;
            x_out.extend_from_slice(self.train_row(i));
            y_out.push(self.train_y[i]);
        }
    }

    /// Gather a batch of test examples (same reserve-once discipline as
    /// [`Dataset::gather_train`] — the eval path must not reallocate
    /// incrementally either).
    pub fn gather_test(&self, idx: &[u32], x_out: &mut Vec<f32>, y_out: &mut Vec<i32>) {
        x_out.clear();
        y_out.clear();
        x_out.reserve(idx.len() * self.dim);
        y_out.reserve(idx.len());
        for &i in idx {
            let i = i as usize;
            x_out.extend_from_slice(&self.test_x[i * self.dim..(i + 1) * self.dim]);
            y_out.push(self.test_y[i]);
        }
    }

    /// Per-class counts over the training labels (test helper / sanity).
    pub fn train_class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &y in &self.train_y {
            h[y as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::synth::{DatasetKind, SynthConfig};

    #[test]
    fn gather_matches_rows() {
        let ds = SynthConfig::preset(DatasetKind::MnistLike)
            .with_sizes(64, 16)
            .build(1);
        let mut x = Vec::new();
        let mut y = Vec::new();
        ds.gather_train(&[3, 0, 7], &mut x, &mut y);
        assert_eq!(x.len(), 3 * ds.dim);
        assert_eq!(&x[0..ds.dim], ds.train_row(3));
        assert_eq!(y[1], ds.train_y[0]);
    }
}
