//! IDX (MNIST / Fashion-MNIST) binary format: parser, encoder, and the
//! [`DataSource`] provider that materialises a [`Dataset`] from the four
//! classic ubyte files.
//!
//! Format (LeCun's specification): a big-endian header
//! `[0x00, 0x00, dtype, ndim]` followed by `ndim` u32 dimension sizes,
//! then the payload in row-major order. This module supports
//! `dtype = 0x08` (unsigned byte) — the only dtype the MNIST-family
//! files use — with `ndim = 3` for image tensors `[n, rows, cols]` and
//! `ndim = 1` for label vectors `[n]`.
//!
//! Hygiene mirrors `cluster/wire.rs`: the declared element count is
//! computed with checked arithmetic and validated against the actual
//! byte length *before* any payload allocation, so truncated, oversized,
//! or dimension-lying files are rejected with a pointed error — never a
//! panic or an attempted huge allocation (property-tested in
//! `tests/data_props.rs`; the committed golden fixture is pinned
//! byte-for-byte by `tests/data_fixtures.rs`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use super::source::{DataSource, Normalization};
use super::synth::DatasetKind;
use super::Dataset;

/// IDX dtype code for unsigned bytes (the MNIST-family payload type).
pub const DTYPE_U8: u8 = 0x08;

/// Classic file names of an IDX dataset directory, in
/// (train images, train labels, test images, test labels) order —
/// what MNIST and Fashion-MNIST ship as (after gunzip).
pub const FILE_NAMES: [&str; 4] = [
    "train-images-idx3-ubyte",
    "train-labels-idx1-ubyte",
    "t10k-images-idx3-ubyte",
    "t10k-labels-idx1-ubyte",
];

/// A parsed IDX image tensor `[n, rows, cols]` of raw u8 pixels.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IdxImages {
    /// Image count.
    pub n: usize,
    /// Pixel rows per image.
    pub rows: usize,
    /// Pixel columns per image.
    pub cols: usize,
    /// Row-major pixels, `n · rows · cols` bytes.
    pub pixels: Vec<u8>,
}

/// Parse an IDX image file (`ndim = 3`, dtype u8). The byte length must
/// match the declared dimensions exactly — truncated *and* oversized
/// payloads are both rejected, before any allocation.
pub fn parse_images(bytes: &[u8]) -> Result<IdxImages> {
    let (dims, payload) = parse_header(bytes, 3, "images")?;
    Ok(IdxImages { n: dims[0], rows: dims[1], cols: dims[2], pixels: payload.to_vec() })
}

/// Parse an IDX label file (`ndim = 1`, dtype u8) into raw label bytes.
pub fn parse_labels(bytes: &[u8]) -> Result<Vec<u8>> {
    let (_dims, payload) = parse_header(bytes, 1, "labels")?;
    Ok(payload.to_vec())
}

/// Validate magic + dims and return (dims, payload slice). The payload
/// is only a borrow here: nothing is allocated until the caller has a
/// fully validated view.
fn parse_header<'a>(
    bytes: &'a [u8],
    want_ndim: usize,
    what: &str,
) -> Result<(Vec<usize>, &'a [u8])> {
    ensure!(bytes.len() >= 4, "idx {what}: {} bytes is too short for the magic", bytes.len());
    ensure!(
        bytes[0] == 0 && bytes[1] == 0,
        "idx {what}: bad magic 0x{:02x}{:02x} (expected 0x0000)",
        bytes[0],
        bytes[1]
    );
    let dtype = bytes[2];
    ensure!(
        dtype == DTYPE_U8,
        "idx {what}: dtype 0x{dtype:02x} unsupported (only 0x08 = unsigned byte)"
    );
    let ndim = bytes[3] as usize;
    ensure!(ndim == want_ndim, "idx {what}: rank {ndim}, expected {want_ndim}");
    let header = 4 + 4 * ndim;
    ensure!(
        bytes.len() >= header,
        "idx {what}: {} bytes is too short for a rank-{ndim} dimension header",
        bytes.len()
    );
    let mut dims = Vec::with_capacity(ndim);
    for i in 0..ndim {
        let off = 4 + 4 * i;
        dims.push(u32::from_be_bytes(bytes[off..off + 4].try_into().unwrap()) as usize);
    }
    let total = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow!("idx {what}: dimension product {dims:?} overflows"))?;
    let payload = &bytes[header..];
    ensure!(
        payload.len() == total,
        "idx {what}: payload is {} bytes but dims {dims:?} declare {total}",
        payload.len()
    );
    Ok((dims, payload))
}

/// Encode an IDX image tensor — the exact inverse of [`parse_images`]
/// (round-trip property-tested), used by the fixture generators and the
/// hermetic test suites.
pub fn encode_images(n: usize, rows: usize, cols: usize, pixels: &[u8]) -> Vec<u8> {
    assert_eq!(pixels.len(), n * rows * cols, "pixel buffer ≠ n·rows·cols");
    let mut out = Vec::with_capacity(16 + pixels.len());
    out.extend_from_slice(&[0, 0, DTYPE_U8, 3]);
    out.extend_from_slice(&(n as u32).to_be_bytes());
    out.extend_from_slice(&(rows as u32).to_be_bytes());
    out.extend_from_slice(&(cols as u32).to_be_bytes());
    out.extend_from_slice(pixels);
    out
}

/// Encode an IDX label vector — the exact inverse of [`parse_labels`].
pub fn encode_labels(labels: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + labels.len());
    out.extend_from_slice(&[0, 0, DTYPE_U8, 1]);
    out.extend_from_slice(&(labels.len() as u32).to_be_bytes());
    out.extend_from_slice(labels);
    out
}

/// The IDX [`DataSource`]: four ubyte files (train/test × images/labels)
/// normalised with the dataset family's mean/std. Image geometry is
/// whatever the files declare — 28×28 for real (Fashion-)MNIST, but any
/// `rows × cols` parses, which is what lets hermetic tests run tiny
/// 8×8 IDX datasets through the full tcp fabric.
pub struct IdxSource {
    kind: DatasetKind,
    classes: usize,
    norm: Normalization,
    train_images: PathBuf,
    train_labels: PathBuf,
    test_images: PathBuf,
    test_labels: PathBuf,
}

impl IdxSource {
    /// Probe `dir` (then `dir/<kind-name>/`) for the four classic IDX
    /// file names; `None` when any of them is missing.
    pub fn locate(dir: &Path, kind: DatasetKind) -> Option<Self> {
        for base in [dir.to_path_buf(), dir.join(kind.name())] {
            let paths: Vec<PathBuf> = FILE_NAMES.iter().map(|f| base.join(f)).collect();
            if paths.iter().all(|p| p.is_file()) {
                return Some(Self {
                    kind,
                    classes: crate::data::synth::SynthConfig::preset(kind).classes,
                    norm: Normalization::for_kind(kind),
                    train_images: paths[0].clone(),
                    train_labels: paths[1].clone(),
                    test_images: paths[2].clone(),
                    test_labels: paths[3].clone(),
                })
            }
        }
        None
    }

    /// Load one (images, labels) file pair into normalised rows.
    fn load_split(&self, images: &Path, labels: &Path) -> Result<(Vec<f32>, Vec<i32>, usize)> {
        let img_bytes = std::fs::read(images)
            .with_context(|| format!("reading {}", images.display()))?;
        let img = parse_images(&img_bytes)
            .with_context(|| format!("parsing {}", images.display()))?;
        let lab_bytes = std::fs::read(labels)
            .with_context(|| format!("reading {}", labels.display()))?;
        let lab = parse_labels(&lab_bytes)
            .with_context(|| format!("parsing {}", labels.display()))?;
        ensure!(
            lab.len() == img.n,
            "{}: {} labels for {} images in {}",
            labels.display(),
            lab.len(),
            img.n,
            images.display()
        );
        for (i, &l) in lab.iter().enumerate() {
            ensure!(
                (l as usize) < self.classes,
                "{}: label {l} at index {i} out of range for {} {} classes",
                labels.display(),
                self.kind.name(),
                self.classes
            );
        }
        let dim = img.rows * img.cols;
        let x = img.pixels.iter().map(|&b| self.norm.apply(0, b)).collect();
        let y = lab.iter().map(|&l| l as i32).collect();
        Ok((x, y, dim))
    }
}

impl DataSource for IdxSource {
    fn provenance(&self) -> &'static str {
        "idx"
    }

    fn materialise(&self) -> Result<Dataset> {
        let (train_x, train_y, dim) = self.load_split(&self.train_images, &self.train_labels)?;
        let (test_x, test_y, test_dim) = self.load_split(&self.test_images, &self.test_labels)?;
        ensure!(
            dim == test_dim,
            "idx train images are {dim}-dimensional but test images are {test_dim}-dimensional"
        );
        Ok(Dataset {
            name: self.kind.name().to_string(),
            dim,
            classes: self.classes,
            train_x,
            train_y,
            test_x,
            test_y,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_pixels(n: usize, rows: usize, cols: usize) -> Vec<u8> {
        (0..n * rows * cols).map(|i| ((i * 31 + 7) % 251) as u8).collect()
    }

    #[test]
    fn images_roundtrip() {
        let px = demo_pixels(3, 4, 5);
        let bytes = encode_images(3, 4, 5, &px);
        assert_eq!(bytes.len(), 16 + px.len());
        let back = parse_images(&bytes).unwrap();
        assert_eq!(back.n, 3);
        assert_eq!(back.rows, 4);
        assert_eq!(back.cols, 5);
        assert_eq!(back.pixels, px);
    }

    #[test]
    fn labels_roundtrip() {
        let bytes = encode_labels(&[0, 1, 2, 9]);
        assert_eq!(parse_labels(&bytes).unwrap(), vec![0, 1, 2, 9]);
        assert!(parse_labels(&encode_labels(&[])).unwrap().is_empty());
    }

    #[test]
    fn truncated_and_oversized_rejected() {
        let good = encode_images(2, 3, 3, &demo_pixels(2, 3, 3));
        assert!(parse_images(&good[..good.len() - 1]).is_err(), "truncated payload");
        assert!(parse_images(&good[..10]).is_err(), "truncated header");
        let mut fat = good.clone();
        fat.push(0);
        assert!(parse_images(&fat).is_err(), "oversized payload");
    }

    #[test]
    fn bad_magic_dtype_and_rank_rejected() {
        let good = encode_images(1, 2, 2, &demo_pixels(1, 2, 2));
        let mut bad = good.clone();
        bad[0] = 0xFF;
        assert!(parse_images(&bad).is_err(), "bad magic");
        let mut bad = good.clone();
        bad[2] = 0x0D; // float dtype
        assert!(parse_images(&bad).is_err(), "unsupported dtype");
        // An images file parsed as labels (rank mismatch) must fail too.
        assert!(parse_labels(&good).is_err());
        assert!(parse_images(&encode_labels(&[1, 2])).is_err());
    }

    #[test]
    fn lying_dims_rejected_before_allocation() {
        // Header declares ~2⁶⁴ pixels over a 4-byte body: the checked
        // product must reject it without ever allocating.
        let mut bytes = vec![0, 0, DTYPE_U8, 3];
        for _ in 0..3 {
            bytes.extend_from_slice(&u32::MAX.to_be_bytes());
        }
        bytes.extend_from_slice(&[1, 2, 3, 4]);
        let err = parse_images(&bytes).unwrap_err();
        assert!(format!("{err}").contains("overflow"), "{err}");
    }

    #[test]
    fn locate_and_materialise_from_dir() {
        let dir = std::env::temp_dir().join(format!("wasgd_idx_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(IdxSource::locate(&dir, DatasetKind::Tiny).is_none());
        let train_px = demo_pixels(6, 4, 4);
        let test_px = demo_pixels(2, 4, 4);
        std::fs::write(dir.join(FILE_NAMES[0]), encode_images(6, 4, 4, &train_px)).unwrap();
        std::fs::write(dir.join(FILE_NAMES[1]), encode_labels(&[0, 1, 0, 1, 1, 0])).unwrap();
        std::fs::write(dir.join(FILE_NAMES[2]), encode_images(2, 4, 4, &test_px)).unwrap();
        std::fs::write(dir.join(FILE_NAMES[3]), encode_labels(&[1, 0])).unwrap();

        let src = IdxSource::locate(&dir, DatasetKind::Tiny).expect("all four files present");
        assert_eq!(src.provenance(), "idx");
        let ds = src.materialise().unwrap();
        assert_eq!(ds.dim, 16);
        assert_eq!(ds.classes, 2);
        assert_eq!(ds.n_train(), 6);
        assert_eq!(ds.n_test(), 2);
        assert_eq!(ds.train_y, vec![0, 1, 0, 1, 1, 0]);
        // Normalisation: (b/255 − mean)/std with the Tiny constants.
        let norm = Normalization::for_kind(DatasetKind::Tiny);
        assert_eq!(ds.train_x[5].to_bits(), norm.apply(0, train_px[5]).to_bits());

        // A label outside the family's class count is rejected.
        std::fs::write(dir.join(FILE_NAMES[1]), encode_labels(&[0, 1, 0, 9, 1, 0])).unwrap();
        let src = IdxSource::locate(&dir, DatasetKind::Tiny).unwrap();
        assert!(src.materialise().is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
