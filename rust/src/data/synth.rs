//! Synthetic dataset generators — the stand-ins for MNIST / Fashion-MNIST /
//! CIFAR-10 / CIFAR-100 (DESIGN.md §3 substitution table).
//!
//! Each dataset is a Gaussian mixture with one anisotropic mode per class
//! plus a nonlinear "style" warp, generated deterministically from a seed:
//!
//! * class centres `μ_c ~ N(0, sep²/√dim · I)` — `sep` controls class
//!   separability and is the primary difficulty knob;
//! * per-example `x = μ_c + noise·ε + warp·(ε² − 1)` — the elementwise
//!   quadratic warp makes the Bayes-optimal boundary nonlinear so the MLP
//!   and CNN variants have capacity to exploit (a pure mixture would be
//!   linearly separable and every algorithm would converge instantly);
//! * label noise flips a fraction of training labels uniformly, emulating
//!   the irreducible error that keeps CIFAR-like losses bounded away from
//!   zero.
//!
//! Presets are calibrated so relative task hardness matches the paper:
//! mnist < fashion < cifar10 < cifar100 (validated in the integration
//! suite by comparing losses after a fixed training budget).

use crate::rng::Rng;

use super::Dataset;

/// The four paper datasets plus a tiny smoke-test workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// 16-dim, 2 classes — pairs with the `tiny_mlp` model variant.
    Tiny,
    /// 784-dim, 10 classes, well separated (MNIST analogue).
    MnistLike,
    /// 784-dim, 10 classes, moderately separated (Fashion-MNIST analogue).
    FashionLike,
    /// 3072-dim, 10 classes, weakly separated (CIFAR-10 analogue).
    Cifar10Like,
    /// 3072-dim, 100 classes, weakly separated (CIFAR-100 analogue).
    Cifar100Like,
}

impl DatasetKind {
    /// Parse a CLI name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "tiny" => Self::Tiny,
            "mnist" => Self::MnistLike,
            "fashion" => Self::FashionLike,
            "cifar10" => Self::Cifar10Like,
            "cifar100" => Self::Cifar100Like,
            _ => return None,
        })
    }

    /// CLI name (`--dataset …`).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Tiny => "tiny",
            Self::MnistLike => "mnist",
            Self::FashionLike => "fashion",
            Self::Cifar10Like => "cifar10",
            Self::Cifar100Like => "cifar100",
        }
    }

    /// The model variant whose artifacts pair with this dataset.
    pub fn default_variant(&self) -> &'static str {
        match self {
            Self::Tiny => "tiny_mlp",
            Self::MnistLike => "mnist_mlp",
            Self::FashionLike => "fashion_mlp",
            Self::Cifar10Like => "cifar_cnn10",
            Self::Cifar100Like => "cifar_cnn100",
        }
    }
}

/// Generator parameters; start from a [`SynthConfig::preset`] and tweak.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    /// Which preset family this config derives from.
    pub kind: DatasetKind,
    /// Feature count per example.
    pub dim: usize,
    /// Number of classes.
    pub classes: usize,
    /// Training split size.
    pub n_train: usize,
    /// Test split size.
    pub n_test: usize,
    /// Class-centre separation (difficulty knob; larger = easier).
    pub sep: f32,
    /// Within-class isotropic noise scale.
    pub noise: f32,
    /// Elementwise quadratic warp strength (nonlinearity knob).
    pub warp: f32,
    /// Fraction of *training* labels flipped uniformly at random.
    pub label_noise: f32,
}

impl SynthConfig {
    /// The calibrated preset for one dataset kind.
    pub fn preset(kind: DatasetKind) -> Self {
        match kind {
            DatasetKind::Tiny => Self {
                kind,
                dim: 16,
                classes: 2,
                n_train: 512,
                n_test: 128,
                sep: 3.0,
                noise: 1.0,
                warp: 0.1,
                label_noise: 0.0,
            },
            DatasetKind::MnistLike => Self {
                kind,
                dim: 784,
                classes: 10,
                n_train: 8192,
                n_test: 2048,
                sep: 3.2,
                noise: 1.0,
                warp: 0.15,
                label_noise: 0.01,
            },
            DatasetKind::FashionLike => Self {
                kind,
                dim: 784,
                classes: 10,
                n_train: 8192,
                n_test: 2048,
                sep: 2.0,
                noise: 1.0,
                warp: 0.25,
                label_noise: 0.03,
            },
            DatasetKind::Cifar10Like => Self {
                kind,
                dim: 3072,
                classes: 10,
                n_train: 4096,
                n_test: 1024,
                sep: 1.3,
                noise: 1.0,
                warp: 0.35,
                label_noise: 0.05,
            },
            DatasetKind::Cifar100Like => Self {
                kind,
                dim: 3072,
                classes: 100,
                n_train: 4096,
                n_test: 1024,
                sep: 1.2,
                noise: 1.0,
                warp: 0.35,
                label_noise: 0.06,
            },
        }
    }

    /// Override the split sizes (tests use small ones).
    pub fn with_sizes(mut self, n_train: usize, n_test: usize) -> Self {
        self.n_train = n_train;
        self.n_test = n_test;
        self
    }

    /// Materialise the dataset; everything is a pure function of `seed`.
    pub fn build(&self, seed: u64) -> Dataset {
        let root = Rng::new(seed ^ 0xDA7A_5E7);
        let mut centre_rng = root.child(1);
        let mut sample_rng = root.child(2);
        let mut label_rng = root.child(3);

        // Class centres: scale so expected pairwise distance ≈ sep·√2.
        let centre_scale = self.sep / (self.dim as f32).sqrt();
        let mut centres = vec![0.0f32; self.classes * self.dim];
        centre_rng.fill_normal(&mut centres, 0.0, centre_scale);

        let gen_split = |rng: &mut Rng, lrng: &mut Rng, n: usize, flip: f32| {
            let mut x = vec![0.0f32; n * self.dim];
            let mut y = Vec::with_capacity(n);
            for i in 0..n {
                let c = rng.below(self.classes);
                let row = &mut x[i * self.dim..(i + 1) * self.dim];
                let centre = &centres[c * self.dim..(c + 1) * self.dim];
                for (v, &m) in row.iter_mut().zip(centre.iter()) {
                    let e = rng.normal() as f32;
                    *v = m + self.noise * e + self.warp * (e * e - 1.0);
                }
                let label = if flip > 0.0 && lrng.uniform() < flip as f64 {
                    lrng.below(self.classes) as i32
                } else {
                    c as i32
                };
                y.push(label);
            }
            (x, y)
        };

        let (train_x, train_y) =
            gen_split(&mut sample_rng, &mut label_rng, self.n_train, self.label_noise);
        let (test_x, test_y) = gen_split(&mut sample_rng, &mut label_rng, self.n_test, 0.0);

        Dataset {
            name: self.kind.name().to_string(),
            dim: self.dim,
            classes: self.classes,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let cfg = SynthConfig::preset(DatasetKind::Tiny);
        let a = cfg.build(5);
        let b = cfg.build(5);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.train_y, b.train_y);
        let c = cfg.build(6);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn shapes_and_labels_in_range() {
        let ds = SynthConfig::preset(DatasetKind::MnistLike)
            .with_sizes(256, 64)
            .build(0);
        assert_eq!(ds.train_x.len(), 256 * 784);
        assert_eq!(ds.test_y.len(), 64);
        assert!(ds.train_y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn classes_roughly_balanced() {
        let ds = SynthConfig::preset(DatasetKind::FashionLike)
            .with_sizes(5000, 100)
            .build(2);
        let h = ds.train_class_histogram();
        for &c in &h {
            assert!((c as f64 - 500.0).abs() < 150.0, "{h:?}");
        }
    }

    #[test]
    fn separation_orders_difficulty() {
        // Nearest-centroid train accuracy should order: mnist > fashion > cifar10.
        fn centroid_acc(kind: DatasetKind) -> f64 {
            let ds = SynthConfig::preset(kind).with_sizes(512, 256).build(9);
            // Estimate class centroids from train, classify test.
            let mut centroids = vec![0.0f64; ds.classes * ds.dim];
            let mut counts = vec![0usize; ds.classes];
            for i in 0..ds.n_train() {
                let c = ds.train_y[i] as usize;
                counts[c] += 1;
                for (k, &v) in ds.train_row(i).iter().enumerate() {
                    centroids[c * ds.dim + k] += v as f64;
                }
            }
            for c in 0..ds.classes {
                if counts[c] > 0 {
                    for k in 0..ds.dim {
                        centroids[c * ds.dim + k] /= counts[c] as f64;
                    }
                }
            }
            let mut correct = 0;
            for i in 0..ds.n_test() {
                let row = &ds.test_x[i * ds.dim..(i + 1) * ds.dim];
                let mut best = (f64::INFINITY, 0usize);
                for c in 0..ds.classes {
                    let d: f64 = row
                        .iter()
                        .enumerate()
                        .map(|(k, &v)| (v as f64 - centroids[c * ds.dim + k]).powi(2))
                        .sum();
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                if best.1 as i32 == ds.test_y[i] {
                    correct += 1;
                }
            }
            correct as f64 / ds.n_test() as f64
        }
        let m = centroid_acc(DatasetKind::MnistLike);
        let f = centroid_acc(DatasetKind::FashionLike);
        let c10 = centroid_acc(DatasetKind::Cifar10Like);
        assert!(m > f && f > c10, "m={m} f={f} c10={c10}");
        assert!(m > 0.65, "mnist-like should be easy, got {m}");
    }
}
