//! Sample-order machinery — the paper's §3.4 and Algorithm 2.
//!
//! Three pieces:
//!
//! * [`RecordWindow`] — `RecordIndex(D, m, c, τ)` (Algorithm 2, Function 1):
//!   which iterations inside a communication period have their loss
//!   recorded for the weight estimate. The m records are spread across c
//!   blocks (the last m/c iterations of each τ/c block), the paper's
//!   "assignment distribution" of Eq. (26) that samples the trajectory in
//!   time instead of only at the boundary.
//! * [`OrderState`] — per-worker seeds + scores for the n order parts.
//!   `OrderGen` (Function 2): a part whose score satisfied the judgment
//!   (≤ −1, i.e. better than ~84% of the cohort under the normality
//!   assumption) keeps its shuffle seed for the next epoch; otherwise the
//!   seed is redrawn. A sample order is therefore a pure function of the
//!   seed, which is what lets "good orders" survive.
//! * [`delta_blocked_order`] — the Fig. 3 workload generator: an order in
//!   which δ consecutive samples share a label (δ=1 ≈ fully interleaved,
//!   δ=1000 ≈ sorted by label).

use crate::rng::Rng;

/// Which iterations (k = 0-based index inside a communication period of
/// length τ) get their per-batch loss recorded into the estimation window.
#[derive(Clone, Copy, Debug)]
pub struct RecordWindow {
    /// Communication period length τ.
    pub tau: usize,
    /// Total recorded iterations per period (the paper's m).
    pub m: usize,
    /// Number of blocks the records are spread over (the paper's c).
    pub c: usize,
}

impl RecordWindow {
    /// Construct, clamping to feasible values: 1 ≤ c ≤ m ≤ τ.
    pub fn new(tau: usize, m: usize, c: usize) -> Self {
        let tau = tau.max(1);
        let m = m.clamp(1, tau);
        let c = c.clamp(1, m).min(tau);
        Self { tau, m, c }
    }

    /// End (exclusive) of block `b` — blocks tile [0, τ) proportionally.
    fn block_end(&self, b: usize) -> usize {
        ((b + 1) * self.tau) / self.c
    }

    /// Records assigned to block `b` — quotas tile m proportionally, so
    /// they sum to exactly m over the c blocks.
    fn quota(&self, b: usize) -> usize {
        ((b + 1) * self.m) / self.c - (b * self.m) / self.c
    }

    /// Is iteration `k` (0-based, k ∈ [0, τ)) recorded?
    ///
    /// True for the last `quota(b)` iterations of each block — the
    /// paper's Eq. (26) assignment distribution (tail samples approximate
    /// the boundary loss best). Intervals are packed right-to-left: when
    /// a block's quota exceeds its length (τ and m both barely above c),
    /// the interval spills into the preceding block's free tail instead
    /// of overlapping, so **exactly m** iterations per period are
    /// recorded for every clamped (τ, m, c) — see `recorded_count`.
    pub fn is_recorded(&self, k: usize) -> bool {
        let k = k % self.tau;
        let mut hi = self.tau;
        for b in (0..self.c).rev() {
            let end = self.block_end(b).min(hi);
            let start = end - self.quota(b);
            if (start..end).contains(&k) {
                return true;
            }
            hi = start;
        }
        false
    }

    /// Exact number of recorded iterations per period: always m (the
    /// clamped value). `Σ_{k<τ} is_recorded(k) == recorded_count()` is
    /// asserted property-style in `tests/proptests.rs`.
    pub fn recorded_count(&self) -> usize {
        self.m
    }

    /// How many iterations in one period are recorded, counted the slow
    /// way (test oracle for [`RecordWindow::recorded_count`]).
    pub fn count_per_period(&self) -> usize {
        (0..self.tau).filter(|&k| self.is_recorded(k)).count()
    }
}

/// Per-worker order state: the paper's `Scores`, `Seed` arrays plus the
/// accept/reject rule of `OrderGen`.
#[derive(Clone, Debug)]
pub struct OrderState {
    /// Training samples covered by the order.
    pub n_samples: usize,
    /// Number of order parts n (Algorithm 1).
    pub n_parts: usize,
    seeds: Vec<u64>,
    scores: Vec<f32>,
    fresh: Rng,
    /// Count of parts that kept their seed across epochs (telemetry).
    pub kept: u64,
    /// Count of parts that redrew (telemetry).
    pub redrawn: u64,
}

/// Paper's judgment threshold: keep an order whose z-score ≤ −1
/// (better than ≈84% of the cohort under normality).
pub const JUDGE_THRESHOLD: f32 = -1.0;

impl OrderState {
    /// Fresh state: every part starts "bad" so epoch 0 shuffles fresh.
    pub fn new(n_samples: usize, n_parts: usize, seed: u64) -> Self {
        let n_parts = n_parts.clamp(1, n_samples.max(1));
        let mut fresh = Rng::new(seed ^ 0x0bde_05ee_d5);
        let seeds = (0..n_parts).map(|_| fresh.next_u64()).collect();
        Self {
            n_samples,
            n_parts,
            seeds,
            // Start "bad" so the first epoch always shuffles fresh.
            scores: vec![f32::INFINITY; n_parts],
            fresh,
            kept: 0,
            redrawn: 0,
        }
    }

    /// Length of order part `l` (last part absorbs the remainder).
    pub fn part_len(&self, part: usize) -> usize {
        let base = self.n_samples / self.n_parts;
        if part + 1 == self.n_parts {
            self.n_samples - base * (self.n_parts - 1)
        } else {
            base
        }
    }

    /// Global index offset of part `l`.
    pub fn part_offset(&self, part: usize) -> usize {
        (self.n_samples / self.n_parts) * part
    }

    /// The paper's `OrderGen`: keep the seed iff the recorded score
    /// satisfied the judgment, then emit the permutation *of global
    /// sample indices* for this part.
    pub fn order_for_part(&mut self, part: usize) -> Vec<u32> {
        assert!(part < self.n_parts);
        if self.scores[part] > JUDGE_THRESHOLD {
            self.seeds[part] = self.fresh.next_u64();
            self.redrawn += 1;
        } else {
            self.kept += 1;
        }
        let mut rng = Rng::new(self.seeds[part]);
        let off = self.part_offset(part) as u32;
        let mut perm = rng.permutation(self.part_len(part));
        for v in perm.iter_mut() {
            *v += off;
        }
        perm
    }

    /// Record the score produced by `Judge` at the end of part `l`.
    pub fn record_score(&mut self, part: usize, score: f32) {
        self.scores[part] = score;
    }

    /// Current seed of a part (test hook).
    pub fn seed_of(&self, part: usize) -> u64 {
        self.seeds[part]
    }
}

/// `Judge` (Algorithm 2, Function 3): z-score of worker i's loss energy
/// against the cohort. Negative = better than average.
pub fn judge(h: &[f32], i: usize) -> f32 {
    let ave = crate::linalg::mean(h);
    let stdv = crate::linalg::stddev(h);
    if stdv <= f64::EPSILON {
        return 0.0;
    }
    ((h[i] as f64 - ave) / stdv) as f32
}

/// Build a sample order where δ consecutive samples share a label — the
/// Fig. 3 order-effect workload. δ=1 interleaves labels maximally;
/// δ→n/classes degenerates to label-sorted order.
pub fn delta_blocked_order(labels: &[i32], delta: usize, rng: &mut Rng) -> Vec<u32> {
    let delta = delta.max(1);
    let classes = labels.iter().map(|&y| y as usize + 1).max().unwrap_or(1);
    let mut pools: Vec<Vec<u32>> = vec![Vec::new(); classes];
    for (i, &y) in labels.iter().enumerate() {
        pools[y as usize].push(i as u32);
    }
    for pool in pools.iter_mut() {
        rng.shuffle(pool);
    }
    let mut cursors = vec![0usize; classes];
    let mut out = Vec::with_capacity(labels.len());
    let mut live: Vec<usize> = (0..classes).filter(|&c| !pools[c].is_empty()).collect();
    while !live.is_empty() {
        // Pick a random class that still has samples, emit up to δ of them.
        let pick = live[rng.below(live.len())];
        let start = cursors[pick];
        let take = delta.min(pools[pick].len() - start);
        out.extend_from_slice(&pools[pick][start..start + take]);
        cursors[pick] += take;
        if cursors[pick] == pools[pick].len() {
            live.retain(|&c| c != pick);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_window_counts() {
        // τ=1000, m=100, c=4: 25 records in each of 4 blocks of 250.
        let w = RecordWindow::new(1000, 100, 4);
        assert_eq!(w.count_per_period(), 100);
        assert!(w.is_recorded(249));
        assert!(w.is_recorded(225));
        assert!(!w.is_recorded(224));
        assert!(!w.is_recorded(0));
        assert!(w.is_recorded(999));
    }

    #[test]
    fn record_window_c1_is_tail() {
        // c=1 reduces to WASGD's "last m iterations" (Algorithm 3: k ≥ τ−m).
        let w = RecordWindow::new(50, 10, 1);
        for k in 0..50 {
            assert_eq!(w.is_recorded(k), k >= 40, "k={k}");
        }
    }

    #[test]
    fn record_window_clamps() {
        let w = RecordWindow::new(10, 100, 7);
        assert_eq!(w.m, 10);
        assert_eq!(w.recorded_count(), 10);
        assert_eq!(w.count_per_period(), 10);
    }

    #[test]
    fn record_window_exact_when_quota_spills() {
        // τ=8, m=7, c=5: block 2 spans [3,4) but owes 2 records — the
        // naive per-block tail would overlap and under-record; the
        // right-packed intervals must still record exactly m.
        let w = RecordWindow::new(8, 7, 5);
        assert_eq!(w.count_per_period(), w.recorded_count());
        assert_eq!(w.recorded_count(), 7);
    }

    #[test]
    fn order_state_keeps_good_seed() {
        let mut st = OrderState::new(100, 4, 1);
        let first = st.order_for_part(0); // score=∞ ⇒ redraw
        let seed_after = st.seed_of(0);
        st.record_score(0, -1.5); // good ⇒ keep
        let second = st.order_for_part(0);
        assert_eq!(st.seed_of(0), seed_after);
        assert_eq!(first.len(), second.len());
        st.record_score(0, 0.3); // bad ⇒ redraw
        st.order_for_part(0);
        assert_ne!(st.seed_of(0), seed_after);
    }

    #[test]
    fn order_covers_part_exactly() {
        let mut st = OrderState::new(103, 4, 2);
        for part in 0..4 {
            let mut o = st.order_for_part(part);
            o.sort_unstable();
            let off = st.part_offset(part) as u32;
            let len = st.part_len(part) as u32;
            assert_eq!(o, (off..off + len).collect::<Vec<_>>());
        }
        // Parts tile the dataset.
        assert_eq!((0..4).map(|p| st.part_len(p)).sum::<usize>(), 103);
    }

    #[test]
    fn judge_zscore() {
        let h = [1.0, 2.0, 3.0, 4.0];
        let s = judge(&h, 0);
        assert!(s < 0.0);
        let s_hi = judge(&h, 3);
        assert!(s_hi > 0.0);
        assert!((judge(&[2.0, 2.0, 2.0], 1)).abs() < 1e-6);
    }

    #[test]
    fn delta_order_is_permutation() {
        let labels: Vec<i32> = (0..500).map(|i| (i % 10) as i32).collect();
        let mut rng = Rng::new(3);
        for delta in [1usize, 10, 100, 1000] {
            let mut o = delta_blocked_order(&labels, delta, &mut rng);
            o.sort_unstable();
            assert_eq!(o, (0..500u32).collect::<Vec<_>>(), "delta={delta}");
        }
    }

    #[test]
    fn delta_order_block_structure() {
        let labels: Vec<i32> = (0..1000).map(|i| (i % 10) as i32).collect();
        let mut rng = Rng::new(4);
        let o = delta_blocked_order(&labels, 50, &mut rng);
        // Average same-label run length should be close to δ.
        let mut runs = Vec::new();
        let mut len = 1;
        for i in 1..o.len() {
            if labels[o[i] as usize] == labels[o[i - 1] as usize] {
                len += 1;
            } else {
                runs.push(len);
                len = 1;
            }
        }
        runs.push(len);
        let avg = runs.iter().sum::<usize>() as f64 / runs.len() as f64;
        assert!(avg > 25.0, "avg run {avg}");
        // And δ=1 should interleave much more.
        let o1 = delta_blocked_order(&labels, 1, &mut rng);
        let switches = (1..o1.len())
            .filter(|&i| labels[o1[i] as usize] != labels[o1[i - 1] as usize])
            .count();
        assert!(switches > o1.len() * 7 / 10, "switches={switches}");
    }
}
