//! Micro-benchmark harness — substrate replacing `criterion` in the
//! offline build. Provides warm-up, calibrated iteration counts, robust
//! statistics (median + MAD), and a criterion-like report format so
//! `cargo bench` output stays familiar.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>,
    pub median_s: f64,
    pub mad_s: f64,
    pub mean_s: f64,
    pub iters_per_sample: u64,
}

impl BenchStats {
    pub fn throughput_per_s(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{:>10}]  ±{:>9}  ({} samples × {} iters, {:.1}/s)",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
            self.samples.len(),
            self.iters_per_sample,
            self.throughput_per_s()
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A criterion-flavoured bench runner.
pub struct Bencher {
    /// Target time per measurement phase.
    pub measure_time: Duration,
    pub warmup_time: Duration,
    pub sample_count: usize,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Honour the conventional quick-mode env var.
        let quick = std::env::var("WASGD_BENCH_QUICK").is_ok();
        Self {
            measure_time: Duration::from_millis(if quick { 200 } else { 1500 }),
            warmup_time: Duration::from_millis(if quick { 50 } else { 300 }),
            sample_count: if quick { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // Warm-up + calibration: how many iters fit in one sample slot?
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let slot = self.measure_time.as_secs_f64() / self.sample_count as f64;
        let iters = ((slot / per_iter.max(1e-12)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let mut devs: Vec<f64> = sorted.iter().map(|&v| (v - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;

        let stats = BenchStats {
            name: name.to_string(),
            samples,
            median_s: median,
            mad_s: mad,
            mean_s: mean,
            iters_per_sample: iters,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn summary(&self, title: &str) {
        println!("\n== {title} ==");
        for r in &self.results {
            println!("  {:<44} {:>12}", r.name, fmt_time(r.median_s));
        }
    }
}

/// Prevent the optimiser from discarding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("WASGD_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.measure_time = Duration::from_millis(30);
        b.warmup_time = Duration::from_millis(5);
        b.sample_count = 3;
        let mut acc = 0u64;
        let st = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(st.median_s > 0.0);
        assert!(st.median_s < 1e-3);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with("s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
