//! Micro-benchmark harness — substrate replacing `criterion` in the
//! offline build. Provides warm-up, calibrated iteration counts, robust
//! statistics (median + MAD), a criterion-like report format so
//! `cargo bench` output stays familiar, and the repo's *persisted perf
//! trajectory*: every bench binary appends its stats to
//! `BENCH_native.json` at the repo root via [`append_bench_json`], one
//! run record per (suite, git rev), so successive PRs accumulate a
//! machine-readable speed history.
//!
//! Quick mode (`--quick` on the bench binaries, or the
//! `WASGD_BENCH_QUICK` env var) shrinks warm-up/measure budgets so a
//! whole suite finishes in a couple of seconds — what CI's bench-smoke
//! job runs before uploading the JSON as an artifact.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::json::Json;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Benchmark name (unique within a suite).
    pub name: String,
    /// Raw wall-seconds of each measured sample. Every sample runs
    /// `iters_per_sample` units of work, so these are *per-sample*
    /// times — the per-iteration statistics below divide by
    /// `iters_per_sample` exactly once.
    pub samples: Vec<f64>,
    /// Median seconds per *iteration* (one unit of work).
    pub median_s: f64,
    /// Median absolute deviation, per iteration.
    pub mad_s: f64,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Loop iterations folded into each raw sample.
    pub iters_per_sample: u64,
    /// Intra-op thread budget the benched code ran with (1 when the
    /// knob does not apply).
    pub threads: usize,
}

impl BenchStats {
    /// Units of work per second. `samples` hold per-*sample* times
    /// covering `iters_per_sample` iterations each, so the sample median
    /// must be divided by `iters_per_sample` before inverting (done once
    /// when `median_s` is computed) — inverting the raw sample median
    /// would report per-sample throughput, under-counting ops/s by a
    /// factor of `iters_per_sample`.
    pub fn throughput_per_s(&self) -> f64 {
        if self.median_s > 0.0 {
            1.0 / self.median_s
        } else {
            f64::INFINITY
        }
    }

    /// One human-readable summary line.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{:>10}]  ±{:>9}  ({} samples × {} iters, {:.1}/s)",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
            self.samples.len(),
            self.iters_per_sample,
            self.throughput_per_s()
        )
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// A criterion-flavoured bench runner.
pub struct Bencher {
    /// Target time per measurement phase.
    pub measure_time: Duration,
    /// Warm-up duration before sampling starts.
    pub warmup_time: Duration,
    /// Number of samples collected per benchmark.
    pub sample_count: usize,
    quick: bool,
    results: Vec<BenchStats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Honour the conventional quick-mode env var; bench binaries OR it
    /// with their `--quick` flag via [`Bencher::with_quick`].
    pub fn new() -> Self {
        Self::with_quick(Self::env_quick())
    }

    /// Is the `WASGD_BENCH_QUICK` env var set?
    pub fn env_quick() -> bool {
        std::env::var_os("WASGD_BENCH_QUICK").is_some()
    }

    /// Explicit quick-mode selection (`--quick` CLI flag). Quick budgets
    /// keep a whole suite under ~2 s — the CI smoke configuration.
    pub fn with_quick(quick: bool) -> Self {
        Self {
            measure_time: Duration::from_millis(if quick { 60 } else { 1500 }),
            warmup_time: Duration::from_millis(if quick { 15 } else { 300 }),
            sample_count: if quick { 3 } else { 15 },
            quick,
            results: Vec::new(),
        }
    }

    /// Is this bencher in quick (smoke) mode?
    pub fn is_quick(&self) -> bool {
        self.quick
    }

    /// Benchmark `f`, which performs ONE unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchStats {
        self.bench_with_threads(name, 1, f)
    }

    /// Benchmark `f` and tag the stats with the intra-op thread budget
    /// it ran under (recorded into the `BENCH_native.json` entries).
    pub fn bench_with_threads<F: FnMut()>(
        &mut self,
        name: &str,
        threads: usize,
        mut f: F,
    ) -> &BenchStats {
        // Warm-up + calibration: how many iters fit in one sample slot?
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time {
            f();
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters.max(1) as f64;
        let slot = self.measure_time.as_secs_f64() / self.sample_count as f64;
        let iters = ((slot / per_iter.max(1e-12)) as u64).clamp(1, 1_000_000);

        // Samples are raw per-sample wall times; the per-iteration
        // statistics divide by `iters` exactly once, below.
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median_sample = sorted[sorted.len() / 2];
        let mut devs: Vec<f64> = sorted.iter().map(|&v| (v - median_sample).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad_sample = devs[devs.len() / 2];
        let mean_sample = samples.iter().sum::<f64>() / samples.len() as f64;

        let scale = 1.0 / iters as f64;
        let stats = BenchStats {
            name: name.to_string(),
            samples,
            median_s: median_sample * scale,
            mad_s: mad_sample * scale,
            mean_s: mean_sample * scale,
            iters_per_sample: iters,
            threads,
        };
        println!("{}", stats.report());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All stats collected so far, in bench order.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn summary(&self, title: &str) {
        println!("\n== {title} ==");
        for r in &self.results {
            println!("  {:<44} {:>12}", r.name, fmt_time(r.median_s));
        }
    }
}

/// Prevent the optimiser from discarding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Short git revision of the working tree, or `"unknown"` outside a git
/// checkout — tags every `BENCH_native.json` run record so the perf
/// trajectory is attributable PR by PR.
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// `BENCH_native.json` at the repo root: the nearest ancestor of the
/// current directory containing `.git` (bench binaries run from the
/// crate dir, one level down), falling back to the current directory.
pub fn bench_json_path() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join(".git").exists() {
            return dir.join("BENCH_native.json");
        }
        if !dir.pop() {
            return PathBuf::from("BENCH_native.json");
        }
    }
}

/// Append one suite's stats to the perf-trajectory file.
///
/// Schema (`schema: 1`): `{ schema, runs: [ { suite, git_rev, quick,
/// entries: [ { name, median_s, mad_s, iters, threads,
/// throughput_per_s } ] } ] }`. Re-running the same suite at the same
/// revision *and the same quick flag* replaces its record (benches are
/// idempotent per configuration) — a `--quick` smoke never clobbers a
/// precise full-run record at the same rev, or vice versa. Records from
/// other suites, revisions and modes are preserved, which is what turns
/// the file into a speed *history* across PRs; when no git revision can
/// be resolved (`"unknown"`), records only accumulate, never replace,
/// so a git-less environment cannot silently erase history spanning
/// unidentifiable revisions. An unreadable or
/// unparseable existing file is replaced rather than an error — the
/// trajectory must never block a bench run.
pub fn append_bench_json(
    path: &Path,
    suite: &str,
    quick: bool,
    stats: &[BenchStats],
) -> Result<()> {
    use std::collections::BTreeMap;
    let rev = git_rev();

    let mut runs: Vec<Json> = Vec::new();
    if let Ok(existing) = std::fs::read_to_string(path) {
        if let Ok(doc) = Json::parse(&existing) {
            if let Some(old) = doc.get("runs").and_then(|r| r.as_arr()) {
                for run in old {
                    // Replacement needs a real revision: with git
                    // unresolvable every run would tag "unknown" and
                    // silently erase the history it is meant to extend,
                    // so "unknown" records always accumulate.
                    let same = rev != "unknown"
                        && run.get("suite").and_then(|s| s.as_str()) == Some(suite)
                        && run.get("git_rev").and_then(|s| s.as_str()) == Some(rev.as_str())
                        && run.get("quick") == Some(&Json::Bool(quick));
                    if !same {
                        runs.push(run.clone());
                    }
                }
            }
        }
    }

    let entries: Vec<Json> = stats
        .iter()
        .map(|s| {
            let mut e = BTreeMap::new();
            e.insert("name".to_string(), Json::Str(s.name.clone()));
            e.insert("median_s".to_string(), Json::Num(s.median_s));
            e.insert("mad_s".to_string(), Json::Num(s.mad_s));
            e.insert("iters".to_string(), Json::Num(s.iters_per_sample as f64));
            e.insert("threads".to_string(), Json::Num(s.threads as f64));
            e.insert("throughput_per_s".to_string(), Json::Num(s.throughput_per_s()));
            Json::Obj(e)
        })
        .collect();
    let mut run = BTreeMap::new();
    run.insert("suite".to_string(), Json::Str(suite.to_string()));
    run.insert("git_rev".to_string(), Json::Str(rev));
    run.insert("quick".to_string(), Json::Bool(quick));
    run.insert("entries".to_string(), Json::Arr(entries));
    runs.push(Json::Obj(run));

    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::Num(1.0));
    doc.insert("runs".to_string(), Json::Arr(runs));
    std::fs::write(path, Json::Obj(doc).serialize())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_bencher() -> Bencher {
        let mut b = Bencher::with_quick(true);
        b.measure_time = Duration::from_millis(30);
        b.warmup_time = Duration::from_millis(5);
        b.sample_count = 3;
        b
    }

    #[test]
    fn bench_measures_something() {
        let mut b = tiny_bencher();
        assert!(b.is_quick());
        let mut acc = 0u64;
        let st = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(st.median_s > 0.0);
        assert!(st.median_s < 1e-3);
        assert_eq!(st.threads, 1);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn per_iteration_stats_divide_raw_samples_once() {
        // The throughput-accounting contract: `samples` are raw
        // per-sample times, `median_s` is the sample median over
        // `iters_per_sample`, and ops/s inverts the per-iteration value
        // (inverting the raw sample median would undercount by ×iters).
        let mut b = tiny_bencher();
        let mut acc = 0u64;
        let st = b.bench("accounting", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let mut sorted = st.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let raw_median = sorted[sorted.len() / 2];
        let per_iter = raw_median / st.iters_per_sample as f64;
        assert!((st.median_s - per_iter).abs() <= 1e-12 * per_iter.max(1.0));
        assert!((st.throughput_per_s() - 1.0 / per_iter).abs() <= 1e-6 * (1.0 / per_iter));
        // This workload is far sub-microsecond: many iters per sample,
        // so the two interpretations differ by orders of magnitude.
        assert!(st.iters_per_sample > 10);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with("s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }

    #[test]
    fn quick_bench_emits_well_formed_trajectory_json() {
        // The bench-smoke contract: a quick run writes BENCH_native.json
        // with the documented schema, same-rev reruns replace their
        // suite's record, and other suites accumulate.
        let mut b = tiny_bencher();
        let mut acc = 0u64;
        b.bench_with_threads("smoke kernel t=2", 2, || {
            acc = black_box(acc.wrapping_add(1));
        });

        let dir = std::env::temp_dir().join(format!("wasgd_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_native.json");
        let _ = std::fs::remove_file(&path);

        append_bench_json(&path, "smoke", true, b.results()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.req_usize("schema").unwrap(), 1);
        let runs = doc.req_arr("runs").unwrap();
        assert_eq!(runs.len(), 1);
        let run = &runs[0];
        assert_eq!(run.req_str("suite").unwrap(), "smoke");
        assert!(!run.req_str("git_rev").unwrap().is_empty());
        assert_eq!(run.get("quick"), Some(&Json::Bool(true)));
        let entries = run.req_arr("entries").unwrap();
        assert_eq!(entries.len(), 1);
        let e = &entries[0];
        assert_eq!(e.req_str("name").unwrap(), "smoke kernel t=2");
        assert_eq!(e.req_usize("threads").unwrap(), 2);
        assert!(e.get("median_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(e.get("mad_s").and_then(|v| v.as_f64()).is_some());
        assert!(e.req_usize("iters").unwrap() >= 1);
        assert!(e.get("throughput_per_s").and_then(|v| v.as_f64()).unwrap() > 0.0);

        // Replacement semantics need a resolvable git rev ("unknown"
        // records always accumulate so a git-less env can't erase
        // history); the repo's own test run always has one.
        if git_rev() != "unknown" {
            // Same suite + same rev + same mode → replaced, not duplicated.
            append_bench_json(&path, "smoke", true, b.results()).unwrap();
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            assert_eq!(doc.req_arr("runs").unwrap().len(), 1);

            // A different suite accumulates alongside.
            append_bench_json(&path, "smoke2", false, b.results()).unwrap();
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            assert_eq!(doc.req_arr("runs").unwrap().len(), 2);

            // A full (non-quick) run of the same suite at the same rev
            // does NOT clobber the quick record — mode is part of the
            // identity.
            append_bench_json(&path, "smoke", false, b.results()).unwrap();
            let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
            assert_eq!(doc.req_arr("runs").unwrap().len(), 3);
        }

        let _ = std::fs::remove_dir_all(&dir);
    }
}
