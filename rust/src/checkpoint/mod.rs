//! Checkpointing: durable snapshots of a training run.
//!
//! A checkpoint is a directory with one `state.json` (run metadata: the
//! experiment label, iteration, epoch, per-worker seeds, sim clock) and
//! one `worker_{i}.f32` flat little-endian parameter file per worker.
//! The format is deliberately dumb — `xxd`-able, python-readable with
//! `np.fromfile(..., '<f4')` — so checkpoints double as an interchange
//! format with the build-time python side.
//!
//! Elastic sessions (`--elastic`, see `docs/FABRIC.md`) reuse the same
//! format for their **epoch anchors**: at every membership boundary the
//! rendezvous snapshots the committed cohort panels to
//! `<ckpt-dir>/epoch_NNNN/` before re-forming, so a crashed session can
//! be resumed — as a fixed cohort — from the last boundary it survived.
//! The anchor's cohort digest also rides the journal's `EpochCommitted`
//! record, which is how `wasgd replay --verify` chains epochs together.

use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Json;

/// Everything needed to resume (or inspect) a run.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Run label (algorithm + geometry).
    pub label: String,
    /// Local iterations completed per worker.
    pub iteration: u64,
    /// Epochs completed (fractional).
    pub epoch: f64,
    /// Simulated cluster seconds at snapshot time.
    pub sim_time_s: f64,
    /// Flat parameter vector per worker.
    pub workers: Vec<Vec<f32>>,
}

impl Checkpoint {
    /// Write to `dir` (created if needed). Atomic per file: written to a
    /// `.tmp` sibling then renamed, so a crash never leaves a torn
    /// checkpoint behind.
    pub fn save(&self, dir: &Path) -> Result<()> {
        fs::create_dir_all(dir).with_context(|| format!("mkdir {}", dir.display()))?;
        // Parameters first, meta last: an existing state.json implies
        // complete parameter files.
        for (i, params) in self.workers.iter().enumerate() {
            let path = dir.join(format!("worker_{i}.f32"));
            let tmp = dir.join(format!("worker_{i}.f32.tmp"));
            {
                let mut f = fs::File::create(&tmp)?;
                let bytes: Vec<u8> =
                    params.iter().flat_map(|v| v.to_le_bytes()).collect();
                f.write_all(&bytes)?;
                f.sync_all()?;
            }
            fs::rename(&tmp, &path)?;
        }
        let meta = format!(
            r#"{{"label": {:?}, "iteration": {}, "epoch": {}, "sim_time_s": {}, "p": {}, "d": {}}}"#,
            self.label,
            self.iteration,
            self.epoch,
            self.sim_time_s,
            self.workers.len(),
            self.workers.first().map(|w| w.len()).unwrap_or(0),
        );
        let tmp = dir.join("state.json.tmp");
        fs::write(&tmp, meta)?;
        fs::rename(tmp, dir.join("state.json"))?;
        Ok(())
    }

    /// Load a checkpoint written by [`Checkpoint::save`].
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("state.json");
        let body = fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&body).map_err(|e| anyhow::anyhow!("{e}"))?;
        let p = j.req_usize("p")?;
        let d = j.req_usize("d")?;
        let mut workers = Vec::with_capacity(p);
        for i in 0..p {
            let path = dir.join(format!("worker_{i}.f32"));
            let mut bytes = Vec::new();
            fs::File::open(&path)
                .with_context(|| format!("opening {}", path.display()))?
                .read_to_end(&mut bytes)?;
            anyhow::ensure!(
                bytes.len() == d * 4,
                "{}: expected {} bytes, found {}",
                path.display(),
                d * 4,
                bytes.len()
            );
            let params: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            workers.push(params);
        }
        Ok(Self {
            label: j.req_str("label")?.to_string(),
            iteration: j.req_usize("iteration")? as u64,
            epoch: j
                .get("epoch")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("epoch missing"))?,
            sim_time_s: j
                .get("sim_time_s")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("sim_time_s missing"))?,
            workers,
        })
    }
}

/// Scan `dir` for epoch-anchor subdirectories (`epoch_NNNN/`, written by
/// an elastic rendezvous at every commit boundary) and return the
/// highest-numbered one as `(epoch_index, path)`. `Ok(None)` when the
/// directory is missing or holds no anchors — callers decide whether
/// that is an error.
pub fn latest_epoch_anchor(dir: &Path) -> Result<Option<(u64, PathBuf)>> {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(None),
    };
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in entries {
        let entry = entry.with_context(|| format!("scanning {}", dir.display()))?;
        let name = entry.file_name();
        let Some(idx) = name
            .to_str()
            .and_then(|n| n.strip_prefix("epoch_"))
            .and_then(|n| n.parse::<u64>().ok())
        else {
            continue;
        };
        if best.as_ref().is_none_or(|(b, _)| idx > *b) {
            best = Some((idx, entry.path()));
        }
    }
    Ok(best)
}

/// Resolve `--resume DIR` to a checkpoint: a plain checkpoint directory
/// (`state.json` present) loads directly; otherwise the directory is
/// treated as an elastic session's anchor root and the **latest**
/// `epoch_NNNN/` anchor inside it is loaded. Errors name both shapes so
/// a typo'd path gets a pointed message rather than a bare ENOENT.
pub fn load_resume_dir(dir: &Path) -> Result<Checkpoint> {
    if dir.join("state.json").is_file() {
        return Checkpoint::load(dir);
    }
    match latest_epoch_anchor(dir)? {
        Some((idx, path)) => Checkpoint::load(&path)
            .with_context(|| format!("loading epoch anchor {idx} from {}", path.display())),
        None => anyhow::bail!(
            "{}: neither a checkpoint (no state.json) nor an elastic anchor root \
             (no epoch_NNNN/ subdirectories)",
            dir.display()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("wasgd_ckpt_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            label: "wasgd+ p=2".into(),
            iteration: 512,
            epoch: 2.0,
            sim_time_s: 3.25,
            workers: vec![vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE], vec![9.5, 0.25, -1.0, 7.0]],
        }
    }

    #[test]
    fn roundtrip_exact() {
        let dir = tmpdir("rt");
        let ck = sample();
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(ck, back);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_is_idempotent_overwrite() {
        let dir = tmpdir("ow");
        let mut ck = sample();
        ck.save(&dir).unwrap();
        ck.iteration = 1024;
        ck.workers[0][0] = 42.0;
        ck.save(&dir).unwrap();
        let back = Checkpoint::load(&dir).unwrap();
        assert_eq!(back.iteration, 1024);
        assert_eq!(back.workers[0][0], 42.0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_truncated_params() {
        let dir = tmpdir("trunc");
        sample().save(&dir).unwrap();
        // Truncate one worker file.
        let path = dir.join("worker_1.f32");
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();
        assert!(Checkpoint::load(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(Checkpoint::load(Path::new("/nonexistent/wasgd")).is_err());
    }

    #[test]
    fn latest_epoch_anchor_picks_the_highest_index() {
        let dir = tmpdir("anchors");
        assert_eq!(latest_epoch_anchor(&dir).unwrap(), None, "missing dir is not an error");
        sample().save(&dir.join("epoch_0001")).unwrap();
        sample().save(&dir.join("epoch_0003")).unwrap();
        sample().save(&dir.join("epoch_0002")).unwrap();
        fs::create_dir_all(dir.join("not_an_anchor")).unwrap();
        let (idx, path) = latest_epoch_anchor(&dir).unwrap().expect("anchors present");
        assert_eq!(idx, 3);
        assert_eq!(path, dir.join("epoch_0003"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_resume_dir_prefers_plain_checkpoint_then_latest_anchor() {
        let dir = tmpdir("resume");
        // Anchor-root shape: no state.json at the top, anchors inside.
        let mut early = sample();
        early.iteration = 100;
        early.save(&dir.join("epoch_0001")).unwrap();
        let mut late = sample();
        late.iteration = 200;
        late.save(&dir.join("epoch_0002")).unwrap();
        assert_eq!(load_resume_dir(&dir).unwrap().iteration, 200);
        // Plain-checkpoint shape wins once state.json exists at the top.
        let mut top = sample();
        top.iteration = 999;
        top.save(&dir).unwrap();
        assert_eq!(load_resume_dir(&dir).unwrap().iteration, 999);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_resume_dir_names_both_shapes_on_miss() {
        let dir = tmpdir("miss");
        fs::create_dir_all(&dir).unwrap();
        let err = load_resume_dir(&dir).unwrap_err().to_string();
        assert!(err.contains("state.json"), "{err}");
        assert!(err.contains("epoch_NNNN"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }
}
