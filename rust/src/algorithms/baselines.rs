//! Baseline schemes: sequential SGD, SimuParallelSGD, and EASGD.

use anyhow::Result;

use super::{host_aggregate, CommContext, CommPolicy};
use crate::linalg;

/// Plain sequential SGD — the p=1 reference; a boundary is a no-op.
pub struct Sequential;

impl CommPolicy for Sequential {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn at_boundary(&mut self, _ctx: &mut CommContext<'_>) -> Result<()> {
        Ok(())
    }
}

/// SimuParallelSGD (Zinkevich et al., 2010). Data is split into p
/// disjoint shards; workers never talk until a boundary, where all
/// parameters are *equally* averaged. In the paper's framing this is the
/// equally-weighted, β=1 special case — and its instability at larger p
/// on non-convex losses (Fig. 8) is one of WASGD's motivations.
pub struct Spsgd {
    theta: Vec<f32>,
}

impl Spsgd {
    /// A fresh SPSGD policy.
    pub fn new() -> Self {
        Self { theta: Vec::new() }
    }
}

impl Default for Spsgd {
    fn default() -> Self {
        Self::new()
    }
}

impl CommPolicy for Spsgd {
    fn name(&self) -> &'static str {
        "spsgd"
    }

    fn shards_data(&self) -> bool {
        true
    }

    fn at_boundary(&mut self, ctx: &mut CommContext<'_>) -> Result<()> {
        let p = ctx.params.len();
        self.theta = vec![1.0 / p as f32; p];
        ctx.cluster.sync_allgather(ctx.msg_bytes);
        host_aggregate(ctx.params, &self.theta, 1.0);
        Ok(())
    }

    fn last_weights(&self) -> Option<&[f32]> {
        if self.theta.is_empty() {
            None
        } else {
            Some(&self.theta)
        }
    }
}

/// Elastic Averaging SGD (Zhang, Choromanska & LeCun, 2015).
///
/// A master stores the center variable x̃. At a boundary each worker i
/// does the elastic round trip of Eq. (3)–(4):
///
/// ```text
/// xᵢ ← xᵢ − α(xᵢ − x̃)
/// x̃  ← x̃ + α(xᵢ − x̃)        (sequentially, worker by worker — Eq. 5)
/// ```
///
/// The sequential-update form is exactly what §2 of the paper analyses:
/// with small α the center keeps most of its (stale) mass, which is the
/// mis-allocation WASGD removes.
pub struct Easgd {
    center: Vec<f32>,
    alpha: f32,
}

impl Easgd {
    /// A fresh EASGD policy with the paper's α default for `cfg`.
    pub fn new(cfg: &crate::config::ExperimentConfig) -> Self {
        Self { center: Vec::new(), alpha: cfg.easgd_alpha() }
    }

    /// The current center variable x̃ (empty before the first boundary).
    pub fn center(&self) -> &[f32] {
        &self.center
    }
}

impl CommPolicy for Easgd {
    fn name(&self) -> &'static str {
        "easgd"
    }

    fn at_boundary(&mut self, ctx: &mut CommContext<'_>) -> Result<()> {
        if self.center.is_empty() {
            // x̃ initialises to the mean of the cohort's starting points.
            let p = ctx.params.len() as f32;
            self.center = vec![0.0; ctx.params[0].len()];
            let rows: Vec<&[f32]> = ctx.params.iter().map(|v| v.as_slice()).collect();
            linalg::weighted_sum(&mut self.center, &rows, &vec![1.0 / p; rows.len()]);
        }
        let alpha = self.alpha;
        for (i, x) in ctx.params.iter_mut().enumerate() {
            // Worker↔master round trip (no global barrier — EASGD's
            // communication is per-worker with the center).
            ctx.cluster.p2p_roundtrip(i, ctx.msg_bytes);
            for (xv, cv) in x.iter_mut().zip(self.center.iter_mut()) {
                let diff = alpha * (*xv - *cv);
                *xv -= diff;
                *cv += diff;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::tests::test_cluster;
    use crate::config::ExperimentConfig;

    // Policy tests that need a real Engine (and therefore artifacts on
    // disk) live in rust/tests/integration.rs; here we cover the pure
    // math the policies are made of.

    #[test]
    fn spsgd_averages_equally() {
        // Exercise host_aggregate directly (the policy's math) — the full
        // policy is covered by the integration suite with a real Engine.
        let mut params = vec![vec![1.0f32, 5.0], vec![3.0, 7.0]];
        host_aggregate(&mut params, &[0.5, 0.5], 1.0);
        assert_eq!(params[0], vec![2.0, 6.0]);
        assert_eq!(params[0], params[1]);
    }

    #[test]
    fn easgd_pull_shrinks_distance_to_center() {
        let cfg = ExperimentConfig::default();
        let mut pol = Easgd::new(&cfg);
        pol.alpha = 0.25;
        pol.center = vec![0.0, 0.0];
        let mut cluster = test_cluster(2);
        // Manual elastic update (mirrors at_boundary's inner loop).
        let mut x = vec![4.0f32, -4.0];
        let before = linalg::dist2(&x, &pol.center);
        cluster.p2p_roundtrip(0, 64);
        for (xv, cv) in x.iter_mut().zip(pol.center.iter_mut()) {
            let diff = pol.alpha * (*xv - *cv);
            *xv -= diff;
            *cv += diff;
        }
        let after = linalg::dist2(&x, &pol.center);
        assert!(after < before);
        assert!(cluster.comm_time_total > 0.0);
        let _ = ExperimentConfig::default();
    }
}
