//! Multiplicative Weight Update baselines (the paper's OMWU / MMWU).
//!
//! The classical MWU method (Arora–Hazan–Kale framing, cited in the paper
//! via Dwork & Roth) maintains a multiplicative weight per expert — here,
//! per worker. At a boundary each worker's weight is decayed by its loss,
//! a leader is sampled from the induced distribution, and the leader's
//! parameters are broadcast. Over enough rounds the distribution
//! concentrates on the best-performing worker.
//!
//! * **OMWU** evaluates each worker's loss over the *entire training set*
//!   at every boundary. That cost is real: the policy charges
//!   `p · N/B · step_cost_fwd` to the simulated clocks, which is exactly
//!   why the paper's Fig. 8 shows OMWU trailing — the weight signal is
//!   precise but the time price is ruinous.
//! * **MMWU** is the paper's fix applied to MWU: reuse the windowed
//!   per-batch losses (Eq. 26) that the forward pass already produced, so
//!   the boundary is free; the weight estimate is noisier.

use anyhow::Result;

use super::{CommContext, CommPolicy};

/// Multiplicative-weights decay rate ε in w ← w·exp(−ε·normalised loss).
const MWU_ETA: f64 = 0.5;

/// The MWU policy state (shared by OMWU and MMWU).
pub struct Mwu {
    /// Running multiplicative weights (unnormalised, in log space).
    log_w: Vec<f64>,
    /// Last boundary's selection distribution (telemetry).
    theta: Vec<f32>,
    use_full_loss: bool,
}

impl Mwu {
    /// A fresh policy for `p` workers (`use_full_loss` selects OMWU).
    pub fn new(p: usize, use_full_loss: bool) -> Self {
        Self { log_w: vec![0.0; p], theta: vec![1.0 / p as f32; p], use_full_loss }
    }

    /// The current selection distribution softmax(log_w).
    fn distribution(&self) -> Vec<f64> {
        let mx = self.log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = self.log_w.iter().map(|&v| (v - mx).exp()).collect();
        let s: f64 = e.iter().sum();
        e.iter().map(|&v| v / s).collect()
    }
}

impl CommPolicy for Mwu {
    fn name(&self) -> &'static str {
        if self.use_full_loss {
            "omwu"
        } else {
            "mmwu"
        }
    }

    fn needs_full_losses(&self) -> bool {
        self.use_full_loss
    }

    fn at_boundary(&mut self, ctx: &mut CommContext<'_>) -> Result<()> {
        let p = ctx.params.len();
        if self.log_w.len() != p {
            self.log_w = vec![0.0; p];
        }

        // Loss signal: exact (OMWU) or the free windowed estimate (MMWU).
        let losses: Vec<f64> = if self.use_full_loss {
            let full = ctx
                .full_losses
                .ok_or_else(|| anyhow::anyhow!("OMWU needs full losses from the trainer"))?;
            full.iter().map(|&v| v as f64).collect()
        } else {
            ctx.energies.iter().map(|&v| v as f64).collect()
        };

        // Normalise to [0,1] so ε has a scale-free meaning.
        let total: f64 = losses.iter().sum();
        if total > 0.0 {
            for (lw, &l) in self.log_w.iter_mut().zip(losses.iter()) {
                *lw -= MWU_ETA * l / total * p as f64;
            }
        }

        // All workers exchange parameters (gather) then receive the leader.
        ctx.cluster.sync_allgather(ctx.msg_bytes);

        // Sample the leader from the MWU distribution.
        let dist = self.distribution();
        self.theta = dist.iter().map(|&v| v as f32).collect();
        let u = ctx.rng.uniform();
        let mut acc = 0.0;
        let mut leader = p - 1;
        for (i, &q) in dist.iter().enumerate() {
            acc += q;
            if u < acc {
                leader = i;
                break;
            }
        }

        // Broadcast the leader's parameters.
        let chosen = ctx.params[leader].clone();
        for (i, x) in ctx.params.iter_mut().enumerate() {
            if i != leader {
                x.copy_from_slice(&chosen);
            }
        }
        Ok(())
    }

    fn last_weights(&self) -> Option<&[f32]> {
        Some(&self.theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribution_is_simplex() {
        let mut m = Mwu::new(4, false);
        m.log_w = vec![-0.1, -2.0, -0.5, 0.0];
        let d = m.distribution();
        let s: f64 = d.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(d.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn weights_concentrate_on_best_worker() {
        let mut m = Mwu::new(3, false);
        // Worker 0 always loses least.
        for _ in 0..200 {
            let losses = [0.1f64, 1.0, 1.0];
            let total: f64 = losses.iter().sum();
            for (lw, &l) in m.log_w.iter_mut().zip(losses.iter()) {
                *lw -= MWU_ETA * l / total * 3.0;
            }
        }
        let d = m.distribution();
        assert!(d[0] > 0.99, "{d:?}");
    }

    #[test]
    fn names_distinguish_variants() {
        assert_eq!(Mwu::new(2, true).name(), "omwu");
        assert_eq!(Mwu::new(2, false).name(), "mmwu");
        assert!(Mwu::new(2, true).needs_full_losses());
        assert!(!Mwu::new(2, false).needs_full_losses());
    }
}
