//! The paper's contributions: WASGD (ICDM'19) and WASGD+ (this paper).

use anyhow::Result;

use super::{host_aggregate, CommContext, CommPolicy};
use crate::linalg;

/// WASGD — Algorithm 3. Inverse-loss weights θᵢ ∝ 1/hᵢ, full acceptance
/// (β = 1), loss energies from the tail window (c = 1) of each period.
/// Aggregation runs on the host: the Pallas artifact computes the
/// *Boltzmann* family, which WASGD predates.
pub struct Wasgd {
    theta: Vec<f32>,
}

impl Wasgd {
    /// A fresh WASGD policy.
    pub fn new() -> Self {
        Self { theta: Vec::new() }
    }
}

impl Default for Wasgd {
    fn default() -> Self {
        Self::new()
    }
}

impl CommPolicy for Wasgd {
    fn name(&self) -> &'static str {
        "wasgd"
    }

    fn at_boundary(&mut self, ctx: &mut CommContext<'_>) -> Result<()> {
        ctx.cluster.sync_allgather(ctx.msg_bytes);
        self.theta = linalg::inverse_loss_weights(ctx.energies);
        // β fixed to 1 in the ICDM'19 algorithm.
        host_aggregate(ctx.params, &self.theta, 1.0);
        Ok(())
    }

    fn last_weights(&self) -> Option<&[f32]> {
        if self.theta.is_empty() {
            None
        } else {
            Some(&self.theta)
        }
    }
}

/// WASGD+ — Algorithm 1 (sync) / Algorithm 4 (async).
///
/// Boltzmann weights θᵢ = e^(−ã·h′ᵢ)/Σe^(−ã·h′ᵏ) (Eq. 13) and the
/// β-negotiated update xᵢ ← (1−β)xᵢ + β·Σθⱼxⱼ (Eq. 10). The numerical
/// work runs through the backend's aggregation kernel (the Pallas PJRT
/// artifact, or the native engine's panel kernel) when the backend can
/// serve this cohort size, with a bit-compatible host fallback otherwise
/// (the test suites assert the paths agree).
///
/// The async flavour (Algorithm 4) proceeds once the first p−1 peers —
/// out of p+b−1 — have reached the boundary; the trainer passes the
/// quorum's members only, and the simulated clock uses
/// [`SimCluster::async_gather`](crate::cluster::SimCluster::async_gather).
pub struct WasgdPlus {
    theta: Vec<f32>,
    is_async: bool,
    /// Number of boundaries served by the backend kernel vs the host
    /// fallback (telemetry for the perf pass).
    pub engine_boundaries: u64,
    /// Boundaries served by the host fallback.
    pub host_boundaries: u64,
}

impl WasgdPlus {
    /// A fresh policy (async = Algorithm 4 flavour).
    pub fn new(is_async: bool) -> Self {
        Self { theta: Vec::new(), is_async, engine_boundaries: 0, host_boundaries: 0 }
    }
}

impl CommPolicy for WasgdPlus {
    fn name(&self) -> &'static str {
        if self.is_async {
            "wasgd+async"
        } else {
            "wasgd+"
        }
    }

    fn uses_order_search(&self) -> bool {
        true
    }

    fn async_quorum(&self) -> Option<usize> {
        if self.is_async {
            Some(1) // placeholder; the trainer computes p−1 from cfg
        } else {
            None
        }
    }

    fn at_boundary(&mut self, ctx: &mut CommContext<'_>) -> Result<()> {
        let p = ctx.params.len();
        let d = ctx.params[0].len();
        // Clock charge: sync barrier + all-gather (the async trainer path
        // charges async_gather itself before building the quorum context).
        if !self.is_async {
            ctx.cluster.sync_allgather(ctx.msg_bytes);
        }

        self.theta = linalg::boltzmann_weights(ctx.energies, ctx.cfg.a_tilde);

        // On this CPU testbed the host path is ~20× faster at large D
        // (bench: pjrt_aggregate mnist p=4 22 ms vs host 0.5 ms — the
        // artifact pays interpret-mode copies + host↔device transfers);
        // the artifact is the TPU-deployment path. WASGD_HOST_AGG=1
        // forces the host twin (numerically equal, pinned by tests).
        let force_host = std::env::var_os("WASGD_HOST_AGG").is_some();

        if !force_host && ctx.engine.has_aggregate(p) {
            // Hot path: the backend's aggregation kernel (Pallas via PJRT,
            // or the native panel kernel).
            let mut stacked = Vec::with_capacity(p * d);
            for row in ctx.params.iter() {
                stacked.extend_from_slice(row);
            }
            let out =
                ctx.engine.aggregate(&stacked, ctx.energies, ctx.cfg.a_tilde, ctx.cfg.beta)?;
            for (i, row) in ctx.params.iter_mut().enumerate() {
                row.copy_from_slice(&out[i * d..(i + 1) * d]);
            }
            self.engine_boundaries += 1;
        } else {
            host_aggregate(ctx.params, &self.theta, ctx.cfg.beta);
            self.host_boundaries += 1;
        }
        Ok(())
    }

    fn last_weights(&self) -> Option<&[f32]> {
        if self.theta.is_empty() {
            None
        } else {
            Some(&self.theta)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wasgd_weights_inverse_loss() {
        let th = linalg::inverse_loss_weights(&[1.0, 2.0]);
        assert!((th[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn wasgd_plus_names() {
        assert_eq!(WasgdPlus::new(false).name(), "wasgd+");
        assert_eq!(WasgdPlus::new(true).name(), "wasgd+async");
        assert!(WasgdPlus::new(false).uses_order_search());
        assert!(WasgdPlus::new(true).async_quorum().is_some());
        assert!(WasgdPlus::new(false).async_quorum().is_none());
    }

    #[test]
    fn host_fallback_matches_manual_math() {
        // θ from Boltzmann, then Eq. 10 by hand vs host_aggregate.
        let h = [0.2f32, 0.8];
        let a_tilde = 1.0;
        let beta = 0.6;
        let th = linalg::boltzmann_weights(&h, a_tilde);
        let mut params = vec![vec![1.0f32, 0.0], vec![0.0, 1.0]];
        let agg = [
            th[0] * 1.0 + th[1] * 0.0,
            th[0] * 0.0 + th[1] * 1.0,
        ];
        let expect0 = [
            (1.0 - beta) * 1.0 + beta * agg[0],
            (1.0 - beta) * 0.0 + beta * agg[1],
        ];
        host_aggregate(&mut params, &th, beta);
        assert!((params[0][0] - expect0[0]).abs() < 1e-6);
        assert!((params[0][1] - expect0[1]).abs() < 1e-6);
    }
}
