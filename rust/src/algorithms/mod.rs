//! The parallel-SGD scheme zoo — the paper's §5.2.2 benchmark set.
//!
//! All schemes share one training loop (the coordinator's [`Trainer`]):
//! every worker runs local SGD steps through the PJRT engine, and at each
//! communication boundary the scheme's [`CommPolicy`] decides what the
//! workers exchange and how local parameters are rewritten. The policies:
//!
//! | scheme       | boundary action                                      |
//! |--------------|------------------------------------------------------|
//! | `sgd`        | nothing (p=1)                                        |
//! | `spsgd`      | equal average of all workers (β=1, θ=1/p), sharded data |
//! | `easgd`      | elastic pull toward a center variable x̃ (Eq. 3–4)    |
//! | `omwu`       | multiplicative weights over workers from *full-dataset* losses; sample + broadcast a leader |
//! | `mmwu`       | same, but with the paper's free loss estimate        |
//! | `wasgd`      | inverse-loss weights 1/h, β=1 (ICDM'19, Algorithm 3) |
//! | `wasgd+`     | Boltzmann weights e^(−ã·h′), β-negotiation (Eq. 10+13), aggregation through the Pallas artifact |
//! | `wasgd+async`| Algorithm 4: same update over the first p−1 arrivals among p+b−1 peers |
//!
//! [`Trainer`]: crate::coordinator::Trainer

pub mod baselines;
pub mod mwu;
pub mod wasgd;

use anyhow::Result;

use crate::cluster::SimCluster;
use crate::config::{AlgoKind, ExperimentConfig};
use crate::rng::Rng;
use crate::runtime::Backend;

/// Everything a policy can see/touch at a communication boundary.
pub struct CommContext<'a> {
    /// Per-worker flat parameter vectors (the policy rewrites these).
    pub params: &'a mut [Vec<f32>],
    /// Per-worker estimated loss energies h (windowed sums, Eq. 26).
    pub energies: &'a [f32],
    /// The execution backend (for the Eq. 10+13 aggregation kernel and
    /// for full-dataset evals — OMWU pays for those in simulated time
    /// too).
    pub engine: &'a dyn Backend,
    /// Virtual cluster: policies charge their communication here.
    pub cluster: &'a mut SimCluster,
    /// The experiment being run.
    pub cfg: &'a ExperimentConfig,
    /// Policy-private randomness (MWU leader sampling), replicated
    /// across fabric workers so decentralized boundaries agree.
    pub rng: &'a mut Rng,
    /// Size of one parameter message on the wire.
    pub msg_bytes: usize,
    /// Full-dataset mean train loss per worker, only populated when the
    /// policy declared [`CommPolicy::needs_full_losses`] (OMWU) or when
    /// the trainer tracks Eq. 27 estimation error.
    pub full_losses: Option<&'a [f32]>,
    /// Local iteration index of this boundary (multiple of τ).
    pub iteration: u64,
}

/// The per-scheme behaviour plugged into the shared training loop.
pub trait CommPolicy {
    /// The scheme's CLI/log name.
    fn name(&self) -> &'static str;

    /// Apply the scheme's exchange at a τ-boundary. Must also charge the
    /// communication cost to `ctx.cluster`.
    fn at_boundary(&mut self, ctx: &mut CommContext<'_>) -> Result<()>;

    /// The weights θ the policy computed at the last boundary (for
    /// telemetry and the Eq. 27 estimation-error probe). Equal weights if
    /// the scheme has no notion of them.
    fn last_weights(&self) -> Option<&[f32]> {
        None
    }

    /// SPSGD: restrict each worker to its own 1/p shard of the data.
    fn shards_data(&self) -> bool {
        false
    }

    /// WASGD+: run the §3.4 sample-order search (Judge / OrderGen).
    fn uses_order_search(&self) -> bool {
        false
    }

    /// OMWU: the trainer must compute full-dataset losses (expensive —
    /// that cost is the point of the MMWU comparison) before calling
    /// `at_boundary`.
    fn needs_full_losses(&self) -> bool {
        false
    }

    /// Async schemes communicate with a quorum instead of a barrier.
    fn async_quorum(&self) -> Option<usize> {
        None
    }
}

/// Instantiate the policy for an algorithm under a given config.
pub fn make_policy(cfg: &ExperimentConfig) -> Box<dyn CommPolicy> {
    match cfg.algo {
        AlgoKind::Sequential => Box::new(baselines::Sequential),
        AlgoKind::Spsgd => Box::new(baselines::Spsgd::new()),
        AlgoKind::Easgd => Box::new(baselines::Easgd::new(cfg)),
        AlgoKind::Omwu => Box::new(mwu::Mwu::new(cfg.p, /*use_full_loss=*/ true)),
        AlgoKind::Mmwu => Box::new(mwu::Mwu::new(cfg.p, /*use_full_loss=*/ false)),
        AlgoKind::Wasgd => Box::new(wasgd::Wasgd::new()),
        AlgoKind::WasgdPlus => Box::new(wasgd::WasgdPlus::new(false)),
        AlgoKind::WasgdPlusAsync => Box::new(wasgd::WasgdPlus::new(true)),
    }
}

/// Host-side weighted aggregation shared by several policies:
/// agg = Σ θⱼ·xⱼ, then xᵢ ← (1−β)xᵢ + β·agg. Used when the Pallas
/// artifact path is unavailable or the weight family differs.
pub fn host_aggregate(params: &mut [Vec<f32>], theta: &[f32], beta: f32) {
    debug_assert_eq!(params.len(), theta.len());
    let d = params[0].len();
    let mut agg = vec![0.0f32; d];
    {
        let rows: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        crate::linalg::weighted_sum(&mut agg, &rows, theta);
    }
    crate::linalg::beta_mix_rows(params, &agg, beta);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ComputeModel, FabricConfig};

    pub(crate) fn test_cluster(p: usize) -> SimCluster {
        SimCluster::new(
            p,
            FabricConfig::default(),
            ComputeModel { step_time_s: 1e-3, jitter_cv: 0.0, straggler_prob: 0.0, straggler_factor: 1.0 },
            0,
        )
    }

    #[test]
    fn host_aggregate_equal_weights_is_mean() {
        let mut params = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        host_aggregate(&mut params, &[0.5, 0.5], 1.0);
        assert_eq!(params[0], vec![2.0, 4.0]);
        assert_eq!(params[1], vec![2.0, 4.0]);
    }

    #[test]
    fn host_aggregate_beta_mixes() {
        let mut params = vec![vec![0.0f32], vec![2.0]];
        host_aggregate(&mut params, &[0.5, 0.5], 0.5);
        assert_eq!(params[0], vec![0.5]);
        assert_eq!(params[1], vec![1.5]);
    }

    #[test]
    fn factory_builds_every_algo() {
        for algo in AlgoKind::ALL {
            let mut cfg = ExperimentConfig::default();
            cfg.algo = algo;
            cfg.backups = 1;
            let p = make_policy(&cfg);
            assert_eq!(p.name(), algo.name());
        }
    }
}
