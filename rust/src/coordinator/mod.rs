//! The decentralized training coordinator — the paper's Algorithm 1/4
//! driving loop, shared by every scheme in [`crate::algorithms`].
//!
//! One [`Trainer`] borrows an execution [`Backend`] (PJRT artifacts or
//! the pure-Rust native engine), the dataset, the simulated cluster, and
//! `p (+ b)` [`worker::Worker`]s. The loop is the paper's:
//! each worker takes local SGD steps through the engine; iterations that
//! fall into the [`RecordWindow`](crate::data::RecordWindow) accumulate
//! the worker's loss energy h (Eq. 26 — free, the losses are forward-pass
//! byproducts); every τ steps the scheme's
//! [`CommPolicy`](crate::algorithms::CommPolicy) rewrites the parameters;
//! `Judge` scores feed the §3.4 sample-order search.
//!
//! Numerics are exact (every step runs the backend's kernels); *time* is
//! virtual (DESIGN.md §3): compute and communication costs advance the
//! [`SimCluster`] clocks so the recorded curves reflect the paper's
//! cluster, not this host's core count.

pub mod worker;

use anyhow::Result;

use crate::algorithms::{make_policy, CommContext, CommPolicy};
use crate::cluster::fabric::{round_origins, PanelCodec, Topology};
use crate::cluster::SimCluster;
use crate::config::{AlgoKind, ExperimentConfig};
use crate::data::order::judge;
use crate::data::source::{shard_range, BatchPlanner, DataPipeline};
use crate::data::{Dataset, RecordWindow};
use crate::journal::{
    canonical_comm_bytes, digest_cohort, digest_params, Event, EventSink, JournalWriter,
    MembershipChange, RANK_COHORT,
};
use crate::linalg;
use crate::metrics::{Record, RunLog, Stopwatch};
use crate::rng::Rng;
use crate::runtime::{load_backend, Backend};

use worker::Worker;

/// Fraction of a train step charged for one forward-only (eval) batch in
/// simulated time — OMWU's full-dataset weight evaluation pays this.
const EVAL_STEP_FRACTION: f64 = 0.4;

/// Everything a run produces beyond the record stream.
#[derive(Debug)]
pub struct RunOutput {
    /// The labelled record stream (one entry per evaluation point).
    pub log: RunLog,
    /// Eq. (27) weight-estimation error per boundary: (iteration, error).
    pub estimation_errors: Vec<(u64, f32)>,
    /// Simulated seconds spent in collectives.
    pub comm_time_s: f64,
    /// Simulated seconds workers were blocked at barriers.
    pub wait_time_s: f64,
    /// Order-search telemetry (WASGD+): parts that kept their seed.
    pub orders_kept: u64,
    /// Order-search telemetry (WASGD+): parts that redrew their seed.
    pub orders_redrawn: u64,
    /// Backend kernel executions performed (PJRT programs or native calls).
    pub exec_count: u64,
    /// Final per-worker parameter vectors (checkpointable via
    /// [`RunOutput::to_checkpoint`]).
    pub final_workers: Vec<Vec<f32>>,
}

impl RunOutput {
    /// Snapshot the run's end state as a durable [`Checkpoint`].
    pub fn to_checkpoint(&self) -> crate::checkpoint::Checkpoint {
        let last = self.log.records.last();
        crate::checkpoint::Checkpoint {
            label: self.log.label.clone(),
            iteration: last.map(|r| r.iteration).unwrap_or(0),
            epoch: last.map(|r| r.epoch).unwrap_or(0.0),
            sim_time_s: last.map(|r| r.sim_time_s).unwrap_or(0.0),
            workers: self.final_workers.clone(),
        }
    }
}

/// Run one experiment, returning just the record stream.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunLog> {
    Ok(run_experiment_full(cfg)?.log)
}

/// Run one experiment with full telemetry (loads the backend selected by
/// `cfg.backend` and builds the dataset itself; sweeps should use
/// [`crate::harness::SharedEnv`] to amortise backend construction and
/// step-time calibration). The dataset comes from the
/// [`DataPipeline`] — the source resolved from `cfg.data_spec()`,
/// validated against the variant's input geometry — which is exactly
/// what the worker fabrics build, so `--fabric sim` and `--fabric tcp`
/// train on the identical split for every source (synthetic or real
/// files) and every variant (including the dim-adapted synth ones like
/// `tiny_cnn`).
pub fn run_experiment_full(cfg: &ExperimentConfig) -> Result<RunOutput> {
    let engine = load_backend(cfg)?;
    let dataset = DataPipeline::from_config(cfg)?.load(engine.manifest())?;
    let mut tr = Trainer::new(cfg.clone(), engine.as_ref(), &dataset)?;
    tr.run()
}

/// The shared training loop. Borrows the backend and the dataset so
/// sweeps can reuse both across dozens of runs.
pub struct Trainer<'a> {
    /// The experiment being run.
    pub cfg: ExperimentConfig,
    /// The execution backend every worker steps through.
    pub engine: &'a dyn Backend,
    /// The training/evaluation data.
    pub dataset: &'a Dataset,
    cluster: SimCluster,
    policy: Box<dyn CommPolicy>,
    workers: Vec<Worker>,
    /// Per-worker panel codecs: the error-feedback residual state of
    /// lossy encodings (zero-sized for f32). Indexed like `workers`.
    codecs: Vec<PanelCodec>,
    window: RecordWindow,
    eval_rng: Rng,
    comm_rng: Rng,
    /// Reusable batch index/gather buffers (hot loop, allocation-free).
    idx_buf: Vec<u32>,
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
    /// Event sink when the run is journaled (`cfg.journal` or
    /// [`Trainer::set_journal`]); the trainer journals the whole cohort
    /// from its single vantage point ([`RANK_COHORT`]).
    journal: Option<Box<dyn EventSink + 'a>>,
    /// The checkpoint vectors this run resumed from (embedded in
    /// `RunStarted` so the journal segment is replayable on its own).
    resumed_from: Vec<Vec<f32>>,
    /// Collective rounds crossed so far.
    rounds_done: u64,
}

impl<'a> Trainer<'a> {
    /// Validate the config against the engine/dataset geometry and set
    /// up the cluster, policy, and per-worker state.
    pub fn new(
        cfg: ExperimentConfig,
        engine: &'a dyn Backend,
        dataset: &'a Dataset,
    ) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        anyhow::ensure!(
            dataset.dim == engine.manifest().input_dim,
            "dataset dim {} ≠ model input dim {} (dataset {} vs variant {})",
            dataset.dim,
            engine.manifest().input_dim,
            dataset.name,
            engine.manifest().name
        );

        let p_primary = if cfg.algo == AlgoKind::Sequential { 1 } else { cfg.p };
        let p_total = p_primary
            + if cfg.algo == AlgoKind::WasgdPlusAsync { cfg.backups } else { 0 };

        // Calibrate the compute model from the real engine if requested.
        let mut compute = cfg.compute;
        if compute.step_time_s <= 0.0 {
            compute.step_time_s = engine.calibrate_step_time(3)?;
        }
        let cluster = SimCluster::new(p_total, cfg.fabric_cost, compute, cfg.seed);

        let policy = make_policy(&cfg);
        let root = Rng::new(cfg.seed);
        let n = dataset.n_train();
        let batch = engine.manifest().batch;
        anyhow::ensure!(n >= batch, "dataset smaller than one batch");

        let mut workers = Vec::with_capacity(p_total);
        let mut codecs = Vec::with_capacity(p_total);
        for i in 0..p_total {
            // The one rank-stable sharding rule every execution layer
            // shares (backups mirror their primary's shard).
            let shard = policy.shards_data().then(|| shard_range(n, i % p_primary, p_primary));
            if let Some((lo, hi)) = shard {
                anyhow::ensure!(
                    hi - lo >= batch,
                    "worker {i}'s data shard holds {} examples — fewer than one batch of \
                     {batch}; reduce p or train on a larger split",
                    hi - lo
                );
            }
            let params = engine.manifest().init_params(cfg.seed ^ 0x9a9a);
            let planner = BatchPlanner::new(
                i,
                root.child(100 + i as u64),
                n,
                batch,
                shard,
                policy.uses_order_search() && cfg.force_delta_order.is_none(),
                cfg.n_parts,
                cfg.force_delta_order,
                dataset.train_y.clone(),
            );
            codecs.push(PanelCodec::new(cfg.encoding, params.len()));
            workers.push(Worker::new(i, params, planner));
        }

        let journal: Option<Box<dyn EventSink + 'a>> = match &cfg.journal {
            Some(path) => Some(Box::new(JournalWriter::create(path)?)),
            None => None,
        };

        Ok(Self {
            window: RecordWindow::new(cfg.tau, cfg.m, cfg.c),
            eval_rng: root.child(7),
            comm_rng: root.child(8),
            cfg,
            engine,
            dataset,
            cluster,
            policy,
            workers,
            codecs,
            idx_buf: Vec::new(),
            x_buf: Vec::new(),
            y_buf: Vec::new(),
            journal,
            resumed_from: Vec::new(),
            rounds_done: 0,
        })
    }

    /// Attach (or replace) the run's event sink — how `wasgd replay`
    /// captures the re-executed event stream in memory instead of a
    /// file.
    pub fn set_journal(&mut self, sink: Box<dyn EventSink + 'a>) {
        self.journal = Some(sink);
    }

    /// Start every worker from the given checkpoint vectors (rank
    /// order) instead of the seeded init. The vectors are also embedded
    /// in the journal's `RunStarted`, keeping a resumed segment
    /// self-contained for replay. Error-feedback residuals are *not*
    /// checkpointed: a resumed lossy run starts them at zero (see
    /// `docs/FABRIC.md`).
    pub fn resume_workers(&mut self, initial: &[Vec<f32>]) -> Result<()> {
        anyhow::ensure!(
            initial.len() == self.workers.len(),
            "checkpoint holds {} worker vectors, this run has {} workers",
            initial.len(),
            self.workers.len()
        );
        for (w, v) in self.workers.iter_mut().zip(initial) {
            anyhow::ensure!(
                v.len() == w.params().len(),
                "checkpoint vector of {} params ≠ model's {}",
                v.len(),
                w.params().len()
            );
            w.set_params(v.clone());
        }
        self.resumed_from = initial.to_vec();
        Ok(())
    }

    fn emit_journal(&mut self, ev: &Event) -> Result<()> {
        if let Some(j) = self.journal.as_mut() {
            j.emit(ev)?;
        }
        Ok(())
    }

    /// Steps per epoch per worker (dataset passes ÷ batch).
    pub fn steps_per_epoch(&self) -> usize {
        (self.dataset.n_train() / self.engine.manifest().batch).max(1)
    }

    /// Drive the run to completion over the configured epoch budget.
    pub fn run(&mut self) -> Result<RunOutput> {
        let spe = self.steps_per_epoch();
        let total_steps = ((self.cfg.epochs * spe as f64).ceil() as usize).max(1);
        self.run_for(total_steps)
    }

    /// Drive the run for an explicit step budget — what `wasgd replay`
    /// uses to re-execute exactly the steps a journal records (the
    /// journaled run may have stopped early on `--target-loss`).
    pub fn run_for(&mut self, total_steps: usize) -> Result<RunOutput> {
        let spe = self.steps_per_epoch();
        let watch = Stopwatch::new();
        let mut log = RunLog::new(self.cfg.label())
            .tag("dataset", self.dataset.name.clone())
            .tag("variant", &self.cfg.variant)
            .tag("beta", self.cfg.beta)
            .tag("a_tilde", self.cfg.a_tilde)
            .tag("m", self.cfg.m)
            .tag("seed", self.cfg.seed);
        let mut estimation_errors = Vec::new();

        if self.journal.is_some() {
            self.emit_journal(&Event::RunStarted {
                rank: RANK_COHORT,
                p: self.workers.len() as u32,
                seed: self.cfg.seed,
                encoding: self.cfg.encoding,
                git_rev: crate::bench::git_rev(),
                config_json: self.cfg.to_wire_json(),
                resume: self.resumed_from.clone(),
            })?;
            for i in 0..self.workers.len() {
                self.emit_journal(&Event::Membership {
                    epoch: 0,
                    rank: i as u32,
                    change: MembershipChange::Joined,
                })?;
            }
        }

        // Initial point (iteration 0).
        log.push(self.evaluate(0, 0.0, &watch)?);

        let mut steps_done = 0u64;
        for step in 1..=total_steps {
            let k_in_period = (step - 1) % self.cfg.tau;
            let recorded = self.window.is_recorded(k_in_period);

            for wi in 0..self.workers.len() {
                self.local_step(wi, recorded)?;
            }
            steps_done = step as u64;

            if step % self.cfg.tau == 0 {
                self.communicate(step as u64, &mut estimation_errors)?;
            }

            if step % self.cfg.eval_every == 0 || step == total_steps {
                let rec = self.evaluate(step as u64, step as f64 / spe as f64, &watch)?;
                let done = self
                    .cfg
                    .target_loss
                    .map(|t| rec.train_loss <= t)
                    .unwrap_or(false);
                log.push(rec);
                if done {
                    break;
                }
            }
        }

        let final_workers: Vec<Vec<f32>> =
            self.workers.iter().map(|w| w.params().to_vec()).collect();
        if self.journal.is_some() {
            self.emit_journal(&Event::RunFinished {
                steps: steps_done,
                rounds: self.rounds_done,
                final_digest: digest_cohort(final_workers.iter().map(|v| v.as_slice())),
            })?;
        }

        Ok(RunOutput {
            log,
            estimation_errors,
            comm_time_s: self.cluster.comm_time_total,
            wait_time_s: self.cluster.wait_time_total,
            orders_kept: self.workers.iter().map(|w| w.orders_kept()).sum(),
            orders_redrawn: self.workers.iter().map(|w| w.orders_redrawn()).sum(),
            exec_count: self.engine.exec_count(),
            final_workers,
        })
    }

    /// One local SGD step of worker `wi` — allocation-free: the planner
    /// refills the reusable index buffer, the gather refills the x/y
    /// buffers.
    fn local_step(&mut self, wi: usize, recorded: bool) -> Result<()> {
        self.workers[wi].next_batch_into(&mut self.idx_buf);
        self.dataset.gather_train(&self.idx_buf, &mut self.x_buf, &mut self.y_buf);
        let (new_params, out) = self.engine.train_step(
            self.workers[wi].params(),
            &self.x_buf,
            &self.y_buf,
            self.cfg.lr,
        )?;
        let w = &mut self.workers[wi];
        w.set_params(new_params);
        if recorded {
            w.add_energy(out.loss);
        }
        self.cluster.advance_compute(wi, 1);
        Ok(())
    }

    /// A τ-boundary: estimation, the scheme's exchange, Judge scores.
    fn communicate(
        &mut self,
        iteration: u64,
        estimation_errors: &mut Vec<(u64, f32)>,
    ) -> Result<()> {
        self.rounds_done += 1;
        let round = iteration / self.cfg.tau as u64;

        // Run every worker's panel through its codec first: transmit the
        // error-compensated vector, fold the dropped coordinates into the
        // residual, and keep the decoded panel — bit-identical to what a
        // TCP cohort would decode from the wire bytes. For f32 this is θ
        // verbatim, so lossless runs are unchanged byte for byte.
        let mut decoded: Vec<Vec<f32>> = Vec::with_capacity(self.workers.len());
        for (codec, w) in self.codecs.iter_mut().zip(self.workers.iter()) {
            let outgoing = codec.outgoing(w.params());
            decoded.push(codec.committed(&outgoing));
        }

        // Journal every rank's panel exactly as the fabrics see it at
        // the collective's entry: the *decoded* pre-aggregation θ plus
        // the windowed energy h. This is what makes a sim journal and a
        // tcp journal of the same run byte-compare equal — lossy modes
        // included, because both sides digest the post-decode panels.
        if self.journal.is_some() {
            let d = decoded[0].len();
            for i in 0..self.workers.len() {
                let (digest, loss) = (digest_params(&decoded[i]), self.workers[i].energy());
                self.emit_journal(&Event::PanelDigest {
                    round,
                    rank: i as u32,
                    digest,
                    loss,
                    comm_bytes: canonical_comm_bytes(round, d),
                })?;
            }
        }

        if matches!(self.cfg.algo, AlgoKind::Sequential) {
            // No cohort — still reset windows so energies don't grow.
            for w in self.workers.iter_mut() {
                w.reset_energy();
            }
            return Ok(());
        }

        let energies: Vec<f32> = self.workers.iter().map(|w| w.energy()).collect();

        // Full-dataset losses when the policy (OMWU) or the Eq. 27 probe
        // needs them. OMWU is *charged* for this in simulated time; the
        // probe is instrumentation and charges nothing.
        let needs_full = self.policy.needs_full_losses() || self.cfg.track_estimation_error;
        let full_losses = if needs_full {
            let mut v = Vec::with_capacity(self.workers.len());
            for w in 0..self.workers.len() {
                v.push(self.full_train_loss(w)?);
            }
            if self.policy.needs_full_losses() {
                let spe = self.steps_per_epoch() as f64;
                let cost = spe * self.cluster.compute.step_time_s * EVAL_STEP_FRACTION;
                for i in 0..self.cluster.clocks.len() {
                    self.cluster.clocks[i] += cost;
                }
            }
            Some(v)
        } else {
            None
        };

        let msg_bytes = self.engine.manifest().message_bytes();

        if self.cfg.algo == AlgoKind::WasgdPlusAsync {
            self.communicate_async(&decoded, &energies, msg_bytes)?;
        } else if let Topology::Gossip { .. } = self.cfg.topology {
            // Peer sampling: each worker aggregates only its sampled
            // subset, exactly as `run_fabric_worker` does — the policy
            // (stateless for every gossip-eligible scheme) runs once per
            // worker over the sub-cohort, so the Eq. 10/13 weights
            // renormalize over the actually-received panels. Each
            // sub-gather charges the cost model separately: under gossip
            // there is no single cohort-wide collective to amortize.
            let p = self.workers.len();
            let mut new_params: Vec<Vec<f32>> = Vec::with_capacity(p);
            let mut judge_scores: Vec<f32> = Vec::with_capacity(p);
            for i in 0..p {
                let origins = round_origins(self.cfg.topology, p, i, round, self.cfg.seed);
                let own_pos = origins
                    .iter()
                    .position(|&o| o == i)
                    .expect("a rank always aggregates its own panel");
                let mut sub: Vec<Vec<f32>> =
                    origins.iter().map(|&o| decoded[o].clone()).collect();
                let sub_h: Vec<f32> = origins.iter().map(|&o| energies[o]).collect();
                let mut ctx = CommContext {
                    params: &mut sub,
                    energies: &sub_h,
                    engine: self.engine,
                    cluster: &mut self.cluster,
                    cfg: &self.cfg,
                    rng: &mut self.comm_rng,
                    msg_bytes,
                    full_losses: full_losses.as_deref(),
                    iteration,
                };
                self.policy.at_boundary(&mut ctx)?;
                new_params.push(sub.swap_remove(own_pos));
                judge_scores.push(judge(&sub_h, own_pos));
            }
            for (w, p) in self.workers.iter_mut().zip(new_params.into_iter()) {
                w.set_params(p);
            }
            // §3.4 order search over the subset each worker actually saw
            // (mirrors the fabric worker's judge call bit for bit).
            if self.policy.uses_order_search() {
                for (w, s) in self.workers.iter_mut().zip(judge_scores) {
                    w.record_judge_score(s);
                }
            }
            for w in self.workers.iter_mut() {
                w.reset_energy();
            }
            return Ok(());
        } else {
            // Full and ring both gather the whole cohort (ring is only a
            // different *delivery* of identical content), so one policy
            // call rewrites every row, as before.
            let mut params = decoded;
            let mut ctx = CommContext {
                params: &mut params,
                energies: &energies,
                engine: self.engine,
                cluster: &mut self.cluster,
                cfg: &self.cfg,
                rng: &mut self.comm_rng,
                msg_bytes,
                full_losses: full_losses.as_deref(),
                iteration,
            };
            self.policy.at_boundary(&mut ctx)?;
            for (w, p) in self.workers.iter_mut().zip(params.into_iter()) {
                w.set_params(p);
            }
        }

        // Eq. 27: |θ_est − θ_true|₁ against the same weight family
        // computed from exact full-dataset losses.
        if self.cfg.track_estimation_error {
            if let (Some(est), Some(full)) = (self.policy.last_weights(), full_losses.as_deref())
            {
                let truth = true_weights(self.cfg.algo, full, self.cfg.a_tilde);
                let err: f32 = est
                    .iter()
                    .zip(truth.iter())
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                estimation_errors.push((iteration, err));
            }
        }

        // §3.4 order search: score every worker against the cohort.
        if self.policy.uses_order_search() {
            for (i, w) in self.workers.iter_mut().enumerate() {
                w.record_judge_score(judge(&energies, i));
            }
        }

        for w in self.workers.iter_mut() {
            w.reset_energy();
        }
        Ok(())
    }

    /// Algorithm 4: every worker aggregates with the first p−1 peers (by
    /// simulated clock) among the p+b−1 others; stragglers are ignored.
    /// `snapshot` holds the codec-decoded boundary panels (θ verbatim
    /// under the lossless default).
    fn communicate_async(
        &mut self,
        snapshot: &[Vec<f32>],
        energies: &[f32],
        msg_bytes: usize,
    ) -> Result<()> {
        let p = self.cfg.p;
        let total = self.workers.len();
        let need = p.saturating_sub(1).max(1);
        let clocks = self.cluster.clocks.clone();

        let mut new_params: Vec<Vec<f32>> = Vec::with_capacity(total);
        for i in 0..total {
            // Quorum: the `need` earliest peers.
            let mut peers: Vec<usize> = (0..total).filter(|&j| j != i).collect();
            peers.sort_by(|&a, &b| clocks[a].partial_cmp(&clocks[b]).unwrap());
            peers.truncate(need);
            self.cluster.async_gather(i, need, msg_bytes);

            // Cohort = self + quorum; aggregate and keep row 0 (self).
            let mut cohort_params: Vec<Vec<f32>> = Vec::with_capacity(need + 1);
            let mut cohort_h: Vec<f32> = Vec::with_capacity(need + 1);
            cohort_params.push(snapshot[i].clone());
            cohort_h.push(energies[i].max(1e-12));
            for &j in &peers {
                cohort_params.push(snapshot[j].clone());
                cohort_h.push(energies[j].max(1e-12));
            }
            let theta = linalg::boltzmann_weights(&cohort_h, self.cfg.a_tilde);
            let d = snapshot[i].len();
            let mut agg = vec![0.0f32; d];
            {
                let rows: Vec<&[f32]> =
                    cohort_params.iter().map(|v| v.as_slice()).collect();
                linalg::weighted_sum(&mut agg, &rows, &theta);
            }
            let mut mine = snapshot[i].clone();
            linalg::lerp_into(&mut mine, self.cfg.beta, &agg);
            new_params.push(mine);
        }
        for (w, pnew) in self.workers.iter_mut().zip(new_params.into_iter()) {
            w.set_params(pnew);
        }
        Ok(())
    }

    /// Exact mean train loss of one worker over the whole training split.
    fn full_train_loss(&mut self, wi: usize) -> Result<f32> {
        let b = self.engine.manifest().batch;
        let n = self.dataset.n_train();
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut lo = 0;
        while lo + b <= n {
            let idx: Vec<u32> = (lo as u32..(lo + b) as u32).collect();
            self.dataset.gather_train(&idx, &mut self.x_buf, &mut self.y_buf);
            let out =
                self.engine
                    .eval_batch(self.workers[wi].params(), &self.x_buf, &self.y_buf)?;
            total += out.sum_loss as f64;
            count += b;
            lo += b;
        }
        Ok((total / count.max(1) as f64) as f32)
    }

    /// Sampled train/test evaluation → one metrics record. Evaluates
    /// worker 0 (the cohort is exchangeable; after a boundary with β=1
    /// all workers coincide). Instrumentation only: charges no sim time.
    fn evaluate(&mut self, iteration: u64, epoch: f64, watch: &Stopwatch) -> Result<Record> {
        let b = self.engine.manifest().batch;
        let params = self.workers[0].params().to_vec();

        let sample = |n: usize, rng: &mut Rng| -> Vec<u32> {
            (0..b).map(|_| rng.below(n) as u32).collect()
        };

        let mut tr_loss = 0.0f64;
        let mut tr_correct = 0.0f64;
        let mut te_loss = 0.0f64;
        let mut te_correct = 0.0f64;
        let batches = self.cfg.eval_batches.max(1);
        for _ in 0..batches {
            let idx = sample(self.dataset.n_train(), &mut self.eval_rng);
            self.dataset.gather_train(&idx, &mut self.x_buf, &mut self.y_buf);
            let out = self.engine.eval_batch(&params, &self.x_buf, &self.y_buf)?;
            tr_loss += out.sum_loss as f64;
            tr_correct += out.correct as f64;

            let idx = sample(self.dataset.n_test(), &mut self.eval_rng);
            self.dataset.gather_test(&idx, &mut self.x_buf, &mut self.y_buf);
            let out = self.engine.eval_batch(&params, &self.x_buf, &self.y_buf)?;
            te_loss += out.sum_loss as f64;
            te_correct += out.correct as f64;
        }
        let denom = (batches * b) as f64;
        Ok(Record {
            iteration,
            epoch,
            sim_time_s: self.cluster.now(),
            wall_time_s: watch.elapsed_s(),
            train_loss: tr_loss / denom,
            train_error: 1.0 - tr_correct / denom,
            test_loss: te_loss / denom,
            test_error: 1.0 - te_correct / denom,
        })
    }
}

/// The "exact" weights a scheme would compute from full-dataset losses —
/// the θ_true of Eq. (20)/(27).
pub fn true_weights(algo: AlgoKind, full_losses: &[f32], a_tilde: f32) -> Vec<f32> {
    match algo {
        AlgoKind::Wasgd => linalg::inverse_loss_weights(full_losses),
        _ => linalg::boltzmann_weights(full_losses, a_tilde),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn true_weights_family_dispatch() {
        let h = [0.5f32, 1.0];
        let w_inv = true_weights(AlgoKind::Wasgd, &h, 1.0);
        assert!((w_inv[0] - 2.0 / 3.0).abs() < 1e-6);
        let w_b = true_weights(AlgoKind::WasgdPlus, &h, 0.0);
        assert!((w_b[0] - 0.5).abs() < 1e-6);
    }
}
