//! Per-worker state: parameters, batch stream, energy window, order seeds.
//!
//! A worker walks the training set in an *order* — either a fresh uniform
//! shuffle per epoch (baselines), a δ-label-blocked order (the Fig. 3
//! study), a shard-restricted shuffle (SPSGD), or the §3.4 seeded
//! per-part orders whose seeds survive epochs when the worker's `Judge`
//! score was good ([`OrderState`]). Each `next_batch` yields the next
//! `batch` indices of the current order.

use crate::data::order::{delta_blocked_order, OrderState};
use crate::rng::Rng;

/// Per-worker training state (see the module docs).
pub struct Worker {
    /// Worker index i in the cohort.
    pub id: usize,
    params: Vec<f32>,
    rng: Rng,
    n_samples: usize,
    batch: usize,
    /// SPSGD shard bounds [lo, hi) in sample-index space.
    shard: Option<(usize, usize)>,
    /// Some(state) when the §3.4 order search is active.
    order_state: Option<OrderState>,
    /// Fig. 3: force δ-blocked orders instead of uniform shuffles.
    force_delta: Option<usize>,
    /// Training labels (needed to build δ-blocked orders).
    labels: Vec<i32>,
    /// Current epoch order and cursor.
    epoch_order: Vec<u32>,
    pos: usize,
    /// Completed epochs (order regenerations).
    pub epoch: u64,
    /// Windowed loss-energy accumulator h (Eq. 26).
    energy: f32,
    recorded: u32,
    /// Judge score pending for the part currently being walked.
    pending_score: Option<f32>,
}

impl Worker {
    /// Construct a worker and build its first epoch order.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        params: Vec<f32>,
        rng: Rng,
        n_samples: usize,
        batch: usize,
        shard: Option<(usize, usize)>,
        order_search: bool,
        n_parts: usize,
        force_delta: Option<usize>,
        labels: Vec<i32>,
    ) -> Self {
        let order_state = if order_search && shard.is_none() {
            Some(OrderState::new(n_samples, n_parts, rng.clone().next_u64() ^ id as u64))
        } else {
            None
        };
        let mut w = Self {
            id,
            params,
            rng,
            n_samples,
            batch,
            shard,
            order_state,
            force_delta,
            labels,
            epoch_order: Vec::new(),
            pos: 0,
            epoch: 0,
            energy: 0.0,
            recorded: 0,
            pending_score: None,
        };
        w.new_epoch();
        w
    }

    /// Current flat parameter vector.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Replace the parameter vector (same length).
    pub fn set_params(&mut self, p: Vec<f32>) {
        debug_assert_eq!(p.len(), self.params.len());
        self.params = p;
    }

    /// Loss-energy window (the h sent at a boundary). Guaranteed > 0.
    pub fn energy(&self) -> f32 {
        if self.recorded == 0 {
            1.0 // no records yet (m window hasn't opened) → neutral
        } else {
            self.energy.max(1e-12)
        }
    }

    /// Record one batch loss into the energy window.
    pub fn add_energy(&mut self, batch_loss: f32) {
        self.energy += batch_loss;
        self.recorded += 1;
    }

    /// Clear the energy window (after a boundary).
    pub fn reset_energy(&mut self) {
        self.energy = 0.0;
        self.recorded = 0;
    }

    /// Record the cohort z-score from `Judge` (Algorithm 2, Function 3);
    /// it is committed to the order part the worker is currently inside,
    /// so the part's seed survives iff its *latest* score was good —
    /// exactly Algorithm 1's `Scores[l] = score`.
    pub fn record_judge_score(&mut self, score: f32) {
        self.pending_score = Some(score);
        if let Some(st) = self.order_state.as_mut() {
            let part_len = (self.n_samples / st.n_parts).max(1);
            let sample_pos = self.pos * self.batch;
            let part = (sample_pos / part_len).min(st.n_parts - 1);
            st.record_score(part, score);
        }
    }

    /// Order parts that kept their seed so far (telemetry).
    pub fn orders_kept(&self) -> u64 {
        self.order_state.as_ref().map(|s| s.kept).unwrap_or(0)
    }

    /// Order parts that redrew their seed so far (telemetry).
    pub fn orders_redrawn(&self) -> u64 {
        self.order_state.as_ref().map(|s| s.redrawn).unwrap_or(0)
    }

    /// Build the next epoch's order.
    fn new_epoch(&mut self) {
        self.epoch_order.clear();
        self.pos = 0;
        if let Some(delta) = self.force_delta {
            self.epoch_order = delta_blocked_order(&self.labels, delta, &mut self.rng);
        } else if let Some(st) = self.order_state.as_mut() {
            // §3.4: per-part seeded permutations (keep-or-redraw applied
            // inside order_for_part based on recorded scores).
            for part in 0..st.n_parts {
                self.epoch_order.extend(st.order_for_part(part));
            }
        } else if let Some((lo, hi)) = self.shard {
            let mut idx: Vec<u32> = (lo as u32..hi as u32).collect();
            self.rng.shuffle(&mut idx);
            self.epoch_order = idx;
        } else {
            self.epoch_order = self.rng.permutation(self.n_samples);
        }
    }

    /// The next `batch` sample indices (wraps to a new epoch as needed).
    pub fn next_batch(&mut self) -> Vec<u32> {
        let b = self.batch;
        if (self.pos + 1) * b > self.epoch_order.len() {
            self.epoch += 1;
            self.new_epoch();
        }
        let lo = self.pos * b;
        self.pos += 1;
        self.epoch_order[lo..lo + b].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_worker(order_search: bool, shard: Option<(usize, usize)>) -> Worker {
        let labels: Vec<i32> = (0..120).map(|i| (i % 4) as i32).collect();
        Worker::new(
            0,
            vec![0.0; 8],
            Rng::new(5),
            120,
            10,
            shard,
            order_search,
            4,
            None,
            labels,
        )
    }

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let mut w = mk_worker(false, None);
        let mut seen = Vec::new();
        for _ in 0..12 {
            seen.extend(w.next_batch());
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..120u32).collect::<Vec<_>>());
        assert_eq!(w.epoch, 0);
        w.next_batch();
        assert_eq!(w.epoch, 1);
    }

    #[test]
    fn shard_restricts_indices() {
        let mut w = mk_worker(false, Some((30, 60)));
        for _ in 0..6 {
            for i in w.next_batch() {
                assert!((30..60).contains(&(i as usize)));
            }
        }
    }

    #[test]
    fn order_search_covers_epoch_too() {
        let mut w = mk_worker(true, None);
        let mut seen = Vec::new();
        for _ in 0..12 {
            seen.extend(w.next_batch());
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..120u32).collect::<Vec<_>>());
    }

    #[test]
    fn good_score_preserves_epoch_order_part() {
        let mut w = mk_worker(true, None);
        let first: Vec<u32> = (0..12).flat_map(|_| w.next_batch()).collect();
        // Mark every part good right before it would regenerate.
        for part in 0..4 {
            w.order_state.as_mut().unwrap().record_score(part, -2.0);
        }
        let second: Vec<u32> = (0..12).flat_map(|_| w.next_batch()).collect();
        assert_eq!(first, second, "good scores must keep all seeds");

        for part in 0..4 {
            w.order_state.as_mut().unwrap().record_score(part, 2.0);
        }
        let third: Vec<u32> = (0..12).flat_map(|_| w.next_batch()).collect();
        assert_ne!(second, third, "bad scores must reshuffle");
    }

    #[test]
    fn energy_window_accumulates_and_resets() {
        let mut w = mk_worker(false, None);
        assert_eq!(w.energy(), 1.0); // neutral before any record
        w.add_energy(0.5);
        w.add_energy(0.25);
        assert!((w.energy() - 0.75).abs() < 1e-6);
        w.reset_energy();
        assert_eq!(w.energy(), 1.0);
    }

    #[test]
    fn delta_forced_orders_have_blocks() {
        let labels: Vec<i32> = (0..120).map(|i| (i % 4) as i32).collect();
        let mut w = Worker::new(
            0,
            vec![0.0; 4],
            Rng::new(9),
            120,
            10,
            None,
            false,
            4,
            Some(30),
            labels.clone(),
        );
        let idx = w.next_batch();
        let first_label = labels[idx[0] as usize];
        assert!(idx.iter().all(|&i| labels[i as usize] == first_label));
    }
}
