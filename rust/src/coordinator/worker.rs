//! Per-worker state: parameters, the energy window, and the streaming
//! [`BatchPlanner`] that walks the training set.
//!
//! The order machinery — fresh uniform shuffles (baselines),
//! δ-label-blocked orders (the Fig. 3 study), shard-restricted shuffles
//! (SPSGD), and the §3.4 seeded per-part orders whose seeds survive
//! epochs when the worker's `Judge` score was good — lives in
//! [`crate::data::source::BatchPlanner`] since the data-pipeline
//! refactor, so the same planner drives the simulated trainer, the
//! threaded fabric, and remote tcp workers over synthetic and real data
//! alike. The worker keeps what is genuinely per-worker: the flat
//! parameter vector and the Eq. 26 loss-energy window.

use crate::data::source::BatchPlanner;

/// Per-worker training state (see the module docs).
pub struct Worker {
    /// Worker index i in the cohort.
    pub id: usize,
    params: Vec<f32>,
    planner: BatchPlanner,
    /// Windowed loss-energy accumulator h (Eq. 26).
    energy: f32,
    recorded: u32,
}

impl Worker {
    /// Construct a worker around its sample-stream planner.
    pub fn new(id: usize, params: Vec<f32>, planner: BatchPlanner) -> Self {
        Self { id, params, planner, energy: 0.0, recorded: 0 }
    }

    /// Current flat parameter vector.
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Replace the parameter vector (same length).
    pub fn set_params(&mut self, p: Vec<f32>) {
        debug_assert_eq!(p.len(), self.params.len());
        self.params = p;
    }

    /// Loss-energy window (the h sent at a boundary). Guaranteed > 0.
    pub fn energy(&self) -> f32 {
        if self.recorded == 0 {
            1.0 // no records yet (m window hasn't opened) → neutral
        } else {
            self.energy.max(1e-12)
        }
    }

    /// Record one batch loss into the energy window.
    pub fn add_energy(&mut self, batch_loss: f32) {
        self.energy += batch_loss;
        self.recorded += 1;
    }

    /// Clear the energy window (after a boundary).
    pub fn reset_energy(&mut self) {
        self.energy = 0.0;
        self.recorded = 0;
    }

    /// Record the cohort z-score from `Judge` (Algorithm 2, Function 3);
    /// the planner commits it to the order part the worker is currently
    /// inside, so the part's seed survives iff its *latest* score was
    /// good — exactly Algorithm 1's `Scores[l] = score`.
    pub fn record_judge_score(&mut self, score: f32) {
        self.planner.record_score(score);
    }

    /// Order parts that kept their seed so far (telemetry).
    pub fn orders_kept(&self) -> u64 {
        self.planner.orders_kept()
    }

    /// Order parts that redrew their seed so far (telemetry).
    pub fn orders_redrawn(&self) -> u64 {
        self.planner.orders_redrawn()
    }

    /// Completed epochs (order regenerations).
    pub fn epoch(&self) -> u64 {
        self.planner.epoch()
    }

    /// Refill `out` with the next `batch` sample indices (wrapping to a
    /// new epoch as needed) — allocation-free on the hot loop.
    pub fn next_batch_into(&mut self, out: &mut Vec<u32>) {
        self.planner.next_batch_into(out);
    }

    /// The worker's sample-stream planner (test hook).
    pub fn planner_mut(&mut self) -> &mut BatchPlanner {
        &mut self.planner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn mk_worker(order_search: bool, shard: Option<(usize, usize)>) -> Worker {
        let labels: Vec<i32> = (0..120).map(|i| (i % 4) as i32).collect();
        let planner =
            BatchPlanner::new(0, Rng::new(5), 120, 10, shard, order_search, 4, None, labels);
        Worker::new(0, vec![0.0; 8], planner)
    }

    fn next(w: &mut Worker) -> Vec<u32> {
        let mut idx = Vec::new();
        w.next_batch_into(&mut idx);
        idx
    }

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let mut w = mk_worker(false, None);
        let mut seen = Vec::new();
        for _ in 0..12 {
            seen.extend(next(&mut w));
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..120u32).collect::<Vec<_>>());
        assert_eq!(w.epoch(), 0);
        next(&mut w);
        assert_eq!(w.epoch(), 1);
    }

    #[test]
    fn shard_restricts_indices() {
        let mut w = mk_worker(false, Some((30, 60)));
        for _ in 0..6 {
            for i in next(&mut w) {
                assert!((30..60).contains(&(i as usize)));
            }
        }
    }

    #[test]
    fn judge_scores_reach_the_planner() {
        let mut w = mk_worker(true, None);
        let first: Vec<u32> = (0..12).flat_map(|_| next(&mut w)).collect();
        // A good score at the end of the epoch keeps every visited seed.
        w.record_judge_score(-2.0);
        for part in 0..4 {
            w.planner_mut().order_state_mut().unwrap().record_score(part, -2.0);
        }
        let second: Vec<u32> = (0..12).flat_map(|_| next(&mut w)).collect();
        assert_eq!(first, second, "good scores must keep all seeds");
        assert!(w.orders_kept() > 0);
    }

    #[test]
    fn energy_window_accumulates_and_resets() {
        let mut w = mk_worker(false, None);
        assert_eq!(w.energy(), 1.0); // neutral before any record
        w.add_energy(0.5);
        w.add_energy(0.25);
        assert!((w.energy() - 0.75).abs() < 1e-6);
        w.reset_energy();
        assert_eq!(w.energy(), 1.0);
    }
}
