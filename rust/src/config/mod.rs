//! Typed configuration for experiments.
//!
//! One [`ExperimentConfig`] fully determines a run: dataset, model
//! variant (which artifact directory to load), algorithm, cohort
//! geometry (p, backups), the paper's hyper-parameters (τ, β, ã, m, c,
//! n), the cluster cost model and the seed. Presets reproduce the
//! paper's §5.2 settings; the CLI (`wasgd run …`) and every bench binary
//! construct these.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::cluster::fabric::Topology;
use crate::cluster::wire::WireEncoding;
use crate::cluster::{ComputeModel, FabricConfig};
use crate::data::source::{DataSpec, SourceKind};
use crate::data::synth::DatasetKind;
use crate::util::json::Json;

/// Which execution backend drives the numerics (see `crate::runtime`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// PJRT when the build has the `pjrt` feature and artifacts exist on
    /// disk; the pure-Rust native engine otherwise.
    #[default]
    Auto,
    /// Force the pure-Rust native engine (hermetic: no artifacts).
    Native,
    /// Force the PJRT artifact engine (errors without `--features pjrt`).
    Pjrt,
}

impl BackendKind {
    /// Every backend kind, in CLI listing order.
    pub const ALL: [BackendKind; 3] = [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt];

    /// CLI name (`--backend auto|native|pjrt`).
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse a CLI name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            _ => return None,
        })
    }
}

/// Which worker-fabric substrate carries the cohort's collectives.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum FabricKind {
    /// In-process deterministic simulation: virtual clocks, the explicit
    /// cluster cost model, every scheme — what the figures use.
    #[default]
    Sim,
    /// Real multi-process workers over loopback/LAN TCP (`wasgd serve` /
    /// `wasgd worker`): each OS process owns its own engine, panels are
    /// peer-relayed through a rendezvous node, and the Eq. 10+13 update
    /// is applied locally by every worker (no center variable). With the
    /// lossless f32 wire encoding the final parameters match `sim` bit
    /// for bit.
    Tcp,
}

impl FabricKind {
    /// Every fabric kind, in CLI listing order.
    pub const ALL: [FabricKind; 2] = [FabricKind::Sim, FabricKind::Tcp];

    /// CLI name (`--fabric sim|tcp`).
    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::Sim => "sim",
            FabricKind::Tcp => "tcp",
        }
    }

    /// Parse a CLI name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sim" => FabricKind::Sim,
            "tcp" => FabricKind::Tcp,
            _ => return None,
        })
    }
}

/// Which parallel scheme to run — the paper's benchmark set (§5.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// Standard sequential SGD (one worker).
    Sequential,
    /// SimuParallelSGD — Zinkevich et al. 2010: split data, average once.
    Spsgd,
    /// Elastic Averaging SGD — Zhang et al. 2015 (center variable).
    Easgd,
    /// Original multiplicative-weight update (full-dataset weights).
    Omwu,
    /// MWU with the paper's free loss estimation.
    Mmwu,
    /// WASGD (ICDM'19): inverse-loss weights, β=1, tail estimation.
    Wasgd,
    /// WASGD+ (this paper): Boltzmann weights, β-negotiation, order search.
    WasgdPlus,
    /// Asynchronous WASGD+ with b backup workers (Algorithm 4).
    WasgdPlusAsync,
}

impl AlgoKind {
    /// Every scheme, in the paper's benchmark-table order.
    pub const ALL: [AlgoKind; 8] = [
        AlgoKind::Sequential,
        AlgoKind::Spsgd,
        AlgoKind::Easgd,
        AlgoKind::Omwu,
        AlgoKind::Mmwu,
        AlgoKind::Wasgd,
        AlgoKind::WasgdPlus,
        AlgoKind::WasgdPlusAsync,
    ];

    /// CLI name (`--algo …`).
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Sequential => "sgd",
            AlgoKind::Spsgd => "spsgd",
            AlgoKind::Easgd => "easgd",
            AlgoKind::Omwu => "omwu",
            AlgoKind::Mmwu => "mmwu",
            AlgoKind::Wasgd => "wasgd",
            AlgoKind::WasgdPlus => "wasgd+",
            AlgoKind::WasgdPlusAsync => "wasgd+async",
        }
    }

    /// Parse a CLI name; `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sgd" | "sequential" => AlgoKind::Sequential,
            "spsgd" => AlgoKind::Spsgd,
            "easgd" => AlgoKind::Easgd,
            "omwu" => AlgoKind::Omwu,
            "mmwu" => AlgoKind::Mmwu,
            "wasgd" => AlgoKind::Wasgd,
            "wasgd+" | "wasgdplus" => AlgoKind::WasgdPlus,
            "wasgd+async" | "wasgd_async" => AlgoKind::WasgdPlusAsync,
            _ => return None,
        })
    }
}

/// Full experiment description. `Default` is a fast tiny-workload run;
/// [`ExperimentConfig::paper_preset`] reproduces §5.2 per dataset.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Which dataset family the run trains on.
    pub dataset: DatasetKind,
    /// Which data provider materialises it (`--source`, default auto:
    /// real files when `data_dir` holds them, synth otherwise). See
    /// [`crate::data::DataPipeline`].
    pub source: SourceKind,
    /// Directory holding real MNIST/Fashion-MNIST/CIFAR files
    /// (`--data-dir`); `None` trains on the synthetic analogue.
    pub data_dir: Option<PathBuf>,
    /// Artifact directory name under `artifacts_root` (model variant).
    pub variant: String,
    /// Root directory holding per-variant artifact directories.
    pub artifacts_root: PathBuf,
    /// Execution backend (PJRT artifacts vs the pure-Rust native engine).
    pub backend: BackendKind,
    /// Worker-fabric substrate: the deterministic simulation or real TCP
    /// processes (`--fabric sim|tcp`).
    pub fabric: FabricKind,
    /// Which parallel-SGD scheme runs.
    pub algo: AlgoKind,
    /// Number of primary workers p.
    pub p: usize,
    /// Backup workers b (async WASGD+ only).
    pub backups: usize,
    /// Communication period τ (local steps between collectives).
    pub tau: usize,
    /// Acceptance β of the aggregation result (Eq. 10).
    pub beta: f32,
    /// Boltzmann temperature ã (Eq. 13). T = 1/ã.
    pub a_tilde: f32,
    /// Estimation sample count m (recorded batches per period).
    pub m: usize,
    /// Estimation spreading blocks c (Eq. 26 / RecordIndex).
    pub c: usize,
    /// Number of order parts n (Algorithm 1).
    pub n_parts: usize,
    /// Intra-op GEMM threads per backend instance (`--threads`; 0 = all
    /// available cores). Plumbed through backend construction into
    /// [`crate::kernels::Gemm`], whose row-panel partitioning makes the
    /// kernel outputs bit-identical at every value — the knob trades
    /// wall-clock only, never numerics.
    pub threads: usize,
    /// Learning rate η.
    pub lr: f32,
    /// Epoch budget (fractional allowed).
    pub epochs: f64,
    /// Evaluate every this many local iterations.
    pub eval_every: usize,
    /// Batches per evaluation pass (train and test each).
    pub eval_batches: usize,
    /// EASGD moving rate α (paper: 0.9/p or 0.009/p).
    pub easgd_alpha: Option<f32>,
    /// Base seed for everything stochastic.
    pub seed: u64,
    /// Interconnect cost model for the simulated cluster (and for
    /// estimating what measured TCP traffic would cost on that link).
    pub fabric_cost: FabricConfig,
    /// Compute model; `step_time_s = 0` means "calibrate from the real
    /// engine at startup".
    pub compute: ComputeModel,
    /// Stop early once train loss reaches this value (None = run budget).
    pub target_loss: Option<f64>,
    /// Track Eq. (27) weight-estimation error at every communication
    /// point (costs a full-dataset eval per boundary — Fig. 6 only).
    pub track_estimation_error: bool,
    /// Force a δ-label-blocked sample order (Fig. 3 order-effect study);
    /// disables the order search.
    pub force_delta_order: Option<usize>,
    /// Write an event-sourced run journal to this path (`--journal`):
    /// per-round panel digests, replayable with `wasgd replay`. Local
    /// instrumentation — never transported in the wire JSON (each
    /// participant decides its own journaling).
    pub journal: Option<PathBuf>,
    /// Epoch-based elastic membership (`--elastic`, tcp fabric only):
    /// workers may join, leave, and crash at epoch boundaries instead
    /// of a single death poisoning the cohort. Rides the wire JSON so
    /// welcomed workers know to heartbeat and to rejoin after an
    /// `EpochCommit`. See `docs/FABRIC.md`.
    pub elastic: bool,
    /// Worker heartbeat period in milliseconds (`--heartbeat-ms`,
    /// elastic sessions only); the rendezvous declares a peer dead
    /// after ~4 silent periods.
    pub heartbeat_ms: u64,
    /// Fewest workers an elastic epoch may commit with
    /// (`--min-workers`); the session errors out below this.
    pub min_workers: usize,
    /// Absolute step budget overriding the epochs-derived plan. The
    /// elastic rendezvous sets this per epoch (remaining steps), so the
    /// per-epoch wire config replays as a self-contained run; `None`
    /// (the CLI default) plans from `epochs` as usual.
    pub step_budget: Option<usize>,
    /// Panel wire encoding (`--encoding f32|qi8|topk:R`). Rides the wire
    /// JSON because the top-k *rate* determines the numerics every
    /// worker (and `wasgd replay`) must reproduce — the frame header
    /// only carries the encoding family.
    pub encoding: WireEncoding,
    /// Exchange topology (`--topology full|ring|gossip:F`): which peers'
    /// panels each rank aggregates per round. Rides the wire JSON so
    /// every participant computes the same deterministic exchange
    /// schedule. See `docs/FABRIC.md`.
    pub topology: Topology,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Tiny,
            source: SourceKind::Auto,
            data_dir: None,
            variant: "tiny_mlp".to_string(),
            artifacts_root: PathBuf::from("artifacts"),
            backend: BackendKind::Auto,
            fabric: FabricKind::Sim,
            algo: AlgoKind::WasgdPlus,
            p: 4,
            backups: 0,
            tau: 50,
            beta: 0.9,
            a_tilde: 1.0,
            m: 10,
            c: 2,
            n_parts: 4,
            threads: 1,
            lr: 0.05,
            epochs: 2.0,
            eval_every: 50,
            eval_batches: 4,
            easgd_alpha: None,
            seed: 42,
            fabric_cost: FabricConfig::default(),
            compute: ComputeModel { step_time_s: 0.0, ..ComputeModel::default() },
            target_loss: None,
            track_estimation_error: false,
            force_delta_order: None,
            journal: None,
            elastic: false,
            heartbeat_ms: 500,
            min_workers: 1,
            step_budget: None,
            encoding: WireEncoding::F32,
            topology: Topology::Full,
        }
    }
}

impl ExperimentConfig {
    /// The paper's §5.2 settings for one dataset, **rescaled to this
    /// testbed** (DESIGN.md §3):
    ///
    /// * η — the paper runs per-sample SGD; our artifacts are B=32
    ///   mini-batched, so η is scaled by √B (≈5.7×) to keep the gradient
    ///   noise per unit progress — the regime the weighting scheme acts
    ///   on — comparable (0.01 → 0.05 for (F)MNIST, 0.001 → 0.005 for
    ///   CIFAR).
    /// * τ — the paper's τ=1000 against 50–60k per-sample iterations per
    ///   epoch is ~50–60 communications per epoch; at our 128–256
    ///   batch-iterations per epoch the same *communication density* is
    ///   τ≈50 (≈5/epoch, the paper's large-τ regime relative to machine
    ///   throughput). The τ-sweep harness still explores 10…10⁴.
    /// * m/τ — kept at the paper's ratio (m=100 of τ=1000 → m=10 of τ=50)
    ///   with c=2 spreading blocks.
    /// * β and T=1/ã — the §5.3 per-dataset optima, unchanged.
    pub fn paper_preset(dataset: DatasetKind) -> Self {
        let mut cfg = Self { dataset, ..Self::default() };
        cfg.variant = dataset.default_variant().to_string();
        cfg.tau = 50;
        cfg.m = 10;
        cfg.c = 2;
        cfg.n_parts = 4;
        match dataset {
            DatasetKind::Tiny => {
                cfg.lr = 0.05;
            }
            DatasetKind::MnistLike => {
                cfg.lr = 0.05;
                cfg.beta = 0.9; // §5.3.2
                cfg.a_tilde = 1.0; // T* = 1 (§5.3.1)
            }
            DatasetKind::FashionLike => {
                cfg.lr = 0.05;
                cfg.beta = 0.7;
                cfg.a_tilde = 0.1; // T* = 10
            }
            DatasetKind::Cifar10Like => {
                cfg.lr = 0.005;
                cfg.beta = 0.9;
                cfg.a_tilde = 1.0; // T* = 1
            }
            DatasetKind::Cifar100Like => {
                cfg.lr = 0.005;
                cfg.beta = 0.8;
                cfg.a_tilde = 10.0; // T* = 10⁻¹
            }
        }
        cfg
    }

    /// EASGD α default per the paper: 0.9/p (CIFAR) or 0.009/p (MNIST).
    pub fn easgd_alpha(&self) -> f32 {
        self.easgd_alpha.unwrap_or(match self.dataset {
            DatasetKind::Cifar10Like | DatasetKind::Cifar100Like => 0.9 / self.p as f32,
            _ => 0.009 / self.p as f32,
        })
    }

    /// The data-pipeline description this config implies — what
    /// [`crate::data::DataPipeline::from_config`] resolves and what the
    /// tcp fabric's wire JSON transports (with `source` concretised by
    /// the rendezvous, so every worker loads the same data).
    pub fn data_spec(&self) -> DataSpec {
        DataSpec { kind: self.dataset, source: self.source, data_dir: self.data_dir.clone() }
    }

    /// Effective temperature T = 1/ã (∞ when ã=0).
    pub fn temperature(&self) -> f32 {
        if self.a_tilde == 0.0 {
            f32::INFINITY
        } else {
            1.0 / self.a_tilde
        }
    }

    /// Artifact directory for the chosen variant.
    pub fn artifact_dir(&self) -> PathBuf {
        self.artifacts_root.join(&self.variant)
    }

    /// A short run label for logs/CSV ("wasgd+ p=4 τ=1000").
    pub fn label(&self) -> String {
        format!("{} p={} tau={}", self.algo.name(), self.p, self.tau)
    }

    /// Sanity-check the geometry; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if self.p == 0 {
            return Err("p must be ≥ 1".into());
        }
        if !(0.0..=1.0).contains(&self.beta) {
            return Err(format!("β must be in [0,1], got {}", self.beta));
        }
        if self.a_tilde < 0.0 {
            return Err("ã must be ≥ 0".into());
        }
        if self.tau == 0 {
            return Err("τ must be ≥ 1".into());
        }
        if self.m == 0 || self.c == 0 {
            return Err("m and c must be ≥ 1".into());
        }
        if self.algo == AlgoKind::WasgdPlusAsync && self.backups == 0 {
            return Err("async WASGD+ needs backups ≥ 1".into());
        }
        // Data-source consistency lives in one place: the spec's own
        // static rules (no filesystem access here).
        if let Err(e) = self.data_spec().check() {
            return Err(e.to_string());
        }
        if self.fabric == FabricKind::Tcp {
            match self.algo {
                AlgoKind::Spsgd
                | AlgoKind::Easgd
                | AlgoKind::Mmwu
                | AlgoKind::Wasgd
                | AlgoKind::WasgdPlus => {}
                other => {
                    return Err(format!(
                        "--fabric tcp supports the synchronous decentralized schemes \
                         (spsgd, easgd, mmwu, wasgd, wasgd+); {} needs --fabric sim",
                        other.name()
                    ))
                }
            }
            if self.target_loss.is_some() {
                return Err(
                    "--fabric tcp runs a fixed step budget; --target-loss needs --fabric sim"
                        .into(),
                );
            }
        }
        // Elastic knobs are checked regardless of fabric: `wasgd
        // replay` rebuilds elastic epoch configs under sim rules, and
        // they must validate there too.
        if self.elastic {
            if self.heartbeat_ms == 0 {
                return Err("--heartbeat-ms must be ≥ 1".into());
            }
            if self.min_workers == 0 {
                return Err("--min-workers must be ≥ 1".into());
            }
            if self.encoding != WireEncoding::F32 {
                return Err(format!(
                    "--elastic requires --encoding f32 (epoch anchors are decoded from the \
                     relayed panel bytes), got {}",
                    self.encoding.label()
                ));
            }
            if self.topology != Topology::Full {
                return Err(format!(
                    "--elastic requires --topology full (epoch anchors need every member's \
                     panel at the commit boundary), got {}",
                    self.topology.label()
                ));
            }
        }
        // Topology rules hold on every fabric: replay rebuilds tcp
        // configs under sim rules and must re-run the same schedule.
        match self.topology {
            Topology::Full => {}
            Topology::Ring => {
                if self.p < 2 {
                    return Err("--topology ring needs p ≥ 2".into());
                }
            }
            Topology::Gossip { fanout } => {
                if self.p < 2 {
                    return Err("--topology gossip needs p ≥ 2".into());
                }
                if fanout == 0 {
                    return Err("--topology gossip:F needs fanout ≥ 1".into());
                }
                match self.algo {
                    AlgoKind::Spsgd | AlgoKind::Wasgd | AlgoKind::WasgdPlus => {}
                    other => {
                        return Err(format!(
                            "--topology gossip renormalizes stateless per-round weights over \
                             the sampled subset; {} carries cross-round aggregation state and \
                             needs --topology full or ring",
                            other.name()
                        ))
                    }
                }
            }
        }
        Ok(())
    }

    /// Serialise the numerics-determining subset of this config as the
    /// wire JSON the rendezvous node ships in its Welcome. Lossless for
    /// every field: f32 hyper-parameters survive the f64 JSON round trip
    /// bit-exactly (f32 → f64 is exact; the serializer prints shortest
    /// round-trip decimals), and the u64 seed rides as a string because
    /// JSON numbers only cover 2⁵³.
    pub fn to_wire_json(&self) -> String {
        let mut m = BTreeMap::new();
        let num = Json::Num;
        m.insert("dataset".to_string(), Json::Str(self.dataset.name().to_string()));
        m.insert("source".to_string(), Json::Str(self.source.name().to_string()));
        m.insert(
            "data_dir".to_string(),
            match &self.data_dir {
                Some(dir) => Json::Str(dir.display().to_string()),
                None => Json::Null,
            },
        );
        m.insert("variant".to_string(), Json::Str(self.variant.clone()));
        m.insert("algo".to_string(), Json::Str(self.algo.name().to_string()));
        m.insert("backend".to_string(), Json::Str(self.backend.name().to_string()));
        m.insert("p".to_string(), num(self.p as f64));
        m.insert("backups".to_string(), num(self.backups as f64));
        m.insert("tau".to_string(), num(self.tau as f64));
        m.insert("beta".to_string(), num(self.beta as f64));
        m.insert("a_tilde".to_string(), num(self.a_tilde as f64));
        m.insert("m".to_string(), num(self.m as f64));
        m.insert("c".to_string(), num(self.c as f64));
        m.insert("n_parts".to_string(), num(self.n_parts as f64));
        m.insert("threads".to_string(), num(self.threads as f64));
        m.insert("lr".to_string(), num(self.lr as f64));
        m.insert("epochs".to_string(), num(self.epochs));
        m.insert("seed".to_string(), Json::Str(self.seed.to_string()));
        m.insert(
            "easgd_alpha".to_string(),
            match self.easgd_alpha {
                Some(a) => num(a as f64),
                None => Json::Null,
            },
        );
        m.insert(
            "force_delta_order".to_string(),
            match self.force_delta_order {
                Some(d) => num(d as f64),
                None => Json::Null,
            },
        );
        m.insert("elastic".to_string(), Json::Bool(self.elastic));
        m.insert("heartbeat_ms".to_string(), num(self.heartbeat_ms as f64));
        m.insert("min_workers".to_string(), num(self.min_workers as f64));
        m.insert(
            "step_budget".to_string(),
            match self.step_budget {
                Some(s) => num(s as f64),
                None => Json::Null,
            },
        );
        m.insert("encoding".to_string(), Json::Str(self.encoding.label()));
        m.insert("topology".to_string(), Json::Str(self.topology.label()));
        Json::Obj(m).serialize()
    }

    /// Rebuild a config from [`ExperimentConfig::to_wire_json`] output.
    /// Untransported fields (eval cadence, cost models, checkpointing)
    /// take their defaults — none of them influence the fabric loop's
    /// numerics. The result always has `fabric = tcp` and is validated.
    pub fn from_wire_json(s: &str) -> anyhow::Result<Self> {
        Self::from_wire_json_as(s, FabricKind::Tcp)
    }

    /// [`ExperimentConfig::from_wire_json`] with an explicit fabric for
    /// the rebuilt config. The tcp handshake wants `Tcp` (workers must
    /// obey the tcp validation rules); `wasgd replay` wants `Sim`, which
    /// accepts every scheme a journal can record — a sim-only algorithm
    /// like async WASGD+ journals a wire config that would be rejected
    /// under the tcp rules but must still replay.
    pub fn from_wire_json_as(s: &str, fabric: FabricKind) -> anyhow::Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("wire config: {e}"))?;
        let req_f64 = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("wire config field {key:?} missing or not a number"))
        };
        let dataset_s = j.req_str("dataset")?;
        let dataset = DatasetKind::parse(dataset_s)
            .ok_or_else(|| anyhow::anyhow!("wire config names unknown dataset {dataset_s:?}"))?;
        let mut cfg = Self { dataset, ..Self::default() };
        cfg.fabric = fabric;
        // Absent data-source keys default to the pre-DataSpec behaviour
        // (auto with no data dir ⇒ synth), so a newer worker still
        // joins an older rendezvous cleanly.
        cfg.source = match j.get("source") {
            None | Some(Json::Null) => SourceKind::Auto,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("wire config source must be a string"))?;
                SourceKind::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("wire config names unknown data source {s:?}"))?
            }
        };
        cfg.data_dir = match j.get("data_dir") {
            None | Some(Json::Null) => None,
            Some(v) => Some(PathBuf::from(v.as_str().ok_or_else(|| {
                anyhow::anyhow!("wire config data_dir must be a string or null")
            })?)),
        };
        cfg.variant = j.req_str("variant")?.to_string();
        let algo_s = j.req_str("algo")?;
        cfg.algo = AlgoKind::parse(algo_s)
            .ok_or_else(|| anyhow::anyhow!("wire config names unknown algorithm {algo_s:?}"))?;
        let backend_s = j.req_str("backend")?;
        cfg.backend = BackendKind::parse(backend_s)
            .ok_or_else(|| anyhow::anyhow!("wire config names unknown backend {backend_s:?}"))?;
        cfg.p = j.req_usize("p")?;
        // Optional for wire-format back-compat: configs journaled or
        // shipped before the key existed read as "no backups".
        cfg.backups = match j.get("backups") {
            None | Some(Json::Null) => 0,
            Some(v) => v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("wire config backups must be an integer or null")
            })?,
        };
        cfg.tau = j.req_usize("tau")?;
        cfg.m = j.req_usize("m")?;
        cfg.c = j.req_usize("c")?;
        cfg.n_parts = j.req_usize("n_parts")?;
        cfg.threads = j.req_usize("threads")?;
        cfg.beta = req_f64("beta")? as f32;
        cfg.a_tilde = req_f64("a_tilde")? as f32;
        cfg.lr = req_f64("lr")? as f32;
        cfg.epochs = req_f64("epochs")?;
        let seed_s = j.req_str("seed")?;
        cfg.seed = seed_s
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("wire config seed {seed_s:?}: {e}"))?;
        cfg.easgd_alpha = match j.get("easgd_alpha") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64().ok_or_else(|| {
                anyhow::anyhow!("wire config easgd_alpha must be a number or null")
            })? as f32),
        };
        cfg.force_delta_order = match j.get("force_delta_order") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("wire config force_delta_order must be an integer or null")
            })?),
        };
        // Elastic keys are optional for wire-format back-compat: a v1
        // config (journaled or served before elasticity existed) reads
        // as a fixed-cohort session with the default knobs.
        cfg.elastic = match j.get("elastic") {
            None | Some(Json::Null) => false,
            Some(Json::Bool(b)) => *b,
            Some(_) => anyhow::bail!("wire config elastic must be a boolean or null"),
        };
        cfg.heartbeat_ms = match j.get("heartbeat_ms") {
            None | Some(Json::Null) => 500,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("wire config heartbeat_ms must be an integer"))?
                as u64,
        };
        cfg.min_workers = match j.get("min_workers") {
            None | Some(Json::Null) => 1,
            Some(v) => v
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("wire config min_workers must be an integer"))?,
        };
        cfg.step_budget = match j.get("step_budget") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_usize().ok_or_else(|| {
                anyhow::anyhow!("wire config step_budget must be an integer or null")
            })?),
        };
        // Encoding/topology keys are optional for wire-format
        // back-compat: a config journaled or shipped before lossy modes
        // existed reads as the lossless full-cohort session it was.
        cfg.encoding = match j.get("encoding") {
            None | Some(Json::Null) => WireEncoding::F32,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("wire config encoding must be a string"))?;
                WireEncoding::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("wire config names unknown panel encoding {s:?}")
                })?
            }
        };
        cfg.topology = match j.get("topology") {
            None | Some(Json::Null) => Topology::Full,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("wire config topology must be a string"))?;
                Topology::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("wire config names unknown exchange topology {s:?}")
                })?
            }
        };
        cfg.validate().map_err(|e| anyhow::anyhow!("wire config invalid: {e}"))?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_hyperparams() {
        // η rescaled by √B≈5.7 (per-sample → B=32), τ by comm density
        // (DESIGN.md §3); β and T stay at the paper's §5.3 optima.
        let c10 = ExperimentConfig::paper_preset(DatasetKind::Cifar10Like);
        assert_eq!(c10.lr, 0.005);
        assert_eq!(c10.tau, 50);
        assert_eq!(c10.m, 10);
        assert_eq!(c10.beta, 0.9);
        let mn = ExperimentConfig::paper_preset(DatasetKind::MnistLike);
        assert_eq!(mn.lr, 0.05);
        let fa = ExperimentConfig::paper_preset(DatasetKind::FashionLike);
        assert_eq!(fa.beta, 0.7);
        assert!((fa.temperature() - 10.0).abs() < 1e-6);
        let c100 = ExperimentConfig::paper_preset(DatasetKind::Cifar100Like);
        assert!((c100.temperature() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn easgd_alpha_follows_paper() {
        let mut c = ExperimentConfig::paper_preset(DatasetKind::Cifar10Like);
        c.p = 4;
        assert!((c.easgd_alpha() - 0.225).abs() < 1e-6);
        let mut m = ExperimentConfig::paper_preset(DatasetKind::MnistLike);
        m.p = 8;
        assert!((m.easgd_alpha() - 0.009 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn validation_rejects_bad_geometry() {
        let mut c = ExperimentConfig::default();
        assert!(c.validate().is_ok());
        c.beta = 1.5;
        assert!(c.validate().is_err());
        c.beta = 0.5;
        c.p = 0;
        assert!(c.validate().is_err());
        c.p = 2;
        c.algo = AlgoKind::WasgdPlusAsync;
        c.backups = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in AlgoKind::ALL {
            assert_eq!(AlgoKind::parse(a.name()), Some(a));
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn fabric_parse_roundtrip_and_default() {
        for f in FabricKind::ALL {
            assert_eq!(FabricKind::parse(f.name()), Some(f));
        }
        assert_eq!(FabricKind::parse("grpc"), None);
        assert_eq!(ExperimentConfig::default().fabric, FabricKind::Sim);
    }

    #[test]
    fn tcp_fabric_validation_rules() {
        let mut cfg = ExperimentConfig::default();
        cfg.fabric = FabricKind::Tcp;
        assert!(cfg.validate().is_ok(), "wasgd+ over tcp is the headline path");
        for algo in [AlgoKind::Spsgd, AlgoKind::Easgd, AlgoKind::Mmwu, AlgoKind::Wasgd] {
            cfg.algo = algo;
            assert!(cfg.validate().is_ok(), "{} should be tcp-capable", algo.name());
        }
        for algo in [AlgoKind::Sequential, AlgoKind::Omwu] {
            cfg.algo = algo;
            assert!(cfg.validate().is_err(), "{} must be rejected on tcp", algo.name());
        }
        cfg.algo = AlgoKind::WasgdPlus;
        cfg.target_loss = Some(0.5);
        assert!(cfg.validate().is_err(), "early stop is sim-only");
    }

    #[test]
    fn wire_json_roundtrip_is_lossless() {
        let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Cifar100Like);
        cfg.fabric = FabricKind::Tcp;
        cfg.p = 7;
        cfg.tau = 123;
        cfg.beta = 0.8;
        cfg.a_tilde = 10.0;
        cfg.lr = 0.005;
        cfg.epochs = 1.75;
        cfg.seed = u64::MAX - 3; // beyond 2^53: must survive as a string
        cfg.threads = 3;
        cfg.force_delta_order = Some(16);
        cfg.easgd_alpha = Some(0.125);
        cfg.source = SourceKind::Cifar;
        cfg.data_dir = Some(PathBuf::from("/srv/data/cifar"));
        cfg.elastic = true;
        cfg.heartbeat_ms = 250;
        cfg.min_workers = 3;
        cfg.step_budget = Some(4096);
        let json = cfg.to_wire_json();
        let back = ExperimentConfig::from_wire_json(&json).unwrap();
        assert_eq!(back.dataset, cfg.dataset);
        assert_eq!(back.source, cfg.source, "the resolved DataSpec source must ride the wire");
        assert_eq!(back.data_dir, cfg.data_dir, "workers must load from the same data dir");
        assert_eq!(back.variant, cfg.variant);
        assert_eq!(back.algo, cfg.algo);
        assert_eq!(back.backend, cfg.backend);
        assert_eq!(back.fabric, FabricKind::Tcp);
        assert_eq!(back.p, cfg.p);
        assert_eq!(back.tau, cfg.tau);
        assert_eq!(back.beta.to_bits(), cfg.beta.to_bits());
        assert_eq!(back.a_tilde.to_bits(), cfg.a_tilde.to_bits());
        assert_eq!(back.lr.to_bits(), cfg.lr.to_bits());
        assert_eq!(back.epochs.to_bits(), cfg.epochs.to_bits());
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.m, cfg.m);
        assert_eq!(back.c, cfg.c);
        assert_eq!(back.n_parts, cfg.n_parts);
        assert_eq!(back.threads, cfg.threads);
        assert_eq!(back.force_delta_order, cfg.force_delta_order);
        assert_eq!(
            back.easgd_alpha.unwrap().to_bits(),
            cfg.easgd_alpha.unwrap().to_bits(),
            "a custom EASGD α must reach the workers bit-exactly"
        );
        assert!(back.elastic, "the elastic flag must ride the wire");
        assert_eq!(back.heartbeat_ms, 250);
        assert_eq!(back.min_workers, 3);
        assert_eq!(back.step_budget, Some(4096), "the epoch's step budget must ride the wire");

        // Awkward f32 bit patterns survive too.
        cfg.beta = 0.700000048f32;
        cfg.a_tilde = f32::MIN_POSITIVE;
        cfg.force_delta_order = None;
        cfg.source = SourceKind::Auto;
        cfg.data_dir = None;
        let back = ExperimentConfig::from_wire_json(&cfg.to_wire_json()).unwrap();
        assert_eq!(back.beta.to_bits(), cfg.beta.to_bits());
        assert_eq!(back.a_tilde.to_bits(), cfg.a_tilde.to_bits());
        assert_eq!(back.force_delta_order, None);
        assert_eq!(back.source, SourceKind::Auto);
        assert_eq!(back.data_dir, None);
    }

    #[test]
    fn data_source_validation_rules() {
        let mut cfg = ExperimentConfig::default();
        cfg.source = SourceKind::Idx;
        assert!(cfg.validate().is_err(), "forced idx needs --data-dir");
        cfg.data_dir = Some(PathBuf::from("data"));
        assert!(cfg.validate().is_ok(), "tiny ships as idx in hermetic tests");
        cfg.dataset = DatasetKind::Cifar10Like;
        assert!(cfg.validate().is_err(), "cifar10 is not idx");
        cfg.source = SourceKind::Cifar;
        assert!(cfg.validate().is_ok());
        cfg.dataset = DatasetKind::MnistLike;
        assert!(cfg.validate().is_err(), "mnist is not cifar");
        cfg.source = SourceKind::Auto;
        assert!(cfg.validate().is_ok(), "auto composes with any family");
    }

    #[test]
    fn wire_json_without_data_spec_keys_defaults_to_synth_behaviour() {
        // A pre-DataSpec rendezvous ships a config without the
        // source/data_dir keys; a newer worker must adopt the old
        // semantics (auto + no dir ⇒ synth) instead of failing.
        let mut cfg = ExperimentConfig::default();
        cfg.fabric = FabricKind::Tcp;
        let mut doc = match Json::parse(&cfg.to_wire_json()).unwrap() {
            Json::Obj(m) => m,
            _ => unreachable!("wire config is an object"),
        };
        doc.remove("source");
        doc.remove("data_dir");
        let back = ExperimentConfig::from_wire_json(&Json::Obj(doc).serialize()).unwrap();
        assert_eq!(back.source, SourceKind::Auto);
        assert_eq!(back.data_dir, None);
    }

    #[test]
    fn wire_json_without_elastic_keys_reads_as_a_fixed_cohort() {
        // A v1 config (pre-elasticity) must still parse: fixed cohort,
        // default heartbeat knobs, epochs-derived step budget.
        let mut cfg = ExperimentConfig::default();
        cfg.fabric = FabricKind::Tcp;
        let mut doc = match Json::parse(&cfg.to_wire_json()).unwrap() {
            Json::Obj(m) => m,
            _ => unreachable!("wire config is an object"),
        };
        for key in ["elastic", "heartbeat_ms", "min_workers", "step_budget"] {
            doc.remove(key);
        }
        let back = ExperimentConfig::from_wire_json(&Json::Obj(doc).serialize()).unwrap();
        assert!(!back.elastic);
        assert_eq!(back.heartbeat_ms, 500);
        assert_eq!(back.min_workers, 1);
        assert_eq!(back.step_budget, None);
    }

    #[test]
    fn elastic_knobs_are_validated_even_under_sim_rules() {
        // `wasgd replay` rebuilds elastic epoch configs as sim; the
        // combination must validate (and bad knobs must not).
        let mut cfg = ExperimentConfig::default();
        cfg.elastic = true;
        cfg.step_budget = Some(0); // an epilogue epoch: legal
        assert!(cfg.validate().is_ok(), "elastic + sim is the replay path");
        cfg.heartbeat_ms = 0;
        assert!(cfg.validate().is_err());
        cfg.heartbeat_ms = 500;
        cfg.min_workers = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn wire_json_as_sim_accepts_every_journaled_scheme() {
        // `wasgd replay` rebuilds journaled configs under sim rules:
        // schemes the tcp fabric rejects (sequential, omwu, async
        // wasgd+) must still round-trip, backups included.
        let mut cfg = ExperimentConfig::default();
        cfg.algo = AlgoKind::WasgdPlusAsync;
        cfg.backups = 2;
        let json = cfg.to_wire_json();
        assert!(ExperimentConfig::from_wire_json(&json).is_err(), "async is sim-only on tcp");
        let back = ExperimentConfig::from_wire_json_as(&json, FabricKind::Sim).unwrap();
        assert_eq!(back.fabric, FabricKind::Sim);
        assert_eq!(back.backups, 2, "backups must ride the wire for async replay");

        // Back-compat: a config without the backups key reads as 0.
        let mut doc = match Json::parse(&json).unwrap() {
            Json::Obj(m) => m,
            _ => unreachable!("wire config is an object"),
        };
        doc.remove("backups");
        doc.insert("algo".to_string(), Json::Str("wasgd+".to_string()));
        let back = ExperimentConfig::from_wire_json(&Json::Obj(doc).serialize()).unwrap();
        assert_eq!(back.backups, 0);
    }

    #[test]
    fn wire_json_carries_encoding_and_topology() {
        let mut cfg = ExperimentConfig::default();
        cfg.fabric = FabricKind::Tcp;
        cfg.encoding = WireEncoding::TopK { k_ppm: 10_000 };
        cfg.topology = Topology::Ring;
        let back = ExperimentConfig::from_wire_json(&cfg.to_wire_json()).unwrap();
        assert_eq!(back.encoding, WireEncoding::TopK { k_ppm: 10_000 });
        assert_eq!(back.topology, Topology::Ring);

        cfg.topology = Topology::Gossip { fanout: 2 };
        let back = ExperimentConfig::from_wire_json(&cfg.to_wire_json()).unwrap();
        assert_eq!(back.topology, Topology::Gossip { fanout: 2 });
    }

    #[test]
    fn wire_json_without_encoding_keys_reads_as_lossless_full() {
        // A pre-lossy-modes config must still parse: f32 panels over
        // the full-cohort gather.
        let mut cfg = ExperimentConfig::default();
        cfg.fabric = FabricKind::Tcp;
        let mut doc = match Json::parse(&cfg.to_wire_json()).unwrap() {
            Json::Obj(m) => m,
            _ => unreachable!("wire config is an object"),
        };
        for key in ["encoding", "topology"] {
            doc.remove(key);
        }
        let back = ExperimentConfig::from_wire_json(&Json::Obj(doc).serialize()).unwrap();
        assert_eq!(back.encoding, WireEncoding::F32);
        assert_eq!(back.topology, Topology::Full);
    }

    #[test]
    fn topology_and_lossy_mode_validation_rules() {
        let mut cfg = ExperimentConfig::default();
        cfg.topology = Topology::Ring;
        assert!(cfg.validate().is_ok(), "ring at p=4");
        cfg.p = 1;
        assert!(cfg.validate().is_err(), "ring needs p ≥ 2");
        cfg.p = 4;
        cfg.topology = Topology::Gossip { fanout: 0 };
        assert!(cfg.validate().is_err(), "gossip needs fanout ≥ 1");
        cfg.topology = Topology::Gossip { fanout: 2 };
        assert!(cfg.validate().is_ok(), "wasgd+ gossip is the headline sparse path");
        cfg.algo = AlgoKind::Easgd;
        assert!(cfg.validate().is_err(), "easgd's center state is not subset-safe");
        cfg.algo = AlgoKind::Mmwu;
        assert!(cfg.validate().is_err(), "mwu's weight state is not subset-safe");
        cfg.algo = AlgoKind::Wasgd;
        assert!(cfg.validate().is_ok());

        // Elastic sessions stay on the lossless full-cohort path.
        let mut el = ExperimentConfig::default();
        el.elastic = true;
        el.encoding = WireEncoding::TopK { k_ppm: 10_000 };
        assert!(el.validate().is_err(), "elastic anchors need f32 panels");
        el.encoding = WireEncoding::F32;
        el.topology = Topology::Ring;
        assert!(el.validate().is_err(), "elastic anchors need the full gather");
        el.topology = Topology::Full;
        assert!(el.validate().is_ok());
    }

    #[test]
    fn wire_json_rejects_garbage() {
        assert!(ExperimentConfig::from_wire_json("not json").is_err());
        assert!(ExperimentConfig::from_wire_json("{}").is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.algo = AlgoKind::Omwu; // not fabric-capable → validate fails
        assert!(ExperimentConfig::from_wire_json(&cfg.to_wire_json()).is_err());
    }

    #[test]
    fn backend_parse_roundtrip_and_default() {
        for b in BackendKind::ALL {
            assert_eq!(BackendKind::parse(b.name()), Some(b));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(ExperimentConfig::default().backend, BackendKind::Auto);
        // Intra-op threading defaults to 1: opt-in throughput, and the
        // bit-determinism guarantee makes any other value safe.
        assert_eq!(ExperimentConfig::default().threads, 1);
    }
}
