//! Typed configuration for experiments.
//!
//! One [`ExperimentConfig`] fully determines a run: dataset, model
//! variant (which artifact directory to load), algorithm, cohort
//! geometry (p, backups), the paper's hyper-parameters (τ, β, ã, m, c,
//! n), the cluster cost model and the seed. Presets reproduce the
//! paper's §5.2 settings; the CLI (`wasgd run …`) and every bench binary
//! construct these.

use std::path::PathBuf;

use crate::cluster::{ComputeModel, FabricConfig};
use crate::data::synth::DatasetKind;

/// Which execution backend drives the numerics (see `crate::runtime`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// PJRT when the build has the `pjrt` feature and artifacts exist on
    /// disk; the pure-Rust native engine otherwise.
    #[default]
    Auto,
    /// Force the pure-Rust native engine (hermetic: no artifacts).
    Native,
    /// Force the PJRT artifact engine (errors without `--features pjrt`).
    Pjrt,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] = [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt];

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "auto" => BackendKind::Auto,
            "native" => BackendKind::Native,
            "pjrt" => BackendKind::Pjrt,
            _ => return None,
        })
    }
}

/// Which parallel scheme to run — the paper's benchmark set (§5.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// Standard sequential SGD (one worker).
    Sequential,
    /// SimuParallelSGD — Zinkevich et al. 2010: split data, average once.
    Spsgd,
    /// Elastic Averaging SGD — Zhang et al. 2015 (center variable).
    Easgd,
    /// Original multiplicative-weight update (full-dataset weights).
    Omwu,
    /// MWU with the paper's free loss estimation.
    Mmwu,
    /// WASGD (ICDM'19): inverse-loss weights, β=1, tail estimation.
    Wasgd,
    /// WASGD+ (this paper): Boltzmann weights, β-negotiation, order search.
    WasgdPlus,
    /// Asynchronous WASGD+ with b backup workers (Algorithm 4).
    WasgdPlusAsync,
}

impl AlgoKind {
    pub const ALL: [AlgoKind; 8] = [
        AlgoKind::Sequential,
        AlgoKind::Spsgd,
        AlgoKind::Easgd,
        AlgoKind::Omwu,
        AlgoKind::Mmwu,
        AlgoKind::Wasgd,
        AlgoKind::WasgdPlus,
        AlgoKind::WasgdPlusAsync,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::Sequential => "sgd",
            AlgoKind::Spsgd => "spsgd",
            AlgoKind::Easgd => "easgd",
            AlgoKind::Omwu => "omwu",
            AlgoKind::Mmwu => "mmwu",
            AlgoKind::Wasgd => "wasgd",
            AlgoKind::WasgdPlus => "wasgd+",
            AlgoKind::WasgdPlusAsync => "wasgd+async",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "sgd" | "sequential" => AlgoKind::Sequential,
            "spsgd" => AlgoKind::Spsgd,
            "easgd" => AlgoKind::Easgd,
            "omwu" => AlgoKind::Omwu,
            "mmwu" => AlgoKind::Mmwu,
            "wasgd" => AlgoKind::Wasgd,
            "wasgd+" | "wasgdplus" => AlgoKind::WasgdPlus,
            "wasgd+async" | "wasgd_async" => AlgoKind::WasgdPlusAsync,
            _ => return None,
        })
    }
}

/// Full experiment description. `Default` is a fast tiny-workload run;
/// [`ExperimentConfig::paper_preset`] reproduces §5.2 per dataset.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub dataset: DatasetKind,
    /// Artifact directory name under `artifacts_root` (model variant).
    pub variant: String,
    pub artifacts_root: PathBuf,
    /// Execution backend (PJRT artifacts vs the pure-Rust native engine).
    pub backend: BackendKind,
    pub algo: AlgoKind,
    /// Number of primary workers p.
    pub p: usize,
    /// Backup workers b (async WASGD+ only).
    pub backups: usize,
    /// Communication period τ (local steps between collectives).
    pub tau: usize,
    /// Acceptance β of the aggregation result (Eq. 10).
    pub beta: f32,
    /// Boltzmann temperature ã (Eq. 13). T = 1/ã.
    pub a_tilde: f32,
    /// Estimation sample count m (recorded batches per period).
    pub m: usize,
    /// Estimation spreading blocks c (Eq. 26 / RecordIndex).
    pub c: usize,
    /// Number of order parts n (Algorithm 1).
    pub n_parts: usize,
    /// Intra-op GEMM threads per backend instance (`--threads`; 0 = all
    /// available cores). Plumbed through backend construction into
    /// [`crate::kernels::Gemm`], whose row-panel partitioning makes the
    /// kernel outputs bit-identical at every value — the knob trades
    /// wall-clock only, never numerics.
    pub threads: usize,
    /// Learning rate η.
    pub lr: f32,
    /// Epoch budget (fractional allowed).
    pub epochs: f64,
    /// Evaluate every this many local iterations.
    pub eval_every: usize,
    /// Batches per evaluation pass (train and test each).
    pub eval_batches: usize,
    /// EASGD moving rate α (paper: 0.9/p or 0.009/p).
    pub easgd_alpha: Option<f32>,
    /// Base seed for everything stochastic.
    pub seed: u64,
    pub fabric: FabricConfig,
    /// Compute model; `step_time_s = 0` means "calibrate from the real
    /// engine at startup".
    pub compute: ComputeModel,
    /// Stop early once train loss reaches this value (None = run budget).
    pub target_loss: Option<f64>,
    /// Track Eq. (27) weight-estimation error at every communication
    /// point (costs a full-dataset eval per boundary — Fig. 6 only).
    pub track_estimation_error: bool,
    /// Force a δ-label-blocked sample order (Fig. 3 order-effect study);
    /// disables the order search.
    pub force_delta_order: Option<usize>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: DatasetKind::Tiny,
            variant: "tiny_mlp".to_string(),
            artifacts_root: PathBuf::from("artifacts"),
            backend: BackendKind::Auto,
            algo: AlgoKind::WasgdPlus,
            p: 4,
            backups: 0,
            tau: 50,
            beta: 0.9,
            a_tilde: 1.0,
            m: 10,
            c: 2,
            n_parts: 4,
            threads: 1,
            lr: 0.05,
            epochs: 2.0,
            eval_every: 50,
            eval_batches: 4,
            easgd_alpha: None,
            seed: 42,
            fabric: FabricConfig::default(),
            compute: ComputeModel { step_time_s: 0.0, ..ComputeModel::default() },
            target_loss: None,
            track_estimation_error: false,
            force_delta_order: None,
        }
    }
}

impl ExperimentConfig {
    /// The paper's §5.2 settings for one dataset, **rescaled to this
    /// testbed** (DESIGN.md §3):
    ///
    /// * η — the paper runs per-sample SGD; our artifacts are B=32
    ///   mini-batched, so η is scaled by √B (≈5.7×) to keep the gradient
    ///   noise per unit progress — the regime the weighting scheme acts
    ///   on — comparable (0.01 → 0.05 for (F)MNIST, 0.001 → 0.005 for
    ///   CIFAR).
    /// * τ — the paper's τ=1000 against 50–60k per-sample iterations per
    ///   epoch is ~50–60 communications per epoch; at our 128–256
    ///   batch-iterations per epoch the same *communication density* is
    ///   τ≈50 (≈5/epoch, the paper's large-τ regime relative to machine
    ///   throughput). The τ-sweep harness still explores 10…10⁴.
    /// * m/τ — kept at the paper's ratio (m=100 of τ=1000 → m=10 of τ=50)
    ///   with c=2 spreading blocks.
    /// * β and T=1/ã — the §5.3 per-dataset optima, unchanged.
    pub fn paper_preset(dataset: DatasetKind) -> Self {
        let mut cfg = Self { dataset, ..Self::default() };
        cfg.variant = dataset.default_variant().to_string();
        cfg.tau = 50;
        cfg.m = 10;
        cfg.c = 2;
        cfg.n_parts = 4;
        match dataset {
            DatasetKind::Tiny => {
                cfg.lr = 0.05;
            }
            DatasetKind::MnistLike => {
                cfg.lr = 0.05;
                cfg.beta = 0.9; // §5.3.2
                cfg.a_tilde = 1.0; // T* = 1 (§5.3.1)
            }
            DatasetKind::FashionLike => {
                cfg.lr = 0.05;
                cfg.beta = 0.7;
                cfg.a_tilde = 0.1; // T* = 10
            }
            DatasetKind::Cifar10Like => {
                cfg.lr = 0.005;
                cfg.beta = 0.9;
                cfg.a_tilde = 1.0; // T* = 1
            }
            DatasetKind::Cifar100Like => {
                cfg.lr = 0.005;
                cfg.beta = 0.8;
                cfg.a_tilde = 10.0; // T* = 10⁻¹
            }
        }
        cfg
    }

    /// EASGD α default per the paper: 0.9/p (CIFAR) or 0.009/p (MNIST).
    pub fn easgd_alpha(&self) -> f32 {
        self.easgd_alpha.unwrap_or(match self.dataset {
            DatasetKind::Cifar10Like | DatasetKind::Cifar100Like => 0.9 / self.p as f32,
            _ => 0.009 / self.p as f32,
        })
    }

    /// Effective temperature T = 1/ã (∞ when ã=0).
    pub fn temperature(&self) -> f32 {
        if self.a_tilde == 0.0 {
            f32::INFINITY
        } else {
            1.0 / self.a_tilde
        }
    }

    /// Artifact directory for the chosen variant.
    pub fn artifact_dir(&self) -> PathBuf {
        self.artifacts_root.join(&self.variant)
    }

    /// A short run label for logs/CSV ("wasgd+ p=4 τ=1000").
    pub fn label(&self) -> String {
        format!("{} p={} tau={}", self.algo.name(), self.p, self.tau)
    }

    /// Sanity-check the geometry; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if self.p == 0 {
            return Err("p must be ≥ 1".into());
        }
        if !(0.0..=1.0).contains(&self.beta) {
            return Err(format!("β must be in [0,1], got {}", self.beta));
        }
        if self.a_tilde < 0.0 {
            return Err("ã must be ≥ 0".into());
        }
        if self.tau == 0 {
            return Err("τ must be ≥ 1".into());
        }
        if self.m == 0 || self.c == 0 {
            return Err("m and c must be ≥ 1".into());
        }
        if self.algo == AlgoKind::WasgdPlusAsync && self.backups == 0 {
            return Err("async WASGD+ needs backups ≥ 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_hyperparams() {
        // η rescaled by √B≈5.7 (per-sample → B=32), τ by comm density
        // (DESIGN.md §3); β and T stay at the paper's §5.3 optima.
        let c10 = ExperimentConfig::paper_preset(DatasetKind::Cifar10Like);
        assert_eq!(c10.lr, 0.005);
        assert_eq!(c10.tau, 50);
        assert_eq!(c10.m, 10);
        assert_eq!(c10.beta, 0.9);
        let mn = ExperimentConfig::paper_preset(DatasetKind::MnistLike);
        assert_eq!(mn.lr, 0.05);
        let fa = ExperimentConfig::paper_preset(DatasetKind::FashionLike);
        assert_eq!(fa.beta, 0.7);
        assert!((fa.temperature() - 10.0).abs() < 1e-6);
        let c100 = ExperimentConfig::paper_preset(DatasetKind::Cifar100Like);
        assert!((c100.temperature() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn easgd_alpha_follows_paper() {
        let mut c = ExperimentConfig::paper_preset(DatasetKind::Cifar10Like);
        c.p = 4;
        assert!((c.easgd_alpha() - 0.225).abs() < 1e-6);
        let mut m = ExperimentConfig::paper_preset(DatasetKind::MnistLike);
        m.p = 8;
        assert!((m.easgd_alpha() - 0.009 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn validation_rejects_bad_geometry() {
        let mut c = ExperimentConfig::default();
        assert!(c.validate().is_ok());
        c.beta = 1.5;
        assert!(c.validate().is_err());
        c.beta = 0.5;
        c.p = 0;
        assert!(c.validate().is_err());
        c.p = 2;
        c.algo = AlgoKind::WasgdPlusAsync;
        c.backups = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn algo_parse_roundtrip() {
        for a in AlgoKind::ALL {
            assert_eq!(AlgoKind::parse(a.name()), Some(a));
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn backend_parse_roundtrip_and_default() {
        for b in BackendKind::ALL {
            assert_eq!(BackendKind::parse(b.name()), Some(b));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(ExperimentConfig::default().backend, BackendKind::Auto);
        // Intra-op threading defaults to 1: opt-in throughput, and the
        // bit-determinism guarantee makes any other value safe.
        assert_eq!(ExperimentConfig::default().threads, 1);
    }
}
