//! Shared machinery for the figure-regeneration harnesses (DESIGN.md §5).
//!
//! Every `bench_*` binary builds [`ExperimentConfig`]s, runs them through
//! the coordinator, reduces the record streams with the paper's Eq. (47)
//! scoring, and writes one CSV per figure under `results/`.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{RunOutput, Trainer};
use crate::data::Dataset;
use crate::metrics::{Record, RunLog};
use crate::runtime::{load_backend, Backend};

/// Where harness CSVs land.
pub const RESULTS_DIR: &str = "results";

/// A shared backend + dataset + calibrated step time for a whole sweep:
/// backend construction (for PJRT: seconds of XLA compilation) and
/// step-time calibration happen once, and every run in the sweep uses
/// the *same* simulated step cost so sim-time comparisons across
/// configurations are exact.
pub struct SharedEnv {
    /// The shared execution backend.
    pub engine: Box<dyn Backend>,
    /// The shared dataset (built once from the base seed).
    pub dataset: Dataset,
    /// Calibrated (or configured) seconds per local SGD step.
    pub step_time_s: f64,
}

impl SharedEnv {
    /// Build from a base config (dataset seed = base.seed; backend from
    /// `base.backend` — PJRT artifacts or the hermetic native engine;
    /// dataset from the resolved [`crate::data::DataPipeline`] — synth
    /// dim-adapted to the variant's input geometry, or real files under
    /// `--data-dir` — matching `run_experiment_full` and the worker
    /// fabrics).
    pub fn new(base: &ExperimentConfig) -> Result<Self> {
        let engine = load_backend(base)?;
        let dataset = crate::data::DataPipeline::from_config(base)?.load(engine.manifest())?;
        let step_time_s = if base.compute.step_time_s > 0.0 {
            base.compute.step_time_s
        } else {
            engine.calibrate_step_time(8)?
        };
        Ok(Self { engine, dataset, step_time_s })
    }

    /// Run one config against the shared backend/dataset.
    pub fn run(&self, cfg: &ExperimentConfig) -> Result<RunOutput> {
        let mut cfg = cfg.clone();
        cfg.compute.step_time_s = self.step_time_s;
        let mut tr = Trainer::new(cfg, self.engine.as_ref(), &self.dataset)?;
        tr.run()
    }

    /// Run one config across several seeds (the dataset stays fixed;
    /// seeds vary inits, orders and the cluster's stochasticity).
    pub fn run_seeds(&self, base: &ExperimentConfig, seeds: &[u64]) -> Result<Vec<RunOutput>> {
        let mut outs = Vec::with_capacity(seeds.len());
        for &s in seeds {
            let mut cfg = base.clone();
            cfg.seed = s;
            outs.push(self.run(&cfg)?);
        }
        Ok(outs)
    }
}

/// The paper's Eq. (47): for each candidate run i,
/// dᵢ = (1/N)·Σⱼ (v̄_jud(j) − vᵢ(j)), where v̄_jud is the per-record mean
/// of the baseline runs. Returns (mean over i, sample std over i) —
/// the figure's point and error bar. Positive = candidate better (its
/// metric is lower than the baseline's).
pub fn eq47_point(
    baselines: &[RunLog],
    candidates: &[RunLog],
    metric: impl Fn(&Record) -> f64,
) -> (f64, f64) {
    let n = baselines
        .iter()
        .chain(candidates.iter())
        .map(|r| r.records.len())
        .min()
        .unwrap_or(0);
    if n == 0 || baselines.is_empty() || candidates.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    // v̄_jud per record index.
    let mut jud = vec![0.0f64; n];
    for b in baselines {
        for j in 0..n {
            jud[j] += metric(&b.records[j]) / baselines.len() as f64;
        }
    }
    let ds: Vec<f64> = candidates
        .iter()
        .map(|c| {
            (0..n)
                .map(|j| jud[j] - metric(&c.records[j]))
                .sum::<f64>()
                / n as f64
        })
        .collect();
    let mean = ds.iter().sum::<f64>() / ds.len() as f64;
    let var = if ds.len() > 1 {
        ds.iter().map(|d| (d - mean).powi(2)).sum::<f64>() / (ds.len() - 1) as f64
    } else {
        0.0
    };
    (mean, var.sqrt())
}

/// Standard sweep-table printer: one row per swept value.
pub fn print_sweep(
    title: &str,
    axis: &str,
    rows: &[(String, f64, f64)], // (value label, point, err)
) {
    println!("\n== {title} ==");
    println!("{axis:>12}  {:>14}  {:>12}", "Δ vs baseline", "± err");
    for (label, point, err) in rows {
        println!("{label:>12}  {point:>14.6}  {err:>12.6}");
    }
}

/// Write a sweep CSV: `value,point,err` rows.
pub fn write_sweep_csv(
    path: &str,
    header: &str,
    rows: &[(String, f64, f64)],
) -> std::io::Result<()> {
    use std::io::Write as _;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{header}")?;
    for (label, point, err) in rows {
        writeln!(f, "{label},{point:.8},{err:.8}")?;
    }
    Ok(())
}

/// Harness-wide default seeds (the paper uses 5 repetitions).
pub const SWEEP_SEEDS: [u64; 5] = [11, 22, 33, 44, 55];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::metrics::Record;

    #[test]
    fn shared_env_runs_native_sweeps() {
        let mut base = ExperimentConfig::default();
        base.backend = BackendKind::Native;
        base.compute.step_time_s = 1e-3;
        base.epochs = 0.5;
        base.eval_every = 16;
        let env = SharedEnv::new(&base).unwrap();
        assert_eq!(env.engine.name(), "native");
        let out = env.run(&base).unwrap();
        assert!(out.log.records.len() >= 2);
        let outs = env.run_seeds(&base, &[1, 2]).unwrap();
        assert_eq!(outs.len(), 2);
    }

    fn log_with(losses: &[f64]) -> RunLog {
        let mut l = RunLog::new("x");
        for (i, &v) in losses.iter().enumerate() {
            l.push(Record {
                iteration: i as u64,
                epoch: i as f64,
                sim_time_s: i as f64,
                wall_time_s: i as f64,
                train_loss: v,
                train_error: v,
                test_loss: v,
                test_error: v,
            });
        }
        l
    }

    #[test]
    fn eq47_positive_when_candidate_lower() {
        let base = vec![log_with(&[2.0, 2.0]), log_with(&[2.0, 2.0])];
        let cand = vec![log_with(&[1.0, 1.0])];
        let (point, err) = eq47_point(&base, &cand, |r| r.train_loss);
        assert!((point - 1.0).abs() < 1e-12);
        assert_eq!(err, 0.0);
    }

    #[test]
    fn eq47_zero_for_identical() {
        let base = vec![log_with(&[1.5, 0.5, 0.25])];
        let cand = vec![log_with(&[1.5, 0.5, 0.25])];
        let (point, _) = eq47_point(&base, &cand, |r| r.train_loss);
        assert!(point.abs() < 1e-12);
    }

    #[test]
    fn eq47_handles_unequal_lengths() {
        let base = vec![log_with(&[2.0, 2.0, 2.0])];
        let cand = vec![log_with(&[1.0, 1.0])];
        let (point, _) = eq47_point(&base, &cand, |r| r.train_loss);
        assert!((point - 1.0).abs() < 1e-12);
    }

    #[test]
    fn eq47_empty_is_nan() {
        let (p, e) = eq47_point(&[], &[log_with(&[1.0])], |r| r.train_loss);
        assert!(p.is_nan() && e.is_nan());
    }
}
