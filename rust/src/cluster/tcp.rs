//! TCP worker fabric: the rendezvous relay and the remote worker's
//! [`Collective`] — decentralized WASGD on the wire.
//!
//! Topology: `wasgd serve` binds a listener and accepts exactly p
//! connections; each `wasgd worker` process connects, handshakes
//! ([`hello_frame`] → [`Welcome`] carrying its rank and the session's
//! [`ExperimentConfig`] as JSON), builds its own engine and dataset
//! (pure functions of the config), and runs
//! [`run_fabric_worker`] with a [`RemoteCluster`] as the collective. At
//! every τ-boundary a worker sends its `(h, θ)` [`Panel`]; the
//! rendezvous node barriers the round on a [`PanelExchange`] and relays
//! the full [`Cohort`] back to every peer, which then applies the
//! Boltzmann β-negotiation (Eq. 10+13) *locally* — the rendezvous never
//! aggregates and holds no center variable; it is a dumb relay, exactly
//! the role a switch or a gossip overlay would play.
//!
//! Failure semantics, fixed cohort: a worker that dies poisons the
//! exchange with a message naming its rank and last completed round;
//! every other relay handler then pushes an [`MsgKind::Error`] frame to
//! its worker so the whole cohort errors out instead of deadlocking.
//!
//! Failure semantics, elastic (`--elastic`, [`ServeOptions::elastic`]):
//! the session is a sequence of *epochs*, each a fixed-membership
//! mini-session. Workers heartbeat between panels; a dead or silent
//! peer, a [`MsgKind::Leave`], or a queued joiner *cuts* the epoch at
//! the last fully published round instead of poisoning it. Survivors
//! receive an [`MsgKind::EpochCommit`] and reconnect; the rendezvous
//! re-forms the cohort (survivors keep rank order, joiners append),
//! ships every member an anchor row in its [`Welcome`], and the next
//! epoch proceeds at the new member count — re-sharded automatically,
//! because `shard_range(n, rank, p)` is a pure function of the new
//! geometry. Each epoch journals as a self-contained segment terminated
//! by `EpochCommitted`, so `wasgd replay` verifies the whole run across
//! membership changes. See `docs/FABRIC.md` for the full state machine.
//!
//! Resumable rendezvous: a fixed-cohort `serve` can start from a saved
//! [`Checkpoint`] (each rank receives its `worker_{i}.f32` parameters in
//! the Welcome), and the final panels can be written back as a
//! checkpoint by the CLI — so a multi-process run survives restarts of
//! the whole fabric. Elastic sessions write *epoch anchors* (the
//! committed pre-aggregation panels) to `DIR/epoch_NNNN/` at every
//! boundary — plus a terminal anchor on completion — and can be resumed
//! from them: `--resume DIR` on an elastic serve seeds the first epoch's
//! formation from the latest anchor's rows, journaled as a round-0
//! commit so the stitched journal still verifies survivor by survivor.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::checkpoint::{latest_epoch_anchor, Checkpoint};
use crate::config::ExperimentConfig;
use crate::data::source::DataPipeline;
use crate::journal::{
    canonical_comm_bytes, digest_cohort, digest_params, fnv64, rank_journal_path, Event, EventSink,
    JournalWriter, MembershipChange, RANK_COHORT,
};
use crate::metrics::CommCounters;
use crate::runtime::load_backend;

use super::fabric::{
    algo_supports_fabric, planned_steps, round_origins, run_fabric_worker, Collective, EpochEnded,
    EpochPlan, FabricWorkerOutcome, PanelExchange, Topology, WorkerPanel,
};
use super::wire::{
    self, cohort_frame_from_raw, decode_vec, error_text, hello_frame, lossy_apply, Cohort,
    EpochCommit, Frame, Heartbeat, JoinRequest, Leave, MsgKind, Panel, RawPanel, Welcome,
    WireEncoding,
};

/// A remote worker's connection to the rendezvous node — the TCP
/// implementation of the fabric's all-gather/barrier surface.
pub struct RemoteCluster {
    reader: BufReader<TcpStream>,
    writer: Arc<Mutex<BufWriter<TcpStream>>>,
    rank: usize,
    p: usize,
    encoding: WireEncoding,
    topology: Topology,
    seed: u64,
    round: u64,
    completed_round: Arc<AtomicU64>,
    bytes_sent: u64,
    hb_bytes: Arc<AtomicU64>,
    bytes_received: u64,
    heartbeat: Option<HeartbeatHandle>,
}

/// A running heartbeat thread; dropping it stops the beats (and joins
/// the thread, waiting at most one period).
struct HeartbeatHandle {
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for HeartbeatHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            t.join().ok();
        }
    }
}

impl RemoteCluster {
    /// Connect to a rendezvous node and complete the handshake. Returns
    /// the cluster plus the [`Welcome`] (session config JSON and
    /// optional resume parameters). The Welcome frame's encoding byte
    /// announces the session's panel encoding.
    pub fn connect(addr: &str) -> Result<(Self, Welcome)> {
        Self::connect_as(addr, None)
    }

    /// Connect as a returning member of an elastic session: `rejoin`
    /// carries this worker's rank in the epoch that just committed, so
    /// the rendezvous can seat it before fresh joiners. Fresh workers
    /// (and fixed-cohort workers) pass `None` and open with a plain
    /// hello. Blocks until the next epoch forms and the Welcome
    /// arrives.
    pub fn connect_as(addr: &str, rejoin: Option<u32>) -> Result<(Self, Welcome)> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to rendezvous at {addr}"))?;
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone().context("cloning the rendezvous stream")?;
        let mut writer = BufWriter::new(stream);
        let mut reader = BufReader::new(read_half);

        let opening = match rejoin {
            None => hello_frame(),
            Some(r) => JoinRequest { prior_rank: Some(r) }.frame(),
        };
        opening.write_to(&mut writer)?;
        let bytes_sent = opening.encoded_len() as u64;

        let frame = Frame::read_from(&mut reader).context("waiting for the rendezvous welcome")?;
        let bytes_received = frame.encoded_len() as u64;
        if frame.kind == MsgKind::Error {
            bail!("rendezvous refused the connection: {}", error_text(&frame));
        }
        let welcome = Welcome::parse(&frame)?;
        ensure!(welcome.p > 0, "rendezvous announced an empty cohort");
        ensure!(
            welcome.rank < welcome.p,
            "rendezvous assigned rank {} in a cohort of {}",
            welcome.rank,
            welcome.p
        );
        Ok((
            Self {
                reader,
                writer: Arc::new(Mutex::new(writer)),
                rank: welcome.rank as usize,
                p: welcome.p as usize,
                encoding: frame.encoding,
                topology: Topology::Full,
                seed: 0,
                round: 0,
                completed_round: Arc::new(AtomicU64::new(0)),
                bytes_sent,
                hb_bytes: Arc::new(AtomicU64::new(0)),
                bytes_received,
                heartbeat: None,
            },
            welcome,
        ))
    }

    /// The session's panel encoding (dictated by the rendezvous node).
    pub fn encoding(&self) -> WireEncoding {
        self.encoding
    }

    /// Adopt the session's full communication modes from the welcomed
    /// wire config. The Welcome frame's header byte only carries the
    /// encoding *family* (a top-k header cannot spell its rate), so the
    /// worker upgrades to the rate-bearing encoding — and learns the
    /// exchange topology and the seed keying the gossip sampler — from
    /// the config JSON before its first collective.
    pub fn adopt_modes(
        &mut self,
        encoding: WireEncoding,
        topology: Topology,
        seed: u64,
    ) -> Result<()> {
        ensure!(
            encoding.id() == self.encoding.id(),
            "the welcome announced the {} encoding family but the session config says {}",
            self.encoding.name(),
            encoding.name()
        );
        ensure!(self.round == 0, "communication modes must be adopted before the first round");
        self.encoding = encoding;
        self.topology = topology;
        self.seed = seed;
        Ok(())
    }

    /// Read one relay reply, counting its bytes and converting Error /
    /// EpochCommit frames into the corresponding failure modes.
    fn read_reply(&mut self) -> Result<Frame> {
        let reply = Frame::read_from(&mut self.reader)
            .with_context(|| format!("waiting for cohort of round {}", self.round))?;
        self.bytes_received += reply.encoded_len() as u64;
        if reply.kind == MsgKind::Error {
            bail!("rendezvous aborted the session: {}", error_text(&reply));
        }
        if reply.kind == MsgKind::EpochCommit {
            // The epoch ended under this round: surface a recoverable
            // EpochEnded so the worker loop reconnects instead of dying.
            let commit = EpochCommit::parse(&reply)?;
            return Err(anyhow::Error::new(EpochEnded { reason: commit.reason }));
        }
        Ok(reply)
    }

    /// Start a background liveness thread sending one [`Heartbeat`]
    /// (carrying the last completed round) every `period`. The writer
    /// is mutex-shared with the training thread, so beats and panels
    /// never interleave mid-frame. Stops when the cluster is dropped or
    /// the connection dies. No-op if already beating.
    pub fn start_heartbeats(&mut self, period: Duration) {
        if self.heartbeat.is_some() {
            return;
        }
        let writer = Arc::clone(&self.writer);
        let round = Arc::clone(&self.completed_round);
        let bytes = Arc::clone(&self.hb_bytes);
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let thread = std::thread::spawn(move || loop {
            std::thread::sleep(period);
            if flag.load(Ordering::Relaxed) {
                return;
            }
            let frame = Heartbeat { round: round.load(Ordering::Relaxed) }.frame();
            let mut w = writer.lock().unwrap();
            if frame.write_to(&mut *w).is_err() {
                return;
            }
            bytes.fetch_add(frame.encoded_len() as u64, Ordering::Relaxed);
        });
        self.heartbeat = Some(HeartbeatHandle { stop, thread: Some(thread) });
    }

    /// Send the final `(mean energy, θ)` panel after the step budget.
    /// `steps` is the total local step count this worker ran (carried in
    /// the panel's round field so checkpoints record real progress).
    /// Finals always ride the lossless f32 encoding — they are the
    /// session's end state (checkpoints, the serve summary, bit-exact
    /// cross-topology comparisons), not part of the per-round traffic a
    /// lossy mode compresses.
    pub fn send_final(&mut self, steps: u64, mean_energy: f32, params: &[f32]) -> Result<()> {
        let frame = Panel::frame(MsgKind::Final, steps, mean_energy, params, WireEncoding::F32);
        frame.write_to(&mut *self.writer.lock().unwrap())?;
        self.bytes_sent += frame.encoded_len() as u64;
        Ok(())
    }
}

impl Collective for RemoteCluster {
    fn p(&self) -> usize {
        self.p
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn all_gather(&mut self, h: f32, params: &[f32]) -> Result<Vec<WorkerPanel>> {
        self.round += 1;
        let frame = Panel::frame(MsgKind::Panel, self.round, h, params, self.encoding);
        frame.write_to(&mut *self.writer.lock().unwrap())?;
        self.bytes_sent += frame.encoded_len() as u64;

        let panels = match self.topology {
            Topology::Full => {
                let cohort = Cohort::parse(&self.read_reply()?)?;
                ensure!(
                    cohort.round == self.round,
                    "cohort carries round {}, expected {}",
                    cohort.round,
                    self.round
                );
                ensure!(
                    cohort.panels.len() == self.p,
                    "cohort has {} panels, expected {}",
                    cohort.panels.len(),
                    self.p
                );
                cohort.panels
            }
            Topology::Ring => {
                // The relay delivers the cohort one neighbour hop at a
                // time: p−1 single-panel frames, the s-th carrying rank
                // (rank − s) mod p's panel. The own slot is filled from
                // the local encode→decode mirror — the relay never
                // echoes a rank its own panel in ring mode — so the
                // assembled content is identical to a full gather.
                let mut slots: Vec<Option<WorkerPanel>> =
                    (0..self.p).map(|_| None).collect();
                slots[self.rank] = Some((h, lossy_apply(self.encoding, params)));
                for s in 1..self.p {
                    let cohort = Cohort::parse(&self.read_reply()?)?;
                    ensure!(
                        cohort.round == self.round,
                        "ring hop {s} carries round {}, expected {}",
                        cohort.round,
                        self.round
                    );
                    ensure!(
                        cohort.panels.len() == 1,
                        "ring hop {s} carries {} panels, expected 1",
                        cohort.panels.len()
                    );
                    let origin = (self.rank + self.p - s) % self.p;
                    ensure!(
                        slots[origin].is_none(),
                        "ring hop {s} duplicates rank {origin}'s panel"
                    );
                    slots[origin] = cohort.panels.into_iter().next();
                }
                slots.into_iter().map(|s| s.expect("every ring slot is filled")).collect()
            }
            Topology::Gossip { .. } => {
                // One subset frame, rows in ascending-origin order —
                // the schedule is a pure function both sides compute.
                let origins =
                    round_origins(self.topology, self.p, self.rank, self.round, self.seed);
                let cohort = Cohort::parse(&self.read_reply()?)?;
                ensure!(
                    cohort.round == self.round,
                    "cohort carries round {}, expected {}",
                    cohort.round,
                    self.round
                );
                ensure!(
                    cohort.panels.len() == origins.len(),
                    "gossip round {} delivered {} panels, the sampling schedule expects {}",
                    self.round,
                    cohort.panels.len(),
                    origins.len()
                );
                cohort.panels
            }
        };
        self.completed_round.store(self.round, Ordering::Relaxed);
        Ok(panels)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent + self.hb_bytes.load(Ordering::Relaxed)
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    fn encoding(&self) -> WireEncoding {
        self.encoding
    }
}

/// Elastic-membership knobs for a rendezvous session (the epoch state
/// machine of `docs/FABRIC.md`). Present = elastic; absent = the
/// classic fixed-cohort session.
pub struct ElasticOptions {
    /// Commit an epoch only if at least this many workers are present;
    /// fewer and the session fails rather than limp along.
    pub min_workers: usize,
    /// Never grow the cohort past this many workers; extra joiners stay
    /// parked until a seat frees up.
    pub max_workers: usize,
    /// Worker heartbeat period; the relay declares a peer dead after
    /// 4 missed beats.
    pub heartbeat_ms: u64,
    /// Write the committed anchor (pre-aggregation panels of the last
    /// published round) as a checkpoint under this directory at every
    /// epoch boundary.
    pub anchor_dir: Option<PathBuf>,
}

/// What a rendezvous session runs: the experiment, the panel encoding,
/// and optionally a checkpoint to resume the cohort from.
pub struct ServeOptions {
    /// The session config, shipped verbatim to every worker.
    pub cfg: ExperimentConfig,
    /// Panel encoding on the wire (f32 = lossless, qi8 = 4× smaller).
    pub encoding: WireEncoding,
    /// Resume each rank from `workers[rank]` of this checkpoint.
    pub resume: Option<Checkpoint>,
    /// Journal the session's event stream here. A resumed session
    /// *appends*, stitching its segment onto the original journal; a
    /// fresh session truncates. With the f32 encoding the relay digests
    /// every rank's raw panel bytes per round (numerics-free: the f32
    /// panel body IS θ's little-endian bytes), making the journal
    /// bit-exactly verifiable with `wasgd replay`.
    pub journal: Option<PathBuf>,
    /// Run with epoch-based elastic membership instead of a fixed
    /// cohort: workers may join, leave, and crash at epoch boundaries.
    pub elastic: Option<ElasticOptions>,
}

/// What a completed rendezvous session produced.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Final `(mean energy, θ)` per rank, in rank order (of the final
    /// epoch's cohort, for elastic sessions).
    pub finals: Vec<WorkerPanel>,
    /// Collective rounds relayed (τ-boundaries crossed), cumulative
    /// across epochs.
    pub rounds: u64,
    /// Local SGD steps each worker ran (as reported in its Final panel;
    /// the max across ranks — they agree in a well-formed session). For
    /// elastic sessions, cumulative across epochs.
    pub steps: u64,
    /// Per-peer relay traffic, feeding the cluster cost model. Elastic
    /// sessions attribute traffic at epoch-local ranks.
    pub comm: CommCounters,
    /// Every epoch boundary's human-readable commit reason, in order
    /// (who died/left/joined/finished, at what round — the same strings
    /// the journal's `EpochCommitted` records carry). Empty for
    /// fixed-cohort sessions, which have no boundaries.
    pub commit_reasons: Vec<String>,
}

struct RelayStats {
    sent: u64,
    received: u64,
    rounds: u64,
}

/// A silent non-protocol connection may stall the handshake read at most
/// this long before being dropped.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(30);
/// Give up on the session after this many failed handshakes.
const MAX_BAD_HANDSHAKES: usize = 64;
/// How long an elastic boundary waits for committed survivors to
/// reconnect before forming the next epoch with whoever is present.
const FORMATION_TIMEOUT: Duration = Duration::from_secs(10);

type HandshakeOk = (BufReader<TcpStream>, BufWriter<TcpStream>, u64, u64);

/// Validate one connection's hello and answer with its Welcome. The
/// read timeout applies only during the handshake (relay reads must
/// block indefinitely: τ compute periods are legitimately long).
fn handshake(
    stream: &TcpStream,
    rank: usize,
    p: usize,
    cfg_json: &str,
    opts: &ServeOptions,
) -> Result<HandshakeOk> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    let read_half = stream.try_clone().context("cloning a worker stream")?;
    let mut reader = BufReader::new(read_half);
    let hello = Frame::read_from(&mut reader).context("reading the hello")?;
    ensure!(hello.kind == MsgKind::Hello, "opened with {:?}, expected a hello", hello.kind);
    stream.set_read_timeout(None).ok();
    let mut writer = BufWriter::new(stream.try_clone().context("cloning a worker stream")?);
    let welcome = Welcome {
        rank: rank as u32,
        p: p as u32,
        config_json: cfg_json.to_string(),
        resume: opts.resume.as_ref().map(|ck| ck.workers[rank].clone()),
    };
    let frame = welcome.frame(opts.encoding);
    frame.write_to(&mut writer).context("writing the welcome")?;
    Ok((reader, writer, hello.encoded_len() as u64, frame.encoded_len() as u64))
}

/// Run one rendezvous session to completion. With
/// [`ServeOptions::elastic`] unset this is the classic fixed cohort:
/// accept `cfg.p` workers (rank = accept order), handshake each, then
/// relay `(h, θ)` panels round by round until every worker has delivered
/// its final panel. With it set, the session advances through epochs
/// with committed member sets (see the module docs).
///
/// The rendezvous is numerics-free: it never touches θ beyond framing,
/// so the aggregation stays fully decentralized (each worker applies
/// Eq. 10+13 itself — no center variable anywhere). The one exception
/// is the elastic anchor, which decodes the relay's *own* already-f32
/// bytes back into floats; no arithmetic is ever performed on them.
pub fn serve(listener: TcpListener, opts: &ServeOptions) -> Result<ServeOutcome> {
    match &opts.elastic {
        None => serve_static(listener, opts),
        Some(el) => serve_elastic(listener, opts, el),
    }
}

fn serve_static(listener: TcpListener, opts: &ServeOptions) -> Result<ServeOutcome> {
    let cfg = &opts.cfg;
    cfg.validate().map_err(|e| anyhow!(e))?;
    ensure!(
        algo_supports_fabric(cfg.algo),
        "the tcp fabric supports the synchronous decentralized schemes; {} needs --fabric sim",
        cfg.algo.name()
    );
    let p = cfg.p;
    if let Some(ck) = &opts.resume {
        ensure!(
            ck.workers.len() == p,
            "resume checkpoint has {} workers, session wants p={p}",
            ck.workers.len()
        );
    }
    // Ship a *concrete* data source in the wire config: the rendezvous
    // resolves `auto` against its own filesystem once, so a worker
    // whose host is missing the promised files errors out pointedly
    // instead of silently training on the synthetic analogue (which
    // would de-synchronise the cohort's data).
    let wire_cfg = {
        let pipeline = DataPipeline::from_config(cfg)?;
        if let Some(note) = pipeline.note() {
            eprintln!("rendezvous: {note}");
        }
        let mut c = cfg.clone();
        c.source = pipeline.source_kind();
        // The Welcome header byte carries only the encoding *family*; the
        // wire config is where workers learn the authoritative rate-bearing
        // encoding (e.g. topk:0.01), the topology, and the schedule seed —
        // adopted via `RemoteCluster::adopt_modes` before round 1.
        c.encoding = opts.encoding;
        c
    };
    let cfg_json = wire_cfg.to_wire_json();
    let mut comm = CommCounters::new(p);

    // Cohort-scope journal: the rendezvous sees every rank's panel, so
    // its journal carries the whole cohort's digests — and, on resume,
    // all p checkpoint vectors (workers only ever learn their own),
    // which is why `wasgd replay` verifies *this* journal for resumed
    // sessions. Resume appends: the stitched file replays segment by
    // segment.
    let journal: Option<Mutex<JournalWriter>> = match &opts.journal {
        Some(path) => Some(Mutex::new(if opts.resume.is_some() {
            JournalWriter::append_to(path)?
        } else {
            JournalWriter::create(path)?
        })),
        None => None,
    };
    jemit(
        journal.as_ref(),
        &Event::RunStarted {
            rank: RANK_COHORT,
            p: p as u32,
            seed: cfg.seed,
            encoding: opts.encoding,
            git_rev: crate::bench::git_rev(),
            config_json: cfg_json.clone(),
            resume: opts.resume.as_ref().map(|ck| ck.workers.clone()).unwrap_or_default(),
        },
    )?;

    // Handshake phase: rank = accept order *of completed handshakes*. A
    // stray connection (port scan, health probe) is dropped — after a
    // bounded read timeout if it stays silent — and the rank re-offered,
    // instead of wedging the serial accept loop or aborting the session.
    let mut bad_handshakes = 0usize;
    let mut conns = Vec::with_capacity(p);
    while conns.len() < p {
        let rank = conns.len();
        let (stream, peer) = listener.accept().context("accepting a worker connection")?;
        stream.set_nodelay(true).ok();
        match handshake(&stream, rank, p, &cfg_json, opts) {
            Ok((reader, writer, hello_len, welcome_len)) => {
                comm.add(rank, welcome_len, hello_len);
                jemit(
                    journal.as_ref(),
                    &Event::Membership {
                        epoch: 0,
                        rank: rank as u32,
                        change: MembershipChange::Joined,
                    },
                )?;
                conns.push((reader, writer));
            }
            Err(e) => {
                bad_handshakes += 1;
                eprintln!("rendezvous: dropping connection from {peer}: {e:#}");
                ensure!(
                    bad_handshakes < MAX_BAD_HANDSHAKES,
                    "{bad_handshakes} failed handshakes — is something else probing this port?"
                );
            }
        }
    }

    // Relay phase: one handler thread per connection, barriered on a
    // poisonable exchange. Panels stay in their *encoded* form end to
    // end — the relay validates framing and memcpys bytes, it never
    // decodes θ (and so can never re-quantise a qi8 panel).
    let exchange: PanelExchange<(f32, Vec<u8>)> = PanelExchange::new(p);
    let finals: Mutex<Vec<Option<(u64, WorkerPanel)>>> = Mutex::new(vec![None; p]);
    let ctx = RelayCtx {
        exchange: &exchange,
        finals: &finals,
        enc: opts.encoding,
        topology: cfg.topology,
        seed: cfg.seed,
        journal: journal.as_ref(),
    };
    let results: Vec<Result<RelayStats>> = std::thread::scope(|s| {
        let ctx = &ctx;
        let handles: Vec<_> = conns
            .into_iter()
            .enumerate()
            .map(|(rank, (mut reader, mut writer))| {
                s.spawn(move || {
                    let mut stats = RelayStats { sent: 0, received: 0, rounds: 0 };
                    let result = relay_loop(rank, &mut reader, &mut writer, ctx, &mut stats);
                    if let Err(e) = &result {
                        // Name the offending rank AND its last completed
                        // round, so a dead-peer error localises the
                        // failure in training time, not just space.
                        ctx.exchange.poison(&format!(
                            "relay for rank {rank} failed after round {}: {e}",
                            stats.rounds
                        ));
                        let _ = wire::error_frame(&format!("{e}")).write_to(&mut writer);
                    }
                    result.map(|()| stats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("relay thread panicked"))))
            .collect()
    });

    let mut rounds = 0u64;
    for (rank, result) in results.into_iter().enumerate() {
        let stats = result.with_context(|| format!("worker rank {rank}"))?;
        comm.add(rank, stats.sent, stats.received);
        rounds = rounds.max(stats.rounds);
    }
    let finals = finals.into_inner().unwrap();
    let mut out = Vec::with_capacity(p);
    let mut steps = 0u64;
    for (rank, f) in finals.into_iter().enumerate() {
        let (s, panel) =
            f.ok_or_else(|| anyhow!("rank {rank} never delivered its final panel"))?;
        steps = steps.max(s);
        out.push(panel);
    }
    jemit(
        journal.as_ref(),
        &Event::RunFinished {
            steps,
            rounds,
            final_digest: digest_cohort(out.iter().map(|(_, t)| t.as_slice())),
        },
    )?;
    Ok(ServeOutcome { finals: out, rounds, steps, comm, commit_reasons: Vec::new() })
}

/// Emit into an optional mutex-shared journal (the rendezvous's relay
/// threads all funnel through one writer).
fn jemit(journal: Option<&Mutex<JournalWriter>>, ev: &Event) -> Result<()> {
    if let Some(j) = journal {
        j.lock().unwrap().emit(ev)?;
    }
    Ok(())
}

/// Session state shared by every relay handler thread.
struct RelayCtx<'a> {
    exchange: &'a PanelExchange<(f32, Vec<u8>)>,
    finals: &'a Mutex<Vec<Option<(u64, WorkerPanel)>>>,
    enc: WireEncoding,
    /// Who receives whose panel each round. The exchange barrier is
    /// still full-cohort under every topology — sparsity lives in the
    /// *reply* direction only.
    topology: Topology,
    /// Session seed, keying the gossip sampling schedule.
    seed: u64,
    journal: Option<&'a Mutex<JournalWriter>>,
}

fn relay_loop(
    rank: usize,
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    ctx: &RelayCtx,
    stats: &mut RelayStats,
) -> Result<()> {
    loop {
        let frame = Frame::read_from(reader)?;
        stats.received += frame.encoded_len() as u64;
        match frame.kind {
            MsgKind::Panel => {
                ensure!(
                    frame.encoding.id() == ctx.enc.id(),
                    "rank {rank} sent a {} panel in a {} session",
                    frame.encoding.name(),
                    ctx.enc.name()
                );
                let panel = RawPanel::parse(&frame)?;
                ensure!(
                    panel.round == stats.rounds + 1,
                    "rank {rank} jumped to round {} (expected {})",
                    panel.round,
                    stats.rounds + 1
                );
                let cohort = ctx.exchange.exchange(rank, (panel.h, panel.body))?;
                // One designated emitter (rank 0's handler) journals the
                // round's cohort — the exchange is a full barrier under
                // every topology, so the relay always sees all p panels.
                // The barrier also guarantees rank 0 cannot deposit
                // round n+1 before round n published, so rounds journal
                // in order.
                if rank == 0 {
                    journal_round(ctx.journal, panel.round, &cohort, ctx.enc)?;
                }
                let p = cohort.len();
                match ctx.topology {
                    Topology::Full => {
                        let reply = cohort_frame_from_raw(panel.round, &cohort[..], ctx.enc);
                        reply.write_to(writer)?;
                        stats.sent += reply.encoded_len() as u64;
                    }
                    Topology::Ring => {
                        // p−1 neighbour hops: the s-th frame carries
                        // rank (rank − s) mod p's panel. The worker
                        // fills its own slot locally, so the assembled
                        // cohort content equals a full gather.
                        for s in 1..p {
                            let origin = (rank + p - s) % p;
                            let reply = cohort_frame_from_raw(
                                panel.round,
                                std::slice::from_ref(&cohort[origin]),
                                ctx.enc,
                            );
                            reply.write_to(writer)?;
                            stats.sent += reply.encoded_len() as u64;
                        }
                    }
                    Topology::Gossip { .. } => {
                        // One subset frame: this rank's sampled origins
                        // for the round (self-inclusive, ascending), per
                        // the schedule both sides compute from the seed.
                        let origins =
                            round_origins(ctx.topology, p, rank, panel.round, ctx.seed);
                        let sub: Vec<(f32, Vec<u8>)> =
                            origins.iter().map(|&o| cohort[o].clone()).collect();
                        let reply = cohort_frame_from_raw(panel.round, &sub[..], ctx.enc);
                        reply.write_to(writer)?;
                        stats.sent += reply.encoded_len() as u64;
                    }
                }
                stats.rounds += 1;
            }
            MsgKind::Final => {
                let panel = Panel::parse(&frame)?;
                // A Final's round field is the worker's total step count.
                ctx.finals.lock().unwrap()[rank] = Some((panel.round, (panel.h, panel.theta)));
                // A departed participant can never deposit again. In the
                // homogeneous case every rank finishes after the same
                // round, all of whose deposits preceded this Final, so
                // the poison is unobservable; with mismatched step
                // budgets (e.g. different --artifacts resolving a
                // different batch size) it converts what would be a
                // permanent barrier deadlock into a clean session error.
                ctx.exchange.poison(&format!(
                    "rank {rank} finished after round {}; no further collectives can complete",
                    stats.rounds
                ));
                return Ok(());
            }
            MsgKind::Error => bail!("worker rank {rank} reported: {}", error_text(&frame)),
            other => bail!("unexpected {other:?} frame from rank {rank} mid-session"),
        }
    }
}

/// Journal one relayed round's cohort digests, over the panels *as a
/// worker decodes them* — that is what every rank aggregated, so lossy
/// sessions still replay bit-exactly. An f32 panel body is exactly θ's
/// little-endian bytes, so `fnv64(body)` equals the worker-side
/// `digest_params` without decoding; a top-k body is deterministic, so
/// decoding it reproduces the dense panel every worker committed. qi8
/// journals no digests (its decode is not part of any replay contract —
/// `wasgd replay --verify` rejects qi8 journals outright).
fn journal_round(
    journal: Option<&Mutex<JournalWriter>>,
    round: u64,
    cohort: &[(f32, Vec<u8>)],
    enc: WireEncoding,
) -> Result<()> {
    let Some(j) = journal else { return Ok(()) };
    if let WireEncoding::Qi8 = enc {
        return Ok(());
    }
    let mut w = j.lock().unwrap();
    for (r, (h, body)) in cohort.iter().enumerate() {
        let (digest, d) = match enc {
            WireEncoding::F32 => (fnv64(body), body.len() / 4),
            WireEncoding::TopK { .. } => {
                let theta = decode_vec(enc, body)
                    .with_context(|| format!("digesting rank {r}'s round-{round} panel"))?;
                (digest_params(&theta), theta.len())
            }
            WireEncoding::Qi8 => unreachable!("qi8 returned above"),
        };
        w.emit(&Event::PanelDigest {
            round,
            rank: r as u32,
            digest,
            loss: *h,
            comm_bytes: canonical_comm_bytes(round, d),
        })?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Elastic membership: the epoch state machine.
// ---------------------------------------------------------------------

/// A handshaken connection parked by the acceptor thread, waiting to be
/// committed into an epoch at the next boundary.
struct PendingConn {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// The rank this worker held in the epoch that just committed
    /// (`None` for a fresh joiner).
    rejoin: Option<u32>,
    hello_len: u64,
}

/// Read one opening frame (hello or join request) and park the
/// connection; the Welcome is deferred to epoch formation.
fn elastic_handshake(stream: &TcpStream) -> Result<PendingConn> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    let read_half = stream.try_clone().context("cloning a worker stream")?;
    let mut reader = BufReader::new(read_half);
    let first = Frame::read_from(&mut reader).context("reading the opening frame")?;
    let rejoin = match first.kind {
        MsgKind::Hello => None,
        MsgKind::JoinRequest => JoinRequest::parse(&first)?.prior_rank,
        other => bail!("opened with {other:?}, expected a hello or join request"),
    };
    stream.set_read_timeout(None).ok();
    let writer = BufWriter::new(stream.try_clone().context("cloning a worker stream")?);
    Ok(PendingConn { reader, writer, rejoin, hello_len: first.encoded_len() as u64 })
}

/// How an elastic relay handler ended its epoch.
enum RelayFate {
    /// Worker delivered its Final panel — the session is done for it.
    Finished,
    /// The epoch was cut; the worker was notified with an
    /// [`MsgKind::EpochCommit`] and is expected to rejoin.
    Committed,
    /// Worker sent a [`MsgKind::Leave`]; it will not rejoin.
    Left,
    /// The connection failed (crash, hangup, or missed heartbeats).
    Dead(String),
}

struct EpochRelayEnd {
    stats: RelayStats,
    fate: RelayFate,
}

fn serve_elastic(
    listener: TcpListener,
    opts: &ServeOptions,
    el: &ElasticOptions,
) -> Result<ServeOutcome> {
    let cfg = &opts.cfg;
    cfg.validate().map_err(|e| anyhow!(e))?;
    ensure!(
        algo_supports_fabric(cfg.algo),
        "the tcp fabric supports the synchronous decentralized schemes; {} needs --fabric sim",
        cfg.algo.name()
    );
    ensure!(
        opts.encoding == WireEncoding::F32,
        "elastic sessions need the lossless f32 encoding: epoch anchors are decoded from the \
         relayed panel bytes"
    );
    ensure!(
        cfg.topology == Topology::Full,
        "elastic sessions need the full topology: ring/gossip schedules are keyed by a fixed \
         cohort geometry, which re-formation breaks"
    );
    if let Some(ck) = &opts.resume {
        // Geometry is deliberately NOT pinned to p: the anchor's rows
        // are keyed by the prior cohort's ranks, and the rank-stable
        // shard rule re-shards whatever cohort actually forms.
        ensure!(
            !ck.workers.is_empty(),
            "resume checkpoint carries no worker rows; nothing to seed the epoch from"
        );
    }
    ensure!(el.min_workers >= 1, "--min-workers must be at least 1");
    ensure!(
        el.max_workers >= cfg.p.max(el.min_workers),
        "--max-workers ({}) must cover both the initial cohort (p={}) and --min-workers ({})",
        el.max_workers,
        cfg.p,
        el.min_workers
    );
    ensure!(el.heartbeat_ms >= 1, "--heartbeat-ms must be at least 1");

    // Resolve the data source once and compute the run's global step
    // budget up front (it is p-independent: sharding happens inside
    // each worker against the full training split).
    let pipeline = DataPipeline::from_config(cfg)?;
    if let Some(note) = pipeline.note() {
        eprintln!("rendezvous: {note}");
    }
    let (n_train, batch) = {
        let engine = load_backend(cfg)?;
        let dataset = pipeline.load(engine.manifest())?;
        (dataset.n_train(), engine.manifest().batch)
    };
    let total_budget = planned_steps(cfg, n_train, batch);

    let mut base = cfg.clone();
    base.source = pipeline.source_kind();
    base.elastic = true;
    base.heartbeat_ms = el.heartbeat_ms;
    base.min_workers = el.min_workers;

    // A resumed session *appends*, stitching its segments onto the
    // original journal. The resume boundary is journaled as a round-0
    // EpochCommitted — but only when the file actually ends in the
    // killed run's unterminated segment; resuming against a fresh (or
    // absent) journal starts a self-contained file whose first
    // RunStarted carries the anchor rows instead.
    let mut stitch_commit = false;
    let journal: Option<Mutex<JournalWriter>> = match &opts.journal {
        Some(path) => Some(Mutex::new(if opts.resume.is_some() {
            if let Ok((evs, _)) = crate::journal::read_events(path) {
                let mut open = false;
                for ev in &evs {
                    match ev {
                        Event::RunStarted { .. } => open = true,
                        Event::RunFinished { .. } | Event::EpochCommitted { .. } => open = false,
                        _ => {}
                    }
                }
                stitch_commit = open;
            }
            JournalWriter::append_to(path)?
        } else {
            JournalWriter::create(path)?
        })),
        None => None,
    };

    // The acceptor runs for the whole session: it accepts and
    // handshakes continuously, parking connections until a boundary
    // commits them into an epoch. Shutdown: flip `done`, then
    // self-connect to unblock the blocking accept.
    let pending: Arc<Mutex<Vec<PendingConn>>> = Arc::new(Mutex::new(Vec::new()));
    let done = Arc::new(AtomicBool::new(false));
    let local_addr = listener.local_addr().context("reading the listener address")?;
    let acceptor = {
        let pending = Arc::clone(&pending);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            while !done.load(Ordering::Relaxed) {
                let Ok((stream, peer)) = listener.accept() else { continue };
                if done.load(Ordering::Relaxed) {
                    return;
                }
                stream.set_nodelay(true).ok();
                // Handshake off the accept path: a stray connection that
                // never speaks (port scan, health probe) blocks only its
                // own thread for HANDSHAKE_TIMEOUT — a legitimate joiner
                // behind it is accepted and seated immediately. Threads
                // are detached so a silent stray can't stall shutdown;
                // unlike the fixed-cohort serve, a long-lived elastic
                // session never aborts on bad handshakes, it only logs
                // them.
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || match elastic_handshake(&stream) {
                    Ok(conn) => pending.lock().unwrap().push(conn),
                    Err(e) => {
                        eprintln!("rendezvous: dropping connection from {peer}: {e:#}");
                    }
                });
            }
        })
    };

    let session =
        elastic_session(&base, el, total_budget, &pending, journal.as_ref(), opts.resume.as_ref(), stitch_commit);

    done.store(true, Ordering::Relaxed);
    let _ = TcpStream::connect(local_addr);
    let _ = acceptor.join();
    // Anyone still parked has no epoch left to join. Collect under the
    // lock, notify outside it: the notification is blocking IO and
    // late handshake threads may still be pushing.
    let parked: Vec<PendingConn> = {
        let mut q = pending.lock().unwrap();
        q.drain(..).collect()
    };
    for mut c in parked {
        let _ = wire::error_frame("session complete; no epoch to join").write_to(&mut c.writer);
    }
    session
}

/// The epoch loop: form a cohort, run it until it finishes or cuts,
/// commit, repeat. `base` already carries the resolved data source and
/// the elastic knobs; each epoch ships a copy with its own `p` and
/// `step_budget`.
///
/// With `resume_ck`, the first formation is seeded from the checkpoint's
/// rows (an epoch anchor of a previous session of this run) instead of
/// the seed init: rows are keyed by their index — the anchor file's row
/// order IS the killed epoch's rank order — and the boundary is
/// journaled as a round-0 `EpochCommitted` when `stitch_commit` says the
/// journal still ends in that killed epoch's segment.
#[allow(clippy::too_many_arguments)]
fn elastic_session(
    base: &ExperimentConfig,
    el: &ElasticOptions,
    total_budget: usize,
    pending: &Mutex<Vec<PendingConn>>,
    journal: Option<&Mutex<JournalWriter>>,
    resume_ck: Option<&Checkpoint>,
    stitch_commit: bool,
) -> Result<ServeOutcome> {
    let enc = WireEncoding::F32;
    let tau = base.tau;
    let mut comm = CommCounters::new(el.max_workers);
    // The committed anchor: survivors' pre-aggregation θ rows at the
    // last published round, keyed by their rank in the epoch that just
    // ended. `None` until a round commits — members then init from the
    // seed as usual.
    let mut anchor: Option<Vec<(u32, Vec<f32>)>> = None;
    // Ranks (of the previous epoch) expected to rejoin at the boundary.
    let mut expected: Vec<u32> = Vec::new();
    // The boundary to journal once the next member set is known:
    // (committed round, reason).
    let mut pending_commit: Option<(u64, String)> = None;
    let mut epoch: u64 = 0;
    let mut steps_done: usize = 0;
    let mut total_rounds: u64 = 0;
    let mut commit_reasons: Vec<String> = Vec::new();
    // Finals banked across epochs: a partial finale (a worker died or
    // left after some ranks sent `Final`) banks what arrived and
    // re-forms the rest as an epilogue epoch over the remaining budget.
    let mut banked: Vec<WorkerPanel> = Vec::new();
    let mut banked_steps: u64 = 0;
    // Resume boundary: the first formation seats fresh hellos into the
    // anchor's prior ranks positionally (a resumed worker pool is new
    // OS processes — they cannot know the dead session's ranks).
    let mut resume_boundary = false;
    if let Some(ck) = resume_ck {
        let k = ck.workers.len();
        anchor = Some(
            ck.workers.iter().enumerate().map(|(i, v)| (i as u32, v.clone())).collect(),
        );
        expected = (0..k as u32).collect();
        steps_done = (ck.iteration as usize).min(total_budget);
        // Continue the on-disk anchor numbering past whatever the dead
        // session wrote, so new boundaries never clobber old anchors.
        let label_idx = ck
            .label
            .strip_prefix("epoch ")
            .and_then(|s| s.strip_suffix(" anchor"))
            .and_then(|s| s.parse::<u64>().ok());
        let disk_idx = el
            .anchor_dir
            .as_deref()
            .and_then(|d| latest_epoch_anchor(d).ok().flatten())
            .map(|(i, _)| i);
        epoch = label_idx.into_iter().chain(disk_idx).max().unwrap_or(0) + 1;
        let reason = format!(
            "resumed from the epoch anchor at step {steps_done} ({} of {total_budget} steps \
             remaining, {k} anchor row(s))",
            total_budget - steps_done
        );
        if stitch_commit {
            // Terminate the killed segment with a round-0 commit: its
            // published-but-uncommitted rounds are discarded (the next
            // segment resumes from the killed segment's own resume
            // rows), which is exactly what round 0 means to the chain
            // verifier.
            pending_commit = Some((0, reason.clone()));
        }
        commit_reasons.push(reason);
        resume_boundary = true;
    }
    let first_epoch = epoch;

    loop {
        let remaining = total_budget - steps_done;

        // ---- formation: wait for the members, then commit the set ----
        // The first epoch (0, or the resumed index) blocks for the full
        // initial cohort, like a static serve; later epochs wait up to
        // FORMATION_TIMEOUT for the committed survivors before
        // proceeding with whoever is back.
        let deadline = Instant::now() + FORMATION_TIMEOUT;
        loop {
            let q = pending.lock().unwrap();
            let enough = if epoch == first_epoch {
                q.len() >= base.p
            } else {
                let back = q
                    .iter()
                    .filter(|c| c.rejoin.is_some_and(|r| expected.contains(&r)))
                    .count();
                back >= expected.len() || Instant::now() >= deadline
            };
            if enough {
                break;
            }
            drop(q);
            std::thread::sleep(Duration::from_millis(10));
        }
        let mut taken: Vec<(Option<u32>, PendingConn)> = Vec::new();
        {
            let mut q = pending.lock().unwrap();
            // Survivors first, in previous-rank order — the rank-stable
            // seating that makes re-sharding deterministic — then fresh
            // joiners in arrival order, capped at max_workers. Excess
            // joiners stay parked for the next boundary.
            for &r in &expected {
                if let Some(i) = q.iter().position(|c| c.rejoin == Some(r)) {
                    taken.push((Some(r), q.remove(i)));
                }
            }
            let cap = if epoch == first_epoch { base.p } else { el.max_workers };
            if resume_boundary {
                // A resumed pool is fresh OS processes connecting with
                // plain hellos; seat them as the anchor's prior ranks
                // positionally so each inherits a distinct anchor row
                // (and `shard_range` re-shards exactly as it would at a
                // live boundary). Extra workers past the anchor's rows
                // are fresh joiners.
                let mut unclaimed: Vec<u32> = expected
                    .iter()
                    .copied()
                    .filter(|r| !taken.iter().any(|(o, _)| *o == Some(*r)))
                    .collect();
                while taken.len() < cap && !q.is_empty() {
                    let old =
                        if unclaimed.is_empty() { None } else { Some(unclaimed.remove(0)) };
                    taken.push((old, q.remove(0)));
                }
            } else {
                while taken.len() < cap && !q.is_empty() {
                    taken.push((None, q.remove(0)));
                }
            }
        }
        resume_boundary = false;
        let p_e = taken.len();
        ensure!(
            p_e >= el.min_workers,
            "epoch {epoch} cannot form: {p_e} worker(s) present, --min-workers is {}",
            el.min_workers
        );

        let prior: Vec<u32> = taken.iter().filter_map(|(r, _)| *r).collect();
        let plan = EpochPlan { epoch, p: p_e, prior, steps: remaining };

        // Resume rows in new-rank order: survivors get their own anchor
        // row, fresh joiners clone the first member's row (so every
        // row's provenance is checkable at replay time).
        let resume: Option<Vec<Vec<f32>>> = anchor.as_ref().map(|rows| {
            let find = |r: u32| rows.iter().find(|(q, _)| *q == r).map(|(_, v)| v);
            let joiner_row = plan.prior.first().and_then(|&r| find(r)).unwrap_or(&rows[0].1);
            taken
                .iter()
                .map(|(old, _)| old.and_then(find).unwrap_or(joiner_row).clone())
                .collect()
        });
        let anchor_digest =
            resume.as_ref().map(|rows| digest_cohort(rows.iter().map(|v| v.as_slice()))).unwrap_or(0);

        // Journal the boundary. The EpochCommitted terminates the
        // previous segment with the *actual* next member set (survivors
        // that never reconnected are recorded as crashed first).
        if let Some((round, reason)) = pending_commit.take() {
            for &r in expected.iter().filter(|r| !plan.prior.contains(r)) {
                jemit(
                    journal,
                    &Event::Membership {
                        epoch: epoch - 1,
                        rank: r,
                        change: MembershipChange::Crashed,
                    },
                )?;
            }
            jemit(
                journal,
                &Event::EpochCommitted {
                    epoch,
                    round,
                    members: plan.prior.clone(),
                    anchor_digest,
                    reason,
                },
            )?;
        }

        // Open the epoch's segment: a per-epoch config (its own p and
        // step budget) that replays under `--fabric sim` at this member
        // set — the per-epoch determinism guarantee.
        let mut epoch_cfg = base.clone();
        epoch_cfg.p = p_e;
        epoch_cfg.step_budget = Some(remaining);
        let cfg_json = epoch_cfg.to_wire_json();
        jemit(
            journal,
            &Event::RunStarted {
                rank: RANK_COHORT,
                p: p_e as u32,
                seed: base.seed,
                encoding: enc,
                git_rev: crate::bench::git_rev(),
                config_json: cfg_json.clone(),
                resume: resume.clone().unwrap_or_default(),
            },
        )?;
        for (j, (old, _)) in taken.iter().enumerate() {
            if epoch == 0 || old.is_none() {
                jemit(
                    journal,
                    &Event::Membership {
                        epoch,
                        rank: j as u32,
                        change: MembershipChange::Joined,
                    },
                )?;
            }
        }

        // Seat everyone: the Welcome carries rank, p_e, the epoch
        // config, and the member's anchor row.
        let mut conns = Vec::with_capacity(p_e);
        for (j, (_, mut c)) in taken.into_iter().enumerate() {
            let welcome = Welcome {
                rank: j as u32,
                p: p_e as u32,
                config_json: cfg_json.clone(),
                resume: resume.as_ref().map(|rows| rows[j].clone()),
            };
            let frame = welcome.frame(enc);
            frame
                .write_to(&mut c.writer)
                .with_context(|| format!("welcoming rank {j} into epoch {epoch}"))?;
            comm.add(j, frame.encoded_len() as u64, c.hello_len);
            conns.push((c.reader, c.writer));
        }

        // ---- run the epoch ----
        let rounds_in_epoch = (remaining / tau) as u64;
        let exchange: PanelExchange<(f32, Vec<u8>)> = PanelExchange::new(p_e);
        let finals: Mutex<Vec<Option<(u64, WorkerPanel)>>> = Mutex::new(vec![None; p_e]);
        // Elastic sessions are f32 + full by construction (config
        // validation rejects lossy/sparse modes there — EF residuals and
        // gossip schedules don't survive re-formation).
        let ctx = RelayCtx {
            exchange: &exchange,
            finals: &finals,
            enc,
            topology: Topology::Full,
            seed: base.seed,
            journal,
        };
        let liveness = Duration::from_millis(el.heartbeat_ms.saturating_mul(4).max(100));
        let ends: Vec<EpochRelayEnd> = std::thread::scope(|s| {
            let ctx = &ctx;
            let handles: Vec<_> = conns
                .into_iter()
                .enumerate()
                .map(|(rank, (mut reader, mut writer))| {
                    s.spawn(move || {
                        elastic_relay(
                            rank,
                            &mut reader,
                            &mut writer,
                            ctx,
                            pending,
                            rounds_in_epoch,
                            liveness,
                            epoch,
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| EpochRelayEnd {
                        stats: RelayStats { sent: 0, received: 0, rounds: 0 },
                        fate: RelayFate::Dead("relay thread panicked".to_string()),
                    })
                })
                .collect()
        });
        for (rank, end) in ends.iter().enumerate() {
            comm.add(rank, end.stats.sent, end.stats.received);
        }
        let committed_round = exchange.last_published().map(|(r, _)| r).unwrap_or(0);
        total_rounds += committed_round;

        // ---- collect the finals this epoch delivered ----
        let epoch_finals = finals.into_inner().unwrap();
        let mut epoch_final_rows: Vec<WorkerPanel> = Vec::new();
        let mut epoch_steps = 0u64;
        for (s, panel) in epoch_finals.into_iter().flatten() {
            epoch_steps = epoch_steps.max(s);
            epoch_final_rows.push(panel);
        }

        // ---- session finale: every member delivered its Final ----
        if ends.iter().all(|e| matches!(e.fate, RelayFate::Finished)) {
            // The journaled digest covers only THIS segment's cohort —
            // that is what a replay of the segment reproduces. Finals
            // banked from earlier partial finales ride only the outcome.
            jemit(
                journal,
                &Event::RunFinished {
                    steps: epoch_steps,
                    rounds: committed_round,
                    final_digest: digest_cohort(
                        epoch_final_rows.iter().map(|(_, t)| t.as_slice()),
                    ),
                },
            )?;
            let steps = (steps_done as u64 + epoch_steps).max(banked_steps);
            let mut out = banked;
            out.extend(epoch_final_rows);
            if let Some(dir) = &el.anchor_dir {
                // Terminal anchor: the completed run's final rows, so the
                // anchor directory of a finished session always ends in a
                // loadable state.
                save_epoch_anchor(
                    dir,
                    base,
                    total_budget,
                    journal,
                    "terminal anchor".to_string(),
                    epoch + 1,
                    steps,
                    out.iter().map(|(_, t)| t.clone()).collect(),
                )?;
            }
            return Ok(ServeOutcome {
                finals: out,
                rounds: total_rounds,
                steps,
                comm,
                commit_reasons,
            });
        }
        // A partial finale — some ranks delivered their Final before a
        // death or leave cut the epoch. Bank what arrived; the members
        // still owing theirs re-form below as an epilogue epoch over
        // whatever budget remains (possibly zero — the 0-step worker
        // path exists for exactly this) and deliver there.
        let partial_finale = !epoch_final_rows.is_empty();
        if partial_finale {
            banked_steps = banked_steps.max(steps_done as u64 + epoch_steps);
            banked.extend(epoch_final_rows);
        }

        // ---- commit the boundary ----
        let mut next_expected: Vec<u32> = Vec::new();
        let mut fallback_reason: Option<String> = None;
        for (rank, end) in ends.iter().enumerate() {
            match &end.fate {
                RelayFate::Committed => next_expected.push(rank as u32),
                RelayFate::Dead(why) => {
                    jemit(
                        journal,
                        &Event::Membership {
                            epoch,
                            rank: rank as u32,
                            change: MembershipChange::Crashed,
                        },
                    )?;
                    fallback_reason.get_or_insert_with(|| why.clone());
                }
                RelayFate::Left => {
                    jemit(
                        journal,
                        &Event::Membership {
                            epoch,
                            rank: rank as u32,
                            change: MembershipChange::Left,
                        },
                    )?;
                    fallback_reason
                        .get_or_insert_with(|| format!("rank {rank} left the cohort"));
                }
                // Banked above; its Membership record was journaled by
                // the relay the moment the Final arrived.
                RelayFate::Finished => {}
            }
        }
        let reason = if partial_finale {
            // The interesting fact at a finale boundary is who FAILED to
            // deliver; the exchange's first-cut verdict would name a
            // finisher instead of the dead rank.
            fallback_reason.unwrap_or_else(|| {
                format!("re-forming to collect the finale after round {committed_round}")
            })
        } else {
            exchange
                .cut_reason()
                .or(fallback_reason)
                .unwrap_or_else(|| "epoch boundary".to_string())
        };
        eprintln!(
            "rendezvous: committing epoch {} at round {committed_round} \
             ({} survivor(s)): {reason}",
            epoch + 1,
            next_expected.len()
        );

        steps_done += committed_round as usize * tau;

        // ---- completing from the bank: no cohort left to re-form ----
        if !banked.is_empty() && next_expected.is_empty() {
            // Every member still owing a Final died or left, and the
            // ranks that finished are already banked: re-forming
            // mid-finale from queued joiners would train a fresh cohort,
            // not finish this one. Complete from the bank instead.
            // `final_digest: 0` is the partial-finale sentinel — there is
            // no live cohort to digest; steps, rounds, and every
            // per-round digest still verify on replay.
            jemit(
                journal,
                &Event::RunFinished {
                    steps: epoch_steps.max(committed_round * tau as u64),
                    rounds: committed_round,
                    final_digest: 0,
                },
            )?;
            eprintln!(
                "rendezvous: completing from {} banked final(s): {reason}",
                banked.len()
            );
            commit_reasons.push(reason);
            let steps = banked_steps.max(steps_done as u64);
            if let Some(dir) = &el.anchor_dir {
                save_epoch_anchor(
                    dir,
                    base,
                    total_budget,
                    journal,
                    "terminal anchor (partial finale)".to_string(),
                    epoch + 1,
                    steps,
                    banked.iter().map(|(_, t)| t.clone()).collect(),
                )?;
            }
            return Ok(ServeOutcome {
                finals: banked,
                rounds: total_rounds,
                steps,
                comm,
                commit_reasons,
            });
        }
        // New anchor: the survivors' rows of the last published round
        // (the relay's own f32 bytes, decoded — never aggregated), or,
        // if no round completed, their rows of this epoch's resume.
        anchor = if next_expected.is_empty() {
            // Everyone died or left: the next epoch (formed purely from
            // queued joiners, if any) restarts from the seed init.
            None
        } else if committed_round > 0 {
            let (_, panels) = exchange.last_published().expect("committed_round > 0");
            Some(
                next_expected
                    .iter()
                    .map(|&r| {
                        let (_h, body) = &panels[r as usize];
                        let row: Vec<f32> = body
                            .chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect();
                        (r, row)
                    })
                    .collect(),
            )
        } else {
            resume
                .as_ref()
                .map(|rows| next_expected.iter().map(|&r| (r, rows[r as usize].clone())).collect())
        };
        if let (Some(dir), Some(rows)) = (&el.anchor_dir, &anchor) {
            save_epoch_anchor(
                dir,
                base,
                total_budget,
                journal,
                format!("epoch {} anchor", epoch + 1),
                epoch + 1,
                steps_done as u64,
                rows.iter().map(|(_, v)| v.clone()).collect(),
            )?;
        }

        commit_reasons.push(reason.clone());
        pending_commit = Some((committed_round, reason));
        expected = next_expected;
        epoch += 1;
    }
}

/// Persist `workers` as the standard-format anchor checkpoint
/// `dir/epoch_NNNN/` — a boundary anchor or the terminal anchor of a
/// completed session — and journal the write. The row order is the
/// next (or final) epoch's rank order, which is what makes index-keyed
/// resume consistent with the journal's anchor chain.
#[allow(clippy::too_many_arguments)]
fn save_epoch_anchor(
    dir: &Path,
    base: &ExperimentConfig,
    total_budget: usize,
    journal: Option<&Mutex<JournalWriter>>,
    label: String,
    index: u64,
    steps: u64,
    workers: Vec<Vec<f32>>,
) -> Result<()> {
    let ck = Checkpoint {
        label,
        iteration: steps,
        epoch: steps as f64 / n_steps_per_epoch(base, total_budget),
        sim_time_s: 0.0,
        workers,
    };
    let path = dir.join(format!("epoch_{index:04}"));
    ck.save(&path)?;
    jemit(
        journal,
        &Event::CheckpointWritten {
            steps,
            digest: digest_cohort(ck.workers.iter().map(|v| v.as_slice())),
            path: path.display().to_string(),
        },
    )?;
    Ok(())
}

/// Steps per nominal data epoch, for checkpoint metadata only (the
/// elastic budget is tracked in steps).
fn n_steps_per_epoch(cfg: &ExperimentConfig, total_budget: usize) -> f64 {
    if cfg.epochs > 0.0 {
        total_budget as f64 / cfg.epochs
    } else {
        total_budget as f64
    }
}

/// One elastic relay handler: the static [`relay_loop`] plus liveness
/// timeouts, heartbeat/leave frames, the joiner-absorption trigger, and
/// the commit notification. Never returns an error — every failure is
/// converted into a cut plus a [`RelayFate::Dead`].
#[allow(clippy::too_many_arguments)]
fn elastic_relay(
    rank: usize,
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    ctx: &RelayCtx,
    pending: &Mutex<Vec<PendingConn>>,
    rounds_in_epoch: u64,
    liveness: Duration,
    epoch: u64,
) -> EpochRelayEnd {
    let mut stats = RelayStats { sent: 0, received: 0, rounds: 0 };
    let fate = match elastic_relay_inner(
        rank,
        reader,
        writer,
        ctx,
        pending,
        rounds_in_epoch,
        liveness,
        epoch,
        &mut stats,
    ) {
        Ok(fate) => fate,
        Err(e) => {
            let verdict = match e.downcast_ref::<std::io::Error>().map(|io| io.kind()) {
                Some(std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut) => format!(
                    "rank {rank} missed its heartbeats (silent for {liveness:?}) after \
                     completing round {}",
                    stats.rounds
                ),
                _ => format!("rank {rank} died after completing round {}: {e}", stats.rounds),
            };
            ctx.exchange.cut(&verdict);
            let _ = wire::error_frame(&format!("{e}")).write_to(writer);
            RelayFate::Dead(verdict)
        }
    };
    EpochRelayEnd { stats, fate }
}

#[allow(clippy::too_many_arguments)]
fn elastic_relay_inner(
    rank: usize,
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    ctx: &RelayCtx,
    pending: &Mutex<Vec<PendingConn>>,
    rounds_in_epoch: u64,
    liveness: Duration,
    epoch: u64,
    stats: &mut RelayStats,
) -> Result<RelayFate> {
    // Heartbeats arrive every heartbeat_ms even while the worker
    // computes, so the relay read may time out aggressively without
    // bounding τ.
    reader.get_ref().set_read_timeout(Some(liveness)).ok();
    loop {
        let frame = Frame::read_from(reader)?;
        stats.received += frame.encoded_len() as u64;
        match frame.kind {
            MsgKind::Heartbeat => {
                Heartbeat::parse(&frame)?;
            }
            MsgKind::Panel => {
                ensure!(
                    frame.encoding.id() == ctx.enc.id(),
                    "rank {rank} sent a {} panel in a {} session",
                    frame.encoding.name(),
                    ctx.enc.name()
                );
                let panel = RawPanel::parse(&frame)?;
                ensure!(
                    panel.round == stats.rounds + 1,
                    "rank {rank} jumped to round {} (expected {})",
                    panel.round,
                    stats.rounds + 1
                );
                match ctx.exchange.exchange(rank, (panel.h, panel.body)) {
                    Ok(cohort) => {
                        if rank == 0 {
                            journal_round(ctx.journal, panel.round, &cohort, ctx.enc)?;
                        }
                        let reply = cohort_frame_from_raw(panel.round, &cohort[..], ctx.enc);
                        reply.write_to(writer)?;
                        stats.sent += reply.encoded_len() as u64;
                        stats.rounds += 1;
                        // Queued joiners force a boundary — but only
                        // while the epoch still has rounds to give them.
                        if stats.rounds < rounds_in_epoch {
                            let waiting = pending.lock().unwrap().len();
                            if waiting > 0 {
                                ctx.exchange.cut(&format!(
                                    "absorbing {waiting} queued joiner(s) after round {}",
                                    stats.rounds
                                ));
                            }
                        }
                    }
                    Err(e) => {
                        if let Some(end) = e.downcast_ref::<EpochEnded>() {
                            let commit = commit_frame(ctx, epoch, &end.reason);
                            commit.write_to(writer)?;
                            stats.sent += commit.encoded_len() as u64;
                            return Ok(RelayFate::Committed);
                        }
                        return Err(e);
                    }
                }
            }
            MsgKind::Leave => {
                let leave = Leave::parse(&frame)?;
                let reason =
                    format!("rank {rank} left after completing round {}", leave.round);
                ctx.exchange.cut(&reason);
                let _ = commit_frame(ctx, epoch, &reason).write_to(writer);
                return Ok(RelayFate::Left);
            }
            MsgKind::Final => {
                let panel = Panel::parse(&frame)?;
                ctx.finals.lock().unwrap()[rank] = Some((panel.round, (panel.h, panel.theta)));
                jemit(
                    ctx.journal,
                    &Event::Membership {
                        epoch,
                        rank: rank as u32,
                        change: MembershipChange::Finished,
                    },
                )?;
                // A *cut*, not a poison: a finished rank can join no
                // further collectives, but the epoch is recoverable —
                // members caught mid-exchange commit and re-form as the
                // epilogue epoch that collects the remaining finals.
                ctx.exchange.cut(&format!(
                    "rank {rank} finished after round {}; collecting the cohort's finals",
                    stats.rounds
                ));
                return Ok(RelayFate::Finished);
            }
            MsgKind::Error => bail!("worker rank {rank} reported: {}", error_text(&frame)),
            other => bail!("unexpected {other:?} frame from rank {rank} mid-session"),
        }
    }
}

/// The advisory end-of-epoch frame sent to a live worker. The member
/// set is settled only at formation, so this carries the committed
/// round and the reason; the authoritative set arrives in the next
/// Welcome (and is journaled in `EpochCommitted`).
fn commit_frame(ctx: &RelayCtx, epoch: u64, reason: &str) -> Frame {
    let round = ctx.exchange.last_published().map(|(r, _)| r).unwrap_or(0);
    EpochCommit {
        epoch: epoch + 1,
        round,
        members: Vec::new(),
        anchor_digest: 0,
        reason: reason.to_string(),
    }
    .frame()
}

/// Run one remote worker end to end: connect, adopt the session config
/// from the Welcome (CLI `--threads` / `--artifacts` / `--data-dir`
/// override the local knobs), build engine + data pipeline locally,
/// train through the fabric, and deliver the final panel.
///
/// The wire config carries a concrete data source (the rendezvous
/// resolves `auto` before serving), so a worker that cannot locate the
/// promised real files fails with a pointed error instead of silently
/// falling back to synth and de-synchronising the cohort.
///
/// In an elastic session (the welcomed config says `elastic`) the
/// worker heartbeats between panels and, when the rendezvous commits
/// the epoch mid-round, reconnects with its rank and trains on through
/// the next epoch — crashes of *other* workers never kill it.
///
/// `journal_base` journals this worker's view of the run to
/// `base.rank{r}` (the rank is only known after the handshake; the
/// suffix keeps p workers sharing one `--journal` value from clobbering
/// each other — or the rendezvous journal at `base` itself). Elastic
/// sessions skip worker-side journals: ranks shift across epochs, so
/// the rendezvous journal is the authoritative record.
pub fn run_remote_worker(
    addr: &str,
    artifacts_root: Option<PathBuf>,
    threads_override: Option<usize>,
    data_dir_override: Option<PathBuf>,
    journal_base: Option<PathBuf>,
) -> Result<FabricWorkerOutcome> {
    let mut rejoin: Option<u32> = None;
    // Cumulative telemetry across epochs of an elastic session.
    let (mut carry_sent, mut carry_recv) = (0u64, 0u64);
    let (mut carry_steps, mut carry_rounds) = (0usize, 0u64);
    loop {
        let (mut fabric, welcome) = RemoteCluster::connect_as(addr, rejoin)?;
        let mut cfg = ExperimentConfig::from_wire_json(&welcome.config_json)
            .context("parsing the session config from the welcome")?;
        if let Some(threads) = threads_override {
            cfg.threads = threads;
        }
        if let Some(root) = &artifacts_root {
            cfg.artifacts_root = root.clone();
        }
        if let Some(dir) = &data_dir_override {
            cfg.data_dir = Some(dir.clone());
        }
        // The Welcome header announced only the encoding *family*; the
        // wire config carries the full modes (rate-bearing encoding,
        // topology, seed) — adopt them before the first round.
        fabric.adopt_modes(cfg.encoding, cfg.topology, cfg.seed)?;
        let engine = load_backend(&cfg)?;
        let dataset = DataPipeline::from_config(&cfg)?.load(engine.manifest())?;
        let total_steps = match cfg.step_budget {
            Some(budget) => budget,
            None => planned_steps(&cfg, dataset.n_train(), engine.manifest().batch),
        };
        let mut jw = match (&journal_base, cfg.elastic) {
            (Some(_), true) => {
                if rejoin.is_none() {
                    eprintln!(
                        "worker: --journal is ignored in elastic sessions (ranks shift across \
                         epochs); the rendezvous journal is the authoritative record"
                    );
                }
                None
            }
            (Some(base), false) => {
                Some(JournalWriter::create(&rank_journal_path(base, welcome.rank as usize))?)
            }
            (None, _) => None,
        };
        if cfg.elastic {
            fabric.start_heartbeats(Duration::from_millis(cfg.heartbeat_ms.max(1)));
        }
        let result = run_fabric_worker(
            &cfg,
            engine.as_ref(),
            &dataset,
            &mut fabric,
            total_steps,
            welcome.resume.clone(),
            jw.as_mut().map(|w| w as &mut dyn EventSink),
        );
        match result {
            Ok(mut out) => {
                fabric.send_final(out.steps as u64, out.mean_energy, &out.params)?;
                out.bytes_sent = fabric.bytes_sent() + carry_sent;
                out.bytes_received = fabric.bytes_received() + carry_recv;
                out.steps += carry_steps;
                out.boundaries += carry_rounds;
                return Ok(out);
            }
            Err(e) => match e.downcast_ref::<EpochEnded>() {
                Some(end) if cfg.elastic => {
                    eprintln!(
                        "worker rank {}: {end}; rejoining the next epoch",
                        fabric.rank()
                    );
                    carry_sent += fabric.bytes_sent();
                    carry_recv += fabric.bytes_received();
                    // Work since the committed round is discarded with
                    // the epoch; count only full relayed rounds.
                    carry_rounds += fabric.completed_round.load(Ordering::Relaxed);
                    carry_steps +=
                        (fabric.completed_round.load(Ordering::Relaxed) as usize) * cfg.tau;
                    rejoin = Some(fabric.rank() as u32);
                    drop(fabric);
                }
                _ => return Err(e),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, FabricKind};
    use std::thread;

    fn tcp_cfg(p: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = BackendKind::Native;
        cfg.fabric = FabricKind::Tcp;
        cfg.p = p;
        cfg.tau = 8;
        cfg.m = 2;
        cfg.c = 1;
        cfg.epochs = 0.25; // 512/8 per epoch → 16 steps, 2 boundaries
        cfg
    }

    /// Spin up a loopback session with in-process worker threads (the
    /// process-level twin lives in tests/fabric_e2e.rs).
    fn loopback_session(cfg: &ExperimentConfig, opts_enc: WireEncoding) -> ServeOutcome {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: opts_enc,
            resume: None,
            journal: None,
            elastic: None,
        };
        let server = thread::spawn(move || serve(listener, &opts));
        let mut workers = Vec::new();
        for _ in 0..cfg.p {
            let addr = addr.clone();
            workers.push(thread::spawn(move || run_remote_worker(&addr, None, None, None, None)));
        }
        for w in workers {
            w.join().unwrap().unwrap();
        }
        server.join().unwrap().unwrap()
    }

    #[test]
    fn loopback_session_completes_and_counts_bytes() {
        let cfg = tcp_cfg(2);
        let out = loopback_session(&cfg, WireEncoding::F32);
        assert_eq!(out.finals.len(), 2);
        assert_eq!(out.rounds, 2);
        assert_eq!(out.steps, 16, "finals must report the true local step count");
        for (h, theta) in &out.finals {
            assert!(h.is_finite());
            assert!(theta.iter().all(|v| v.is_finite()));
            assert!(!theta.is_empty());
        }
        // The relay receives one panel and sends p panels per round.
        assert!(out.comm.total_sent() > out.comm.total_received());
        for peer in &out.comm.peers {
            assert!(peer.sent > 0 && peer.received > 0);
        }
    }

    #[test]
    fn qi8_session_completes_with_much_less_traffic() {
        let cfg = tcp_cfg(2);
        let f32_out = loopback_session(&cfg, WireEncoding::F32);
        let qi8_out = loopback_session(&cfg, WireEncoding::Qi8);
        assert_eq!(qi8_out.rounds, f32_out.rounds);
        for (h, theta) in &qi8_out.finals {
            assert!(h.is_finite());
            assert!(theta.iter().all(|v| v.is_finite()));
        }
        // Quantised panels are ~4× smaller; allow generous headroom.
        assert!(
            qi8_out.comm.total_sent() * 2 < f32_out.comm.total_sent(),
            "qi8 {} B vs f32 {} B",
            qi8_out.comm.total_sent(),
            f32_out.comm.total_sent()
        );
    }

    #[test]
    fn ring_topology_with_f32_matches_full_bit_for_bit() {
        // The ring delivers the same cohort content as the full gather,
        // one neighbour hop at a time — with a lossless encoding the
        // final parameters must be bit-identical.
        let cfg = tcp_cfg(2);
        let full = loopback_session(&cfg, WireEncoding::F32);
        let mut ring_cfg = cfg.clone();
        ring_cfg.topology = Topology::Ring;
        let ring = loopback_session(&ring_cfg, WireEncoding::F32);
        assert_eq!(ring.rounds, full.rounds);
        assert_eq!(ring.finals.len(), full.finals.len());
        for (rank, ((fh, ft), (rh, rt))) in
            full.finals.iter().zip(ring.finals.iter()).enumerate()
        {
            assert_eq!(fh.to_bits(), rh.to_bits(), "rank {rank} final energy diverged");
            let f: Vec<u32> = ft.iter().map(|v| v.to_bits()).collect();
            let r: Vec<u32> = rt.iter().map(|v| v.to_bits()).collect();
            assert_eq!(f, r, "rank {rank}: ring f32 must be bit-identical to full f32");
        }
    }

    #[test]
    fn topk_ring_session_completes_with_much_less_traffic() {
        // The acceptance-criteria combination in-process: top-k panels
        // over a ring, against the lossless/full oracle's byte counts.
        // τ=2 gives 8 rounds, so round traffic dwarfs the fixed
        // handshake bytes both sessions share.
        let mut cfg = tcp_cfg(2);
        cfg.tau = 2;
        let f32_out = loopback_session(&cfg, WireEncoding::F32);
        let mut topk_cfg = cfg.clone();
        topk_cfg.topology = Topology::Ring;
        let topk_out = loopback_session(&topk_cfg, WireEncoding::TopK { k_ppm: 10_000 });
        assert_eq!(topk_out.rounds, f32_out.rounds);
        for (h, theta) in &topk_out.finals {
            assert!(h.is_finite());
            assert!(theta.iter().all(|v| v.is_finite()));
        }
        // 1% of coordinates at 8 bytes each ≈ 2% of the dense panel;
        // relay→worker traffic must come in far under the oracle's.
        assert!(
            topk_out.comm.total_sent() * 5 < f32_out.comm.total_sent(),
            "topk ring {} B vs f32 full {} B",
            topk_out.comm.total_sent(),
            f32_out.comm.total_sent()
        );
    }

    #[test]
    fn resumed_session_starts_from_checkpoint_params() {
        let cfg = tcp_cfg(2);
        let first = loopback_session(&cfg, WireEncoding::F32);

        // Resume from the first session's finals; the cohort must pick
        // up those parameters (and therefore end somewhere new).
        let ck = Checkpoint {
            label: "resume-test".into(),
            iteration: 16,
            epoch: 0.25,
            sim_time_s: 0.0,
            workers: first.finals.iter().map(|(_, t)| t.clone()).collect(),
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: WireEncoding::F32,
            resume: Some(ck),
            journal: None,
            elastic: None,
        };
        let server = thread::spawn(move || serve(listener, &opts));
        let mut workers = Vec::new();
        for _ in 0..cfg.p {
            let addr = addr.clone();
            workers.push(thread::spawn(move || run_remote_worker(&addr, None, None, None, None)));
        }
        for w in workers {
            w.join().unwrap().unwrap();
        }
        let resumed = server.join().unwrap().unwrap();
        assert_eq!(resumed.finals.len(), 2);
        for ((_, fresh), (_, cont)) in first.finals.iter().zip(resumed.finals.iter()) {
            assert_eq!(fresh.len(), cont.len());
            assert_ne!(fresh, cont, "a resumed cohort must keep moving");
        }
    }

    #[test]
    fn serve_rejects_mismatched_resume_geometry() {
        let cfg = tcp_cfg(2);
        let ck = Checkpoint {
            label: "bad".into(),
            iteration: 0,
            epoch: 0.0,
            sim_time_s: 0.0,
            workers: vec![vec![0.0; 4]], // 1 worker, session wants 2
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let opts = ServeOptions {
            cfg,
            encoding: WireEncoding::F32,
            resume: Some(ck),
            journal: None,
            elastic: None,
        };
        assert!(serve(listener, &opts).is_err());
    }

    #[test]
    fn dead_worker_poisons_the_whole_cohort() {
        let mut cfg = tcp_cfg(2);
        cfg.epochs = 4.0; // long enough that the survivor is mid-session
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg,
            encoding: WireEncoding::F32,
            resume: None,
            journal: None,
            elastic: None,
        };
        let server = thread::spawn(move || serve(listener, &opts));

        // One real worker…
        let real_addr = addr.clone();
        let real = thread::spawn(move || run_remote_worker(&real_addr, None, None, None, None));
        // …and one that handshakes, then hangs up before its first panel.
        let (fabric, _welcome) = RemoteCluster::connect(&addr).unwrap();
        drop(fabric);

        let err = server.join().unwrap().expect_err("serve must report the dead worker");
        // Satellite: the dead-peer diagnostic names the offending rank
        // and its last completed round.
        let msg = format!("{err:#}");
        assert!(msg.contains("rank 1"), "must name the dead rank: {msg}");
        assert!(msg.contains("round"), "must name the last completed round: {msg}");
        assert!(real.join().unwrap().is_err(), "the survivor must be released with an error");
    }

    #[test]
    fn elastic_session_survives_a_worker_death() {
        // p=2 elastic session, min 1: one worker dies after its first
        // round; the survivor is committed into a p=1 epoch and runs to
        // completion. (The OS-process twin, with SIGKILL and a real
        // journal replay, lives in tests/fabric_e2e.rs.)
        let mut cfg = tcp_cfg(2);
        cfg.epochs = 2.0; // 1024 steps → 128 rounds: plenty to survive
        cfg.elastic = true;
        cfg.heartbeat_ms = 50;
        cfg.min_workers = 1;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: WireEncoding::F32,
            resume: None,
            journal: None,
            elastic: Some(ElasticOptions {
                min_workers: 1,
                max_workers: 2,
                heartbeat_ms: 50,
                anchor_dir: None,
            }),
        };
        let server = thread::spawn(move || serve(listener, &opts));

        // One real worker…
        let real_addr = addr.clone();
        let real = thread::spawn(move || run_remote_worker(&real_addr, None, None, None, None));
        // …and one that completes the handshake and one round, then dies.
        let (mut fabric, welcome) = RemoteCluster::connect(&addr).unwrap();
        let quitter_cfg = ExperimentConfig::from_wire_json(&welcome.config_json).unwrap();
        assert!(quitter_cfg.elastic, "the wire config must announce the elastic session");
        fabric.start_heartbeats(Duration::from_millis(50));
        let d = {
            let engine = load_backend(&quitter_cfg).unwrap();
            engine.manifest().init_params(quitter_cfg.seed ^ 0x9a9a).len()
        };
        let _ = fabric.all_gather(1.0, &vec![0.5f32; d]).unwrap();
        drop(fabric); // hang up mid-session

        let out = server.join().unwrap().expect("elastic serve must survive the death");
        assert_eq!(out.finals.len(), 1, "the final epoch runs at p=1");
        let survivor = real.join().unwrap().expect("survivor must complete");
        assert!(survivor.steps >= 1024, "survivor's cumulative steps cover the budget");
    }

    #[test]
    fn stray_socket_does_not_stall_elastic_admission() {
        // Regression: the acceptor once handshook serially (and the
        // boundary drain held the pending lock across blocking IO), so
        // one silent connection stalled every joiner behind it for
        // HANDSHAKE_TIMEOUT. Handshakes now run on detached threads: a
        // stray that never speaks must not delay a legitimate cohort.
        let mut cfg = tcp_cfg(2);
        cfg.elastic = true;
        cfg.heartbeat_ms = 50;
        cfg.min_workers = 1;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: WireEncoding::F32,
            resume: None,
            journal: None,
            elastic: Some(ElasticOptions {
                min_workers: 1,
                max_workers: 2,
                heartbeat_ms: 50,
                anchor_dir: None,
            }),
        };
        let start = Instant::now();
        let server = thread::spawn(move || serve(listener, &opts));
        // The stray connects first and never speaks, holding its socket
        // open across the whole session.
        let stray = TcpStream::connect(&addr).unwrap();
        let mut workers = Vec::new();
        for _ in 0..cfg.p {
            let addr = addr.clone();
            workers.push(thread::spawn(move || run_remote_worker(&addr, None, None, None, None)));
        }
        for w in workers {
            w.join().unwrap().unwrap();
        }
        let out = server.join().unwrap().expect("the cohort completes despite the stray");
        assert_eq!(out.finals.len(), 2);
        assert!(
            start.elapsed() < Duration::from_secs(20),
            "admission stalled behind the stray (took {:?}; the serial acceptor would \
             block a full HANDSHAKE_TIMEOUT)",
            start.elapsed()
        );
        drop(stray);
    }
}
