//! TCP worker fabric: the rendezvous relay and the remote worker's
//! [`Collective`] — decentralized WASGD on the wire.
//!
//! Topology: `wasgd serve` binds a listener and accepts exactly p
//! connections; each `wasgd worker` process connects, handshakes
//! ([`hello_frame`] → [`Welcome`] carrying its rank and the session's
//! [`ExperimentConfig`] as JSON), builds its own engine and dataset
//! (pure functions of the config), and runs
//! [`run_fabric_worker`] with a [`RemoteCluster`] as the collective. At
//! every τ-boundary a worker sends its `(h, θ)` [`Panel`]; the
//! rendezvous node barriers the round on a [`PanelExchange`] and relays
//! the full [`Cohort`] back to every peer, which then applies the
//! Boltzmann β-negotiation (Eq. 10+13) *locally* — the rendezvous never
//! aggregates and holds no center variable; it is a dumb relay, exactly
//! the role a switch or a gossip overlay would play.
//!
//! Failure semantics: a worker that dies poisons the exchange; every
//! other relay handler then pushes an [`MsgKind::Error`] frame to its
//! worker so the whole cohort errors out instead of deadlocking.
//!
//! Resumable rendezvous: `serve` can start the cohort from a saved
//! [`Checkpoint`] (each rank receives its `worker_{i}.f32` parameters in
//! the Welcome), and the final panels can be written back as a
//! checkpoint by the CLI — so a multi-process run survives restarts of
//! the whole fabric.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Mutex;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::config::ExperimentConfig;
use crate::data::source::DataPipeline;
use crate::journal::{
    canonical_comm_bytes, digest_cohort, fnv64, rank_journal_path, Event, EventSink, JournalWriter,
    MembershipChange, RANK_COHORT,
};
use crate::metrics::CommCounters;
use crate::runtime::load_backend;

use super::fabric::{
    algo_supports_fabric, planned_steps, run_fabric_worker, Collective, FabricWorkerOutcome,
    PanelExchange, WorkerPanel,
};
use super::wire::{
    self, cohort_frame_from_raw, error_text, hello_frame, Cohort, Frame, MsgKind, Panel, RawPanel,
    Welcome, WireEncoding,
};

/// A remote worker's connection to the rendezvous node — the TCP
/// implementation of the fabric's all-gather/barrier surface.
pub struct RemoteCluster {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    rank: usize,
    p: usize,
    encoding: WireEncoding,
    round: u64,
    bytes_sent: u64,
    bytes_received: u64,
}

impl RemoteCluster {
    /// Connect to a rendezvous node and complete the handshake. Returns
    /// the cluster plus the [`Welcome`] (session config JSON and
    /// optional resume parameters). The Welcome frame's encoding byte
    /// announces the session's panel encoding.
    pub fn connect(addr: &str) -> Result<(Self, Welcome)> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to rendezvous at {addr}"))?;
        stream.set_nodelay(true).ok();
        let read_half = stream.try_clone().context("cloning the rendezvous stream")?;
        let mut writer = BufWriter::new(stream);
        let mut reader = BufReader::new(read_half);

        let hello = hello_frame();
        hello.write_to(&mut writer)?;
        let bytes_sent = hello.encoded_len() as u64;

        let frame = Frame::read_from(&mut reader).context("waiting for the rendezvous welcome")?;
        let bytes_received = frame.encoded_len() as u64;
        if frame.kind == MsgKind::Error {
            bail!("rendezvous refused the connection: {}", error_text(&frame));
        }
        let welcome = Welcome::parse(&frame)?;
        ensure!(welcome.p > 0, "rendezvous announced an empty cohort");
        ensure!(
            welcome.rank < welcome.p,
            "rendezvous assigned rank {} in a cohort of {}",
            welcome.rank,
            welcome.p
        );
        Ok((
            Self {
                reader,
                writer,
                rank: welcome.rank as usize,
                p: welcome.p as usize,
                encoding: frame.encoding,
                round: 0,
                bytes_sent,
                bytes_received,
            },
            welcome,
        ))
    }

    /// The session's panel encoding (dictated by the rendezvous node).
    pub fn encoding(&self) -> WireEncoding {
        self.encoding
    }

    /// Send the final `(mean energy, θ)` panel after the step budget.
    /// `steps` is the total local step count this worker ran (carried in
    /// the panel's round field so checkpoints record real progress).
    pub fn send_final(&mut self, steps: u64, mean_energy: f32, params: &[f32]) -> Result<()> {
        let frame = Panel::frame(MsgKind::Final, steps, mean_energy, params, self.encoding);
        frame.write_to(&mut self.writer)?;
        self.bytes_sent += frame.encoded_len() as u64;
        Ok(())
    }
}

impl Collective for RemoteCluster {
    fn p(&self) -> usize {
        self.p
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn all_gather(&mut self, h: f32, params: &[f32]) -> Result<Vec<WorkerPanel>> {
        self.round += 1;
        let frame = Panel::frame(MsgKind::Panel, self.round, h, params, self.encoding);
        frame.write_to(&mut self.writer)?;
        self.bytes_sent += frame.encoded_len() as u64;

        let reply = Frame::read_from(&mut self.reader)
            .with_context(|| format!("waiting for cohort of round {}", self.round))?;
        self.bytes_received += reply.encoded_len() as u64;
        if reply.kind == MsgKind::Error {
            bail!("rendezvous aborted the session: {}", error_text(&reply));
        }
        let cohort = Cohort::parse(&reply)?;
        ensure!(
            cohort.round == self.round,
            "cohort carries round {}, expected {}",
            cohort.round,
            self.round
        );
        ensure!(
            cohort.panels.len() == self.p,
            "cohort has {} panels, expected {}",
            cohort.panels.len(),
            self.p
        );
        Ok(cohort.panels)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    fn encoding(&self) -> WireEncoding {
        self.encoding
    }
}

/// What a rendezvous session runs: the experiment, the panel encoding,
/// and optionally a checkpoint to resume the cohort from.
pub struct ServeOptions {
    /// The session config, shipped verbatim to every worker.
    pub cfg: ExperimentConfig,
    /// Panel encoding on the wire (f32 = lossless, qi8 = 4× smaller).
    pub encoding: WireEncoding,
    /// Resume each rank from `workers[rank]` of this checkpoint.
    pub resume: Option<Checkpoint>,
    /// Journal the session's event stream here. A resumed session
    /// *appends*, stitching its segment onto the original journal; a
    /// fresh session truncates. With the f32 encoding the relay digests
    /// every rank's raw panel bytes per round (numerics-free: the f32
    /// panel body IS θ's little-endian bytes), making the journal
    /// bit-exactly verifiable with `wasgd replay`.
    pub journal: Option<PathBuf>,
}

/// What a completed rendezvous session produced.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    /// Final `(mean energy, θ)` per rank, in rank order.
    pub finals: Vec<WorkerPanel>,
    /// Collective rounds relayed (τ-boundaries crossed).
    pub rounds: u64,
    /// Local SGD steps each worker ran (as reported in its Final panel;
    /// the max across ranks — they agree in a well-formed session).
    pub steps: u64,
    /// Per-peer relay traffic, feeding the cluster cost model.
    pub comm: CommCounters,
}

struct RelayStats {
    sent: u64,
    received: u64,
    rounds: u64,
}

/// A silent non-protocol connection may stall the handshake read at most
/// this long before being dropped.
const HANDSHAKE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);
/// Give up on the session after this many failed handshakes.
const MAX_BAD_HANDSHAKES: usize = 64;

type HandshakeOk = (BufReader<TcpStream>, BufWriter<TcpStream>, u64, u64);

/// Validate one connection's hello and answer with its Welcome. The
/// read timeout applies only during the handshake (relay reads must
/// block indefinitely: τ compute periods are legitimately long).
fn handshake(
    stream: &TcpStream,
    rank: usize,
    p: usize,
    cfg_json: &str,
    opts: &ServeOptions,
) -> Result<HandshakeOk> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT)).ok();
    let read_half = stream.try_clone().context("cloning a worker stream")?;
    let mut reader = BufReader::new(read_half);
    let hello = Frame::read_from(&mut reader).context("reading the hello")?;
    ensure!(hello.kind == MsgKind::Hello, "opened with {:?}, expected a hello", hello.kind);
    stream.set_read_timeout(None).ok();
    let mut writer = BufWriter::new(stream.try_clone().context("cloning a worker stream")?);
    let welcome = Welcome {
        rank: rank as u32,
        p: p as u32,
        config_json: cfg_json.to_string(),
        resume: opts.resume.as_ref().map(|ck| ck.workers[rank].clone()),
    };
    let frame = welcome.frame(opts.encoding);
    frame.write_to(&mut writer).context("writing the welcome")?;
    Ok((reader, writer, hello.encoded_len() as u64, frame.encoded_len() as u64))
}

/// Run one rendezvous session to completion: accept `cfg.p` workers
/// (rank = accept order), handshake each, then relay `(h, θ)` panels
/// round by round until every worker has delivered its final panel.
///
/// The rendezvous is numerics-free: it never touches θ beyond framing,
/// so the aggregation stays fully decentralized (each worker applies
/// Eq. 10+13 itself — no center variable anywhere).
pub fn serve(listener: TcpListener, opts: &ServeOptions) -> Result<ServeOutcome> {
    let cfg = &opts.cfg;
    cfg.validate().map_err(|e| anyhow!(e))?;
    ensure!(
        algo_supports_fabric(cfg.algo),
        "the tcp fabric supports the synchronous decentralized schemes; {} needs --fabric sim",
        cfg.algo.name()
    );
    let p = cfg.p;
    if let Some(ck) = &opts.resume {
        ensure!(
            ck.workers.len() == p,
            "resume checkpoint has {} workers, session wants p={p}",
            ck.workers.len()
        );
    }
    // Ship a *concrete* data source in the wire config: the rendezvous
    // resolves `auto` against its own filesystem once, so a worker
    // whose host is missing the promised files errors out pointedly
    // instead of silently training on the synthetic analogue (which
    // would de-synchronise the cohort's data).
    let wire_cfg = {
        let pipeline = DataPipeline::from_config(cfg)?;
        if let Some(note) = pipeline.note() {
            eprintln!("rendezvous: {note}");
        }
        let mut c = cfg.clone();
        c.source = pipeline.source_kind();
        c
    };
    let cfg_json = wire_cfg.to_wire_json();
    let mut comm = CommCounters::new(p);

    // Cohort-scope journal: the rendezvous sees every rank's panel, so
    // its journal carries the whole cohort's digests — and, on resume,
    // all p checkpoint vectors (workers only ever learn their own),
    // which is why `wasgd replay` verifies *this* journal for resumed
    // sessions. Resume appends: the stitched file replays segment by
    // segment.
    let journal: Option<Mutex<JournalWriter>> = match &opts.journal {
        Some(path) => Some(Mutex::new(if opts.resume.is_some() {
            JournalWriter::append_to(path)?
        } else {
            JournalWriter::create(path)?
        })),
        None => None,
    };
    jemit(
        journal.as_ref(),
        &Event::RunStarted {
            rank: RANK_COHORT,
            p: p as u32,
            seed: cfg.seed,
            encoding: opts.encoding,
            git_rev: crate::bench::git_rev(),
            config_json: cfg_json.clone(),
            resume: opts.resume.as_ref().map(|ck| ck.workers.clone()).unwrap_or_default(),
        },
    )?;

    // Handshake phase: rank = accept order *of completed handshakes*. A
    // stray connection (port scan, health probe) is dropped — after a
    // bounded read timeout if it stays silent — and the rank re-offered,
    // instead of wedging the serial accept loop or aborting the session.
    let mut bad_handshakes = 0usize;
    let mut conns = Vec::with_capacity(p);
    while conns.len() < p {
        let rank = conns.len();
        let (stream, peer) = listener.accept().context("accepting a worker connection")?;
        stream.set_nodelay(true).ok();
        match handshake(&stream, rank, p, &cfg_json, opts) {
            Ok((reader, writer, hello_len, welcome_len)) => {
                comm.add(rank, welcome_len, hello_len);
                jemit(
                    journal.as_ref(),
                    &Event::Membership {
                        epoch: 0,
                        rank: rank as u32,
                        change: MembershipChange::Joined,
                    },
                )?;
                conns.push((reader, writer));
            }
            Err(e) => {
                bad_handshakes += 1;
                eprintln!("rendezvous: dropping connection from {peer}: {e:#}");
                ensure!(
                    bad_handshakes < MAX_BAD_HANDSHAKES,
                    "{bad_handshakes} failed handshakes — is something else probing this port?"
                );
            }
        }
    }

    // Relay phase: one handler thread per connection, barriered on a
    // poisonable exchange. Panels stay in their *encoded* form end to
    // end — the relay validates framing and memcpys bytes, it never
    // decodes θ (and so can never re-quantise a qi8 panel).
    let exchange: PanelExchange<(f32, Vec<u8>)> = PanelExchange::new(p);
    let finals: Mutex<Vec<Option<(u64, WorkerPanel)>>> = Mutex::new(vec![None; p]);
    let ctx = RelayCtx {
        exchange: &exchange,
        finals: &finals,
        enc: opts.encoding,
        journal: journal.as_ref(),
    };
    let results: Vec<Result<RelayStats>> = std::thread::scope(|s| {
        let ctx = &ctx;
        let handles: Vec<_> = conns
            .into_iter()
            .enumerate()
            .map(|(rank, (mut reader, mut writer))| {
                s.spawn(move || {
                    let mut stats = RelayStats { sent: 0, received: 0, rounds: 0 };
                    let result = relay_loop(rank, &mut reader, &mut writer, ctx, &mut stats);
                    if let Err(e) = &result {
                        ctx.exchange.poison(&format!("relay for rank {rank} failed: {e}"));
                        let _ = wire::error_frame(&format!("{e}")).write_to(&mut writer);
                    }
                    result.map(|()| stats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("relay thread panicked"))))
            .collect()
    });

    let mut rounds = 0u64;
    for (rank, result) in results.into_iter().enumerate() {
        let stats = result.with_context(|| format!("worker rank {rank}"))?;
        comm.add(rank, stats.sent, stats.received);
        rounds = rounds.max(stats.rounds);
    }
    let finals = finals.into_inner().unwrap();
    let mut out = Vec::with_capacity(p);
    let mut steps = 0u64;
    for (rank, f) in finals.into_iter().enumerate() {
        let (s, panel) =
            f.ok_or_else(|| anyhow!("rank {rank} never delivered its final panel"))?;
        steps = steps.max(s);
        out.push(panel);
    }
    jemit(
        journal.as_ref(),
        &Event::RunFinished {
            steps,
            rounds,
            final_digest: digest_cohort(out.iter().map(|(_, t)| t.as_slice())),
        },
    )?;
    Ok(ServeOutcome { finals: out, rounds, steps, comm })
}

/// Emit into an optional mutex-shared journal (the rendezvous's relay
/// threads all funnel through one writer).
fn jemit(journal: Option<&Mutex<JournalWriter>>, ev: &Event) -> Result<()> {
    if let Some(j) = journal {
        j.lock().unwrap().emit(ev)?;
    }
    Ok(())
}

/// Session state shared by every relay handler thread.
struct RelayCtx<'a> {
    exchange: &'a PanelExchange<(f32, Vec<u8>)>,
    finals: &'a Mutex<Vec<Option<(u64, WorkerPanel)>>>,
    enc: WireEncoding,
    journal: Option<&'a Mutex<JournalWriter>>,
}

fn relay_loop(
    rank: usize,
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    ctx: &RelayCtx,
    stats: &mut RelayStats,
) -> Result<()> {
    loop {
        let frame = Frame::read_from(reader)?;
        stats.received += frame.encoded_len() as u64;
        match frame.kind {
            MsgKind::Panel => {
                ensure!(
                    frame.encoding == ctx.enc,
                    "rank {rank} sent a {:?} panel in a {:?} session",
                    frame.encoding,
                    ctx.enc
                );
                let panel = RawPanel::parse(&frame)?;
                ensure!(
                    panel.round == stats.rounds + 1,
                    "rank {rank} jumped to round {} (expected {})",
                    panel.round,
                    stats.rounds + 1
                );
                let cohort = ctx.exchange.exchange(rank, (panel.h, panel.body))?;
                // One designated emitter (rank 0's handler) journals the
                // round's cohort. An f32 panel body is exactly θ's
                // little-endian bytes, so the relay digests raw wire
                // bytes without ever decoding parameters — and lands on
                // the same fnv64 a worker computes over its floats. The
                // barrier guarantees rank 0 cannot deposit round n+1
                // before round n published, so rounds journal in order.
                if rank == 0 && ctx.enc == WireEncoding::F32 {
                    if let Some(j) = ctx.journal {
                        let mut w = j.lock().unwrap();
                        for (r, (h, body)) in cohort.iter().enumerate() {
                            w.emit(&Event::PanelDigest {
                                round: panel.round,
                                rank: r as u32,
                                digest: fnv64(body),
                                loss: *h,
                                comm_bytes: canonical_comm_bytes(panel.round, body.len() / 4),
                            })?;
                        }
                    }
                }
                let reply = cohort_frame_from_raw(panel.round, &cohort[..], ctx.enc);
                reply.write_to(writer)?;
                stats.sent += reply.encoded_len() as u64;
                stats.rounds += 1;
            }
            MsgKind::Final => {
                let panel = Panel::parse(&frame)?;
                // A Final's round field is the worker's total step count.
                ctx.finals.lock().unwrap()[rank] = Some((panel.round, (panel.h, panel.theta)));
                // A departed participant can never deposit again. In the
                // homogeneous case every rank finishes after the same
                // round, all of whose deposits preceded this Final, so
                // the poison is unobservable; with mismatched step
                // budgets (e.g. different --artifacts resolving a
                // different batch size) it converts what would be a
                // permanent barrier deadlock into a clean session error.
                ctx.exchange.poison(&format!(
                    "rank {rank} finished after round {}; no further collectives can complete",
                    stats.rounds
                ));
                return Ok(());
            }
            MsgKind::Error => bail!("worker rank {rank} reported: {}", error_text(&frame)),
            other => bail!("unexpected {other:?} frame from rank {rank} mid-session"),
        }
    }
}

/// Run one remote worker end to end: connect, adopt the session config
/// from the Welcome (CLI `--threads` / `--artifacts` / `--data-dir`
/// override the local knobs), build engine + data pipeline locally,
/// train through the fabric, and deliver the final panel.
///
/// The wire config carries a concrete data source (the rendezvous
/// resolves `auto` before serving), so a worker that cannot locate the
/// promised real files fails with a pointed error instead of silently
/// falling back to synth and de-synchronising the cohort.
///
/// `journal_base` journals this worker's view of the run to
/// `base.rank{r}` (the rank is only known after the handshake; the
/// suffix keeps p workers sharing one `--journal` value from clobbering
/// each other — or the rendezvous journal at `base` itself).
pub fn run_remote_worker(
    addr: &str,
    artifacts_root: Option<PathBuf>,
    threads_override: Option<usize>,
    data_dir_override: Option<PathBuf>,
    journal_base: Option<PathBuf>,
) -> Result<FabricWorkerOutcome> {
    let (mut fabric, welcome) = RemoteCluster::connect(addr)?;
    let mut cfg = ExperimentConfig::from_wire_json(&welcome.config_json)
        .context("parsing the session config from the welcome")?;
    if let Some(threads) = threads_override {
        cfg.threads = threads;
    }
    if let Some(root) = artifacts_root {
        cfg.artifacts_root = root;
    }
    if let Some(dir) = data_dir_override {
        cfg.data_dir = Some(dir);
    }
    let engine = load_backend(&cfg)?;
    let dataset = DataPipeline::from_config(&cfg)?.load(engine.manifest())?;
    let total_steps = planned_steps(&cfg, dataset.n_train(), engine.manifest().batch);
    let mut jw = match &journal_base {
        Some(base) => {
            Some(JournalWriter::create(&rank_journal_path(base, welcome.rank as usize))?)
        }
        None => None,
    };
    let mut out = run_fabric_worker(
        &cfg,
        engine.as_ref(),
        &dataset,
        &mut fabric,
        total_steps,
        welcome.resume,
        jw.as_mut().map(|w| w as &mut dyn EventSink),
    )?;
    fabric.send_final(out.steps as u64, out.mean_energy, &out.params)?;
    out.bytes_sent = fabric.bytes_sent();
    out.bytes_received = fabric.bytes_received();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, FabricKind};
    use std::thread;

    fn tcp_cfg(p: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = BackendKind::Native;
        cfg.fabric = FabricKind::Tcp;
        cfg.p = p;
        cfg.tau = 8;
        cfg.m = 2;
        cfg.c = 1;
        cfg.epochs = 0.25; // 512/8 per epoch → 16 steps, 2 boundaries
        cfg
    }

    /// Spin up a loopback session with in-process worker threads (the
    /// process-level twin lives in tests/fabric_e2e.rs).
    fn loopback_session(cfg: &ExperimentConfig, opts_enc: WireEncoding) -> ServeOutcome {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts =
            ServeOptions { cfg: cfg.clone(), encoding: opts_enc, resume: None, journal: None };
        let server = thread::spawn(move || serve(listener, &opts));
        let mut workers = Vec::new();
        for _ in 0..cfg.p {
            let addr = addr.clone();
            workers.push(thread::spawn(move || run_remote_worker(&addr, None, None, None, None)));
        }
        for w in workers {
            w.join().unwrap().unwrap();
        }
        server.join().unwrap().unwrap()
    }

    #[test]
    fn loopback_session_completes_and_counts_bytes() {
        let cfg = tcp_cfg(2);
        let out = loopback_session(&cfg, WireEncoding::F32);
        assert_eq!(out.finals.len(), 2);
        assert_eq!(out.rounds, 2);
        assert_eq!(out.steps, 16, "finals must report the true local step count");
        for (h, theta) in &out.finals {
            assert!(h.is_finite());
            assert!(theta.iter().all(|v| v.is_finite()));
            assert!(!theta.is_empty());
        }
        // The relay receives one panel and sends p panels per round.
        assert!(out.comm.total_sent() > out.comm.total_received());
        for peer in &out.comm.peers {
            assert!(peer.sent > 0 && peer.received > 0);
        }
    }

    #[test]
    fn qi8_session_completes_with_much_less_traffic() {
        let cfg = tcp_cfg(2);
        let f32_out = loopback_session(&cfg, WireEncoding::F32);
        let qi8_out = loopback_session(&cfg, WireEncoding::Qi8);
        assert_eq!(qi8_out.rounds, f32_out.rounds);
        for (h, theta) in &qi8_out.finals {
            assert!(h.is_finite());
            assert!(theta.iter().all(|v| v.is_finite()));
        }
        // Quantised panels are ~4× smaller; allow generous headroom.
        assert!(
            qi8_out.comm.total_sent() * 2 < f32_out.comm.total_sent(),
            "qi8 {} B vs f32 {} B",
            qi8_out.comm.total_sent(),
            f32_out.comm.total_sent()
        );
    }

    #[test]
    fn resumed_session_starts_from_checkpoint_params() {
        let cfg = tcp_cfg(2);
        let first = loopback_session(&cfg, WireEncoding::F32);

        // Resume from the first session's finals; the cohort must pick
        // up those parameters (and therefore end somewhere new).
        let ck = Checkpoint {
            label: "resume-test".into(),
            iteration: 16,
            epoch: 0.25,
            sim_time_s: 0.0,
            workers: first.finals.iter().map(|(_, t)| t.clone()).collect(),
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions {
            cfg: cfg.clone(),
            encoding: WireEncoding::F32,
            resume: Some(ck),
            journal: None,
        };
        let server = thread::spawn(move || serve(listener, &opts));
        let mut workers = Vec::new();
        for _ in 0..cfg.p {
            let addr = addr.clone();
            workers.push(thread::spawn(move || run_remote_worker(&addr, None, None, None, None)));
        }
        for w in workers {
            w.join().unwrap().unwrap();
        }
        let resumed = server.join().unwrap().unwrap();
        assert_eq!(resumed.finals.len(), 2);
        for ((_, fresh), (_, cont)) in first.finals.iter().zip(resumed.finals.iter()) {
            assert_eq!(fresh.len(), cont.len());
            assert_ne!(fresh, cont, "a resumed cohort must keep moving");
        }
    }

    #[test]
    fn serve_rejects_mismatched_resume_geometry() {
        let cfg = tcp_cfg(2);
        let ck = Checkpoint {
            label: "bad".into(),
            iteration: 0,
            epoch: 0.0,
            sim_time_s: 0.0,
            workers: vec![vec![0.0; 4]], // 1 worker, session wants 2
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let opts =
            ServeOptions { cfg, encoding: WireEncoding::F32, resume: Some(ck), journal: None };
        assert!(serve(listener, &opts).is_err());
    }

    #[test]
    fn dead_worker_poisons_the_whole_cohort() {
        let mut cfg = tcp_cfg(2);
        cfg.epochs = 4.0; // long enough that the survivor is mid-session
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOptions { cfg, encoding: WireEncoding::F32, resume: None, journal: None };
        let server = thread::spawn(move || serve(listener, &opts));

        // One real worker…
        let real_addr = addr.clone();
        let real = thread::spawn(move || run_remote_worker(&real_addr, None, None, None, None));
        // …and one that handshakes, then hangs up before its first panel.
        let (fabric, _welcome) = RemoteCluster::connect(&addr).unwrap();
        drop(fabric);

        assert!(server.join().unwrap().is_err(), "serve must report the dead worker");
        assert!(real.join().unwrap().is_err(), "the survivor must be released with an error");
    }
}
