//! Real-thread execution mode: one OS thread per worker, each owning its
//! own execution backend, synchronising through an in-process all-gather.
//!
//! The deterministic simulation (`coordinator::Trainer`) is what the
//! figures use; this module is the *launcher-grade* mode proving the
//! decentralized protocol composes with genuinely concurrent workers:
//! backends are single-threaded (the PJRT client is `Rc`-based, not
//! `Send`), so every thread constructs its own — exactly the process
//! topology a multi-host deployment would have, with the [`AllGather`]
//! channel standing in for the NIC.

use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use anyhow::{ensure, Result};

use crate::config::ExperimentConfig;
use crate::data::synth::SynthConfig;
use crate::data::Dataset;
use crate::kernels::Gemm;
use crate::linalg;
use crate::rng::Rng;
use crate::runtime::{load_backend, Backend as _};

/// A reusable p-way all-gather barrier carrying one `T` per participant.
///
/// `exchange(i, v)` blocks until all p participants of the current
/// generation have deposited, then returns the full vector to everyone.
pub struct AllGather<T> {
    inner: Mutex<AgState<T>>,
    cv: Condvar,
    p: usize,
}

struct AgState<T> {
    slots: Vec<Option<T>>,
    published: Arc<Vec<T>>,
    generation: u64,
}

impl<T: Clone> AllGather<T> {
    pub fn new(p: usize) -> Self {
        Self {
            inner: Mutex::new(AgState {
                slots: (0..p).map(|_| None).collect(),
                published: Arc::new(Vec::new()),
                generation: 0,
            }),
            cv: Condvar::new(),
            p,
        }
    }

    /// Deposit worker `i`'s contribution; returns everyone's once the
    /// round completes. Panics on double-deposit within one round.
    pub fn exchange(&self, i: usize, v: T) -> Arc<Vec<T>> {
        let mut st = self.inner.lock().unwrap();
        assert!(st.slots[i].is_none(), "worker {i} deposited twice in one round");
        st.slots[i] = Some(v);
        if st.slots.iter().all(|s| s.is_some()) {
            let vals: Vec<T> = st.slots.iter_mut().map(|s| s.take().unwrap()).collect();
            st.published = Arc::new(vals);
            st.generation += 1;
            self.cv.notify_all();
            return st.published.clone();
        }
        let gen = st.generation;
        while st.generation == gen {
            st = self.cv.wait(st).unwrap();
        }
        st.published.clone()
    }

    pub fn participants(&self) -> usize {
        self.p
    }
}

/// Outcome of a threaded run.
#[derive(Debug)]
pub struct ThreadedOutcome {
    /// Final mean train loss per worker (estimated over its last period).
    pub final_energies: Vec<f32>,
    /// Worker 0's final parameters.
    pub params: Vec<f32>,
    /// Wall seconds for the whole cohort.
    pub wall_time_s: f64,
    /// Total local steps per worker.
    pub steps: usize,
}

/// Run WASGD+ (Eq. 10+13) with `cfg.p` real threads for
/// `total_steps` local iterations each.
///
/// Each thread: own backend (selected by `cfg.backend` — PJRT artifacts
/// or the native engine), own shuffle stream, local SGD; at every
/// τ-boundary, a real blocking all-gather of `(h, params)` followed by
/// the Boltzmann β-negotiation applied locally (every worker computes
/// the same aggregate — decentralized, no parameter server).
pub fn run_wasgd_plus_threaded(
    cfg: &ExperimentConfig,
    total_steps: usize,
) -> Result<ThreadedOutcome> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    // Probe the backend once on this thread so the synthetic dataset can
    // match the variant's input geometry (e.g. `tiny_cnn`'s 8×8×1 = 64
    // against the tiny preset's 16 raw features) — the probe is dropped
    // before any worker spawns.
    let mut synth = SynthConfig::preset(cfg.dataset);
    {
        let probe = load_backend(cfg)?;
        let m = probe.manifest();
        ensure!(
            synth.classes <= m.num_classes,
            "dataset {} has {} classes but variant {} emits {} logits",
            cfg.dataset.name(),
            synth.classes,
            m.name,
            m.num_classes
        );
        synth.dim = m.input_dim;
    }
    let dataset: Arc<Dataset> = Arc::new(synth.build(cfg.seed));
    let gather: Arc<AllGather<(f32, Vec<f32>)>> = Arc::new(AllGather::new(cfg.p));
    let started = std::time::Instant::now();

    let mut handles = Vec::new();
    for i in 0..cfg.p {
        let cfg = cfg.clone();
        let dataset = Arc::clone(&dataset);
        let gather = Arc::clone(&gather);
        handles.push(thread::spawn(move || -> Result<(f32, Vec<f32>)> {
            // Backend is built *inside* the thread: PjRtClient is !Send.
            let engine = load_backend(&cfg)?;
            // Intra-op threads for the local β-negotiation row-combine —
            // bit-identical at any count, so `--threads` stays pure
            // throughput here too.
            let gemm = Gemm::new(cfg.threads);
            let b = engine.manifest().batch;
            let mut params = engine.manifest().init_params(cfg.seed ^ 0x9a9a);
            let mut rng = Rng::new(cfg.seed).child(100 + i as u64);
            let n = dataset.n_train();
            let mut order = rng.permutation(n);
            let mut pos = 0usize;
            let (mut x_buf, mut y_buf) = (Vec::new(), Vec::new());
            let mut energy = 0.0f32;
            let mut recorded = 0u32;
            let mut last_energy = 1.0f32;

            for step in 1..=total_steps {
                if (pos + 1) * b > order.len() {
                    order = rng.permutation(n);
                    pos = 0;
                }
                let idx = &order[pos * b..(pos + 1) * b];
                pos += 1;
                dataset.gather_train(idx, &mut x_buf, &mut y_buf);
                let (next, out) = engine.train_step(&params, &x_buf, &y_buf, cfg.lr)?;
                params = next;
                // Tail-window estimation (c=1 flavour of Eq. 26).
                if step % cfg.tau > cfg.tau.saturating_sub(cfg.m) || step % cfg.tau == 0 {
                    energy += out.loss;
                    recorded += 1;
                }
                if step % cfg.tau == 0 {
                    let h = if recorded == 0 { 1.0 } else { energy.max(1e-12) };
                    last_energy = h / recorded.max(1) as f32;
                    // REAL all-gather: blocks until the whole cohort is here.
                    let cohort = gather.exchange(i, (h, params.clone()));
                    let hs: Vec<f32> = cohort.iter().map(|(h, _)| *h).collect();
                    let theta = linalg::boltzmann_weights(&hs, cfg.a_tilde);
                    let mut agg = vec![0.0f32; params.len()];
                    {
                        let rows: Vec<&[f32]> =
                            cohort.iter().map(|(_, p)| p.as_slice()).collect();
                        gemm.combine_rows(&mut agg, &rows, &theta);
                    }
                    linalg::lerp_into(&mut params, cfg.beta, &agg);
                    energy = 0.0;
                    recorded = 0;
                }
            }
            Ok((last_energy, params))
        }));
    }

    let mut final_energies = Vec::with_capacity(cfg.p);
    let mut params0 = Vec::new();
    for (i, h) in handles.into_iter().enumerate() {
        let (e, p) = h.join().map_err(|_| anyhow::anyhow!("worker {i} panicked"))??;
        final_energies.push(e);
        if i == 0 {
            params0 = p;
        }
    }
    Ok(ThreadedOutcome {
        final_energies,
        params: params0,
        wall_time_s: started.elapsed().as_secs_f64(),
        steps: total_steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::data::synth::DatasetKind;

    #[test]
    fn threaded_run_native_backend_learns() {
        // Hermetic: real threads, one native backend each, two boundaries.
        let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
        cfg.backend = BackendKind::Native;
        cfg.p = 2;
        cfg.tau = 16;
        cfg.m = 4;
        let out = run_wasgd_plus_threaded(&cfg, 96).unwrap();
        assert_eq!(out.final_energies.len(), 2);
        assert!(out.final_energies.iter().all(|&e| e.is_finite() && e < 1.0));
        assert!(!out.params.is_empty());
    }

    #[test]
    fn allgather_roundtrip_two_threads() {
        let ag: Arc<AllGather<u32>> = Arc::new(AllGather::new(2));
        let a = Arc::clone(&ag);
        let t = thread::spawn(move || a.exchange(1, 11).to_vec());
        let got0 = ag.exchange(0, 7).to_vec();
        let got1 = t.join().unwrap();
        assert_eq!(got0, vec![7, 11]);
        assert_eq!(got1, vec![7, 11]);
    }

    #[test]
    fn allgather_many_rounds() {
        let p = 4;
        let ag: Arc<AllGather<usize>> = Arc::new(AllGather::new(p));
        let mut handles = Vec::new();
        for i in 0..p {
            let ag = Arc::clone(&ag);
            handles.push(thread::spawn(move || {
                let mut sums = Vec::new();
                for round in 0..50 {
                    let vals = ag.exchange(i, i * 1000 + round);
                    sums.push(vals.iter().sum::<usize>());
                }
                sums
            }));
        }
        let results: Vec<Vec<usize>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Every worker saw the identical per-round sums.
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
        // Round r sum = Σᵢ (i·1000 + r) = 6000 + 4r.
        for (round, &s) in results[0].iter().enumerate() {
            assert_eq!(s, 6000 + 4 * round);
        }
    }
}
