//! Real-thread execution mode: one OS thread per worker, each owning its
//! own execution backend, synchronising through an in-process all-gather.
//!
//! The deterministic simulation (`coordinator::Trainer`) is what the
//! figures use; this module is the *launcher-grade* mode proving the
//! decentralized protocol composes with genuinely concurrent workers:
//! backends are single-threaded (the PJRT client is `Rc`-based, not
//! `Send`), so every thread constructs its own — exactly the process
//! topology a multi-host deployment would have, with the in-process
//! [`PanelExchange`](crate::cluster::fabric::PanelExchange) standing in
//! for the NIC.
//!
//! Since the fabric refactor the loop itself lives in
//! [`fabric::run_fabric_worker`](crate::cluster::fabric::run_fabric_worker)
//! — the same code that drives `wasgd worker` processes over TCP — and
//! every thread trains on the split materialised by the shared
//! [`DataPipeline`](crate::data::DataPipeline) (synthetic or real
//! files), so a threaded run, a TCP run, and the simulated trainer
//! produce **bit-identical** final parameters for every data source
//! (pinned by `tests/fabric_e2e.rs`; the exchange itself is
//! stress-tested in `tests/allgather_props.rs`). That identity holds
//! under every *deterministic* encoding × topology combination —
//! lossless f32, deterministically lossy top-k, full, ring, and gossip
//! all run the same codec and schedule on all three substrates (the
//! lossy modes just aren't bit-comparable to a *lossless* run; see
//! `docs/FABRIC.md` for the two test tiers).

use anyhow::Result;

use crate::config::ExperimentConfig;

use super::fabric::run_decentralized_threaded;

/// Outcome of a threaded run.
#[derive(Debug)]
pub struct ThreadedOutcome {
    /// Final mean recorded batch loss per worker (over its last period).
    pub final_energies: Vec<f32>,
    /// Worker 0's final parameters.
    pub params: Vec<f32>,
    /// Wall seconds for the whole cohort.
    pub wall_time_s: f64,
    /// Total local steps per worker.
    pub steps: usize,
    /// Wire-equivalent bytes the cohort exchanged (all workers, both
    /// directions) — what the same run would push through a real NIC.
    pub comm_bytes: u64,
}

/// Run WASGD+ (Eq. 10+13) with `cfg.p` real threads for `total_steps`
/// local iterations each.
///
/// Each thread: own backend (selected by `cfg.backend`), the simulated
/// trainer's exact per-worker sample stream (§3.4 order search
/// included), local SGD; at every τ-boundary a real blocking all-gather
/// of `(h, params)` followed by the Boltzmann β-negotiation applied
/// locally through the shared `CommPolicy` code — every worker computes
/// the same aggregate (decentralized, no parameter server), and the
/// final parameters match the simulated trainer bit for bit.
pub fn run_wasgd_plus_threaded(
    cfg: &ExperimentConfig,
    total_steps: usize,
) -> Result<ThreadedOutcome> {
    let started = std::time::Instant::now();
    let mut outs = run_decentralized_threaded(cfg, total_steps)?;
    let final_energies = outs.iter().map(|o| o.mean_energy).collect();
    let comm_bytes = outs.iter().map(|o| o.bytes_sent + o.bytes_received).sum();
    let params = std::mem::take(&mut outs[0].params);
    Ok(ThreadedOutcome {
        final_energies,
        params,
        wall_time_s: started.elapsed().as_secs_f64(),
        steps: total_steps,
        comm_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::BackendKind;
    use crate::data::synth::DatasetKind;

    #[test]
    fn threaded_run_native_backend_learns() {
        // Hermetic: real threads, one native backend each, two boundaries.
        let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
        cfg.backend = BackendKind::Native;
        cfg.p = 2;
        cfg.tau = 16;
        cfg.m = 4;
        let out = run_wasgd_plus_threaded(&cfg, 96).unwrap();
        assert_eq!(out.final_energies.len(), 2);
        assert!(out.final_energies.iter().all(|&e| e.is_finite() && e < 1.0));
        assert!(!out.params.is_empty());
        assert!(out.comm_bytes > 0);
        assert_eq!(out.steps, 96);
    }
}
