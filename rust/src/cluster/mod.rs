//! Simulated cluster substrate (DESIGN.md §3 substitution: Tesla-K80 /
//! CPU-cluster testbed → deterministic in-process simulation).
//!
//! The paper's time-to-loss curves (Figs. 8–11) are wall-clock on a real
//! cluster. We reproduce the *cluster effects* — communication cost
//! growing with τ⁻¹ and message size, stragglers hurting synchronous
//! schemes, backup workers rescuing the async variant — with an explicit
//! cost model driving per-worker virtual clocks:
//!
//! * compute: each local SGD step costs `step_time · (1 + jitter)`, with
//!   a heavy-tail straggler mixture (probability `straggler_prob` of a
//!   `straggler_factor×` slowdown — GC pauses / co-tenants / ECC stalls);
//! * communication: an all-gather of `bytes` over p workers is modelled
//!   as a ring: `(p−1) · (α + bytes/(p·B))` with per-hop latency α and
//!   link bandwidth B — the standard LogP-flavoured collective estimate;
//! * synchronous schemes advance every participant to the barrier max;
//!   the asynchronous WASGD+ proceeds when the first p−1 peers (of
//!   p+b−1) have arrived.
//!
//! Real wall-clock is *also* measured by the harness (the numerics run
//! for real); the simulated clock is what the figures plot, so the
//! curves are independent of this machine's core count.
//!
//! Next to the simulation live the *real* fabric substrates (selected by
//! `--fabric sim|tcp`): [`fabric`] extracts the all-gather surface and
//! the decentralized worker loop, [`wire`] is the length-prefixed binary
//! protocol, [`tcp`] is the multi-process rendezvous/relay substrate,
//! and [`threads`] is the in-process concurrency twin.

pub mod fabric;
pub mod tcp;
pub mod threads;
pub mod wire;

use crate::rng::Rng;

/// Per-message / per-byte cost model for the interconnect.
#[derive(Clone, Copy, Debug)]
pub struct FabricConfig {
    /// Per-hop latency α (seconds). Default 50 µs — 10 GbE-ish RTT/2.
    pub latency_s: f64,
    /// Link bandwidth B (bytes/second). Default 1.25 GB/s (10 GbE).
    pub bandwidth: f64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        Self { latency_s: 50e-6, bandwidth: 1.25e9 }
    }
}

impl FabricConfig {
    /// Time for a p-way ring all-gather where each rank contributes
    /// `bytes`: (p−1) hops, each sending one chunk of `bytes`.
    pub fn allgather_time(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p as f64 - 1.0) * (self.latency_s + bytes as f64 / self.bandwidth)
    }

    /// Point-to-point send of `bytes` (EASGD worker↔master round trip is
    /// two of these).
    pub fn p2p_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth
    }
}

/// Per-step compute-time model with straggler mixture.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Mean seconds per local SGD step (calibrated from the real engine
    /// by the harness, or set explicitly for what-if sweeps).
    pub step_time_s: f64,
    /// Lognormal-ish multiplicative jitter: step · (1 + cv·|N(0,1)|).
    pub jitter_cv: f64,
    /// Probability a step lands on a straggler event.
    pub straggler_prob: f64,
    /// Multiplicative slowdown of a straggler step.
    pub straggler_factor: f64,
}

impl Default for ComputeModel {
    /// Defaults model the paper's *dedicated* cluster (§5.2: synchronous
    /// was chosen because "the time difference for computing each sample
    /// is small"): light jitter, rare mild stragglers. The async/backup
    /// experiments override these with heavy-tail settings.
    fn default() -> Self {
        Self {
            step_time_s: 2e-3,
            jitter_cv: 0.02,
            straggler_prob: 0.002,
            straggler_factor: 4.0,
        }
    }
}

impl ComputeModel {
    /// Sample the duration of one local step.
    pub fn sample_step(&self, rng: &mut Rng) -> f64 {
        let mut t = self.step_time_s * (1.0 + self.jitter_cv * rng.normal().abs());
        if self.straggler_prob > 0.0 && rng.uniform() < self.straggler_prob {
            t *= self.straggler_factor;
        }
        t
    }
}

/// The virtual cluster: one clock per worker plus the cost models.
#[derive(Clone, Debug)]
pub struct SimCluster {
    /// One virtual clock (seconds) per worker.
    pub clocks: Vec<f64>,
    /// Interconnect cost model charged by the collectives.
    pub fabric: FabricConfig,
    /// Per-step compute-time model (jitter + straggler mixture).
    pub compute: ComputeModel,
    rng: Rng,
    /// Accumulated seconds spent inside collectives (telemetry).
    pub comm_time_total: f64,
    /// Accumulated seconds workers spent blocked at barriers (telemetry).
    pub wait_time_total: f64,
}

impl SimCluster {
    /// A fresh cluster of `p` workers with all clocks at zero.
    pub fn new(p: usize, fabric: FabricConfig, compute: ComputeModel, seed: u64) -> Self {
        Self {
            clocks: vec![0.0; p],
            fabric,
            compute,
            rng: Rng::new(seed ^ 0xC1u64.rotate_left(17)),
            comm_time_total: 0.0,
            wait_time_total: 0.0,
        }
    }

    /// Number of workers in the cluster.
    pub fn p(&self) -> usize {
        self.clocks.len()
    }

    /// Advance worker `i` by `steps` local SGD steps.
    pub fn advance_compute(&mut self, i: usize, steps: usize) {
        for _ in 0..steps {
            self.clocks[i] += self.compute.sample_step(&mut self.rng);
        }
    }

    /// Synchronous all-gather among all workers, each contributing
    /// `bytes`: everyone blocks to the slowest participant, then pays the
    /// collective. Returns the post-collective common time.
    pub fn sync_allgather(&mut self, bytes: usize) -> f64 {
        let p = self.p();
        let barrier = self.clocks.iter().cloned().fold(0.0f64, f64::max);
        for c in self.clocks.iter_mut() {
            self.wait_time_total += barrier - *c;
            *c = barrier;
        }
        let cost = self.fabric.allgather_time(p, bytes);
        self.comm_time_total += cost;
        for c in self.clocks.iter_mut() {
            *c += cost;
        }
        barrier + cost
    }

    /// Asynchronous gather for worker `i`: proceeds once the `need`
    /// earliest peers (by clock) have reached the boundary; the straggling
    /// others are ignored (paper Algorithm 4's backup-worker rule).
    /// Returns the time at which worker `i` resumes.
    pub fn async_gather(&mut self, i: usize, need: usize, bytes: usize) -> f64 {
        let mut others: Vec<f64> = self
            .clocks
            .iter()
            .enumerate()
            .filter(|(j, _)| *j != i)
            .map(|(_, &t)| t)
            .collect();
        others.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let need = need.min(others.len());
        let kth = if need == 0 { self.clocks[i] } else { others[need - 1] };
        let start = self.clocks[i].max(kth);
        self.wait_time_total += start - self.clocks[i];
        let cost = self.fabric.allgather_time(need + 1, bytes);
        self.comm_time_total += cost;
        self.clocks[i] = start + cost;
        self.clocks[i]
    }

    /// EASGD-style round trip of worker `i` with a central master.
    pub fn p2p_roundtrip(&mut self, i: usize, bytes: usize) -> f64 {
        let cost = 2.0 * self.fabric.p2p_time(bytes);
        self.comm_time_total += cost;
        self.clocks[i] += cost;
        self.clocks[i]
    }

    /// Maximum clock — "the experiment has run this long".
    pub fn now(&self) -> f64 {
        self.clocks.iter().cloned().fold(0.0f64, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_compute() -> ComputeModel {
        ComputeModel { step_time_s: 1e-3, jitter_cv: 0.0, straggler_prob: 0.0, straggler_factor: 1.0 }
    }

    #[test]
    fn allgather_scales_with_p_and_bytes() {
        let f = FabricConfig::default();
        assert_eq!(f.allgather_time(1, 1 << 20), 0.0);
        let t2 = f.allgather_time(2, 1 << 20);
        let t8 = f.allgather_time(8, 1 << 20);
        assert!(t8 > t2 * 3.0);
        let tbig = f.allgather_time(2, 16 << 20);
        assert!(tbig > t2 * 8.0);
    }

    #[test]
    fn sync_barrier_advances_to_max() {
        let mut c = SimCluster::new(3, FabricConfig::default(), quiet_compute(), 1);
        c.advance_compute(0, 10);
        c.advance_compute(1, 5);
        c.advance_compute(2, 1);
        let before_max = c.now();
        let after = c.sync_allgather(1024);
        assert!(after > before_max);
        for &t in &c.clocks {
            assert!((t - after).abs() < 1e-12);
        }
        assert!(c.wait_time_total > 0.0);
    }

    #[test]
    fn async_ignores_stragglers() {
        let mut c = SimCluster::new(4, FabricConfig::default(), quiet_compute(), 2);
        // Worker 3 is far behind.
        c.advance_compute(0, 10);
        c.advance_compute(1, 10);
        c.advance_compute(2, 10);
        c.advance_compute(3, 1000);
        // Worker 0 needs 2 peers: should resume near worker 1/2's clocks,
        // not worker 3's.
        let resume = c.async_gather(0, 2, 1024);
        assert!(resume < 0.5, "resume={resume}");
    }

    #[test]
    fn straggler_mixture_increases_mean() {
        let quiet = quiet_compute();
        let noisy = ComputeModel { straggler_prob: 0.2, straggler_factor: 10.0, ..quiet };
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean_noisy: f64 =
            (0..n).map(|_| noisy.sample_step(&mut rng)).sum::<f64>() / n as f64;
        // E[noisy] = step·(1 + 0.2·9) = 2.8·step
        assert!(mean_noisy > 2.0e-3, "{mean_noisy}");
        assert!(mean_noisy < 4.0e-3, "{mean_noisy}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mk = || {
            let mut c = SimCluster::new(2, FabricConfig::default(), ComputeModel::default(), 7);
            c.advance_compute(0, 100);
            c.advance_compute(1, 100);
            c.sync_allgather(4096);
            c.now()
        };
        assert_eq!(mk(), mk());
    }
}
