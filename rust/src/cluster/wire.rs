//! Length-prefixed binary wire protocol for the TCP worker fabric.
//!
//! Every message is one *frame*:
//!
//! ```text
//! ┌────────────┬─────────────┬──────────┬──────────────┬─────────────┬─────────┐
//! │ magic (4B) │ version u16 │ kind u8  │ encoding u8  │ len u32 LE  │ payload │
//! │  "WSGD"    │  LE, = 1    │ MsgKind  │ WireEncoding │  ≤ 1 GiB    │ len B   │
//! └────────────┴─────────────┴──────────┴──────────────┴─────────────┴─────────┘
//! ```
//!
//! Parameter vectors inside a payload carry their own `u32` byte length
//! and are encoded per the frame's [`WireEncoding`]:
//!
//! * **f32** — raw little-endian bits, 4 bytes per element. Decoding is
//!   *bit-exact* (including NaN payloads), which is what lets a TCP run
//!   reproduce the simulated trainer's parameters bit for bit.
//! * **qi8** — symmetric linear quantisation: one f32 scale
//!   (`max |x| / 127`) followed by one i8 per element (`x ≈ scale·q`).
//!   4× smaller on the wire; lossy (≤ scale/2 per element), so it trades
//!   bit-reproducibility for bandwidth — the paper's large-τ regime in
//!   byte form.
//! * **topk** — top-k magnitude sparsification: `dim u32 | k u32 |
//!   k strictly-increasing u32 indices | k raw f32 values`. Only the
//!   `k = ⌈dim·rate⌉` largest-magnitude coordinates travel; the
//!   transmitted values themselves are raw bits (NaN payloads included),
//!   so the *selection* is lossy but the decode of what was kept is
//!   bit-exact and fully deterministic. Senders keep the dropped
//!   coordinates in an error-feedback residual and re-inject them into
//!   the next round's panel (see `cluster/fabric.rs`), so compression
//!   error is deferred, not lost.
//!
//! Loss energies `h` and all counters are always raw (never quantised):
//! they are tiny and they steer the Boltzmann weights, where a half-step
//! of quantisation error would be disproportionate.
//!
//! Robustness: [`Frame::read_from`] rejects bad magic, unknown versions /
//! kinds / encodings, and oversized lengths *before* allocating, and any
//! truncated stream surfaces as an error from `read_exact` — all pinned
//! by `tests/wire_props.rs`.

use std::io::{Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// Frame magic: the ASCII bytes `WSGD`.
pub const MAGIC: [u8; 4] = *b"WSGD";
/// Protocol version spoken by this build (bumped on incompatible change).
pub const VERSION: u16 = 1;
/// Bytes of the fixed frame header (magic + version + kind + encoding + len).
pub const HEADER_LEN: usize = 12;
/// Upper bound on a frame payload — rejects hostile/corrupt lengths
/// before any allocation happens.
pub const MAX_FRAME_LEN: u32 = 1 << 30;

/// What a frame carries — the message vocabulary of the fabric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Worker → rendezvous: opening handshake (empty payload; the header
    /// itself carries the protocol version being spoken).
    Hello,
    /// Rendezvous → worker: rank assignment, cohort size, the session's
    /// experiment config as JSON, and optional resume parameters.
    Welcome,
    /// Worker → rendezvous: one round's `(h, θ)` contribution.
    Panel,
    /// Rendezvous → worker: the full cohort's panels for one round, in
    /// rank order.
    Cohort,
    /// Worker → rendezvous: the final `(mean energy, θ)` after the local
    /// step budget is exhausted. Its `round` field carries the worker's
    /// *total local step count* (not a collective round number).
    Final,
    /// Either direction: fatal session error; payload is a UTF-8 message.
    Error,
    /// Worker → rendezvous: periodic liveness beat carrying the worker's
    /// last completed collective round (elastic sessions only).
    Heartbeat,
    /// Worker → rendezvous: ask to join the next epoch. Sent instead of
    /// [`MsgKind::Hello`] by a worker rejoining after an epoch commit
    /// (carrying its previous rank) or by a fresh late connector.
    JoinRequest,
    /// Worker → rendezvous: graceful departure at the next epoch
    /// boundary, carrying the worker's last completed round.
    Leave,
    /// Rendezvous → worker: the current epoch is over — epoch id,
    /// committed member set, anchor-checkpoint digest and a
    /// human-readable reason. Workers reconnect for the next epoch.
    EpochCommit,
}

impl MsgKind {
    fn as_u8(self) -> u8 {
        match self {
            MsgKind::Hello => 1,
            MsgKind::Welcome => 2,
            MsgKind::Panel => 3,
            MsgKind::Cohort => 4,
            MsgKind::Final => 5,
            MsgKind::Error => 6,
            MsgKind::Heartbeat => 7,
            MsgKind::JoinRequest => 8,
            MsgKind::Leave => 9,
            MsgKind::EpochCommit => 10,
        }
    }

    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => MsgKind::Hello,
            2 => MsgKind::Welcome,
            3 => MsgKind::Panel,
            4 => MsgKind::Cohort,
            5 => MsgKind::Final,
            6 => MsgKind::Error,
            7 => MsgKind::Heartbeat,
            8 => MsgKind::JoinRequest,
            9 => MsgKind::Leave,
            10 => MsgKind::EpochCommit,
            _ => return None,
        })
    }
}

/// How parameter vectors are encoded inside payloads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireEncoding {
    /// Raw little-endian f32 bits — lossless and bit-exact.
    #[default]
    F32,
    /// Symmetric linear i8 quantisation with a per-vector f32 scale —
    /// ~4× smaller, lossy (≤ scale/2 per element).
    Qi8,
    /// Top-k magnitude sparsification: only the `⌈dim·k_ppm/10⁶⌉`
    /// largest-magnitude coordinates travel, as strictly-increasing
    /// indices plus raw f32 bits. The rate rides as parts-per-million
    /// so the encoding stays `Eq`/`Copy` (`10_000` ⇒ `topk:0.01`).
    ///
    /// The frame *header* byte carries only the family id: a decoded
    /// header reconstructs `TopK { k_ppm: 0 }`, which is sufficient
    /// because the body is self-describing (`dim` and `k` are in the
    /// payload). The rate-bearing value lives in the session config and
    /// is only needed to *encode*.
    TopK {
        /// Keep-rate in parts-per-million of the panel dimension.
        k_ppm: u32,
    },
}

/// Number of coordinates a top-k encoding keeps for a `dim`-element
/// vector at `k_ppm` parts-per-million: `min(dim, ⌈dim·k_ppm/10⁶⌉)`.
/// The ceiling means any non-zero rate keeps at least one coordinate of
/// a non-empty vector; `k_ppm = 0` keeps none.
pub fn topk_k(dim: usize, k_ppm: u32) -> usize {
    ((dim as u64 * k_ppm as u64).div_ceil(1_000_000) as usize).min(dim)
}

/// The indices a top-k encoding keeps, in strictly increasing order.
///
/// Selection is fully deterministic, including for non-finite values:
/// candidates are ranked by `|x|` under `f32::total_cmp` descending
/// (NaN magnitudes rank above +∞), ties broken by ascending index, and
/// the kept set is then re-sorted ascending for the wire.
pub fn topk_indices(v: &[f32], k_ppm: u32) -> Vec<u32> {
    let k = topk_k(v.len(), k_ppm);
    let mut idx: Vec<u32> = (0..v.len() as u32).collect();
    idx.sort_by(|&a, &b| {
        let (ma, mb) = (v[a as usize].abs(), v[b as usize].abs());
        mb.total_cmp(&ma).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

/// What a receiver decodes from a top-k encoding of `v`: zeros
/// everywhere except the kept coordinates, which carry `v`'s raw bits.
/// This is the sender's local mirror of its own transmitted panel —
/// encode→decode with no wire in between.
pub fn topk_apply(v: &[f32], k_ppm: u32) -> Vec<f32> {
    let mut out = vec![0.0f32; v.len()];
    for i in topk_indices(v, k_ppm) {
        out[i as usize] = v[i as usize];
    }
    out
}

impl WireEncoding {
    /// Every encoding family, in wire-id order (the top-k entry carries
    /// a representative 1% rate).
    pub const ALL: [WireEncoding; 3] =
        [WireEncoding::F32, WireEncoding::Qi8, WireEncoding::TopK { k_ppm: 10_000 }];

    /// Encoding family name (rate-free; see [`WireEncoding::label`] for
    /// the rate-bearing CLI spelling).
    pub fn name(&self) -> &'static str {
        match self {
            WireEncoding::F32 => "f32",
            WireEncoding::Qi8 => "qi8",
            WireEncoding::TopK { .. } => "topk",
        }
    }

    /// Full CLI spelling, including the top-k rate (`topk:0.01`).
    /// `parse(label())` round-trips for every encoding.
    pub fn label(&self) -> String {
        match self {
            WireEncoding::F32 => "f32".to_string(),
            WireEncoding::Qi8 => "qi8".to_string(),
            WireEncoding::TopK { k_ppm } => format!("topk:{}", *k_ppm as f64 / 1e6),
        }
    }

    /// Parse a CLI name (`f32`, `qi8`, `topk:R` with rate `R ∈ (0, 1]`);
    /// `None` for anything unknown or out of range.
    pub fn parse(s: &str) -> Option<Self> {
        if let Some(rate) = s.strip_prefix("topk:") {
            let r: f64 = rate.parse().ok()?;
            if !(r > 0.0 && r <= 1.0) {
                return None;
            }
            let k_ppm = (r * 1e6).round() as u32;
            if k_ppm == 0 {
                return None;
            }
            return Some(WireEncoding::TopK { k_ppm });
        }
        Some(match s {
            "f32" => WireEncoding::F32,
            "qi8" => WireEncoding::Qi8,
            _ => return None,
        })
    }

    /// The wire id this encoding puts in the frame header. Only the
    /// *family* travels in the header; the top-k rate rides in the
    /// session config (the body is self-describing to decode).
    pub fn id(self) -> u8 {
        match self {
            WireEncoding::F32 => 0,
            WireEncoding::Qi8 => 1,
            WireEncoding::TopK { .. } => 2,
        }
    }

    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => WireEncoding::F32,
            1 => WireEncoding::Qi8,
            // The header only names the family; decode never needs the
            // rate, so a parsed frame carries the zero-rate placeholder.
            2 => WireEncoding::TopK { k_ppm: 0 },
            _ => return None,
        })
    }

    /// Encoded byte length of an `n`-element vector body (excluding the
    /// `u32` length prefix messages put in front of it). For top-k this
    /// depends on the rate, so size accounting must use the session's
    /// rate-bearing encoding, not one reconstructed from a header.
    pub fn encoded_vec_len(&self, n: usize) -> usize {
        match self {
            WireEncoding::F32 => 4 * n,
            WireEncoding::Qi8 => 4 + n,
            WireEncoding::TopK { k_ppm } => 8 + 8 * topk_k(n, *k_ppm),
        }
    }
}

/// What a receiver decodes from `v` encoded under `enc` — the canonical
/// encode→decode round trip with no wire in between. The identity for
/// f32; the deterministic lossy transform for qi8 and top-k. Senders use
/// this to mirror their own transmitted panel locally (e.g. under the
/// ring topology, where the relay never echoes a rank its own panel).
pub fn lossy_apply(enc: WireEncoding, v: &[f32]) -> Vec<f32> {
    match enc {
        WireEncoding::F32 => v.to_vec(),
        WireEncoding::Qi8 => {
            let mut body = Vec::with_capacity(enc.encoded_vec_len(v.len()));
            encode_vec(enc, v, &mut body);
            decode_vec(enc, &body).expect("self-encoded qi8 body decodes")
        }
        WireEncoding::TopK { k_ppm } => topk_apply(v, k_ppm),
    }
}

/// One wire frame: a typed header plus an opaque payload.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    /// Message kind from the header.
    pub kind: MsgKind,
    /// Vector encoding used inside the payload.
    pub encoding: WireEncoding,
    /// The message body (layout per [`MsgKind`]).
    pub payload: Vec<u8>,
}

impl Frame {
    /// Total bytes this frame occupies on the wire (header + payload).
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }

    /// Serialise header + payload and flush.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        ensure!(
            self.payload.len() <= MAX_FRAME_LEN as usize,
            "frame payload of {} bytes exceeds the {} byte cap",
            self.payload.len(),
            MAX_FRAME_LEN
        );
        let mut head = [0u8; HEADER_LEN];
        head[0..4].copy_from_slice(&MAGIC);
        head[4..6].copy_from_slice(&VERSION.to_le_bytes());
        head[6] = self.kind.as_u8();
        head[7] = self.encoding.id();
        head[8..12].copy_from_slice(&(self.payload.len() as u32).to_le_bytes());
        w.write_all(&head).context("writing frame header")?;
        w.write_all(&self.payload).context("writing frame payload")?;
        w.flush().context("flushing frame")?;
        Ok(())
    }

    /// Read and validate one frame. Truncated streams error out of
    /// `read_exact`; bad magic / version / kind / encoding / length are
    /// rejected before the payload is allocated.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Frame> {
        let mut head = [0u8; HEADER_LEN];
        r.read_exact(&mut head).context("reading frame header (truncated stream?)")?;
        ensure!(head[0..4] == MAGIC, "bad frame magic — peer is not speaking the wasgd protocol");
        let version = u16::from_le_bytes([head[4], head[5]]);
        ensure!(
            version == VERSION,
            "peer speaks wire protocol v{version}, this build speaks v{VERSION}"
        );
        let kind = MsgKind::from_u8(head[6])
            .ok_or_else(|| anyhow::anyhow!("unknown message kind {}", head[6]))?;
        let encoding = WireEncoding::from_u8(head[7])
            .ok_or_else(|| anyhow::anyhow!("unknown payload encoding {}", head[7]))?;
        let len = u32::from_le_bytes([head[8], head[9], head[10], head[11]]);
        ensure!(len <= MAX_FRAME_LEN, "frame of {len} bytes exceeds the {MAX_FRAME_LEN} byte cap");
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).context("reading frame payload (truncated stream?)")?;
        Ok(Frame { kind, encoding, payload })
    }
}

/// Append the encoded body of `v` to `out` (no length prefix).
fn encode_vec(enc: WireEncoding, v: &[f32], out: &mut Vec<u8>) {
    match enc {
        WireEncoding::F32 => {
            out.reserve(4 * v.len());
            for &x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        WireEncoding::Qi8 => {
            let max_abs = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = if max_abs.is_finite() && max_abs > 0.0 { max_abs / 127.0 } else { 0.0 };
            out.reserve(4 + v.len());
            out.extend_from_slice(&scale.to_le_bytes());
            for &x in v {
                let q = if scale > 0.0 {
                    (x / scale).round().clamp(-127.0, 127.0) as i8
                } else {
                    0
                };
                out.push(q as u8);
            }
        }
        WireEncoding::TopK { k_ppm } => {
            let idx = topk_indices(v, k_ppm);
            out.reserve(8 + 8 * idx.len());
            out.extend_from_slice(&(v.len() as u32).to_le_bytes());
            out.extend_from_slice(&(idx.len() as u32).to_le_bytes());
            for &i in &idx {
                out.extend_from_slice(&i.to_le_bytes());
            }
            for &i in &idx {
                out.extend_from_slice(&v[i as usize].to_le_bytes());
            }
        }
    }
}

/// Decode a vector body produced by [`encode_vec`] (element count is
/// implied by the byte length). Crate-visible so the relay can digest
/// the decoded panels of deterministically lossy sessions without
/// re-framing them; top-k bodies are self-describing, so the encoding's
/// rate field is irrelevant here.
pub(crate) fn decode_vec(enc: WireEncoding, bytes: &[u8]) -> Result<Vec<f32>> {
    match enc {
        WireEncoding::F32 => {
            ensure!(bytes.len() % 4 == 0, "f32 vector body of {} bytes is ragged", bytes.len());
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }
        WireEncoding::Qi8 => {
            ensure!(bytes.len() >= 4, "qi8 vector body shorter than its scale");
            let scale = f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
            ensure!(scale.is_finite() && scale >= 0.0, "qi8 scale {scale} is invalid");
            Ok(bytes[4..].iter().map(|&b| scale * (b as i8) as f32).collect())
        }
        WireEncoding::TopK { .. } => {
            // Everything is validated against the byte length *before*
            // the dense output vector is allocated: a lying count, an
            // out-of-range index, a duplicate, or an unsorted pair all
            // reject while only the (already length-checked) input
            // bytes are held.
            ensure!(bytes.len() >= 8, "top-k vector body shorter than its dim/count header");
            let dim = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
            let k = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
            ensure!(dim <= MAX_FRAME_LEN as usize / 4, "implausible top-k dim {dim}");
            ensure!(k <= dim, "top-k count {k} exceeds dim {dim}");
            ensure!(
                bytes.len() == 8 + 8 * k,
                "top-k body of {} bytes does not match count {k}",
                bytes.len()
            );
            let (ib, vb) = bytes[8..].split_at(4 * k);
            let mut prev: Option<u32> = None;
            for c in ib.chunks_exact(4) {
                let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                ensure!((i as usize) < dim, "top-k index {i} out of range for dim {dim}");
                if let Some(p) = prev {
                    ensure!(i > p, "top-k indices not strictly increasing ({p} then {i})");
                }
                prev = Some(i);
            }
            let mut out = vec![0.0f32; dim];
            for (c, v) in ib.chunks_exact(4).zip(vb.chunks_exact(4)) {
                let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
                out[i] = f32::from_le_bytes([v[0], v[1], v[2], v[3]]);
            }
            Ok(out)
        }
    }
}

/// Little-endian payload cursor with truncation checks.
struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.b.len() >= n, "truncated payload: wanted {n} bytes, have {}", self.b.len());
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn finish(&self) -> Result<()> {
        ensure!(self.b.is_empty(), "{} trailing bytes in payload", self.b.len());
        Ok(())
    }
}

fn put_vec(enc: WireEncoding, v: &[f32], out: &mut Vec<u8>) {
    out.extend_from_slice(&(enc.encoded_vec_len(v.len()) as u32).to_le_bytes());
    encode_vec(enc, v, out);
}

fn get_vec(enc: WireEncoding, cur: &mut Cur<'_>) -> Result<Vec<f32>> {
    let len = cur.u32()? as usize;
    decode_vec(enc, cur.take(len)?)
}

/// One worker's `(h, θ)` contribution for one collective round. The same
/// payload layout serves [`MsgKind::Panel`] and [`MsgKind::Final`].
#[derive(Clone, Debug, PartialEq)]
pub struct Panel {
    /// 1-based collective round (boundary index) this panel belongs to.
    pub round: u64,
    /// Windowed loss energy h (always raw f32 bits, never quantised).
    pub h: f32,
    /// Flat parameter vector θ (encoded per the frame's encoding).
    pub theta: Vec<f32>,
}

impl Panel {
    /// Build the wire frame for a panel (`kind` is [`MsgKind::Panel`] or
    /// [`MsgKind::Final`]).
    pub fn frame(kind: MsgKind, round: u64, h: f32, theta: &[f32], enc: WireEncoding) -> Frame {
        let mut payload = Vec::with_capacity(16 + enc.encoded_vec_len(theta.len()));
        payload.extend_from_slice(&round.to_le_bytes());
        payload.extend_from_slice(&h.to_le_bytes());
        put_vec(enc, theta, &mut payload);
        Frame { kind, encoding: enc, payload }
    }

    /// Parse a [`MsgKind::Panel`] / [`MsgKind::Final`] frame.
    pub fn parse(frame: &Frame) -> Result<Panel> {
        ensure!(
            matches!(frame.kind, MsgKind::Panel | MsgKind::Final),
            "expected a panel/final frame, got {:?}",
            frame.kind
        );
        let mut cur = Cur::new(&frame.payload);
        let round = cur.u64()?;
        let h = cur.f32()?;
        let theta = get_vec(frame.encoding, &mut cur)?;
        cur.finish()?;
        Ok(Panel { round, h, theta })
    }

    /// Exact on-wire size of a panel frame carrying `d` parameters.
    pub fn wire_len(enc: WireEncoding, d: usize) -> usize {
        HEADER_LEN + 8 + 4 + 4 + enc.encoded_vec_len(d)
    }
}

/// A panel whose θ body is kept *encoded* — the relay-side view. The
/// rendezvous node never decodes parameters (and therefore can never
/// re-quantise them): it validates the framing, barriers, and memcpys
/// the original bytes back out.
#[derive(Clone, Debug, PartialEq)]
pub struct RawPanel {
    /// 1-based collective round this panel belongs to.
    pub round: u64,
    /// Windowed loss energy h (raw f32 bits).
    pub h: f32,
    /// The θ vector exactly as encoded by the sender.
    pub body: Vec<u8>,
}

impl RawPanel {
    /// Parse a [`MsgKind::Panel`] / [`MsgKind::Final`] frame without
    /// decoding the θ body.
    pub fn parse(frame: &Frame) -> Result<RawPanel> {
        ensure!(
            matches!(frame.kind, MsgKind::Panel | MsgKind::Final),
            "expected a panel/final frame, got {:?}",
            frame.kind
        );
        let mut cur = Cur::new(&frame.payload);
        let round = cur.u64()?;
        let h = cur.f32()?;
        let len = cur.u32()? as usize;
        let body = cur.take(len)?.to_vec();
        cur.finish()?;
        Ok(RawPanel { round, h, body })
    }

    /// Decode the θ body with the frame's encoding (worker-side use of a
    /// relayed raw panel, e.g. the stored finals).
    pub fn decode(&self, enc: WireEncoding) -> Result<Vec<f32>> {
        decode_vec(enc, &self.body)
    }
}

/// Assemble a cohort frame from already-encoded panel bodies — the
/// relay's path: byte-for-byte identical to [`Cohort::frame`] over the
/// decoded panels, with no decode/re-encode in between.
pub fn cohort_frame_from_raw(round: u64, panels: &[(f32, Vec<u8>)], enc: WireEncoding) -> Frame {
    let body: usize = panels.iter().map(|(_, b)| 8 + b.len()).sum();
    let mut payload = Vec::with_capacity(12 + body);
    payload.extend_from_slice(&round.to_le_bytes());
    payload.extend_from_slice(&(panels.len() as u32).to_le_bytes());
    for (h, bytes) in panels {
        payload.extend_from_slice(&h.to_le_bytes());
        payload.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
        payload.extend_from_slice(bytes);
    }
    Frame { kind: MsgKind::Cohort, encoding: enc, payload }
}

/// The full cohort's panels for one round, relayed back in rank order.
#[derive(Clone, Debug, PartialEq)]
pub struct Cohort {
    /// The round these panels belong to.
    pub round: u64,
    /// `(h, θ)` per rank, index = rank.
    pub panels: Vec<(f32, Vec<f32>)>,
}

impl Cohort {
    /// Build the wire frame for a relayed cohort.
    pub fn frame(round: u64, panels: &[(f32, Vec<f32>)], enc: WireEncoding) -> Frame {
        let body: usize = panels.iter().map(|(_, t)| 8 + enc.encoded_vec_len(t.len())).sum();
        let mut payload = Vec::with_capacity(12 + body);
        payload.extend_from_slice(&round.to_le_bytes());
        payload.extend_from_slice(&(panels.len() as u32).to_le_bytes());
        for (h, theta) in panels {
            payload.extend_from_slice(&h.to_le_bytes());
            put_vec(enc, theta, &mut payload);
        }
        Frame { kind: MsgKind::Cohort, encoding: enc, payload }
    }

    /// Parse a [`MsgKind::Cohort`] frame.
    pub fn parse(frame: &Frame) -> Result<Cohort> {
        ensure!(frame.kind == MsgKind::Cohort, "expected a cohort frame, got {:?}", frame.kind);
        let mut cur = Cur::new(&frame.payload);
        let round = cur.u64()?;
        let p = cur.u32()? as usize;
        ensure!(p <= 1 << 20, "implausible cohort size {p}");
        // Each panel occupies ≥ 8 payload bytes (h + length prefix), so
        // a lying header cannot reserve more than the payload justifies.
        let mut panels = Vec::with_capacity(p.min(frame.payload.len() / 8));
        for _ in 0..p {
            let h = cur.f32()?;
            let theta = get_vec(frame.encoding, &mut cur)?;
            panels.push((h, theta));
        }
        cur.finish()?;
        Ok(Cohort { round, panels })
    }

    /// Exact on-wire size of a cohort frame of `p` same-length rows.
    pub fn wire_len(enc: WireEncoding, d: usize, p: usize) -> usize {
        HEADER_LEN + 8 + 4 + p * (8 + enc.encoded_vec_len(d))
    }
}

/// The rendezvous node's handshake reply: identity + session config.
#[derive(Clone, Debug, PartialEq)]
pub struct Welcome {
    /// This connection's rank in `[0, p)` (accept order).
    pub rank: u32,
    /// Cohort size p.
    pub p: u32,
    /// The session [`ExperimentConfig`](crate::config::ExperimentConfig)
    /// as wire JSON (see `ExperimentConfig::to_wire_json`).
    pub config_json: String,
    /// Starting parameters when resuming from a checkpointed rendezvous.
    /// Always encoded f32 regardless of the session's panel encoding: a
    /// restart transfer happens once, so it never trades precision for
    /// bandwidth (a full-precision checkpoint resumes exactly).
    pub resume: Option<Vec<f32>>,
}

impl Welcome {
    /// Build the wire frame (the frame's encoding byte announces the
    /// session's panel encoding to the worker).
    pub fn frame(&self, enc: WireEncoding) -> Frame {
        let mut payload = Vec::with_capacity(13 + self.config_json.len());
        payload.extend_from_slice(&self.rank.to_le_bytes());
        payload.extend_from_slice(&self.p.to_le_bytes());
        payload.extend_from_slice(&(self.config_json.len() as u32).to_le_bytes());
        payload.extend_from_slice(self.config_json.as_bytes());
        match &self.resume {
            None => payload.push(0),
            Some(v) => {
                payload.push(1);
                put_vec(WireEncoding::F32, v, &mut payload);
            }
        }
        Frame { kind: MsgKind::Welcome, encoding: enc, payload }
    }

    /// Parse a [`MsgKind::Welcome`] frame.
    pub fn parse(frame: &Frame) -> Result<Welcome> {
        ensure!(frame.kind == MsgKind::Welcome, "expected a welcome frame, got {:?}", frame.kind);
        let mut cur = Cur::new(&frame.payload);
        let rank = cur.u32()?;
        let p = cur.u32()?;
        let json_len = cur.u32()? as usize;
        let config_json = std::str::from_utf8(cur.take(json_len)?)
            .context("welcome config is not UTF-8")?
            .to_string();
        let resume = match cur.u8()? {
            0 => None,
            1 => Some(get_vec(WireEncoding::F32, &mut cur)?),
            other => bail!("bad resume marker {other}"),
        };
        cur.finish()?;
        Ok(Welcome { rank, p, config_json, resume })
    }
}

/// A worker's periodic liveness beat (elastic sessions): "I am alive and
/// have completed this many collective rounds." The relay resets its
/// read deadline on any frame, so heartbeats keep an idle-looking but
/// healthy worker (mid-τ local steps) from being declared dead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Heartbeat {
    /// Last collective round the worker completed (0 before the first).
    pub round: u64,
}

impl Heartbeat {
    /// Build the wire frame.
    pub fn frame(&self) -> Frame {
        Frame {
            kind: MsgKind::Heartbeat,
            encoding: WireEncoding::F32,
            payload: self.round.to_le_bytes().to_vec(),
        }
    }

    /// Parse a [`MsgKind::Heartbeat`] frame.
    pub fn parse(frame: &Frame) -> Result<Heartbeat> {
        ensure!(
            frame.kind == MsgKind::Heartbeat,
            "expected a heartbeat frame, got {:?}",
            frame.kind
        );
        let mut cur = Cur::new(&frame.payload);
        let round = cur.u64()?;
        cur.finish()?;
        Ok(Heartbeat { round })
    }
}

/// A worker asking to join the next epoch of an elastic session: either
/// a survivor rejoining after an [`MsgKind::EpochCommit`] (carrying the
/// rank it held in the committed epoch, so the rendezvous can hand it
/// back its own anchor row) or a fresh late connector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct JoinRequest {
    /// The rank this worker held in the epoch that just committed;
    /// `None` for a fresh joiner.
    pub prior_rank: Option<u32>,
}

impl JoinRequest {
    /// Build the wire frame (marker byte 0 = fresh, 1 = rejoin + rank).
    pub fn frame(&self) -> Frame {
        let mut payload = Vec::with_capacity(5);
        match self.prior_rank {
            None => payload.push(0),
            Some(r) => {
                payload.push(1);
                payload.extend_from_slice(&r.to_le_bytes());
            }
        }
        Frame { kind: MsgKind::JoinRequest, encoding: WireEncoding::F32, payload }
    }

    /// Parse a [`MsgKind::JoinRequest`] frame.
    pub fn parse(frame: &Frame) -> Result<JoinRequest> {
        ensure!(
            frame.kind == MsgKind::JoinRequest,
            "expected a join-request frame, got {:?}",
            frame.kind
        );
        let mut cur = Cur::new(&frame.payload);
        let prior_rank = match cur.u8()? {
            0 => None,
            1 => Some(cur.u32()?),
            other => bail!("bad join marker {other}"),
        };
        cur.finish()?;
        Ok(JoinRequest { prior_rank })
    }
}

/// A worker's graceful goodbye: it departs at the next epoch boundary
/// instead of simply vanishing, so the commit reason can say "left"
/// rather than "died".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Leave {
    /// Last collective round the worker completed.
    pub round: u64,
}

impl Leave {
    /// Build the wire frame.
    pub fn frame(&self) -> Frame {
        Frame {
            kind: MsgKind::Leave,
            encoding: WireEncoding::F32,
            payload: self.round.to_le_bytes().to_vec(),
        }
    }

    /// Parse a [`MsgKind::Leave`] frame.
    pub fn parse(frame: &Frame) -> Result<Leave> {
        ensure!(frame.kind == MsgKind::Leave, "expected a leave frame, got {:?}", frame.kind);
        let mut cur = Cur::new(&frame.payload);
        let round = cur.u64()?;
        cur.finish()?;
        Ok(Leave { round })
    }
}

/// The rendezvous telling a surviving worker that the current epoch is
/// over. Advisory on the wire — the worker uses it to log and to know it
/// should reconnect with a [`JoinRequest`]; the authoritative record is
/// the journal's `EpochCommitted` event. The member set here is the
/// survivors known at send time (epoch-local ranks of the epoch that
/// just ended).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EpochCommit {
    /// Id of the epoch being *opened* (the one that just ended plus 1).
    pub epoch: u64,
    /// The collective round the ending epoch committed at (its anchor
    /// round; 0 when the epoch never completed a round).
    pub round: u64,
    /// Surviving members' ranks in the epoch that just ended.
    pub members: Vec<u32>,
    /// FNV-1a 64 digest of the anchor checkpoint (cohort digest of the
    /// committed round's panels), 0 when there is no anchor.
    pub anchor_digest: u64,
    /// Human-readable reason for the commit (who died/left/joined).
    pub reason: String,
}

impl EpochCommit {
    /// Build the wire frame.
    pub fn frame(&self) -> Frame {
        let mut payload = Vec::with_capacity(28 + 4 * self.members.len() + self.reason.len());
        payload.extend_from_slice(&self.epoch.to_le_bytes());
        payload.extend_from_slice(&self.round.to_le_bytes());
        payload.extend_from_slice(&(self.members.len() as u32).to_le_bytes());
        for &r in &self.members {
            payload.extend_from_slice(&r.to_le_bytes());
        }
        payload.extend_from_slice(&self.anchor_digest.to_le_bytes());
        payload.extend_from_slice(&(self.reason.len() as u32).to_le_bytes());
        payload.extend_from_slice(self.reason.as_bytes());
        Frame { kind: MsgKind::EpochCommit, encoding: WireEncoding::F32, payload }
    }

    /// Parse a [`MsgKind::EpochCommit`] frame.
    pub fn parse(frame: &Frame) -> Result<EpochCommit> {
        ensure!(
            frame.kind == MsgKind::EpochCommit,
            "expected an epoch-commit frame, got {:?}",
            frame.kind
        );
        let mut cur = Cur::new(&frame.payload);
        let epoch = cur.u64()?;
        let round = cur.u64()?;
        let n = cur.u32()? as usize;
        ensure!(n <= 1 << 20, "implausible member count {n}");
        // Each member occupies 4 payload bytes, so a lying count cannot
        // reserve more than the payload justifies.
        let mut members = Vec::with_capacity(n.min(frame.payload.len() / 4));
        for _ in 0..n {
            members.push(cur.u32()?);
        }
        let anchor_digest = cur.u64()?;
        let reason_len = cur.u32()? as usize;
        let reason = std::str::from_utf8(cur.take(reason_len)?)
            .context("epoch-commit reason is not UTF-8")?
            .to_string();
        cur.finish()?;
        Ok(EpochCommit { epoch, round, members, anchor_digest, reason })
    }
}

/// The opening handshake frame a worker sends (empty payload; the header
/// carries the version).
pub fn hello_frame() -> Frame {
    Frame { kind: MsgKind::Hello, encoding: WireEncoding::F32, payload: Vec::new() }
}

/// A fatal-error frame carrying a UTF-8 message.
pub fn error_frame(msg: &str) -> Frame {
    Frame { kind: MsgKind::Error, encoding: WireEncoding::F32, payload: msg.as_bytes().to_vec() }
}

/// The message of an [`MsgKind::Error`] frame (lossy UTF-8).
pub fn error_text(frame: &Frame) -> String {
    String::from_utf8_lossy(&frame.payload).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut bytes = Vec::new();
        frame.write_to(&mut bytes).unwrap();
        assert_eq!(bytes.len(), frame.encoded_len());
        Frame::read_from(&mut Cursor::new(&bytes)).unwrap()
    }

    #[test]
    fn panel_f32_roundtrip_is_bit_exact_including_specials() {
        let theta = vec![1.5f32, -0.0, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, -3.25e-30];
        let f = Panel::frame(MsgKind::Panel, 7, f32::NAN, &theta, WireEncoding::F32);
        assert_eq!(f.encoded_len(), Panel::wire_len(WireEncoding::F32, theta.len()));
        let p = Panel::parse(&roundtrip(&f)).unwrap();
        assert_eq!(p.round, 7);
        assert_eq!(p.h.to_bits(), f32::NAN.to_bits());
        assert_eq!(p.theta.len(), theta.len());
        for (a, b) in p.theta.iter().zip(theta.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn qi8_quantisation_bounded_and_smaller() {
        let theta: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) * 0.37).collect();
        let max_abs = theta.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = max_abs / 127.0;
        let f = Panel::frame(MsgKind::Panel, 1, 0.5, &theta, WireEncoding::Qi8);
        assert_eq!(f.encoded_len(), Panel::wire_len(WireEncoding::Qi8, theta.len()));
        assert!(f.encoded_len() < Panel::wire_len(WireEncoding::F32, theta.len()) / 3);
        let p = Panel::parse(&roundtrip(&f)).unwrap();
        for (a, b) in p.theta.iter().zip(theta.iter()) {
            assert!((a - b).abs() <= scale * 0.5 + max_abs * 1e-5, "{a} vs {b}");
        }
        // h is never quantised.
        assert_eq!(p.h.to_bits(), 0.5f32.to_bits());
    }

    #[test]
    fn qi8_degenerate_vectors() {
        for theta in [vec![], vec![0.0f32; 9], vec![f32::NAN, f32::INFINITY]] {
            let f = Panel::frame(MsgKind::Panel, 2, 1.0, &theta, WireEncoding::Qi8);
            let p = Panel::parse(&roundtrip(&f)).unwrap();
            assert_eq!(p.theta.len(), theta.len());
            assert!(p.theta.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn cohort_roundtrip_preserves_rank_order() {
        let panels = vec![
            (0.25f32, vec![1.0f32, 2.0]),
            (0.5, vec![-1.0, -2.0]),
            (1.5, vec![9.0, 8.0]),
        ];
        let f = Cohort::frame(3, &panels, WireEncoding::F32);
        assert_eq!(f.encoded_len(), Cohort::wire_len(WireEncoding::F32, 2, 3));
        let c = Cohort::parse(&roundtrip(&f)).unwrap();
        assert_eq!(c.round, 3);
        assert_eq!(c.panels, panels);
    }

    #[test]
    fn raw_relay_preserves_sender_bytes_verbatim() {
        // The relay pipeline (RawPanel::parse → cohort_frame_from_raw)
        // must hand every worker exactly the bytes each sender encoded:
        // a cohort recipient decodes the identical values the panel
        // sender would decode, under BOTH encodings — i.e. the relay
        // never re-quantises. For f32 the assembled frame is also
        // byte-identical to the decode/re-encode path.
        for enc in WireEncoding::ALL {
            let thetas =
                [vec![1.5f32, -2.25, 0.0], vec![9.0, -0.125, 3.5], vec![0.75, 0.5, -1.0]];
            let mut raws = Vec::new();
            let mut decoded = Vec::new();
            for (i, t) in thetas.iter().enumerate() {
                let pf = Panel::frame(MsgKind::Panel, 4, i as f32, t, enc);
                let raw = RawPanel::parse(&pf).unwrap();
                assert_eq!(raw.round, 4);
                decoded.push((raw.h, raw.decode(enc).unwrap()));
                raws.push((raw.h, raw.body));
            }
            let via_raw = cohort_frame_from_raw(4, &raws, enc);
            let cohort = Cohort::parse(&roundtrip(&via_raw)).unwrap();
            assert_eq!(cohort.round, 4);
            for ((ch, ct), (dh, dt)) in cohort.panels.iter().zip(decoded.iter()) {
                assert_eq!(ch.to_bits(), dh.to_bits());
                assert_eq!(ct.len(), dt.len());
                for (a, b) in ct.iter().zip(dt.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{enc:?} relay altered θ");
                }
            }
            if enc == WireEncoding::F32 {
                assert_eq!(via_raw, Cohort::frame(4, &decoded, enc));
            }
        }
    }

    #[test]
    fn welcome_roundtrip_with_and_without_resume() {
        // Resume params must survive bit-exactly under BOTH session
        // encodings — the one-time restart transfer is never quantised.
        for enc in WireEncoding::ALL {
            for resume in [None, Some(vec![0.5f32, -1.537_218_4, 2.25e-17])] {
                let w = Welcome {
                    rank: 2,
                    p: 4,
                    config_json: "{\"p\": 4}\n".to_string(),
                    resume: resume.clone(),
                };
                let frame = roundtrip(&w.frame(enc));
                // Only the family id rides the header (a top-k rate
                // travels in the session config, not the frame).
                assert_eq!(frame.encoding.id(), enc.id(), "encoding family rides the header");
                let back = Welcome::parse(&frame).unwrap();
                assert_eq!(back, w, "{enc:?}");
            }
        }
    }

    #[test]
    fn hello_and_error_frames() {
        let h = roundtrip(&hello_frame());
        assert_eq!(h.kind, MsgKind::Hello);
        assert!(h.payload.is_empty());
        let e = roundtrip(&error_frame("cohort failed: worker 2 died"));
        assert_eq!(e.kind, MsgKind::Error);
        assert_eq!(error_text(&e), "cohort failed: worker 2 died");
    }

    #[test]
    fn rejects_bad_magic_version_kind_encoding_and_oversize() {
        let mut bytes = Vec::new();
        Panel::frame(MsgKind::Panel, 1, 0.0, &[1.0], WireEncoding::F32)
            .write_to(&mut bytes)
            .unwrap();

        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Frame::read_from(&mut Cursor::new(&bad)).is_err(), "bad magic");

        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(Frame::read_from(&mut Cursor::new(&bad)).is_err(), "bad version");

        let mut bad = bytes.clone();
        bad[6] = 0;
        assert!(Frame::read_from(&mut Cursor::new(&bad)).is_err(), "bad kind");

        let mut bad = bytes.clone();
        bad[7] = 9;
        assert!(Frame::read_from(&mut Cursor::new(&bad)).is_err(), "bad encoding");
        // Family id 2 (top-k) is known, so it parses at the frame layer.
        let mut topk = bytes.clone();
        topk[7] = 2;
        assert_eq!(
            Frame::read_from(&mut Cursor::new(&topk)).unwrap().encoding,
            WireEncoding::TopK { k_ppm: 0 }
        );

        // Oversized length is rejected before any allocation.
        let mut bad = bytes.clone();
        bad[8..12].copy_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert!(Frame::read_from(&mut Cursor::new(&bad)).is_err(), "oversize");
    }

    #[test]
    fn every_truncation_of_a_frame_is_rejected() {
        let mut bytes = Vec::new();
        Cohort::frame(1, &[(0.5, vec![1.0, 2.0, 3.0])], WireEncoding::F32)
            .write_to(&mut bytes)
            .unwrap();
        for k in 0..bytes.len() {
            assert!(
                Frame::read_from(&mut Cursor::new(&bytes[..k])).is_err(),
                "prefix of {k} bytes must not parse"
            );
        }
        // The full frame still parses.
        assert!(Frame::read_from(&mut Cursor::new(&bytes)).is_ok());
    }

    #[test]
    fn payload_level_truncation_is_rejected() {
        // A syntactically valid frame whose payload lies about its inner
        // vector length must fail in the typed parser, not panic.
        let good = Panel::frame(MsgKind::Panel, 1, 0.0, &[1.0, 2.0], WireEncoding::F32);
        let mut evil = good.clone();
        // Inflate the inner vector length prefix past the payload end.
        let off = 12; // round(8) + h(4)
        evil.payload[off..off + 4].copy_from_slice(&1024u32.to_le_bytes());
        assert!(Panel::parse(&evil).is_err());
        // Trailing garbage is rejected too.
        let mut trailing = good.clone();
        trailing.payload.push(0xAB);
        assert!(Panel::parse(&trailing).is_err());
    }

    #[test]
    fn elastic_frames_roundtrip() {
        let hb = Heartbeat { round: 42 };
        assert_eq!(Heartbeat::parse(&roundtrip(&hb.frame())).unwrap(), hb);

        for prior_rank in [None, Some(0), Some(3), Some(u32::MAX)] {
            let j = JoinRequest { prior_rank };
            assert_eq!(JoinRequest::parse(&roundtrip(&j.frame())).unwrap(), j);
        }

        let l = Leave { round: u64::MAX };
        assert_eq!(Leave::parse(&roundtrip(&l.frame())).unwrap(), l);

        let c = EpochCommit {
            epoch: 2,
            round: 17,
            members: vec![0, 2, 3],
            anchor_digest: 0xdead_beef_cafe_f00d,
            reason: "rank 1 died after completing round 17: connection reset".to_string(),
        };
        assert_eq!(EpochCommit::parse(&roundtrip(&c.frame())).unwrap(), c);

        // Empty member set and empty reason are legal (round-0 commit).
        let c0 = EpochCommit {
            epoch: 1,
            round: 0,
            members: vec![],
            anchor_digest: 0,
            reason: String::new(),
        };
        assert_eq!(EpochCommit::parse(&roundtrip(&c0.frame())).unwrap(), c0);
    }

    #[test]
    fn elastic_frames_reject_malformed_payloads() {
        // Bad join marker.
        let mut bad = JoinRequest { prior_rank: None }.frame();
        bad.payload[0] = 7;
        assert!(JoinRequest::parse(&bad).is_err());

        // Truncated rejoin rank.
        let mut short = JoinRequest { prior_rank: Some(3) }.frame();
        short.payload.truncate(3);
        assert!(JoinRequest::parse(&short).is_err());

        // Trailing garbage after a heartbeat round.
        let mut trailing = Heartbeat { round: 1 }.frame();
        trailing.payload.push(0);
        assert!(Heartbeat::parse(&trailing).is_err());

        let commit = EpochCommit {
            epoch: 1,
            round: 3,
            members: vec![0, 1],
            anchor_digest: 9,
            reason: "x".to_string(),
        };
        // A lying member count is rejected without over-allocating.
        let mut lying = commit.frame();
        lying.payload[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(EpochCommit::parse(&lying).is_err());
        // A reason length pointing past the payload end is rejected.
        let mut lying_reason = commit.frame();
        let off = lying_reason.payload.len() - 1 - 4; // reason(1) + len(4)
        lying_reason.payload[off..off + 4].copy_from_slice(&1024u32.to_le_bytes());
        assert!(EpochCommit::parse(&lying_reason).is_err());
        // Every strict prefix of the frame bytes is rejected.
        let mut bytes = Vec::new();
        commit.frame().write_to(&mut bytes).unwrap();
        for k in 0..bytes.len() {
            assert!(
                Frame::read_from(&mut Cursor::new(&bytes[..k])).is_err(),
                "prefix of {k} bytes must not parse"
            );
        }
    }

    #[test]
    fn encoding_names_roundtrip() {
        for e in WireEncoding::ALL {
            assert_eq!(WireEncoding::parse(&e.label()), Some(e), "{e:?}");
        }
        assert_eq!(WireEncoding::parse("f32"), Some(WireEncoding::F32));
        assert_eq!(WireEncoding::parse("topk:0.01"), Some(WireEncoding::TopK { k_ppm: 10_000 }));
        assert_eq!(WireEncoding::TopK { k_ppm: 10_000 }.label(), "topk:0.01");
        assert_eq!(WireEncoding::parse("i4"), None);
        assert_eq!(WireEncoding::parse("topk:0"), None, "zero rate keeps nothing");
        assert_eq!(WireEncoding::parse("topk:1.5"), None, "rate above 1");
        assert_eq!(WireEncoding::parse("topk:-0.1"), None, "negative rate");
        assert_eq!(WireEncoding::parse("topk:"), None, "missing rate");
        assert_eq!(WireEncoding::default(), WireEncoding::F32);
    }

    #[test]
    fn topk_selection_is_deterministic_and_sorted() {
        // |x| descending with index tie-break; kept set re-sorted
        // ascending for the wire.
        let v = [1.0f32, -3.0, 3.0, 0.5, -0.5];
        assert_eq!(topk_indices(&v, 400_000), vec![1, 2]); // k = ⌈5·0.4⌉ = 2
        assert_eq!(topk_indices(&v, 1_000_000), vec![0, 1, 2, 3, 4]);
        assert_eq!(topk_indices(&v, 0), Vec::<u32>::new());
        // NaN magnitude outranks +∞ under total_cmp.
        let w = [f32::INFINITY, 1.0, f32::NAN];
        assert_eq!(topk_indices(&w, 400_000), vec![0, 2]);
        // topk_k edges: any non-zero rate keeps ≥ 1; k never exceeds dim.
        assert_eq!(topk_k(1000, 1), 1);
        assert_eq!(topk_k(0, 500_000), 0);
        assert_eq!(topk_k(3, 1_000_000), 3);
    }

    #[test]
    fn topk_roundtrip_is_bit_exact_on_kept_coordinates() {
        let theta = vec![0.25f32, -8.5, f32::NAN, 0.0, f32::NEG_INFINITY, 1e-30, -2.0];
        let enc = WireEncoding::TopK { k_ppm: 500_000 }; // k = ⌈7·0.5⌉ = 4
        let f = Panel::frame(MsgKind::Panel, 3, 0.75, &theta, enc);
        assert_eq!(f.encoded_len(), Panel::wire_len(enc, theta.len()));
        let p = Panel::parse(&roundtrip(&f)).unwrap();
        let expect = topk_apply(&theta, 500_000);
        assert_eq!(p.theta.len(), theta.len());
        for (a, b) in p.theta.iter().zip(expect.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // k = 0 and k = dim edge cases round-trip too.
        for (ppm, label) in [(0u32, "k=0"), (1_000_000, "k=dim")] {
            let e = WireEncoding::TopK { k_ppm: ppm };
            let f = Panel::frame(MsgKind::Panel, 1, 0.0, &theta, e);
            assert_eq!(f.encoded_len(), Panel::wire_len(e, theta.len()), "{label}");
            let p = Panel::parse(&roundtrip(&f)).unwrap();
            for (a, b) in p.theta.iter().zip(topk_apply(&theta, ppm).iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{label}");
            }
        }
    }

    #[test]
    fn topk_rejects_lying_indices_and_counts() {
        let enc = WireEncoding::TopK { k_ppm: 500_000 };
        let good = Panel::frame(MsgKind::Panel, 1, 0.0, &[1.0f32, 2.0, 3.0, 4.0], enc);
        assert!(Panel::parse(&good).is_ok());
        // Body layout inside the panel payload: round(8) + h(4) +
        // veclen(4) + dim(4) + k(4) + indices + values.
        let dim_off = 16;
        let k_off = 20;
        let idx_off = 24;

        // Count larger than the bytes justify.
        let mut lying_count = good.clone();
        lying_count.payload[k_off..k_off + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(Panel::parse(&lying_count).is_err(), "lying count");

        // Count above dim.
        let mut over_dim = good.clone();
        over_dim.payload[dim_off..dim_off + 4].copy_from_slice(&1u32.to_le_bytes());
        assert!(Panel::parse(&over_dim).is_err(), "k > dim");

        // Index out of range.
        let mut oob = good.clone();
        oob.payload[idx_off..idx_off + 4].copy_from_slice(&9u32.to_le_bytes());
        assert!(Panel::parse(&oob).is_err(), "index ≥ dim");

        // Duplicate / unsorted indices (k = 2 here: indices 2 then 3).
        let mut dup = good.clone();
        let second = idx_off + 4;
        let first = u32::from_le_bytes(dup.payload[idx_off..idx_off + 4].try_into().unwrap());
        dup.payload[second..second + 4].copy_from_slice(&first.to_le_bytes());
        assert!(Panel::parse(&dup).is_err(), "duplicate index");
        let mut unsorted = good.clone();
        let a: [u8; 4] = unsorted.payload[idx_off..idx_off + 4].try_into().unwrap();
        let b: [u8; 4] = unsorted.payload[second..second + 4].try_into().unwrap();
        unsorted.payload[idx_off..idx_off + 4].copy_from_slice(&b);
        unsorted.payload[second..second + 4].copy_from_slice(&a);
        assert!(Panel::parse(&unsorted).is_err(), "unsorted indices");

        // Implausible dim is rejected before the dense vector allocates.
        let mut huge = good.clone();
        huge.payload[dim_off..dim_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Panel::parse(&huge).is_err(), "implausible dim");
    }
}
