//! The worker-fabric seam: one decentralized training loop, pluggable
//! collective substrates.
//!
//! The simulated [`Trainer`](crate::coordinator::Trainer) holds all p
//! workers in one process and lets a
//! [`CommPolicy`](crate::algorithms::CommPolicy) rewrite their parameters
//! at every τ-boundary. This module re-expresses that loop from *one
//! worker's point of view* so it can run on a real fabric: the worker
//! owns its engine and its sample stream, contributes its `(h, θ)` panel
//! to a blocking all-gather, and then applies **the same `CommPolicy`
//! code** to the gathered cohort, keeping only its own row. Because every
//! synchronous estimation-driven policy is a deterministic function of
//! (cohort parameters in rank order, energies, and the shared `root
//! child(8)` comm RNG stream), each worker replicates the exact update
//! the centralized trainer would have produced — the paper's
//! no-center-variable property made literal: there is no master, every
//! peer computes the aggregate locally (cf. gossip training, Blot et al.
//! 2016).
//!
//! Substrates implementing [`Collective`]:
//!
//! * [`LocalCollective`] — in-process threads over a [`PanelExchange`]
//!   barrier (the `--fabric sim` concurrency twin; what
//!   [`run_wasgd_plus_threaded`](crate::cluster::threads::run_wasgd_plus_threaded)
//!   uses);
//! * [`RemoteCluster`](crate::cluster::tcp::RemoteCluster) — a TCP
//!   connection to a rendezvous relay (`--fabric tcp`, `wasgd serve` /
//!   `wasgd worker`), one OS process per worker.
//!
//! With the lossless f32 wire encoding the two substrates produce
//! **bit-identical** final parameters to the simulated trainer — pinned
//! end to end by `tests/fabric_e2e.rs`.

use std::sync::{Arc, Condvar, Mutex};

use anyhow::{ensure, Result};

use crate::algorithms::{make_policy, CommContext};
use crate::cluster::SimCluster;
use crate::config::{AlgoKind, ExperimentConfig};
use crate::coordinator::worker::Worker;
use crate::data::order::judge;
use crate::data::source::{shard_range, BatchPlanner, DataPipeline};
use crate::data::{Dataset, RecordWindow};
use crate::journal::{
    canonical_comm_bytes, digest_params, rank_journal_path, Event, EventSink, JournalWriter,
    MembershipChange,
};
use crate::rng::Rng;
use crate::runtime::Backend;

use super::wire::{lossy_apply, topk_indices, Cohort, Panel, WireEncoding};

/// One worker's contribution to a collective round: its windowed loss
/// energy h and its flat parameter vector θ.
pub type WorkerPanel = (f32, Vec<f32>);

/// Which peers' panels each rank aggregates per collective round
/// (`--topology full|ring|gossip:F`).
///
/// * `full` — every rank aggregates the whole cohort (the bit-exact
///   oracle, and the only topology elastic sessions support).
/// * `ring` — the rendezvous *delivers* the cohort one neighbour hop at
///   a time (p−1 single-panel messages, origin `(rank − s) mod p` at
///   hop s) instead of one p-panel message. After the full rotation the
///   gathered content is identical to `full`, so with f32 panels the
///   numerics are bit-identical — a strong structural test that the
///   topology machinery itself never perturbs the aggregation.
/// * `gossip:F` — peer sampling (cf. Blot et al. 2016, arXiv
///   1611.09726): each rank aggregates its own panel plus `F`
///   deterministically sampled peers', with the Eq. 10/13 weights
///   renormalized over the actually-received subset (the Boltzmann /
///   inverse-loss normalisations are subset-local already, so this
///   falls out of handing the policy the subset's energies).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Topology {
    /// Full-cohort gather — everyone sees everyone, every round.
    #[default]
    Full,
    /// Neighbour-hop delivery of the full cohort; content ≡ `full`.
    Ring,
    /// Deterministic peer sampling with this many peers per round.
    Gossip {
        /// Sampled peers per rank per round (≥ 1; clamped to p−1).
        fanout: u32,
    },
}

impl Topology {
    /// Every topology family, in CLI listing order (the gossip entry
    /// carries a representative fanout of 2).
    pub const ALL: [Topology; 3] = [Topology::Full, Topology::Ring, Topology::Gossip { fanout: 2 }];

    /// Topology family name (fanout-free; see [`Topology::label`]).
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Full => "full",
            Topology::Ring => "ring",
            Topology::Gossip { .. } => "gossip",
        }
    }

    /// Full CLI spelling, including the gossip fanout (`gossip:2`).
    /// `parse(label())` round-trips for every topology.
    pub fn label(&self) -> String {
        match self {
            Topology::Full => "full".to_string(),
            Topology::Ring => "ring".to_string(),
            Topology::Gossip { fanout } => format!("gossip:{fanout}"),
        }
    }

    /// Parse a CLI name (`full`, `ring`, `gossip:F` with `F ≥ 1`);
    /// `None` for anything unknown or out of range.
    pub fn parse(s: &str) -> Option<Self> {
        if let Some(f) = s.strip_prefix("gossip:") {
            let fanout: u32 = f.parse().ok()?;
            if fanout == 0 {
                return None;
            }
            return Some(Topology::Gossip { fanout });
        }
        Some(match s {
            "full" => Topology::Full,
            "ring" => Topology::Ring,
            _ => return None,
        })
    }
}

/// splitmix64 finalizer — the tiny keyed hash behind the gossip peer
/// sampler. Private to keep the schedule in one place.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// The origin ranks whose panels rank `rank` aggregates in collective
/// round `round` (1-based), ascending, always containing `rank` itself.
///
/// This is a pure function of `(topology, p, rank, round, seed)` — the
/// relay, every worker, and the replaying simulator all compute the
/// same schedule with no extra wire traffic. Full and ring gather
/// everyone; gossip draws `fanout` distinct peers by a keyed partial
/// Fisher–Yates shuffle.
pub fn round_origins(
    topology: Topology,
    p: usize,
    rank: usize,
    round: u64,
    seed: u64,
) -> Vec<usize> {
    match topology {
        Topology::Full | Topology::Ring => (0..p).collect(),
        Topology::Gossip { fanout } => {
            let mut others: Vec<usize> = (0..p).filter(|&j| j != rank).collect();
            let n = others.len();
            let f = (fanout as usize).min(n);
            let mut state = mix64(seed ^ mix64(round) ^ mix64(0x6055_1950 ^ rank as u64));
            for i in 0..f {
                state = mix64(state);
                let j = i + (state % (n - i) as u64) as usize;
                others.swap(i, j);
            }
            let mut sel = others[..f].to_vec();
            sel.push(rank);
            sel.sort_unstable();
            sel
        }
    }
}

/// Per-worker panel codec: the error-feedback state a lossy encoding
/// threads from round to round, plus the sender-side mirror of what
/// every receiver decodes.
///
/// Top-k error feedback (cf. EF-SGD): the transmitted panel is the
/// *compensated* vector `θ + residual`; whatever the top-k selection
/// drops stays in the residual and is re-injected next round, so
/// compression error is deferred, never lost. The residual is updated
/// *by construction* (kept coordinates zeroed, dropped coordinates
/// copied bit-for-bit), never by floating-point subtraction — so
/// `decoded + residual` re-assembles the compensated panel bit-exactly,
/// `-0.0`/NaN/±∞ included (pinned by `tests/comm_props.rs`).
///
/// Residuals are per-session, in-memory state: a `--resume` or an
/// elastic re-formation starts them at zero (see `docs/FABRIC.md`).
pub struct PanelCodec {
    enc: WireEncoding,
    residual: Vec<f32>,
}

impl PanelCodec {
    /// A fresh codec for a `d`-parameter panel under `enc` (the
    /// residual starts at zero and only exists for top-k).
    pub fn new(enc: WireEncoding, d: usize) -> Self {
        let residual = match enc {
            WireEncoding::TopK { .. } => vec![0.0; d],
            WireEncoding::F32 | WireEncoding::Qi8 => Vec::new(),
        };
        Self { enc, residual }
    }

    /// The panel this worker transmits for its current params: the
    /// error-compensated `θ + residual` for top-k, θ verbatim otherwise.
    pub fn outgoing(&self, params: &[f32]) -> Vec<f32> {
        match self.enc {
            WireEncoding::TopK { .. } => {
                params.iter().zip(&self.residual).map(|(t, r)| t + r).collect()
            }
            WireEncoding::F32 | WireEncoding::Qi8 => params.to_vec(),
        }
    }

    /// Commit `outgoing` as transmitted: fold the dropped coordinates
    /// into the residual and return the decoded panel — bit-identical
    /// to what every receiver of the encoded bytes decodes.
    pub fn committed(&mut self, outgoing: &[f32]) -> Vec<f32> {
        match self.enc {
            WireEncoding::TopK { k_ppm } => {
                self.residual.clear();
                self.residual.extend_from_slice(outgoing);
                let mut decoded = vec![0.0f32; outgoing.len()];
                for i in topk_indices(outgoing, k_ppm) {
                    decoded[i as usize] = outgoing[i as usize];
                    self.residual[i as usize] = 0.0;
                }
                decoded
            }
            WireEncoding::F32 => outgoing.to_vec(),
            WireEncoding::Qi8 => lossy_apply(WireEncoding::Qi8, outgoing),
        }
    }

    /// The current residual (empty for lossless/qi8 encodings).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }
}

/// The all-gather/barrier surface every fabric substrate provides — the
/// seam between the decentralized loop and the transport underneath it.
pub trait Collective {
    /// Cohort size p.
    fn p(&self) -> usize;

    /// This participant's rank in `[0, p)`.
    fn rank(&self) -> usize;

    /// Blocking all-gather: contribute this worker's `(h, θ)` panel and
    /// return the whole cohort's panels in rank order once every
    /// participant of the round has arrived.
    fn all_gather(&mut self, h: f32, params: &[f32]) -> Result<Vec<WorkerPanel>>;

    /// Bytes this participant has pushed toward its peers so far (wire
    /// bytes for TCP; the wire-equivalent for in-process substrates).
    fn bytes_sent(&self) -> u64;

    /// Bytes received from peers so far (same convention).
    fn bytes_received(&self) -> u64;

    /// The panel encoding this substrate carries: what journals record
    /// so replay knows whether the session is bit-exactly replayable
    /// (`f32`, and `topk` — deterministically lossy — too) or
    /// inspect-only (`qi8`). Substrates that apply a lossy mode report
    /// the rate-bearing session encoding, not a header-derived family.
    fn encoding(&self) -> WireEncoding {
        WireEncoding::F32
    }
}

/// A graceful end-of-epoch signal from an epoch-scoped collective: the
/// rendezvous committed the epoch (peer died, peer left, a queued
/// joiner is being absorbed, or a peer exhausted its step budget and
/// sent its `Final` panel), so this worker should reconnect for the
/// next epoch rather than treat the error as fatal. Carried as the
/// source of an [`anyhow::Error`] so callers can `downcast_ref` it out
/// of the failure chain.
#[derive(Clone, Debug)]
pub struct EpochEnded {
    /// Why the epoch was cut — the same diagnostic that rides the
    /// `EpochCommit` wire frame's reason field.
    pub reason: String,
}

impl std::fmt::Display for EpochEnded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "epoch ended: {}", self.reason)
    }
}

impl std::error::Error for EpochEnded {}

/// The committed plan for one elastic epoch: its id, member count, the
/// survivors' previous-epoch ranks (in new-rank order), and the step
/// budget left in the run. The rendezvous forms one of these at every
/// boundary; its `members` row order *is* the new rank assignment, so
/// re-sharding falls out of the existing rank-stable
/// [`shard_range`](crate::data::source::shard_range).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EpochPlan {
    /// Epoch id (0 is the initial cohort).
    pub epoch: u64,
    /// Committed member count p_e — every collective round of this
    /// epoch gathers exactly this many panels.
    pub p: usize,
    /// For new rank `j < prior.len()`: that member's rank in the
    /// previous epoch (survivors sort before joiners, so fresh joiners
    /// occupy ranks `prior.len()..p` and have no prior rank).
    pub prior: Vec<u32>,
    /// Local SGD steps remaining in the run's global budget.
    pub steps: usize,
}

/// How an exchange stopped: a *cut* ends the epoch gracefully (workers
/// reconnect), a *poison* aborts the session (workers fail).
enum EpochEnd {
    Cut(String),
    Poisoned(String),
}

/// A reusable p-way all-gather barrier carrying one `T` per participant,
/// scoped to one epoch: explicit *poisoning* releases — rather than
/// deadlocks — the cohort on hard failure, and a *cut* releases it with
/// a recoverable [`EpochEnded`] so an elastic rendezvous can commit the
/// next epoch instead of killing the run.
pub struct PanelExchange<T> {
    inner: Mutex<ExchangeState<T>>,
    cv: Condvar,
    p: usize,
}

struct ExchangeState<T> {
    slots: Vec<Option<T>>,
    published: Arc<Vec<T>>,
    generation: u64,
    ended: Option<EpochEnd>,
}

impl<T: Clone> PanelExchange<T> {
    /// A fresh exchange for `p` participants.
    pub fn new(p: usize) -> Self {
        Self {
            inner: Mutex::new(ExchangeState {
                slots: (0..p).map(|_| None).collect(),
                published: Arc::new(Vec::new()),
                generation: 0,
                ended: None,
            }),
            cv: Condvar::new(),
            p,
        }
    }

    /// Cohort size p.
    pub fn participants(&self) -> usize {
        self.p
    }

    /// Deposit participant `rank`'s contribution; blocks until the round
    /// completes, then returns everyone's (index = rank). Errors if the
    /// exchange was poisoned (by a failed peer), ended by an epoch cut
    /// (the error's source is an [`EpochEnded`]), or on double-deposit.
    pub fn exchange(&self, rank: usize, v: T) -> Result<Arc<Vec<T>>> {
        let mut st = self.inner.lock().unwrap();
        if let Some(end) = &st.ended {
            return Err(Self::end_error(end));
        }
        ensure!(st.slots[rank].is_none(), "rank {rank} deposited twice in one round");
        st.slots[rank] = Some(v);
        if st.slots.iter().all(|s| s.is_some()) {
            let vals: Vec<T> = st.slots.iter_mut().map(|s| s.take().unwrap()).collect();
            st.published = Arc::new(vals);
            st.generation += 1;
            self.cv.notify_all();
            return Ok(st.published.clone());
        }
        let gen = st.generation;
        while st.generation == gen && st.ended.is_none() {
            st = self.cv.wait(st).unwrap();
        }
        // A round that published before (or concurrently with) an end
        // still completed: deliver it. Only a round that can never
        // publish reports the end. The mutex linearizes deposit and
        // end-marking, so "which round committed" is consistent across
        // every participant.
        if st.generation != gen {
            return Ok(st.published.clone());
        }
        let end = st.ended.as_ref().expect("woke without publish or end");
        Err(Self::end_error(end))
    }

    fn end_error(end: &EpochEnd) -> anyhow::Error {
        match end {
            EpochEnd::Cut(reason) => anyhow::Error::new(EpochEnded { reason: reason.clone() }),
            EpochEnd::Poisoned(why) => anyhow::anyhow!("collective aborted: {why}"),
        }
    }

    /// Mark the exchange failed: current and future `exchange` calls
    /// return an error carrying `why` instead of blocking forever.
    /// First writer wins; a later cut or poison does not overwrite it.
    ///
    /// Poison is for *unrecoverable* faults (protocol violations, IO
    /// errors on a fixed cohort). Recoverable boundaries — including a
    /// rank reaching its finale and sending `Final` while peers still
    /// train — use [`cut`](Self::cut), so elastic survivors re-form
    /// instead of aborting.
    pub fn poison(&self, why: &str) {
        let mut st = self.inner.lock().unwrap();
        if st.ended.is_none() {
            st.ended = Some(EpochEnd::Poisoned(why.to_string()));
        }
        self.cv.notify_all();
    }

    /// End the epoch gracefully: current and future `exchange` calls
    /// return an error whose source is an [`EpochEnded`] carrying
    /// `reason`, instead of blocking forever. Rounds already published
    /// are unaffected. First writer wins, and a prior poison is never
    /// downgraded to a cut.
    ///
    /// The elastic relay cuts at every recoverable boundary: a death, a
    /// leave, a joiner being absorbed, and a rank's `Final` panel during
    /// the finale — in the last case survivors still owing finals re-form
    /// into an epilogue epoch with a zero-step budget to deliver theirs.
    pub fn cut(&self, reason: &str) {
        let mut st = self.inner.lock().unwrap();
        if st.ended.is_none() {
            st.ended = Some(EpochEnd::Cut(reason.to_string()));
        }
        self.cv.notify_all();
    }

    /// The last fully published round, as `(round, panels)` where
    /// `round` counts from 1 — `None` if no round ever completed. After
    /// a cut this is the epoch's committed round: the anchor every
    /// survivor and the rendezvous agree on.
    pub fn last_published(&self) -> Option<(u64, Arc<Vec<T>>)> {
        let st = self.inner.lock().unwrap();
        (st.generation > 0).then(|| (st.generation, st.published.clone()))
    }

    /// The reason this exchange's epoch was cut, if it was — `None`
    /// while running or when the exchange was poisoned instead. The
    /// first cut wins, so this is the authoritative boundary reason
    /// even when several relay handlers race to report it.
    pub fn cut_reason(&self) -> Option<String> {
        let st = self.inner.lock().unwrap();
        match &st.ended {
            Some(EpochEnd::Cut(r)) => Some(r.clone()),
            _ => None,
        }
    }
}

/// The in-process [`Collective`]: worker threads of one process meeting
/// at a shared [`PanelExchange`] — the concurrency substrate of
/// `--fabric sim` (the channel stands in for the NIC). Byte counters
/// report the *wire-equivalent* frame sizes of the configured
/// encoding × topology so the cost model and the comm-quality tests see
/// the same traffic a TCP session would measure.
///
/// Lossy encodings are applied at deposit time (each rank publishes the
/// encode→decode round trip of its panel), so every peer — the
/// depositor included — aggregates exactly what a TCP cohort would have
/// decoded from the wire bytes.
pub struct LocalCollective {
    exchange: Arc<PanelExchange<WorkerPanel>>,
    rank: usize,
    encoding: WireEncoding,
    topology: Topology,
    seed: u64,
    round: u64,
    bytes_sent: u64,
    bytes_received: u64,
}

impl LocalCollective {
    /// Attach rank `rank` to a shared exchange (lossless f32 panels,
    /// full-cohort gather).
    pub fn new(exchange: Arc<PanelExchange<WorkerPanel>>, rank: usize) -> Self {
        Self::with_modes(exchange, rank, WireEncoding::F32, Topology::Full, 0)
    }

    /// Attach rank `rank` with an explicit panel encoding and exchange
    /// topology (`seed` keys the gossip peer sampler; unused by
    /// full/ring).
    pub fn with_modes(
        exchange: Arc<PanelExchange<WorkerPanel>>,
        rank: usize,
        encoding: WireEncoding,
        topology: Topology,
        seed: u64,
    ) -> Self {
        Self {
            exchange,
            rank,
            encoding,
            topology,
            seed,
            round: 0,
            bytes_sent: 0,
            bytes_received: 0,
        }
    }
}

impl Collective for LocalCollective {
    fn p(&self) -> usize {
        self.exchange.participants()
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn all_gather(&mut self, h: f32, params: &[f32]) -> Result<Vec<WorkerPanel>> {
        let d = params.len();
        let p = self.p();
        self.round += 1;
        let decoded = lossy_apply(self.encoding, params);
        let cohort = self.exchange.exchange(self.rank, (h, decoded))?;
        self.bytes_sent += Panel::wire_len(self.encoding, d) as u64;
        match self.topology {
            Topology::Full => {
                self.bytes_received += Cohort::wire_len(self.encoding, d, p) as u64;
                Ok(cohort.as_ref().clone())
            }
            Topology::Ring => {
                // Content ≡ full; the wire-equivalent delivery is p−1
                // single-panel hops instead of one p-panel message.
                self.bytes_received +=
                    ((p - 1) * Cohort::wire_len(self.encoding, d, 1)) as u64;
                Ok(cohort.as_ref().clone())
            }
            Topology::Gossip { .. } => {
                let origins = round_origins(self.topology, p, self.rank, self.round, self.seed);
                self.bytes_received +=
                    Cohort::wire_len(self.encoding, d, origins.len()) as u64;
                Ok(origins.iter().map(|&o| cohort[o].clone()).collect())
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    fn encoding(&self) -> WireEncoding {
        self.encoding
    }
}

/// Can this scheme run decentralized on a worker fabric? True for the
/// synchronous estimation-driven policies, whose boundary update is a
/// deterministic function of the gathered cohort (plus the replicated
/// comm RNG stream). Sequential has no cohort, OMWU needs centrally
/// computed full-dataset losses, and the async variant needs the
/// cluster's timing quorum — those stay on `--fabric sim`.
pub fn algo_supports_fabric(algo: AlgoKind) -> bool {
    matches!(
        algo,
        AlgoKind::Spsgd | AlgoKind::Easgd | AlgoKind::Mmwu | AlgoKind::Wasgd | AlgoKind::WasgdPlus
    )
}

/// The local step budget the simulated trainer would run for this config
/// — `ceil(epochs · steps_per_epoch)`, at least 1. Every fabric worker
/// computes this independently and identically.
pub fn planned_steps(cfg: &ExperimentConfig, n_train: usize, batch: usize) -> usize {
    let spe = (n_train / batch).max(1);
    ((cfg.epochs * spe as f64).ceil() as usize).max(1)
}

/// Everything one fabric worker reports when its step budget is done.
#[derive(Clone, Debug)]
pub struct FabricWorkerOutcome {
    /// This worker's rank.
    pub rank: usize,
    /// Final flat parameter vector θ.
    pub params: Vec<f32>,
    /// Mean recorded batch loss of the last *completed* communication
    /// period; if the step budget never reached a τ-boundary, the raw
    /// window energy at exit (always finite unless training diverged).
    pub mean_energy: f32,
    /// Local SGD steps taken.
    pub steps: usize,
    /// Communication boundaries (collective rounds) participated in.
    pub boundaries: u64,
    /// Bytes pushed to peers (wire or wire-equivalent).
    pub bytes_sent: u64,
    /// Bytes received from peers.
    pub bytes_received: u64,
}

/// Run one decentralized worker to completion over any [`Collective`].
///
/// This is the [`Trainer`](crate::coordinator::Trainer) loop from worker
/// `rank`'s point of view, operation for operation: the same parameter
/// init (`seed ^ 0x9a9a`), the same per-worker batch stream
/// ([`Worker`] seeded `root.child(100 + rank)`, §3.4 order search
/// included), the same [`RecordWindow`] estimation, and the same
/// [`CommPolicy`](crate::algorithms::CommPolicy) boundary code applied
/// to the gathered cohort — so on a lossless fabric the final θ matches
/// the simulated trainer bit for bit (pinned by `tests/fabric_e2e.rs`).
///
/// `initial_params` overrides the seeded init when resuming from a
/// checkpointed rendezvous (resumed runs are deterministic but no longer
/// comparable to a fresh sim run). The policy charges its communication
/// to a local [`SimCluster`] mirror, which keeps the cost model's
/// telemetry available even on a real fabric.
///
/// When `journal` is given, the worker records the run as an event
/// stream: because every all-gather hands it the *whole* cohort's
/// panels, a single worker's journal carries all p ranks' per-round
/// digests — identical, on a lossless fabric, to the simulated
/// trainer's own journal of the same config.
pub fn run_fabric_worker(
    cfg: &ExperimentConfig,
    engine: &dyn Backend,
    dataset: &Dataset,
    fabric: &mut dyn Collective,
    total_steps: usize,
    initial_params: Option<Vec<f32>>,
    mut journal: Option<&mut dyn EventSink>,
) -> Result<FabricWorkerOutcome> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    ensure!(
        algo_supports_fabric(cfg.algo),
        "the worker fabric replicates synchronous estimation-driven schemes \
         (spsgd, easgd, mmwu, wasgd, wasgd+); {} needs the simulated trainer (--fabric sim)",
        cfg.algo.name()
    );
    let p = fabric.p();
    let rank = fabric.rank();
    ensure!(p == cfg.p, "fabric has {p} participants but the config says p={}", cfg.p);
    ensure!(rank < p, "rank {rank} out of range for p={p}");

    if let Some(j) = journal.as_mut() {
        j.emit(&Event::RunStarted {
            rank: rank as u32,
            p: p as u32,
            seed: cfg.seed,
            encoding: fabric.encoding(),
            git_rev: crate::bench::git_rev(),
            config_json: cfg.to_wire_json(),
            resume: initial_params.iter().cloned().collect(),
        })?;
        j.emit(&Event::Membership {
            epoch: 0,
            rank: rank as u32,
            change: MembershipChange::Joined,
        })?;
    }

    let mut policy = make_policy(cfg);
    let manifest = engine.manifest();
    ensure!(
        dataset.dim == manifest.input_dim,
        "dataset dim {} ≠ model input dim {} (dataset {} vs variant {})",
        dataset.dim,
        manifest.input_dim,
        dataset.name,
        manifest.name
    );
    let batch = manifest.batch;
    let n = dataset.n_train();
    ensure!(n >= batch, "dataset smaller than one batch");

    let root = Rng::new(cfg.seed);
    let mut comm_rng = root.child(8);
    let mut params = manifest.init_params(cfg.seed ^ 0x9a9a);
    if let Some(init) = initial_params {
        ensure!(
            init.len() == params.len(),
            "resume parameters have {} elements, model {} wants {}",
            init.len(),
            manifest.name,
            params.len()
        );
        params = init;
    }
    // The same rank-stable shard rule and batch planner the simulated
    // trainer builds — operation for operation, so the sample streams
    // agree bit for bit.
    let shard = policy.shards_data().then(|| shard_range(n, rank, p));
    if let Some((lo, hi)) = shard {
        ensure!(
            hi - lo >= batch,
            "worker {rank}'s data shard holds {} examples — fewer than one batch of {batch}; \
             reduce p or train on a larger split",
            hi - lo
        );
    }
    let planner = BatchPlanner::new(
        rank,
        root.child(100 + rank as u64),
        n,
        batch,
        shard,
        policy.uses_order_search() && cfg.force_delta_order.is_none(),
        cfg.n_parts,
        cfg.force_delta_order,
        dataset.train_y.clone(),
    );
    let mut worker = Worker::new(rank, params, planner);
    // Error-feedback state for lossy encodings: the codec carries the
    // dropped coordinates from round to round (zero-sized for f32/qi8).
    let mut codec = PanelCodec::new(fabric.encoding(), worker.params().len());
    let window = RecordWindow::new(cfg.tau, cfg.m, cfg.c);
    // Dormant cost-model mirror: policies charge communication here so
    // the modelled comm/wait telemetry exists on real fabrics too. It
    // never feeds back into the numerics.
    let mut cluster = SimCluster::new(p, cfg.fabric_cost, cfg.compute, cfg.seed);
    let msg_bytes = manifest.message_bytes();

    let (mut idx_buf, mut x_buf, mut y_buf) = (Vec::new(), Vec::new(), Vec::new());
    let mut boundaries = 0u64;
    let mut mean_energy = f32::NAN;

    for step in 1..=total_steps {
        let k_in_period = (step - 1) % cfg.tau;
        let recorded = window.is_recorded(k_in_period);
        worker.next_batch_into(&mut idx_buf);
        dataset.gather_train(&idx_buf, &mut x_buf, &mut y_buf);
        let (new_params, out) = engine.train_step(worker.params(), &x_buf, &y_buf, cfg.lr)?;
        worker.set_params(new_params);
        if recorded {
            worker.add_energy(out.loss);
        }

        if step % cfg.tau == 0 {
            let round = (step / cfg.tau) as u64;
            let h = worker.energy();
            // Transmit the error-compensated panel (θ + residual for
            // top-k, θ verbatim otherwise) …
            let outgoing = codec.outgoing(worker.params());
            let cohort = fabric.all_gather(h, &outgoing)?;
            // … and commit it: fold the dropped coordinates back into
            // the residual, keep the sender-side mirror of the decode.
            let own_decoded = codec.committed(&outgoing);
            let origins = round_origins(cfg.topology, p, rank, round, cfg.seed);
            ensure!(
                cohort.len() == origins.len(),
                "round {round} gathered {} panels, topology {} expected {}",
                cohort.len(),
                cfg.topology.label(),
                origins.len()
            );
            let own_pos = origins
                .iter()
                .position(|&o| o == rank)
                .expect("a rank always aggregates its own panel");
            ensure!(
                cohort[own_pos].0.to_bits() == h.to_bits(),
                "fabric corrupted rank {rank}'s own panel"
            );
            let energies: Vec<f32> = cohort.iter().map(|(e, _)| *e).collect();
            let d = worker.params().len();
            let mut rows = Vec::with_capacity(origins.len());
            for (j, (_, row)) in cohort.into_iter().enumerate() {
                ensure!(
                    row.len() == d,
                    "cohort row {j} carries {} params, expected {d}",
                    row.len()
                );
                rows.push(row);
            }
            // The gathered own row must be bit-identical to the local
            // encode→decode mirror — any divergence means the fabric
            // (or the codec) altered the panel in flight.
            ensure!(
                digest_params(&rows[own_pos]) == digest_params(&own_decoded),
                "fabric corrupted rank {rank}'s own panel body"
            );
            // Journal the gathered decoded panels before the policy
            // rewrites them — the same pre-aggregation vantage point the
            // simulated trainer journals at. Digests are over what this
            // rank *actually aggregated* (post-decode), so a
            // deterministically lossy run still replays bit-exactly
            // from its own journal.
            if let Some(j) = journal.as_mut() {
                for (i, row) in rows.iter().enumerate() {
                    j.emit(&Event::PanelDigest {
                        round,
                        rank: origins[i] as u32,
                        digest: digest_params(row),
                        loss: energies[i],
                        comm_bytes: canonical_comm_bytes(round, d),
                    })?;
                }
            }
            {
                let mut ctx = CommContext {
                    params: &mut rows,
                    energies: &energies,
                    engine,
                    cluster: &mut cluster,
                    cfg,
                    rng: &mut comm_rng,
                    msg_bytes,
                    full_losses: None,
                    iteration: step as u64,
                };
                policy.at_boundary(&mut ctx)?;
            }
            worker.set_params(rows.swap_remove(own_pos));
            if policy.uses_order_search() {
                worker.record_judge_score(judge(&energies, own_pos));
            }
            mean_energy = h / window.recorded_count().max(1) as f32;
            worker.reset_energy();
            boundaries += 1;
        }
    }
    if boundaries == 0 {
        // Shorter-than-τ budgets never cross a boundary; report the raw
        // window energy instead of a NaN that downstream consumers
        // (serve summary, checkpoints, aggregate's finiteness checks)
        // would choke on.
        mean_energy = worker.energy();
    }

    if let Some(j) = journal.as_mut() {
        j.emit(&Event::RunFinished {
            steps: total_steps as u64,
            rounds: boundaries,
            final_digest: digest_params(worker.params()),
        })?;
    }

    Ok(FabricWorkerOutcome {
        rank,
        params: worker.params().to_vec(),
        mean_energy,
        steps: total_steps,
        boundaries,
        bytes_sent: fabric.bytes_sent(),
        bytes_received: fabric.bytes_received(),
    })
}

/// Run a whole decentralized cohort on the in-process substrate: p OS
/// threads, each owning its own backend, meeting at a [`PanelExchange`].
/// Returns the per-worker outcomes in rank order. A failed worker
/// poisons the exchange so the rest of the cohort errors out instead of
/// deadlocking.
pub fn run_decentralized_threaded(
    cfg: &ExperimentConfig,
    total_steps: usize,
) -> Result<Vec<FabricWorkerOutcome>> {
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    // Probe once on this thread so the pipeline can validate against
    // the variant's input geometry; dropped before any worker spawns
    // (backends are per-thread: the PJRT client is not Send).
    let dataset = {
        let probe = crate::runtime::load_backend(cfg)?;
        Arc::new(DataPipeline::from_config(cfg)?.load(probe.manifest())?)
    };
    let exchange: Arc<PanelExchange<WorkerPanel>> = Arc::new(PanelExchange::new(cfg.p));

    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(cfg.p);
        for rank in 0..cfg.p {
            let exchange = Arc::clone(&exchange);
            let dataset = Arc::clone(&dataset);
            handles.push(s.spawn(move || {
                let run = || -> Result<FabricWorkerOutcome> {
                    let engine = crate::runtime::load_backend(cfg)?;
                    let mut fabric = LocalCollective::with_modes(
                        Arc::clone(&exchange),
                        rank,
                        cfg.encoding,
                        cfg.topology,
                        cfg.seed,
                    );
                    let mut jw = match &cfg.journal {
                        Some(base) => {
                            Some(JournalWriter::create(&rank_journal_path(base, rank))?)
                        }
                        None => None,
                    };
                    run_fabric_worker(
                        cfg,
                        engine.as_ref(),
                        &dataset,
                        &mut fabric,
                        total_steps,
                        None,
                        jw.as_mut().map(|w| w as &mut dyn EventSink),
                    )
                };
                let result = run();
                if let Err(e) = &result {
                    exchange.poison(&format!("worker {rank} failed: {e}"));
                }
                result
            }));
        }
        handles
            .into_iter()
            .enumerate()
            .map(|(rank, h)| h.join().map_err(|_| anyhow::anyhow!("worker {rank} panicked"))?)
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn panel_exchange_roundtrip_and_generations() {
        let p = 3;
        let ex: Arc<PanelExchange<usize>> = Arc::new(PanelExchange::new(p));
        let mut handles = Vec::new();
        for rank in 0..p {
            let ex = Arc::clone(&ex);
            handles.push(thread::spawn(move || {
                let mut sums = Vec::new();
                for round in 0..20 {
                    let vals = ex.exchange(rank, rank * 100 + round).unwrap();
                    sums.push(vals.iter().sum::<usize>());
                }
                sums
            }));
        }
        let results: Vec<Vec<usize>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results[1..] {
            assert_eq!(r, &results[0]);
        }
        for (round, &s) in results[0].iter().enumerate() {
            // Σ rank·100 + round over ranks 0..3.
            assert_eq!(s, 300 + 3 * round);
        }
    }

    #[test]
    fn poison_releases_waiters_with_an_error() {
        let ex: Arc<PanelExchange<u32>> = Arc::new(PanelExchange::new(2));
        let a = Arc::clone(&ex);
        let waiter = thread::spawn(move || a.exchange(0, 1));
        // Give the waiter time to block, then poison instead of joining.
        thread::sleep(std::time::Duration::from_millis(20));
        ex.poison("peer died");
        let err = waiter.join().unwrap().unwrap_err();
        assert!(format!("{err}").contains("peer died"));
        // A poison is a hard failure, not an epoch boundary.
        assert!(err.downcast_ref::<EpochEnded>().is_none());
        // Subsequent exchanges fail fast too.
        assert!(ex.exchange(1, 2).is_err());
    }

    #[test]
    fn cut_releases_waiters_with_a_recoverable_epoch_end() {
        let ex: Arc<PanelExchange<u32>> = Arc::new(PanelExchange::new(2));
        // Complete one round so there is a committed anchor.
        let a = Arc::clone(&ex);
        let peer = thread::spawn(move || a.exchange(1, 20));
        ex.exchange(0, 10).unwrap();
        peer.join().unwrap().unwrap();
        assert_eq!(ex.last_published().map(|(r, v)| (r, v.as_ref().clone())), Some((1, vec![
            10, 20
        ])));

        // Round 2 never completes: rank 0 deposits, then the epoch is
        // cut. The waiter gets a downcastable EpochEnded, not a fatal
        // poison, and the committed round is unchanged.
        let a = Arc::clone(&ex);
        let waiter = thread::spawn(move || a.exchange(0, 11));
        thread::sleep(std::time::Duration::from_millis(20));
        ex.cut("rank 1 died after completing round 1");
        let err = waiter.join().unwrap().unwrap_err();
        let end = err.downcast_ref::<EpochEnded>().expect("cut must surface as EpochEnded");
        assert!(end.reason.contains("rank 1"));
        assert_eq!(ex.last_published().map(|(r, _)| r), Some(1));
        // A cut never upgrades to (or masks) a poison retroactively.
        ex.poison("too late");
        let err = ex.exchange(1, 21).unwrap_err();
        assert!(err.downcast_ref::<EpochEnded>().is_some());
    }

    #[test]
    fn fabric_support_matrix() {
        assert!(algo_supports_fabric(AlgoKind::WasgdPlus));
        assert!(algo_supports_fabric(AlgoKind::Wasgd));
        assert!(algo_supports_fabric(AlgoKind::Mmwu));
        assert!(algo_supports_fabric(AlgoKind::Spsgd));
        assert!(algo_supports_fabric(AlgoKind::Easgd));
        assert!(!algo_supports_fabric(AlgoKind::Sequential));
        assert!(!algo_supports_fabric(AlgoKind::Omwu));
        assert!(!algo_supports_fabric(AlgoKind::WasgdPlusAsync));
    }

    #[test]
    fn planned_steps_matches_trainer_budget() {
        let mut cfg = ExperimentConfig::default();
        cfg.epochs = 2.0;
        assert_eq!(planned_steps(&cfg, 512, 8), 128);
        cfg.epochs = 0.1;
        assert_eq!(planned_steps(&cfg, 512, 8), 7); // ceil(6.4)
        cfg.epochs = 0.0;
        assert_eq!(planned_steps(&cfg, 512, 8), 1);
        // Tiny datasets: steps-per-epoch floors at 1.
        cfg.epochs = 3.0;
        assert_eq!(planned_steps(&cfg, 4, 8), 3);
    }

    #[test]
    fn topology_names_roundtrip() {
        for t in Topology::ALL {
            assert_eq!(Topology::parse(&t.label()), Some(t), "{t:?}");
        }
        assert_eq!(Topology::parse("gossip:3"), Some(Topology::Gossip { fanout: 3 }));
        assert_eq!(Topology::parse("gossip:0"), None, "fanout 0 samples nobody");
        assert_eq!(Topology::parse("gossip:"), None);
        assert_eq!(Topology::parse("mesh"), None);
        assert_eq!(Topology::default(), Topology::Full);
    }

    #[test]
    fn round_origins_full_and_ring_gather_everyone() {
        for t in [Topology::Full, Topology::Ring] {
            for rank in 0..4 {
                assert_eq!(round_origins(t, 4, rank, 7, 42), vec![0, 1, 2, 3]);
            }
        }
    }

    #[test]
    fn gossip_origins_are_deterministic_self_inclusive_and_vary_by_round() {
        let p = 8;
        let fanout = 2;
        let t = Topology::Gossip { fanout };
        let mut saw_different_rounds = false;
        for rank in 0..p {
            let first = round_origins(t, p, rank, 1, 42);
            // Deterministic: same inputs, same subset.
            assert_eq!(first, round_origins(t, p, rank, 1, 42));
            // Own rank always included; fanout peers; ascending; unique.
            assert_eq!(first.len(), 1 + fanout as usize);
            assert!(first.contains(&rank), "rank {rank} missing from {first:?}");
            assert!(first.windows(2).all(|w| w[0] < w[1]), "{first:?} not strictly ascending");
            assert!(first.iter().all(|&o| o < p));
            if first != round_origins(t, p, rank, 2, 42) {
                saw_different_rounds = true;
            }
        }
        assert!(saw_different_rounds, "the sample must vary across rounds");
        // Fanout clamps to p−1 (everyone) without duplication.
        let all = round_origins(Topology::Gossip { fanout: 99 }, 3, 1, 5, 7);
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn panel_codec_error_feedback_invariant() {
        let enc = WireEncoding::TopK { k_ppm: 400_000 }; // keep 2 of 5
        let mut codec = PanelCodec::new(enc, 5);
        let params = [1.0f32, -4.0, 0.25, 3.0, -0.0];
        // Round 1: residual is zero, outgoing ≡ params.
        let out1 = codec.outgoing(&params);
        assert_eq!(out1, params.to_vec());
        let dec1 = codec.committed(&out1);
        // decoded + residual re-assembles the compensated panel
        // bit-for-bit: kept coords travel, dropped coords stay local.
        for i in 0..5 {
            let (d, r) = (dec1[i], codec.residual()[i]);
            if d.to_bits() == 0 && r.to_bits() == out1[i].to_bits() {
                continue; // dropped
            }
            assert_eq!(d.to_bits(), out1[i].to_bits(), "kept coord {i} must be bit-exact");
            assert_eq!(r, 0.0, "kept coord {i} must leave no residual");
        }
        // |−4| and |3| are the top 2.
        assert_eq!(dec1[1], -4.0);
        assert_eq!(dec1[3], 3.0);
        assert_eq!(codec.residual()[0], 1.0);
        // Round 2 with unchanged params: the residual re-injects the
        // dropped coordinates into the compensated panel.
        let out2 = codec.outgoing(&params);
        assert_eq!(out2[0], 2.0, "1.0 param + 1.0 residual");
        // Lossless codecs are pass-through with no residual state.
        let mut f32c = PanelCodec::new(WireEncoding::F32, 5);
        let o = f32c.outgoing(&params);
        assert_eq!(f32c.committed(&o), params.to_vec());
        assert!(f32c.residual().is_empty());
    }

    #[test]
    fn local_collective_gossip_returns_the_subset_in_origin_order() {
        let p = 4;
        let t = Topology::Gossip { fanout: 1 };
        let ex: Arc<PanelExchange<WorkerPanel>> = Arc::new(PanelExchange::new(p));
        let mut handles = Vec::new();
        for rank in 0..p {
            let ex = Arc::clone(&ex);
            handles.push(thread::spawn(move || {
                let mut c = LocalCollective::with_modes(ex, rank, WireEncoding::F32, t, 99);
                let got = c.all_gather(rank as f32, &[rank as f32 * 10.0]).unwrap();
                (rank, got)
            }));
        }
        for h in handles {
            let (rank, got) = h.join().unwrap();
            let origins = round_origins(t, p, rank, 1, 99);
            assert_eq!(got.len(), origins.len());
            for (row, &o) in got.iter().zip(origins.iter()) {
                assert_eq!(row.0, o as f32, "row order must follow ascending origins");
                assert_eq!(row.1, vec![o as f32 * 10.0]);
            }
        }
    }

    #[test]
    fn decentralized_threaded_runs_and_reports_bytes() {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = crate::config::BackendKind::Native;
        cfg.p = 2;
        cfg.tau = 8;
        cfg.m = 2;
        cfg.c = 1;
        let outs = run_decentralized_threaded(&cfg, 16).unwrap();
        assert_eq!(outs.len(), 2);
        for (rank, o) in outs.iter().enumerate() {
            assert_eq!(o.rank, rank);
            assert_eq!(o.steps, 16);
            assert_eq!(o.boundaries, 2);
            assert!(o.mean_energy.is_finite());
            assert!(o.bytes_sent > 0 && o.bytes_received > o.bytes_sent);
        }
    }
}
