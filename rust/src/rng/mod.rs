//! Deterministic PRNG substrate.
//!
//! Every stochastic decision in the coordinator — data synthesis, sample
//! orders, straggler jitter, communication probability ζ — must be exactly
//! reproducible from a seed so that experiments (and the proptest suite)
//! are bit-stable across runs. We implement the substrate from scratch:
//! SplitMix64 for seeding and xoshiro256** as the workhorse generator,
//! plus Box–Muller normals and Fisher–Yates permutations.
//!
//! The paper's `OrderGen` (Algorithm 2, Function 2) is a *seeded* shuffle:
//! a worker that scored well keeps its seed, a worker that scored badly
//! redraws. That contract is exactly "a permutation is a pure function of
//! a u64 seed", which this module provides.

/// SplitMix64 — used to expand one u64 seed into generator state.
/// Reference: Steele, Lea & Flood, "Fast splittable pseudorandom number
/// generators", OOPSLA 2014.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the expander.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly mixed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the workhorse generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached spare normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Construct from a single seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, spare_normal: None }
    }

    /// Derive an independent child stream (worker i, purpose tag, …).
    /// Streams with different tags are decorrelated by the SplitMix hash.
    pub fn child(&self, tag: u64) -> Self {
        let mut sm = SplitMix64::new(self.s[0] ^ tag.wrapping_mul(0xA24B_AED4_963E_E407));
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, spare_normal: None }
    }

    /// Next 64 random bits (xoshiro256** output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits → exactly representable double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Unbiased integer in [0, n) via Lemire rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// N(mu, sigma²) as f32.
    #[inline]
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal() as f32
    }

    /// Fill a slice with N(mu, sigma²) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mu, sigma);
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, data: &mut [T]) {
        for i in (1..data.len()).rev() {
            let j = self.below(i + 1);
            data.swap(i, j);
        }
    }

    /// A fresh permutation of 0..n — the paper's `OrderGen` primitive:
    /// the permutation is a pure function of the generator state.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut v);
        v
    }

    /// Exponential with rate lambda (for the fabric latency model).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.uniform();
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn child_streams_decorrelated() {
        let root = Rng::new(42);
        let mut c0 = root.child(0);
        let mut c1 = root.child(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_valid_and_seed_stable() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let p1 = r1.permutation(1000);
        let p2 = r2.permutation(1000);
        assert_eq!(p1, p2);
        let mut sorted = p1.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000u32).collect::<Vec<_>>());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }
}
