//! `wasgd` — CLI launcher for the WASGD/WASGD+ training coordinator.
//!
//! ```text
//! wasgd run --dataset mnist --algo wasgd+ --p 8 --tau 1000 --epochs 2
//! wasgd compare --dataset tiny --p 4            # all schemes, one table
//! wasgd calibrate --variant mnist_mlp           # measure step time
//! wasgd list                                    # algorithms & datasets
//! ```

use std::path::PathBuf;

use anyhow::{bail, Result};

use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::synth::DatasetKind;
use wasgd::metrics::{format_table, write_csv};
use wasgd::runtime::{backend_for_variant, Backend as _};
use wasgd::util::Args;

const USAGE: &str = "\
wasgd — Weighted Aggregating SGD for parallel deep learning

USAGE:
  wasgd run       [--dataset D] [--algo A] [--p N] [--tau N] [--beta F]
                  [--a-tilde F] [--m N] [--c N] [--lr F] [--epochs F]
                  [--eval-every N] [--seed N] [--backups N] [--variant V]
                  [--artifacts DIR] [--backend B] [--target-loss F]
                  [--out FILE.csv] [--save-checkpoint DIR]
  wasgd compare   (same flags; runs every algorithm)
  wasgd calibrate [--variant V] [--artifacts DIR] [--backend B] [--reps N]
  wasgd list

datasets:   tiny mnist fashion cifar10 cifar100
algorithms: sgd spsgd easgd omwu mmwu wasgd wasgd+ wasgd+async
backends:   auto native pjrt   (auto prefers pjrt artifacts when present)
";

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let dataset_s = args.str_flag("dataset", "tiny");
    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let mut cfg = ExperimentConfig::paper_preset(dataset);

    let algo_s = args.str_flag("algo", "wasgd+");
    cfg.algo = AlgoKind::parse(&algo_s)
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm {algo_s:?}"))?;
    cfg.artifacts_root = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    if let Some(v) = args.opt_str("variant") {
        cfg.variant = v;
    }
    let backend_s = args.str_flag("backend", "auto");
    cfg.backend = BackendKind::parse(&backend_s)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_s:?}"))?;
    cfg.p = args.num_flag("p", 4usize)?;
    cfg.backups = args.num_flag("backups", 1usize)?;
    if let Some(v) = args.opt_num::<usize>("tau")? {
        cfg.tau = v;
    }
    if let Some(v) = args.opt_num::<f32>("beta")? {
        cfg.beta = v;
    }
    if let Some(v) = args.opt_num::<f32>("a-tilde")? {
        cfg.a_tilde = v;
    }
    if let Some(v) = args.opt_num::<usize>("m")? {
        cfg.m = v;
    }
    if let Some(v) = args.opt_num::<usize>("c")? {
        cfg.c = v;
    }
    if let Some(v) = args.opt_num::<f32>("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = args.opt_num::<usize>("eval-every")? {
        cfg.eval_every = v;
    }
    cfg.epochs = args.num_flag("epochs", 2.0f64)?;
    cfg.seed = args.num_flag("seed", 42u64)?;
    cfg.target_loss = args.opt_num::<f64>("target-loss")?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let out_path = args.opt_str("out");
    let ckpt_dir = args.opt_str("save-checkpoint");
    args.finish()?;
    eprintln!(
        "running {} on {} (p={}, τ={}, β={}, ã={}, m={}, η={})",
        cfg.algo.name(),
        cfg.dataset.name(),
        cfg.p,
        cfg.tau,
        cfg.beta,
        cfg.a_tilde,
        cfg.m,
        cfg.lr
    );
    let out = run_experiment_full(&cfg)?;
    for r in &out.log.records {
        println!(
            "iter {:>7}  epoch {:>6.2}  sim {:>9.3}s  train_loss {:>8.4}  \
             train_err {:>6.3}  test_loss {:>8.4}  test_err {:>6.3}",
            r.iteration, r.epoch, r.sim_time_s, r.train_loss, r.train_error, r.test_loss, r.test_error
        );
    }
    eprintln!(
        "comm {:.3}s sim, wait {:.3}s sim, {} kernel execs, orders kept/redrawn {}/{}",
        out.comm_time_s, out.wait_time_s, out.exec_count, out.orders_kept, out.orders_redrawn
    );
    if let Some(path) = out_path {
        write_csv(&path, std::slice::from_ref(&out.log))?;
        eprintln!("wrote {path}");
    }
    if let Some(dir) = ckpt_dir {
        out.to_checkpoint().save(std::path::Path::new(&dir))?;
        eprintln!("checkpoint saved to {dir}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = config_from(args)?;
    let out_path = args.opt_str("out");
    args.finish()?;
    let mut rows = Vec::new();
    let mut logs = Vec::new();
    for algo in AlgoKind::ALL {
        let mut cfg = base.clone();
        cfg.algo = algo;
        if algo == AlgoKind::WasgdPlusAsync && cfg.backups == 0 {
            cfg.backups = 1;
        }
        eprintln!("… {}", algo.name());
        let out = run_experiment_full(&cfg)?;
        rows.push((algo.name().to_string(), out.log.final_train_loss()));
        logs.push(out.log);
    }
    print!("{}", format_table("final train loss (lower is better)", &rows, ""));
    if let Some(path) = out_path {
        write_csv(&path, &logs)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let variant = args.str_flag("variant", "tiny_mlp");
    let artifacts = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    let backend_s = args.str_flag("backend", "auto");
    let kind = BackendKind::parse(&backend_s)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_s:?}"))?;
    let reps = args.num_flag("reps", 20usize)?;
    args.finish()?;
    let engine = backend_for_variant(&artifacts, &variant, kind)?;
    let t = engine.calibrate_step_time(reps)?;
    println!(
        "{variant} [{}]: {:.3} ms/step  (D={}, batch={})",
        engine.name(),
        t * 1e3,
        engine.manifest().param_count,
        engine.manifest().batch
    );
    Ok(())
}

fn cmd_list() {
    println!("algorithms:");
    for a in AlgoKind::ALL {
        println!("  {}", a.name());
    }
    println!("datasets (→ default model variant / paper preset):");
    for d in [
        DatasetKind::Tiny,
        DatasetKind::MnistLike,
        DatasetKind::FashionLike,
        DatasetKind::Cifar10Like,
        DatasetKind::Cifar100Like,
    ] {
        let cfg = ExperimentConfig::paper_preset(d);
        println!(
            "  {:<9} → {:<13} η={} τ={} β={} T={}",
            d.name(),
            cfg.variant,
            cfg.lr,
            cfg.tau,
            cfg.beta,
            cfg.temperature()
        );
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "calibrate" => cmd_calibrate(&args),
        "list" => {
            cmd_list();
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}
