//! `wasgd` — CLI launcher for the WASGD/WASGD+ training coordinator.
//!
//! ```text
//! wasgd run --dataset mnist --algo wasgd+ --p 8 --tau 1000 --epochs 2
//! wasgd run --dataset tiny --fabric tcp --p 4       # 4 real OS processes
//! wasgd compare --dataset tiny --p 4            # all schemes, one table
//! wasgd serve --listen 0.0.0.0:7777 --workers 4 # rendezvous node
//! wasgd worker --connect host:7777              # one remote worker
//! wasgd calibrate --variant mnist_mlp           # measure step time
//! wasgd run --dataset tiny --journal run.jrn    # event-sourced journal
//! wasgd replay run.jrn                          # bit-exact verification
//! wasgd list                                    # algorithms & datasets
//! ```

use std::io::Write as _;
use std::net::TcpListener;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use wasgd::checkpoint::Checkpoint;
use wasgd::cluster::fabric::Topology;
use wasgd::cluster::tcp::{self, ServeOptions, ServeOutcome};
use wasgd::cluster::wire::WireEncoding;
use wasgd::config::{AlgoKind, BackendKind, ExperimentConfig, FabricKind};
use wasgd::coordinator::run_experiment_full;
use wasgd::data::source::{DataPipeline, SourceKind};
use wasgd::data::synth::DatasetKind;
use wasgd::journal::replay::{self, ReplayOptions};
use wasgd::journal::tail::WatchState;
use wasgd::journal::{digest_cohort, format_event, Event, EventSink as _, JournalWriter};
use wasgd::metrics::{format_table, write_csv};
use wasgd::runtime::{backend_for_variant, Backend as _};
use wasgd::util::Args;

const USAGE: &str = "\
wasgd — Weighted Aggregating SGD for parallel deep learning

USAGE:
  wasgd run       [--dataset D] [--algo A] [--p N] [--tau N] [--beta F]
                  [--a-tilde F] [--m N] [--c N] [--lr F] [--epochs F]
                  [--eval-every N] [--seed N] [--backups N] [--variant V]
                  [--artifacts DIR] [--backend B] [--threads N]
                  [--data-dir DIR] [--source auto|synth|idx|cifar]
                  [--fabric sim|tcp] [--encoding f32|qi8|topk:R]
                  [--topology full|ring|gossip:N]
                  [--target-loss F] [--out FILE.csv] [--save-checkpoint DIR]
                  [--resume DIR] [--journal FILE]
                  [--elastic] [--heartbeat-ms N] [--min-workers N]
                  [--max-workers N]
  wasgd compare   (same flags; runs every algorithm on the sim fabric)
  wasgd serve     --listen ADDR [--workers P] [--encoding f32|qi8|topk:R]
                  [--topology full|ring|gossip:N]
                  [--save-checkpoint DIR] [--resume DIR] [--journal FILE]
                  [--elastic] [--heartbeat-ms N] [--min-workers N]
                  [--max-workers N] (+ run flags)
  wasgd worker    --connect ADDR [--threads N] [--artifacts DIR]
                  [--data-dir DIR] [--journal BASE]
  wasgd replay    JOURNAL [--inspect] [--data-dir DIR]
  wasgd watch     JOURNAL
  wasgd calibrate [--variant V] [--artifacts DIR] [--backend B] [--reps N]
                  [--threads N]
  wasgd list

datasets:   tiny mnist fashion cifar10 cifar100
algorithms: sgd spsgd easgd omwu mmwu wasgd wasgd+ wasgd+async
backends:   auto native pjrt   (auto prefers pjrt artifacts when present)
threads:    intra-op GEMM threads per worker backend (default 1; 0 = all
            cores). Kernel outputs are bit-identical at every value, so
            --threads trades wall-clock only — never the science.

data sources (--source, default auto; see docs/DATA.md):
  --dataset selects the family, --data-dir DIR points at real downloaded
  files: MNIST/Fashion-MNIST as the four IDX ubyte files, CIFAR-10/100
  as the python-version .bin record files (probed in DIR and
  DIR/<dataset>/). With files present `auto` trains on them, normalised
  with the corpus' published mean/std; otherwise it falls back to the
  deterministic synthetic analogue with a pointed message. `synth`,
  `idx`, `cifar` force a provider (forced real sources error when the
  files are missing instead of falling back). On --fabric tcp the
  resolved source ships to every worker in the wire config, so the
  whole cohort trains on identical data.

fabrics (--fabric, default sim):
  sim   deterministic in-process simulation: virtual clocks + the explicit
        cluster cost model; every scheme; what the figures use.
  tcp   real multi-process training: `run --fabric tcp` spawns p `wasgd
        worker` OS processes against an in-process rendezvous (or run
        `serve`/`worker` by hand across machines). Each process owns its
        own engine; (theta, h) panels are peer-relayed through the
        rendezvous and every worker applies the Eq. 10+13 update locally
        — no center variable. With the default lossless f32 encoding the
        final parameters match --fabric sim bit for bit; --encoding qi8
        quantises panels to i8 (~4x less traffic, lossy); --encoding
        topk:R keeps the R-fraction largest-|v| coordinates per panel
        with per-worker error-feedback residuals (deterministically
        lossy: sim, threaded, and tcp still match bit for bit).

exchange topologies (--topology, default full; see docs/FABRIC.md):
  full       every round delivers all p panels (the Eq. 10/13 gather).
  ring       same cohort content delivered one neighbour hop at a time —
             bit-identical numerics to full under any encoding.
  gossip:N   each round every rank aggregates a seeded random sample of
             N peers plus itself; Eq. 10/13 weights renormalize over the
             received subset (wasgd/wasgd+/spsgd only).

elastic membership (--elastic, tcp only; see docs/FABRIC.md):
  the session advances through epochs with committed member sets:
  workers heartbeat every --heartbeat-ms (default 500), a crash or
  `Leave` cuts the epoch at its last published round instead of killing
  the cohort, and survivors plus any queued joiners re-form at the
  boundary from the committed anchor (re-sharded by the rank-stable
  shard rule). --min-workers (default 1) floors the cohort;
  --max-workers (serve/run, default p) caps growth. --save-checkpoint
  DIR also writes per-boundary anchors to DIR/epoch_NNNN (plus a
  terminal anchor on completion). A killed elastic session restarts
  with --resume DIR: the rendezvous reloads the latest anchor, seeds
  the first formation from its rows, and stitches the journal with a
  round-0 commit. A worker death during the finale re-forms the
  survivors instead of erroring. Each epoch journals as a
  self-contained segment, so `wasgd replay` verifies runs across
  membership changes and resume boundaries.

run journal (--journal, see docs/JOURNAL.md):
  --journal FILE appends a CRC-framed event log of the run: the full wire
  config + seed, one FNV-1a 64 digest of every rank's θ at every
  collective round, checkpoints, and the final cohort digest. The sim
  trainer and both real fabrics journal the identical stream on lossless
  f32 panels. On `worker`, --journal BASE writes BASE.rank<r>. Verify a
  journal bit for bit with `wasgd replay JOURNAL` (re-executes from the
  embedded config), print its timeline with `replay --inspect`, or tail
  a live run with `wasgd watch JOURNAL`.

backend → variant support:
  native  all built-in presets, MLP and CNN, zero artifacts:
          tiny_mlp mnist_mlp fashion_mlp tiny_cnn mnist_cnn
          cifar_cnn10 cifar_cnn100 cifar_cnn_paper
  pjrt    any variant with artifacts generated by `python -m compile.aot`
          (build with `--features pjrt`)
";

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let dataset_s = args.str_flag("dataset", "tiny");
    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let mut cfg = ExperimentConfig::paper_preset(dataset);

    cfg.data_dir = args.opt_str("data-dir").map(PathBuf::from);
    let source_s = args.str_flag("source", "auto");
    cfg.source = SourceKind::parse(&source_s)
        .ok_or_else(|| anyhow::anyhow!("unknown data source {source_s:?} (auto|synth|idx|cifar)"))?;

    let algo_s = args.str_flag("algo", "wasgd+");
    cfg.algo = AlgoKind::parse(&algo_s)
        .ok_or_else(|| anyhow::anyhow!("unknown algorithm {algo_s:?}"))?;
    cfg.artifacts_root = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    if let Some(v) = args.opt_str("variant") {
        cfg.variant = v;
    }
    let backend_s = args.str_flag("backend", "auto");
    cfg.backend = BackendKind::parse(&backend_s)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_s:?}"))?;
    let fabric_s = args.str_flag("fabric", "sim");
    cfg.fabric = FabricKind::parse(&fabric_s)
        .ok_or_else(|| anyhow::anyhow!("unknown fabric {fabric_s:?} (sim or tcp)"))?;
    cfg.p = args.num_flag("p", 4usize)?;
    cfg.backups = args.num_flag("backups", 1usize)?;
    cfg.threads = args.num_flag("threads", 1usize)?;
    if let Some(v) = args.opt_num::<usize>("tau")? {
        cfg.tau = v;
    }
    if let Some(v) = args.opt_num::<f32>("beta")? {
        cfg.beta = v;
    }
    if let Some(v) = args.opt_num::<f32>("a-tilde")? {
        cfg.a_tilde = v;
    }
    if let Some(v) = args.opt_num::<usize>("m")? {
        cfg.m = v;
    }
    if let Some(v) = args.opt_num::<usize>("c")? {
        cfg.c = v;
    }
    if let Some(v) = args.opt_num::<f32>("lr")? {
        cfg.lr = v;
    }
    if let Some(v) = args.opt_num::<usize>("eval-every")? {
        cfg.eval_every = v;
    }
    cfg.epochs = args.num_flag("epochs", 2.0f64)?;
    cfg.seed = args.num_flag("seed", 42u64)?;
    cfg.target_loss = args.opt_num::<f64>("target-loss")?;
    cfg.journal = args.opt_str("journal").map(PathBuf::from);
    cfg.elastic = args.bool_flag("elastic");
    cfg.heartbeat_ms = args.num_flag("heartbeat-ms", 500u64)?;
    cfg.min_workers = args.num_flag("min-workers", 1usize)?;
    let encoding_s = args.str_flag("encoding", "f32");
    cfg.encoding = WireEncoding::parse(&encoding_s).ok_or_else(|| {
        anyhow::anyhow!("unknown encoding {encoding_s:?} (f32, qi8, or topk:R with 0<R≤1)")
    })?;
    let topology_s = args.str_flag("topology", "full");
    cfg.topology = Topology::parse(&topology_s).ok_or_else(|| {
        anyhow::anyhow!("unknown topology {topology_s:?} (full, ring, or gossip:N with N≥1)")
    })?;
    Ok(cfg)
}

/// Build the rendezvous-side elastic options when `--elastic` is on.
/// `--max-workers` caps cohort growth (default: the initial p — leavers
/// can be replaced but the cohort never grows); `--save-checkpoint DIR`
/// doubles as the epoch-anchor directory.
fn elastic_from(
    cfg: &ExperimentConfig,
    args: &Args,
    ckpt_dir: Option<&str>,
) -> Result<Option<tcp::ElasticOptions>> {
    let max_workers = args.opt_num::<usize>("max-workers")?;
    if !cfg.elastic {
        if max_workers.is_some() {
            bail!("--max-workers sizes an elastic session; add --elastic");
        }
        return Ok(None);
    }
    Ok(Some(tcp::ElasticOptions {
        min_workers: cfg.min_workers,
        max_workers: max_workers.unwrap_or(cfg.p).max(cfg.p),
        heartbeat_ms: cfg.heartbeat_ms,
        anchor_dir: ckpt_dir.map(PathBuf::from),
    }))
}

fn resume_from(args: &Args) -> Result<Option<Checkpoint>> {
    args.opt_str("resume")
        .map(|dir| {
            // A plain checkpoint dir loads directly; an elastic anchor
            // root resolves to its latest DIR/epoch_NNNN/ anchor.
            wasgd::checkpoint::load_resume_dir(Path::new(&dir))
                .with_context(|| format!("loading resume checkpoint from {dir}"))
        })
        .transpose()
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    if cfg.fabric == FabricKind::Tcp {
        return cmd_run_tcp(cfg, args);
    }
    if args.opt_str("resume").is_some() {
        bail!("--resume restarts a tcp rendezvous; add --fabric tcp (or use `wasgd serve`)");
    }
    if cfg.elastic {
        bail!("--elastic is epoch-based membership for real workers; add --fabric tcp");
    }
    let out_path = args.opt_str("out");
    let ckpt_dir = args.opt_str("save-checkpoint");
    args.finish()?;
    // Resolve the data pipeline up front: surface the pointed message
    // (real files found / fallback to synth) before training starts,
    // and pin the concrete source so the run uses what was announced.
    let pipeline = DataPipeline::from_config(&cfg)?;
    if let Some(note) = pipeline.note() {
        eprintln!("{note}");
    }
    cfg.source = pipeline.source_kind();
    if let Some(jp) = &cfg.journal {
        eprintln!("journaling every collective round to {}", jp.display());
    }
    eprintln!(
        "running {} on {} [{}] (p={}, τ={}, β={}, ã={}, m={}, η={})",
        cfg.algo.name(),
        cfg.dataset.name(),
        cfg.source.name(),
        cfg.p,
        cfg.tau,
        cfg.beta,
        cfg.a_tilde,
        cfg.m,
        cfg.lr
    );
    let out = run_experiment_full(&cfg)?;
    for r in &out.log.records {
        println!(
            "iter {:>7}  epoch {:>6.2}  sim {:>9.3}s  train_loss {:>8.4}  \
             train_err {:>6.3}  test_loss {:>8.4}  test_err {:>6.3}",
            r.iteration, r.epoch, r.sim_time_s, r.train_loss, r.train_error, r.test_loss, r.test_error
        );
    }
    eprintln!(
        "comm {:.3}s sim, wait {:.3}s sim, {} kernel execs, orders kept/redrawn {}/{}",
        out.comm_time_s, out.wait_time_s, out.exec_count, out.orders_kept, out.orders_redrawn
    );
    if let Some(path) = out_path {
        write_csv(&path, std::slice::from_ref(&out.log))?;
        eprintln!("wrote {path}");
    }
    if let Some(dir) = ckpt_dir {
        let ck = out.to_checkpoint();
        ck.save(std::path::Path::new(&dir))?;
        journal_checkpoint(&cfg, &ck, Path::new(&dir))?;
        eprintln!("checkpoint saved to {dir}");
    }
    Ok(())
}

/// When the run is journaled, append a `CheckpointWritten` record so the
/// event log also names the durable artifacts the run produced.
fn journal_checkpoint(cfg: &ExperimentConfig, ck: &Checkpoint, dir: &Path) -> Result<()> {
    if let Some(jp) = &cfg.journal {
        let mut w = JournalWriter::append_to(jp)?;
        w.emit(&Event::CheckpointWritten {
            steps: ck.iteration,
            digest: digest_cohort(ck.workers.iter().map(|v| v.as_slice())),
            path: dir.display().to_string(),
        })?;
    }
    Ok(())
}

/// `wasgd run --fabric tcp`: an in-process rendezvous plus p spawned
/// `wasgd worker` OS processes on loopback — the one-command form of the
/// distributed topology.
fn cmd_run_tcp(cfg: ExperimentConfig, args: &Args) -> Result<()> {
    if args.opt_str("out").is_some() {
        bail!("--out records the simulated trainer's curve; use --fabric sim (or serve/worker)");
    }
    let ckpt_dir = args.opt_str("save-checkpoint");
    let encoding = cfg.encoding;
    let resume = resume_from(args)?;
    let elastic = elastic_from(&cfg, args, ckpt_dir.as_deref())?;
    args.finish()?;
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;

    let is_elastic = elastic.is_some();
    let listener = TcpListener::bind("127.0.0.1:0").context("binding the loopback rendezvous")?;
    let addr = listener.local_addr()?;
    eprintln!(
        "fabric tcp: rendezvous on {addr}, spawning {} worker processes ({} panels, {} topology{})",
        cfg.p,
        encoding.label(),
        cfg.topology.label(),
        if is_elastic { ", elastic" } else { "" }
    );
    let opts =
        ServeOptions { cfg: cfg.clone(), encoding, resume, journal: cfg.journal.clone(), elastic };
    let server = std::thread::spawn(move || tcp::serve(listener, &opts));

    let exe = std::env::current_exe().context("locating the wasgd binary for workers")?;
    let mut children = Vec::with_capacity(cfg.p);
    for _ in 0..cfg.p {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .arg("--connect")
            .arg(addr.to_string())
            .arg("--threads")
            .arg(cfg.threads.to_string())
            .arg("--artifacts")
            .arg(&cfg.artifacts_root);
        if let Some(jp) = &cfg.journal {
            // Each worker journals its own vantage point next to the
            // rendezvous log, suffixed `.rank<r>` once its rank is known.
            cmd.arg("--journal").arg(jp);
        }
        let child = cmd.spawn().context("spawning a worker process")?;
        children.push(child);
    }

    // Wait for the session, watching the children: a worker that dies
    // before (or without) connecting would otherwise leave the
    // rendezvous blocked in accept/relay forever.
    let mut reported = vec![false; children.len()];
    let outcome = loop {
        if server.is_finished() {
            break server.join().map_err(|_| anyhow::anyhow!("rendezvous thread panicked"))?;
        }
        let mut dead = None;
        for (i, child) in children.iter_mut().enumerate() {
            if let Some(status) = child.try_wait()? {
                if !status.success() && !reported[i] {
                    reported[i] = true;
                    dead = Some((i, status));
                }
            }
        }
        if let Some((i, status)) = dead {
            if is_elastic {
                // An elastic session absorbs the death at the next epoch
                // boundary; the survivors keep training.
                eprintln!(
                    "worker process {i} exited with {status}; continuing at the next \
                     epoch boundary"
                );
            } else {
                for child in children.iter_mut() {
                    let _ = child.kill();
                }
                for child in children.iter_mut() {
                    let _ = child.wait();
                }
                bail!("worker process {i} exited with {status} before the session completed");
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    };
    let mut failures = 0usize;
    for mut child in children {
        if !child.wait()?.success() {
            failures += 1;
        }
    }
    let outcome = outcome?;
    if failures > 0 {
        if is_elastic {
            eprintln!("{failures} worker process(es) died; the session completed without them");
        } else {
            bail!("{failures} worker process(es) exited with an error");
        }
    }
    print_serve_summary(&cfg, encoding, &outcome);
    if let Some(dir) = ckpt_dir {
        save_fabric_checkpoint(&cfg, &outcome, Path::new(&dir))?;
        eprintln!("checkpoint saved to {dir}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    cfg.fabric = FabricKind::Tcp;
    if let Some(w) = args.opt_num::<usize>("workers")? {
        cfg.p = w;
    }
    let listen = args.str_flag("listen", "127.0.0.1:0");
    let encoding = cfg.encoding;
    let resume = resume_from(args)?;
    let ckpt_dir = args.opt_str("save-checkpoint");
    let elastic = elastic_from(&cfg, args, ckpt_dir.as_deref())?;
    args.finish()?;
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;

    let listener =
        TcpListener::bind(&listen).with_context(|| format!("binding rendezvous on {listen}"))?;
    // Machine-parseable: scripts (and the loopback tests) read this line
    // to learn the port when --listen ends in :0.
    println!("listening on {}", listener.local_addr()?);
    std::io::stdout().flush().ok();
    eprintln!(
        "rendezvous for {} × {} on {} ({} panels, {} topology{}); waiting for workers…",
        cfg.p,
        cfg.algo.name(),
        cfg.dataset.name(),
        encoding.label(),
        cfg.topology.label(),
        if elastic.is_some() { ", elastic" } else { "" }
    );
    let opts =
        ServeOptions { cfg: cfg.clone(), encoding, resume, journal: cfg.journal.clone(), elastic };
    let outcome = tcp::serve(listener, &opts)?;
    print_serve_summary(&cfg, encoding, &outcome);
    if let Some(dir) = ckpt_dir {
        save_fabric_checkpoint(&cfg, &outcome, Path::new(&dir))?;
        eprintln!("checkpoint saved to {dir}");
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    let addr = args
        .opt_str("connect")
        .ok_or_else(|| anyhow::anyhow!("worker needs --connect ADDR (host:port)"))?;
    let threads = args.opt_num::<usize>("threads")?;
    let artifacts = args.opt_str("artifacts").map(PathBuf::from);
    let data_dir = args.opt_str("data-dir").map(PathBuf::from);
    let journal = args.opt_str("journal").map(PathBuf::from);
    args.finish()?;
    let out = tcp::run_remote_worker(&addr, artifacts, threads, data_dir, journal)?;
    eprintln!(
        "worker rank {} done: {} steps, {} boundaries, mean energy {:.4}, \
         sent {} B / received {} B",
        out.rank, out.steps, out.boundaries, out.mean_energy, out.bytes_sent, out.bytes_received
    );
    Ok(())
}

fn print_serve_summary(cfg: &ExperimentConfig, encoding: WireEncoding, out: &ServeOutcome) {
    println!(
        "session complete: {} local steps, {} rounds × p={} ({} panels)",
        out.steps,
        out.rounds,
        out.finals.len(),
        encoding.name()
    );
    for (rank, (h, theta)) in out.finals.iter().enumerate() {
        let peer = out.comm.peers.get(rank).copied().unwrap_or_default();
        println!(
            "  rank {rank}: mean energy {h:.4}, D={}, relayed {} B down / {} B up",
            theta.len(),
            peer.sent,
            peer.received
        );
    }
    println!(
        "relay traffic: {} B down, {} B up; ≈{:.3}s as ring all-gathers on the modelled link",
        out.comm.total_sent(),
        out.comm.total_received(),
        out.comm.estimated_allgather_s(&cfg.fabric_cost, out.rounds)
    );
}

fn save_fabric_checkpoint(cfg: &ExperimentConfig, out: &ServeOutcome, dir: &Path) -> Result<()> {
    let ck = Checkpoint {
        label: format!("{} [tcp]", cfg.label()),
        iteration: out.steps,
        epoch: cfg.epochs,
        sim_time_s: 0.0,
        workers: out.finals.iter().map(|(_, theta)| theta.clone()).collect(),
    };
    ck.save(dir)?;
    journal_checkpoint(cfg, &ck, dir)
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base = config_from(args)?;
    if base.fabric != FabricKind::Sim {
        bail!("compare sweeps every scheme through the simulated trainer; drop --fabric tcp");
    }
    if base.journal.is_some() {
        bail!("--journal records one run's event stream; compare sweeps every scheme — drop it");
    }
    let out_path = args.opt_str("out");
    args.finish()?;
    let mut rows = Vec::new();
    let mut logs = Vec::new();
    for algo in AlgoKind::ALL {
        let mut cfg = base.clone();
        cfg.algo = algo;
        if algo == AlgoKind::WasgdPlusAsync && cfg.backups == 0 {
            cfg.backups = 1;
        }
        eprintln!("… {}", algo.name());
        let out = run_experiment_full(&cfg)?;
        rows.push((algo.name().to_string(), out.log.final_train_loss()));
        logs.push(out.log);
    }
    print!("{}", format_table("final train loss (lower is better)", &rows, ""));
    if let Some(path) = out_path {
        write_csv(&path, &logs)?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// Read a bare boolean flag, reclaiming the journal path if the greedy
/// `--flag value` parser consumed it (`wasgd replay --inspect run.jrn`).
fn bare_flag(args: &Args, key: &str, reclaimed: &mut Option<String>) -> bool {
    match args.opt_str(key) {
        None => false,
        Some(v) if matches!(v.as_str(), "true" | "1" | "yes") => true,
        Some(v) => {
            if reclaimed.is_none() {
                *reclaimed = Some(v);
            }
            true
        }
    }
}

fn cmd_replay(args: &Args) -> Result<()> {
    let mut path = args.positional().get(1).cloned();
    let inspect = bare_flag(args, "inspect", &mut path);
    let verify = bare_flag(args, "verify", &mut path);
    let data_dir = args.opt_str("data-dir").map(PathBuf::from);
    args.finish()?;
    if inspect && verify {
        bail!("--inspect and --verify are mutually exclusive (--verify is the default)");
    }
    let path = PathBuf::from(
        path.ok_or_else(|| anyhow::anyhow!("replay needs a journal path: wasgd replay RUN.jrn"))?,
    );
    if inspect {
        print!("{}", replay::inspect(&path)?);
        return Ok(());
    }
    let report = replay::verify(&path, &ReplayOptions { data_dir })?;
    println!("{report}");
    Ok(())
}

fn cmd_watch(args: &Args) -> Result<()> {
    let path = args
        .positional()
        .get(1)
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("watch needs a journal path: wasgd watch RUN.jrn"))?;
    args.finish()?;
    let path = PathBuf::from(path);
    let mut state = WatchState::new();
    loop {
        let events = state.poll(&path)?;
        let mut finished = false;
        for ev in &events {
            println!("{}", format_event(ev));
            finished = finished || matches!(ev, Event::RunFinished { .. });
        }
        if finished {
            return Ok(());
        }
        std::io::stdout().flush().ok();
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let variant = args.str_flag("variant", "tiny_mlp");
    let artifacts = PathBuf::from(args.str_flag("artifacts", "artifacts"));
    let backend_s = args.str_flag("backend", "auto");
    let kind = BackendKind::parse(&backend_s)
        .ok_or_else(|| anyhow::anyhow!("unknown backend {backend_s:?}"))?;
    let reps = args.num_flag("reps", 20usize)?;
    let threads = args.num_flag("threads", 1usize)?;
    args.finish()?;
    let engine = backend_for_variant(&artifacts, &variant, kind, threads)?;
    let t = engine.calibrate_step_time(reps)?;
    println!(
        "{variant} [{}]: {:.3} ms/step  (D={}, batch={})",
        engine.name(),
        t * 1e3,
        engine.manifest().param_count,
        engine.manifest().batch
    );
    Ok(())
}

fn cmd_list() {
    println!("algorithms:");
    for a in AlgoKind::ALL {
        println!("  {}", a.name());
    }
    println!(
        "native variants (run with zero artifacts): {}",
        wasgd::runtime::Manifest::NATIVE_VARIANTS.join(" ")
    );
    println!("fabrics: sim (deterministic simulation)  tcp (real multi-process workers)");
    println!(
        "data sources: auto synth idx cifar (--source; real files via --data-dir, \
         see docs/DATA.md)"
    );
    println!("datasets (→ default model variant / paper preset):");
    for d in [
        DatasetKind::Tiny,
        DatasetKind::MnistLike,
        DatasetKind::FashionLike,
        DatasetKind::Cifar10Like,
        DatasetKind::Cifar100Like,
    ] {
        let cfg = ExperimentConfig::paper_preset(d);
        println!(
            "  {:<9} → {:<13} η={} τ={} β={} T={}",
            d.name(),
            cfg.variant,
            cfg.lr,
            cfg.tau,
            cfg.beta,
            cfg.temperature()
        );
    }
}

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "replay" => cmd_replay(&args),
        "watch" => cmd_watch(&args),
        "calibrate" => cmd_calibrate(&args),
        "list" => {
            cmd_list();
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}
