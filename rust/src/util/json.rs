//! Minimal JSON parser — substrate for reading artifact manifests.
//!
//! The offline build environment ships no serde facade, so we parse the
//! (machine-generated, trusted) `manifest.json` files with a small
//! recursive-descent parser. Supports the full JSON grammar minus
//! `\uXXXX` surrogate pairs (the manifests are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (JSON does not distinguish int from float).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// A parse failure with its byte position.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub pos: usize,
    /// Human-readable description.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if exactly representable.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialise to pretty-printed JSON (2-space indent, newline
    /// terminated). Inverse of [`Json::parse`] for every value this
    /// crate produces: non-finite numbers become `null` (JSON has no
    /// lexeme for them), integral numbers print without a fraction, and
    /// strings escape exactly the set the parser understands. Used by
    /// the bench harness to persist `BENCH_native.json`.
    pub fn serialize(&self) -> String {
        let mut out = String::new();
        self.write_value(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_value(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if !v.is_finite() {
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
                    out.push_str(&(*v as i64).to_string());
                } else {
                    out.push_str(&format!("{v:?}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write_value(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (key, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, key);
                    out.push_str(": ");
                    val.write_value(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// `obj.key` as usize or a descriptive error.
    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("manifest field {key:?} missing or not a usize"))
    }

    /// `obj.key` as a string or a descriptive error.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("manifest field {key:?} missing or not a string"))
    }

    /// `obj.key` as an array or a descriptive error.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest field {key:?} missing or not an array"))
    }
}

fn push_indent(out: &mut String, levels: usize) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            _ => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    _ => return Err(self.err("unsupported escape")),
                },
                Some(c) => s.push(c as char),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| ParseError { pos: start, msg: format!("bad number `{text}`") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"name":"m","param_count":10,"arr":[1,2,3],
               "layout":[{"name":"w","shape":[2,5]}],"flag":true,"none":null}"#,
        )
        .unwrap();
        assert_eq!(j.req_str("name").unwrap(), "m");
        assert_eq!(j.req_usize("param_count").unwrap(), 10);
        let arr = j.req_arr("arr").unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_usize(), Some(2));
        let layout = j.req_arr("layout").unwrap();
        assert_eq!(layout[0].req_str("name").unwrap(), "w");
        assert_eq!(j.get("flag"), Some(&Json::Bool(true)));
        assert_eq!(j.get("none"), Some(&Json::Null));
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn parses_strings_with_escapes() {
        let j = Json::parse(r#""a\nb\"c""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\"c"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn serialize_roundtrips() {
        let src = r#"{"a":[1,2.5,-3e-7],"b":{"c":"x\"y\n","d":true,"e":null},"f":[],"g":{}}"#;
        let v = Json::parse(src).unwrap();
        let text = v.serialize();
        assert!(text.ends_with('\n'));
        let back = Json::parse(text.trim_end()).unwrap();
        assert_eq!(back, v);
        // Integral floats print without a fraction; non-finite → null.
        assert_eq!(Json::Num(42.0).serialize(), "42\n");
        assert_eq!(Json::Num(f64::NAN).serialize(), "null\n");
    }

    #[test]
    fn nested_structures() {
        let j = Json::parse(r#"[[1,[2]],{"x":[{"y":0}]}]"#).unwrap();
        let outer = j.as_arr().unwrap();
        assert_eq!(outer[0].as_arr().unwrap()[1].as_arr().unwrap()[0].as_usize(), Some(2));
    }
}
