//! Tiny CLI argument parser — substrate replacing `clap` in the offline
//! build. Supports `--flag value`, `--flag=value`, bare `--flag` (bool),
//! and positional arguments; unknown flags are an error so typos don't
//! silently fall through to defaults.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: `--flag value` pairs plus positionals.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    /// Flags the caller has read (for unknown-flag detection).
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `std::env::args()` less
    /// the program name in production.
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Self> {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(name.to_string(), v);
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(tok);
            }
        }
        Ok(Self { flags, positional, seen: Default::default() })
    }

    /// Parse from `std::env::args()` (program name skipped).
    pub fn parse_env() -> Result<Self> {
        Self::parse_from(std::env::args().skip(1))
    }

    /// The positional (non-flag) arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// String flag with default.
    pub fn str_flag(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    /// Parsed numeric flag with default.
    pub fn num_flag<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Optional numeric flag.
    pub fn opt_num<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.flags.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    /// Mark `key` as recognised without reading it. For flags injected
    /// by wrappers — e.g. the bare `--bench` cargo appends when running
    /// `harness = false` bench binaries — that would otherwise trip the
    /// unknown-flag check in [`Args::finish`].
    pub fn accept(&self, key: &str) {
        self.mark(key);
    }

    /// Boolean presence flag.
    pub fn bool_flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    /// Call after all flags are read: errors on unknown flags.
    pub fn finish(&self) -> Result<()> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|s| s == k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_flag_styles() {
        let a = args(&["run", "--p", "8", "--beta=0.7", "--verbose", "--tau", "100"]);
        assert_eq!(a.positional(), &["run".to_string()]);
        assert_eq!(a.num_flag("p", 1usize).unwrap(), 8);
        assert_eq!(a.num_flag("beta", 1.0f32).unwrap(), 0.7);
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.num_flag("tau", 0usize).unwrap(), 100);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_and_missing() {
        let a = args(&[]);
        assert_eq!(a.num_flag("p", 4usize).unwrap(), 4);
        assert_eq!(a.opt_num::<f32>("beta").unwrap(), None);
        assert_eq!(a.str_flag("dataset", "tiny"), "tiny");
        assert!(!a.bool_flag("verbose"));
    }

    #[test]
    fn bad_number_errors() {
        let a = args(&["--p", "abc"]);
        assert!(a.num_flag("p", 1usize).is_err());
    }

    #[test]
    fn unknown_flag_detected() {
        let a = args(&["--typo", "1"]);
        let _ = a.num_flag("p", 1usize);
        assert!(a.finish().is_err());
    }

    #[test]
    fn accept_quiets_wrapper_flags() {
        // cargo appends `--bench` to harness = false bench binaries.
        let a = args(&["--bench", "--quick"]);
        assert!(a.bool_flag("quick"));
        assert!(a.finish().is_err(), "--bench unread must still error");
        let a = args(&["--bench", "--quick"]);
        a.accept("bench");
        assert!(a.bool_flag("quick"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn negative_number_as_value() {
        let a = args(&["--shift", "-3"]);
        // "-3" doesn't start with --, so it's consumed as the value.
        assert_eq!(a.num_flag("shift", 0i64).unwrap(), -3);
    }
}
