//! Small self-contained utilities replacing external crates in the
//! offline build: a JSON parser (`manifest.json`) and a CLI flag parser.

pub mod cli;
pub mod json;

pub use cli::Args;
pub use json::Json;
