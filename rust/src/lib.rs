//! # wasgd — Weighted Aggregating SGD for parallel deep learning
//!
//! A production-shaped reproduction of *"Weighted Aggregating Stochastic
//! Gradient Descent for Parallel Deep Learning"* (Guo, Xiao, Ye, Zhu;
//! 2020) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — `python/compile/kernels/`: MXU-tiled
//!   matmul, fused softmax-xent, and the paper's Boltzmann
//!   weighted-aggregation kernel (Eq. 10+13).
//! * **L2 (JAX, build time)** — `python/compile/model.py`: CNN/MLP
//!   classifiers with a flat-parameter ABI, lowered once to HLO text.
//! * **L3 (this crate, run time)** — the decentralized coordinator:
//!   seven parallel-SGD schemes, the sample-order search, the free
//!   loss-estimation windows, a simulated cluster, and the bench harness
//!   that regenerates every figure of the paper's evaluation.
//!
//! # Execution backends
//!
//! The aggregation protocol is numerics-agnostic, and the runtime makes
//! that explicit with a pluggable [`runtime::Backend`] seam. Two
//! implementations exist:
//!
//! * [`runtime::NativeEngine`] — a pure-Rust forward/backward for **all
//!   built-in variants, MLP and CNN** (a small layer IR: dense, 3×3 SAME
//!   conv lowered to im2col over the shared GEMM kernel, 2×2 max-pool,
//!   flatten) plus the Eq. 10+13 Boltzmann-aggregation kernel. Hermetic:
//!   a clean checkout builds and trains — including the paper's
//!   CIFAR-10/100 presets — with **no Python, no JAX, and no HLO
//!   artifacts** (`cargo build --release && cargo test` is fully
//!   self-contained). Initialisation and data synthesis run through the
//!   in-crate deterministic PRNG, so runs are bit-reproducible across
//!   hosts.
//! * `runtime::Engine` (cargo feature **`pjrt`**) — the PJRT executor
//!   for the Pallas-backed AOT artifacts lowered by `python/compile/`.
//!   Enable by uncommenting the `xla` dependency in `rust/Cargo.toml`
//!   (kept out of the default graph so hermetic builds never resolve
//!   it), building with `--features pjrt`, and generating artifacts
//!   (`python -m compile.aot`); Python never runs on the training path
//!   — artifacts are loaded through the PJRT C API (`xla` crate) and
//!   executed from rust.
//!
//! Selection is per-experiment via
//! [`config::BackendKind`]: `Auto` (the default) prefers PJRT when the
//! feature is compiled in *and* artifacts exist on disk, and falls back
//! to the native engine otherwise; `native`/`pjrt` force a provider
//! (CLI: `wasgd run --backend native …`). The parity suite
//! (`tests/native_parity.rs`) pins the native kernels — dense *and*
//! conv/pool — against the Python reference kernels' recorded fixtures
//! at ≤1e-5.
//!
//! | backend  | variants                                   | needs                  |
//! |----------|--------------------------------------------|------------------------|
//! | `native` | every built-in preset (`tiny_mlp`,         | nothing — hermetic     |
//! |          | `mnist_mlp`, `fashion_mlp`, `tiny_cnn`,    |                        |
//! |          | `mnist_cnn`, `cifar_cnn10`, `cifar_cnn100`,|                        |
//! |          | `cifar_cnn_paper`)                         |                        |
//! | `pjrt`   | any variant with lowered artifacts         | `--features pjrt` +    |
//! |          |                                            | `python -m compile.aot`|
//!
//! # Worker fabrics
//!
//! Orthogonal to the backend seam, [`config::FabricKind`] selects the
//! *collective substrate* (`wasgd run --fabric sim|tcp`):
//!
//! | fabric | substrate                                                   |
//! |--------|-------------------------------------------------------------|
//! | `sim`  | deterministic in-process simulation: virtual clocks + the   |
//! |        | explicit cluster cost model; every scheme; the figures'     |
//! |        | substrate ([`coordinator::Trainer`])                        |
//! | `tcp`  | real OS processes (`wasgd serve` / `wasgd worker`): a       |
//! |        | length-prefixed binary protocol ([`cluster::wire`], f32 or  |
//! |        | quantised-i8 panels) relays `(θ, h)` through a rendezvous   |
//! |        | node; every worker applies Eq. 10+13 locally — no center    |
//! |        | variable ([`cluster::tcp`])                                 |
//!
//! Both substrates drive the *same* decentralized worker loop
//! ([`cluster::fabric::run_fabric_worker`]) and the same
//! [`algorithms::CommPolicy`] boundary code as the simulated trainer, so
//! with lossless f32 panels a 4-process `--fabric tcp` run reproduces
//! `--fabric sim`'s final parameters **bit for bit**
//! (`tests/fabric_e2e.rs`).
//!
//! # Module map
//!
//! | module        | role                                                        |
//! |---------------|-------------------------------------------------------------|
//! | [`kernels`]   | blocked, multi-threaded f32 GEMM (packed panels, MR×NR      |
//! |               | micro-tiles) + the naive `reference` twin; bit-deterministic|
//! |               | across thread counts (`--threads`)                          |
//! | [`linalg`]    | host vector kernels (axpy, Boltzmann weights, norms)        |
//! | [`runtime`]   | `Backend` seam: native engine / PJRT artifacts              |
//! | [`algorithms`]| the paper's seven parallel-SGD schemes                      |
//! | [`coordinator`]| deterministic simulated trainer (the figures)              |
//! | [`cluster`]   | fabrics: simulated cost model, in-process threads, and the  |
//! |               | TCP wire protocol + rendezvous (`wire` / `fabric` / `tcp`)  |
//! | [`checkpoint`]| durable run snapshots (also the tcp fabric's resume format) |
//! | [`data`]      | pluggable `DataSource` pipeline: synth generator + real     |
//! |               | MNIST/CIFAR file loaders (`--data-dir`), normalisation,     |
//! |               | rank-stable sharding, streaming batch planner, §3.4 orders  |
//! | [`journal`]   | event-sourced run journal: CRC-framed on-disk event log,    |
//! |               | FNV-1a 64 panel digests, bit-exact `wasgd replay` verifier  |
//! | [`metrics`]   | run records, CSV sinks, per-peer comm byte counters         |
//! | [`bench`]     | micro-bench harness + the `BENCH_native.json` perf trajectory|
//!
//! Quick taste (see `examples/quickstart.rs` — no artifacts needed):
//!
//! ```no_run
//! use wasgd::config::{AlgoKind, ExperimentConfig};
//! use wasgd::coordinator::run_experiment;
//! use wasgd::data::synth::DatasetKind;
//!
//! let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
//! cfg.algo = AlgoKind::WasgdPlus;
//! cfg.p = 4;
//! let log = run_experiment(&cfg).unwrap();
//! println!("final loss {:.4}", log.final_train_loss());
//! ```

#![warn(missing_docs)]

pub mod algorithms;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod journal;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod util;

pub use config::{AlgoKind, ExperimentConfig};
pub use coordinator::run_experiment;
