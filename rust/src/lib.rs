//! # wasgd — Weighted Aggregating SGD for parallel deep learning
//!
//! A production-shaped reproduction of *"Weighted Aggregating Stochastic
//! Gradient Descent for Parallel Deep Learning"* (Guo, Xiao, Ye, Zhu;
//! 2020) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas, build time)** — `python/compile/kernels/`: MXU-tiled
//!   matmul, fused softmax-xent, and the paper's Boltzmann
//!   weighted-aggregation kernel (Eq. 10+13).
//! * **L2 (JAX, build time)** — `python/compile/model.py`: CNN/MLP
//!   classifiers with a flat-parameter ABI, lowered once to HLO text.
//! * **L3 (this crate, run time)** — the decentralized coordinator:
//!   seven parallel-SGD schemes, the sample-order search, the free
//!   loss-estimation windows, a simulated cluster, and the bench harness
//!   that regenerates every figure of the paper's evaluation.
//!
//! Python never runs on the training path: artifacts are loaded through
//! the PJRT C API (`xla` crate) and executed from rust.
//!
//! Quick taste (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use wasgd::config::{AlgoKind, ExperimentConfig};
//! use wasgd::coordinator::run_experiment;
//! use wasgd::data::synth::DatasetKind;
//!
//! let mut cfg = ExperimentConfig::paper_preset(DatasetKind::Tiny);
//! cfg.algo = AlgoKind::WasgdPlus;
//! cfg.p = 4;
//! let log = run_experiment(&cfg).unwrap();
//! println!("final loss {:.4}", log.final_train_loss());
//! ```

pub mod algorithms;
pub mod bench;
pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod util;

pub use config::{AlgoKind, ExperimentConfig};
pub use coordinator::run_experiment;
