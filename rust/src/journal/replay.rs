//! `wasgd replay`: re-execute a journaled run from its embedded wire
//! config and verify every recorded digest bit for bit.
//!
//! The verification contract rests on the repo's determinism pillars:
//!
//! * the simulated [`Trainer`] and both real fabrics (threaded, tcp)
//!   run the *same* loop and produce identical per-round panels on
//!   lossless f32 exchanges (`tests/fabric_e2e.rs`), so a fresh
//!   `--fabric sim` re-execution is a valid oracle for any f32 journal
//!   regardless of which substrate wrote it;
//! * everything stochastic derives from the seed in the wire config —
//!   replay does not need the original data shuffle, checkpoint files,
//!   or cluster, only the journal;
//! * the compute model's `sample_step` is purely *multiplicative* in
//!   `step_time_s`, so replay pinning a uniform small step time rescales
//!   every virtual clock by the same factor and preserves the async
//!   quorum ordering — journaled `wasgd+async` sim runs replay exactly
//!   even though the original used a calibrated step time;
//! * evaluation draws from its own child RNG stream and charges no
//!   simulated time, so replay can disable it without perturbing the
//!   training numerics.
//!
//! Digests cover every *deterministic* encoding: lossless f32, and
//! deterministically lossy top-k (the sparsifier and its error-feedback
//! residual are pure functions of the panel stream a replay
//! regenerates, so a lossy session's digests still verify bit for bit).
//!
//! Scope limits are surfaced as pointed errors, never wrong answers: a
//! `qi8` session records no digests (`--inspect` still works); a
//! *worker-scope* journal of a resumed session is not self-contained
//! (the worker only ever saw its own resume vector), and a worker-scope
//! journal of a *gossip* session carries only sampled subsets — in both
//! cases the rendezvous-side journal, which digests all p ranks every
//! round, is the verifiable one.
//!
//! [`Trainer`]: crate::coordinator::Trainer

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cluster::fabric::Topology;
use crate::cluster::wire::WireEncoding;
use crate::config::{ExperimentConfig, FabricKind};
use crate::coordinator::Trainer;
use crate::data::source::DataPipeline;
use crate::runtime::load_backend;

use super::{
    digest_cohort, digest_params, format_event, read_events, Event, MemorySink, Truncation,
    RANK_COHORT,
};

/// Knobs for a replay run.
#[derive(Debug, Default)]
pub struct ReplayOptions {
    /// Override the journal's `data_dir` — for verifying a journal on a
    /// machine whose real dataset files live elsewhere.
    pub data_dir: Option<PathBuf>,
}

/// The `RunStarted` header of one journal segment.
#[derive(Clone, Debug)]
pub struct SegmentHeader {
    /// Writer's vantage point ([`RANK_COHORT`] or a worker rank).
    pub rank: u32,
    /// Cohort size.
    pub p: u32,
    /// The run's base seed.
    pub seed: u64,
    /// Panel encoding of the journaled session.
    pub encoding: WireEncoding,
    /// Git revision at record time.
    pub git_rev: String,
    /// The embedded wire config.
    pub config_json: String,
    /// Resume vectors (empty for a fresh start).
    pub resume: Vec<Vec<f32>>,
}

/// One `PanelDigest` row.
#[derive(Clone, Copy, Debug)]
pub struct DigestRow {
    /// 1-based collective round.
    pub round: u64,
    /// The digested rank.
    pub rank: u32,
    /// FNV-1a 64 of the rank's contributed θ.
    pub digest: u64,
    /// The rank's windowed loss energy (bit-compared).
    pub loss: f32,
    /// Canonical cumulative communication bytes.
    pub comm_bytes: u64,
}

/// A segment's `RunFinished` row.
#[derive(Clone, Copy, Debug)]
pub struct Finish {
    /// Local steps per worker.
    pub steps: u64,
    /// Collective rounds crossed.
    pub rounds: u64,
    /// Final digest (cohort- or worker-scope, per the header's rank).
    pub final_digest: u64,
}

/// A segment's terminating `EpochCommitted` row: this epoch was cut at
/// `round` and the run continued in the *next* segment with the listed
/// survivors. Mutually exclusive with [`Finish`].
#[derive(Clone, Debug)]
pub struct Commit {
    /// Id of the epoch being opened by the commit.
    pub epoch: u64,
    /// Last fully published round of the committed (this) segment. A
    /// **stitched resume commit** — written when `--resume DIR` revives
    /// a killed elastic session — records round 0: the resume restarts
    /// from the last durable epoch anchor, discarding any rounds the
    /// dead session published after it.
    pub round: u64,
    /// Survivors' ranks *in this segment*, listed in their next-segment
    /// rank order — the cross-epoch anchor chain.
    pub members: Vec<u32>,
    /// `digest_cohort` over the next segment's resume rows (0 = the
    /// next epoch starts from the seed init).
    pub anchor_digest: u64,
    /// Human-readable boundary reason (who died/left/joined).
    pub reason: String,
}

/// One run segment: a `RunStarted` and everything recorded under it. A
/// stitched journal (resumed sessions append, elastic sessions emit one
/// segment per epoch) holds several, each self-contained and
/// independently verifiable.
#[derive(Clone, Debug)]
pub struct Segment {
    /// The segment's `RunStarted` header.
    pub header: SegmentHeader,
    /// Per-round digests, in emission order (round asc, rank asc).
    pub digests: Vec<DigestRow>,
    /// The `RunFinished`, when the segment completed.
    pub finished: Option<Finish>,
    /// The `EpochCommitted`, when the segment was cut at an elastic
    /// epoch boundary instead of finishing.
    pub committed: Option<Commit>,
    /// Index of the segment's first record in the journal.
    pub first_record: u64,
}

/// What a successful `--verify` proved.
#[derive(Clone, Copy, Debug, Default)]
pub struct VerifyReport {
    /// Run segments re-executed.
    pub segments: u64,
    /// Complete collective rounds verified.
    pub rounds: u64,
    /// Individual panel digests compared bit-exactly.
    pub digests: u64,
    /// Local SGD steps verified as run progress, summed over segments.
    /// A committed elastic epoch counts `committed_round × τ` — rounds
    /// published after the commit (or discarded by a stitched resume
    /// commit, which names round 0) are still re-executed and
    /// digest-checked, but count no progress.
    pub steps: u64,
    /// Elastic epoch boundaries whose anchor chain (committed panels →
    /// next epoch's resume rows) was verified.
    pub commits: u64,
}

impl fmt::Display for VerifyReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "journal verified: {} segment(s), {} round(s), {} digest(s) bit-exact, \
             {} step(s) re-executed",
            self.segments, self.rounds, self.digests, self.steps
        )?;
        if self.commits > 0 {
            write!(f, ", {} epoch boundary(ies) chained", self.commits)?;
        }
        Ok(())
    }
}

/// Group a journal's event stream into run [`Segment`]s. Events between
/// a segment's `RunFinished` and the next `RunStarted` (a
/// `CheckpointWritten` appended by the CLI, say) stay with the finished
/// segment; digests after a finish, or any event before the first
/// `RunStarted`, are malformed.
pub fn segments(events: &[Event]) -> Result<Vec<Segment>> {
    let mut segs: Vec<Segment> = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        match ev {
            Event::RunStarted { rank, p, seed, encoding, git_rev, config_json, resume } => {
                segs.push(Segment {
                    header: SegmentHeader {
                        rank: *rank,
                        p: *p,
                        seed: *seed,
                        encoding: *encoding,
                        git_rev: git_rev.clone(),
                        config_json: config_json.clone(),
                        resume: resume.clone(),
                    },
                    digests: Vec::new(),
                    finished: None,
                    committed: None,
                    first_record: i as u64,
                });
            }
            Event::PanelDigest { round, rank, digest, loss, comm_bytes } => {
                let seg = segs
                    .last_mut()
                    .ok_or_else(|| anyhow!("record #{i}: PanelDigest before any RunStarted"))?;
                ensure!(
                    seg.finished.is_none(),
                    "record #{i}: PanelDigest after the segment's RunFinished"
                );
                ensure!(
                    seg.committed.is_none(),
                    "record #{i}: PanelDigest after the segment's EpochCommitted"
                );
                seg.digests.push(DigestRow {
                    round: *round,
                    rank: *rank,
                    digest: *digest,
                    loss: *loss,
                    comm_bytes: *comm_bytes,
                });
            }
            Event::RunFinished { steps, rounds, final_digest } => {
                let seg = segs
                    .last_mut()
                    .ok_or_else(|| anyhow!("record #{i}: RunFinished before any RunStarted"))?;
                ensure!(seg.finished.is_none(), "record #{i}: duplicate RunFinished");
                ensure!(
                    seg.committed.is_none(),
                    "record #{i}: RunFinished after the segment's EpochCommitted"
                );
                seg.finished =
                    Some(Finish { steps: *steps, rounds: *rounds, final_digest: *final_digest });
            }
            Event::EpochCommitted { epoch, round, members, anchor_digest, reason } => {
                let seg = segs
                    .last_mut()
                    .ok_or_else(|| anyhow!("record #{i}: EpochCommitted before any RunStarted"))?;
                ensure!(
                    seg.finished.is_none(),
                    "record #{i}: EpochCommitted after the segment's RunFinished"
                );
                ensure!(seg.committed.is_none(), "record #{i}: duplicate EpochCommitted");
                seg.committed = Some(Commit {
                    epoch: *epoch,
                    round: *round,
                    members: members.clone(),
                    anchor_digest: *anchor_digest,
                    reason: reason.clone(),
                });
            }
            Event::CheckpointWritten { .. } | Event::Membership { .. } => {
                ensure!(!segs.is_empty(), "record #{i}: event before any RunStarted");
            }
        }
    }
    Ok(segs)
}

struct SegStats {
    rounds: u64,
    digests: u64,
    steps: u64,
}

/// Re-execute every segment of the journal at `path` and verify each
/// recorded digest bit for bit. Digest verification always runs first;
/// only then does an incomplete tail (truncated mid-record, or a
/// segment that never reached `RunFinished`) turn into an error — so
/// the error message can state exactly how many complete rounds *did*
/// verify before the cut.
pub fn verify(path: &Path, opts: &ReplayOptions) -> Result<VerifyReport> {
    let (events, trunc) = read_events(path)?;
    let segs = segments(&events).with_context(|| format!("grouping journal {}", path.display()))?;
    ensure!(
        !segs.is_empty(),
        "journal {} holds no RunStarted record — nothing to replay",
        path.display()
    );
    let mut report = VerifyReport::default();
    let last = segs.len() - 1;
    for (i, seg) in segs.iter().enumerate() {
        let stats = verify_segment(seg, opts).with_context(|| {
            format!("segment #{i} (from journal record #{})", seg.first_record)
        })?;
        report.segments += 1;
        report.rounds += stats.rounds;
        report.digests += stats.digests;
        report.steps += stats.steps;
        if let Some(c) = &seg.committed {
            // An elastic epoch boundary: the segment was verified up to
            // its committed round above; now chain it onto the next
            // epoch's resume rows.
            ensure!(
                i < last,
                "segment #{i} of journal {} commits epoch {} but the journal ends before \
                 that epoch's RunStarted — truncated at the boundary",
                path.display(),
                c.epoch
            );
            verify_commit_chain(i, seg, c, &segs[i + 1])
                .with_context(|| format!("epoch boundary after segment #{i}"))?;
            report.commits += 1;
            continue;
        }
        if seg.finished.is_none() {
            if i == last {
                if let Some(Truncation { offset, record }) = trunc {
                    bail!(
                        "journal {} is truncated mid-record at byte {offset} (record \
                         #{record}): verified {} complete round(s) of segment #{i} \
                         bit-exactly before the cut",
                        path.display(),
                        stats.rounds
                    );
                }
                bail!(
                    "journal {} ends without RunFinished — a strict prefix of a run \
                     (verified {} complete round(s) of segment #{i} bit-exactly first)",
                    path.display(),
                    stats.rounds
                );
            }
            bail!(
                "segment #{i} of journal {} ends without RunFinished mid-file — the \
                 resumed session appended onto an unfinished run",
                path.display()
            );
        }
    }
    Ok(report)
}

/// Verify one elastic epoch boundary: the committed segment's last
/// published panels must be *exactly* the next segment's resume rows,
/// survivor by survivor — the anchor chain that makes a journal with
/// membership changes verifiable end to end.
///
/// `c.members[j]` is the rank (in `seg`) of the worker seated at rank
/// `j` of `next`; ranks `j ≥ members.len()` are fresh joiners, which
/// the rendezvous seeds with the first member's row.
///
/// A commit at round 0 with digests present is a **stitched resume
/// boundary**: the dead session's published-but-uncommitted rounds were
/// discarded and the next epoch re-seeds from the segment's own resume
/// rows (its last durable anchor), so the chain is checked against
/// those instead of a published round.
fn verify_commit_chain(i: usize, seg: &Segment, c: &Commit, next: &Segment) -> Result<()> {
    let max_round = seg.digests.iter().map(|d| d.round).max().unwrap_or(0);
    ensure!(
        c.round == max_round || c.round == 0,
        "EpochCommitted says round {} but the segment's digests reach round {max_round} \
         (only a stitched resume commit may name an earlier round, and it names 0)",
        c.round
    );
    let resume = &next.header.resume;
    if resume.is_empty() {
        // The next epoch starts from the seed init (the boundary hit
        // before any round committed in a fresh-init epoch).
        ensure!(
            c.anchor_digest == 0,
            "next segment resumes from the seed init but the commit records anchor \
             {:#018x}",
            c.anchor_digest
        );
        return Ok(());
    }
    ensure!(
        resume.len() == next.header.p as usize,
        "next segment welcomes p={} but carries {} resume row(s)",
        next.header.p,
        resume.len()
    );
    ensure!(
        c.members.len() <= resume.len(),
        "commit lists {} survivor(s) for a next epoch of p={}",
        c.members.len(),
        resume.len()
    );
    let got = digest_cohort(resume.iter().map(|v| v.as_slice()));
    ensure!(
        got == c.anchor_digest,
        "anchor digest mismatch at the boundary: commit records {:#018x}, the next \
         segment's resume rows digest to {got:#018x}",
        c.anchor_digest
    );
    for (j, row) in resume.iter().enumerate() {
        let d = digest_params(row);
        if let Some(&old) = c.members.get(j) {
            let want = if c.round > 0 {
                seg.digests
                    .iter()
                    .find(|r| r.round == c.round && r.rank == old)
                    .map(|r| r.digest)
                    .ok_or_else(|| {
                        anyhow!(
                            "segment #{i} has no digest for rank {old} at committed round {}",
                            c.round
                        )
                    })?
            } else {
                // Round 0: cut before any round published, or a
                // stitched resume boundary — either way survivors carry
                // this epoch's own resume rows (its anchor) forward
                // unchanged.
                let prev = &seg.header.resume;
                ensure!(
                    (old as usize) < prev.len(),
                    "commit names rank {old} but segment #{i} resumed only {} row(s)",
                    prev.len()
                );
                digest_params(&prev[old as usize])
            };
            ensure!(
                d == want,
                "anchor chain broken at next-epoch rank {j} (was rank {old}): committed \
                 panel digests to {want:#018x}, resume row to {d:#018x}",
            );
        } else {
            // A fresh joiner clones the first member's anchor row.
            let d0 = digest_params(&resume[0]);
            ensure!(
                d == d0,
                "joiner at next-epoch rank {j} carries row {d:#018x}, expected the first \
                 member's anchor {d0:#018x}",
            );
        }
    }
    Ok(())
}

fn verify_segment(seg: &Segment, opts: &ReplayOptions) -> Result<SegStats> {
    let h = &seg.header;
    // Deterministic encodings replay bit-exactly: lossless f32 trivially,
    // top-k because the sparsifier (and its error-feedback residual) is a
    // pure function of the panel stream the replay regenerates. qi8 is
    // the one encoding that records no digests at all.
    ensure!(
        matches!(h.encoding, WireEncoding::F32 | WireEncoding::TopK { .. }),
        "the session used the lossy {} panel encoding, which records no digests and \
         cannot replay bit-exactly; `wasgd replay --inspect` still shows the timeline",
        h.encoding.name()
    );
    if h.rank != RANK_COHORT {
        ensure!(
            h.resume.is_empty(),
            "this is rank {}'s journal of a RESUMED session — a worker only knows its \
             own resume vector, so the segment is not self-contained; replay the \
             rendezvous-side journal, which embeds all {} checkpoint vectors",
            h.rank,
            h.p
        );
    }
    let mut cfg = ExperimentConfig::from_wire_json_as(&h.config_json, FabricKind::Sim)
        .context("parsing the embedded wire config")?;
    if h.rank != RANK_COHORT {
        ensure!(
            !matches!(cfg.topology, Topology::Gossip { .. }),
            "this is rank {}'s journal of a GOSSIP session — a worker journals only the \
             sampled subset it received each round, which cannot prefix-match a full \
             re-execution; replay the rendezvous-side journal, which digests all {} \
             ranks every round",
            h.rank,
            h.p
        );
    }
    ensure!(
        cfg.seed == h.seed,
        "RunStarted records seed {} but the embedded config says {}",
        h.seed,
        cfg.seed
    );
    if let Some(dir) = &opts.data_dir {
        cfg.data_dir = Some(dir.clone());
    }
    // Replay overrides, all provably outside the training numerics:
    // evaluation uses its own RNG stream and charges no simulated time;
    // `sample_step` is multiplicative in `step_time_s`, so one uniform
    // value rescales every virtual clock identically (preserving the
    // async quorum order the original calibrated run produced).
    cfg.eval_every = usize::MAX;
    cfg.eval_batches = 1;
    cfg.compute.step_time_s = 1e-3;
    cfg.journal = None;
    let local_rev = crate::bench::git_rev();
    if local_rev != h.git_rev {
        eprintln!(
            "replay: journal was recorded at rev {} (this build: {local_rev}); the \
             digest comparison is still binding",
            h.git_rev
        );
    }

    let max_round = seg.digests.iter().map(|d| d.round).max().unwrap_or(0);
    let total_steps = match &seg.finished {
        Some(f) => f.steps as usize,
        // No RunFinished (a committed elastic epoch or a truncated
        // tail): re-run through the last journaled round. Every
        // journaled digest must replay bit-exactly — including rounds a
        // stitched resume commit later discarded — so the replay budget
        // follows the digests; the *verified-progress* accounting below
        // follows the commit record instead.
        None => max_round as usize * cfg.tau,
    };

    let engine = load_backend(&cfg)?;
    let dataset = DataPipeline::from_config(&cfg)?.load(engine.manifest())?;
    let mut mem = MemorySink::default();
    let out = {
        let mut tr = Trainer::new(cfg.clone(), engine.as_ref(), &dataset)?;
        if !h.resume.is_empty() {
            tr.resume_workers(&h.resume)?;
        }
        tr.set_journal(Box::new(&mut mem));
        tr.run_for(total_steps)?
    };

    let mut replayed: Vec<DigestRow> = Vec::new();
    let mut replayed_finish: Option<Finish> = None;
    for ev in &mem.events {
        match ev {
            Event::PanelDigest { round, rank, digest, loss, comm_bytes } => {
                replayed.push(DigestRow {
                    round: *round,
                    rank: *rank,
                    digest: *digest,
                    loss: *loss,
                    comm_bytes: *comm_bytes,
                });
            }
            Event::RunFinished { steps, rounds, final_digest } => {
                replayed_finish =
                    Some(Finish { steps: *steps, rounds: *rounds, final_digest: *final_digest });
            }
            _ => {}
        }
    }

    // The journal's digests must be a prefix of the replay's (equal when
    // the segment finished; a truncated tail may have been cut mid-round
    // while the replay always completes whole rounds).
    ensure!(
        replayed.len() >= seg.digests.len(),
        "replay produced only {} digest(s), journal records {}",
        replayed.len(),
        seg.digests.len()
    );
    if seg.finished.is_some() {
        ensure!(
            replayed.len() == seg.digests.len(),
            "replay produced {} digest(s), the finished journal records {}",
            replayed.len(),
            seg.digests.len()
        );
    }
    for (i, (want, got)) in seg.digests.iter().zip(&replayed).enumerate() {
        ensure!(
            want.round == got.round && want.rank == got.rank,
            "digest #{i}: journal says round {} rank {}, replay emitted round {} rank {}",
            want.round,
            want.rank,
            got.round,
            got.rank
        );
        ensure!(
            want.digest == got.digest,
            "θ digest mismatch at round {} rank {}: journal {:#018x}, replay {:#018x}",
            want.round,
            want.rank,
            want.digest,
            got.digest
        );
        ensure!(
            want.loss.to_bits() == got.loss.to_bits(),
            "loss mismatch at round {} rank {}: journal {} ({:#010x}), replay {} ({:#010x})",
            want.round,
            want.rank,
            want.loss,
            want.loss.to_bits(),
            got.loss,
            got.loss.to_bits()
        );
        ensure!(
            want.comm_bytes == got.comm_bytes,
            "comm_bytes mismatch at round {} rank {}: journal {}, replay {}",
            want.round,
            want.rank,
            want.comm_bytes,
            got.comm_bytes
        );
    }

    let mut steps_verified = 0;
    if let Some(f) = &seg.finished {
        let rf = replayed_finish.ok_or_else(|| anyhow!("replay never emitted RunFinished"))?;
        ensure!(
            rf.steps == f.steps,
            "journal records {} step(s) but replay ran {}",
            f.steps,
            rf.steps
        );
        ensure!(
            rf.rounds == f.rounds,
            "journal records {} round(s) but replay crossed {}",
            f.rounds,
            rf.rounds
        );
        if h.rank == RANK_COHORT {
            if f.final_digest == 0 {
                // Partial-finale sentinel: an elastic session that
                // completed from banked finals after a finale death has
                // no live cohort left to digest (see journal::Event::
                // RunFinished). Steps, rounds, and every per-round
                // digest above are still binding.
                eprintln!(
                    "replay: segment completed from banked finals (final_digest sentinel \
                     0); skipping the final cohort comparison, every per-round digest \
                     was verified"
                );
            } else {
                ensure!(
                    rf.final_digest == f.final_digest,
                    "final cohort digest mismatch: journal {:#018x}, replay {:#018x}",
                    f.final_digest,
                    rf.final_digest
                );
            }
        } else {
            let r = h.rank as usize;
            ensure!(
                r < out.final_workers.len(),
                "journal claims rank {r} but the replayed cohort has {} workers",
                out.final_workers.len()
            );
            let d = digest_params(&out.final_workers[r]);
            ensure!(
                d == f.final_digest,
                "rank {r} final θ digest mismatch: journal {:#018x}, replay {d:#018x}",
                f.final_digest
            );
        }
        steps_verified = f.steps;
    }
    if let Some(c) = &seg.committed {
        // A committed elastic epoch kept only the steps through its
        // committed round; anything published after it (a stitched
        // resume commit names round 0) was discarded at the boundary
        // and must not count as verified run progress.
        steps_verified = c.round * cfg.tau as u64;
    }

    Ok(SegStats {
        rounds: seg.digests.len() as u64 / u64::from(h.p.max(1)),
        digests: seg.digests.len() as u64,
        steps: steps_verified,
    })
}

/// Render the journal at `path` as a numbered human-readable timeline
/// (`wasgd replay --inspect`). Truncation is reported, not fatal.
pub fn inspect(path: &Path) -> Result<String> {
    let (events, trunc) = read_events(path)?;
    let mut out = String::new();
    for (i, ev) in events.iter().enumerate() {
        out.push_str(&format!("{i:>6}  {}\n", format_event(ev)));
    }
    if let Some(Truncation { offset, record }) = trunc {
        out.push_str(&format!(
            "        [journal truncated mid-record at byte {offset} (record #{record})]\n"
        ));
    }
    let runs = events.iter().filter(|e| matches!(e, Event::RunStarted { .. })).count();
    out.push_str(&format!("{} record(s), {} run segment(s)\n", events.len(), runs));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::MembershipChange;
    use super::*;

    fn started(rank: u32) -> Event {
        Event::RunStarted {
            rank,
            p: 2,
            seed: 1,
            encoding: WireEncoding::F32,
            git_rev: "r".into(),
            config_json: "{}".into(),
            resume: Vec::new(),
        }
    }

    #[test]
    fn segments_group_and_tolerate_trailing_checkpoints() {
        let evs = vec![
            started(RANK_COHORT),
            Event::Membership { epoch: 0, rank: 0, change: MembershipChange::Joined },
            Event::PanelDigest { round: 1, rank: 0, digest: 1, loss: 0.5, comm_bytes: 10 },
            Event::RunFinished { steps: 8, rounds: 1, final_digest: 2 },
            Event::CheckpointWritten { steps: 8, digest: 2, path: "ck".into() },
            started(RANK_COHORT),
            Event::PanelDigest { round: 1, rank: 0, digest: 3, loss: 0.25, comm_bytes: 10 },
        ];
        let segs = segments(&evs).unwrap();
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].digests.len(), 1);
        assert!(segs[0].finished.is_some());
        assert_eq!(segs[0].first_record, 0);
        assert_eq!(segs[1].first_record, 5);
        assert_eq!(segs[1].digests.len(), 1);
        assert!(segs[1].finished.is_none(), "second segment is an unfinished tail");
    }

    #[test]
    fn segments_reject_events_before_any_run() {
        let evs =
            vec![Event::PanelDigest { round: 1, rank: 0, digest: 1, loss: 0.5, comm_bytes: 1 }];
        let err = segments(&evs).unwrap_err();
        assert!(format!("{err}").contains("before any RunStarted"));
    }

    #[test]
    fn segments_reject_digests_after_finish() {
        let evs = vec![
            started(RANK_COHORT),
            Event::RunFinished { steps: 8, rounds: 1, final_digest: 2 },
            Event::PanelDigest { round: 2, rank: 0, digest: 1, loss: 0.5, comm_bytes: 1 },
        ];
        assert!(segments(&evs).is_err());
    }

    fn committed(epoch: u64, round: u64, members: Vec<u32>, anchor_digest: u64) -> Event {
        Event::EpochCommitted { epoch, round, members, anchor_digest, reason: "test".into() }
    }

    #[test]
    fn segments_attach_epoch_commits_and_reject_stragglers() {
        let evs = vec![
            started(RANK_COHORT),
            Event::PanelDigest { round: 1, rank: 0, digest: 1, loss: 0.5, comm_bytes: 10 },
            Event::Membership { epoch: 0, rank: 1, change: MembershipChange::Crashed },
            committed(1, 1, vec![0], 7),
            started(RANK_COHORT),
            Event::RunFinished { steps: 8, rounds: 1, final_digest: 2 },
        ];
        let segs = segments(&evs).unwrap();
        assert_eq!(segs.len(), 2);
        let c = segs[0].committed.as_ref().expect("first segment was committed");
        assert_eq!((c.epoch, c.round, c.anchor_digest), (1, 1, 7));
        assert_eq!(c.members, vec![0]);
        assert!(segs[0].finished.is_none());
        assert!(segs[1].committed.is_none());

        // A digest, finish, or second commit after the commit is malformed.
        for bad in [
            Event::PanelDigest { round: 2, rank: 0, digest: 1, loss: 0.5, comm_bytes: 1 },
            Event::RunFinished { steps: 8, rounds: 1, final_digest: 2 },
            committed(2, 1, vec![0], 7),
        ] {
            let evs = vec![started(RANK_COHORT), committed(1, 0, vec![], 0), bad];
            assert!(segments(&evs).is_err());
        }
    }

    #[test]
    fn commit_chain_checks_anchor_rows_survivor_by_survivor() {
        // Segment 0: p=2, committed at round 1 with rank 1 surviving
        // (seated at rank 0 of the next epoch) plus one fresh joiner.
        let row: Vec<f32> = vec![1.0, 2.0, 3.0];
        let other: Vec<f32> = vec![4.0, 5.0, 6.0];
        let resume = vec![row.clone(), row.clone()];
        let anchor = digest_cohort(resume.iter().map(|v| v.as_slice()));
        let seg0 = Segment {
            header: SegmentHeader {
                rank: RANK_COHORT,
                p: 2,
                seed: 1,
                encoding: WireEncoding::F32,
                git_rev: "r".into(),
                config_json: "{}".into(),
                resume: Vec::new(),
            },
            digests: vec![
                DigestRow {
                    round: 1,
                    rank: 0,
                    digest: digest_params(&other),
                    loss: 0.5,
                    comm_bytes: 1,
                },
                DigestRow {
                    round: 1,
                    rank: 1,
                    digest: digest_params(&row),
                    loss: 0.5,
                    comm_bytes: 1,
                },
            ],
            finished: None,
            committed: Some(Commit {
                epoch: 1,
                round: 1,
                members: vec![1],
                anchor_digest: anchor,
                reason: "rank 0 died".into(),
            }),
            first_record: 0,
        };
        let mut seg1 = Segment {
            header: SegmentHeader { p: 2, resume, ..seg0.header.clone() },
            digests: Vec::new(),
            finished: None,
            committed: None,
            first_record: 4,
        };
        let c = seg0.committed.clone().unwrap();
        verify_commit_chain(0, &seg0, &c, &seg1).expect("a well-formed chain verifies");

        // Survivor carrying the wrong row breaks the chain.
        seg1.header.resume[0] = other.clone();
        assert!(verify_commit_chain(0, &seg0, &c, &seg1).is_err());

        // Fresh-init boundary: empty resume demands a zero anchor digest.
        seg1.header.resume = Vec::new();
        let fresh = Commit { round: 0, members: vec![], anchor_digest: 0, ..c.clone() };
        let mut seg0_fresh = seg0.clone();
        seg0_fresh.digests.clear();
        verify_commit_chain(0, &seg0_fresh, &fresh, &seg1).expect("fresh-init chain verifies");
        let lying = Commit { anchor_digest: 9, ..fresh };
        assert!(verify_commit_chain(0, &seg0_fresh, &lying, &seg1).is_err());
    }

    #[test]
    fn commit_chain_accepts_a_stitched_resume_boundary_at_round_zero() {
        // The killed session published round 1 but the resume discarded
        // it: the stitched commit names round 0 and the revived epoch
        // carries the dead segment's own resume rows (its last durable
        // anchor) forward unchanged.
        let a: Vec<f32> = vec![1.0, 2.0];
        let b: Vec<f32> = vec![3.0, 4.0];
        let published: Vec<f32> = vec![9.0, 9.0];
        let resume = vec![a.clone(), b.clone()];
        let anchor = digest_cohort(resume.iter().map(|v| v.as_slice()));
        let seg0 = Segment {
            header: SegmentHeader {
                rank: RANK_COHORT,
                p: 2,
                seed: 1,
                encoding: WireEncoding::F32,
                git_rev: "r".into(),
                config_json: "{}".into(),
                resume: resume.clone(),
            },
            digests: vec![
                DigestRow {
                    round: 1,
                    rank: 0,
                    digest: digest_params(&published),
                    loss: 0.5,
                    comm_bytes: 1,
                },
                DigestRow {
                    round: 1,
                    rank: 1,
                    digest: digest_params(&published),
                    loss: 0.5,
                    comm_bytes: 1,
                },
            ],
            finished: None,
            committed: Some(Commit {
                epoch: 2,
                round: 0,
                members: vec![0, 1],
                anchor_digest: anchor,
                reason: "resumed from the epoch anchor".into(),
            }),
            first_record: 0,
        };
        let mut seg1 = Segment {
            header: SegmentHeader { resume, ..seg0.header.clone() },
            digests: Vec::new(),
            finished: None,
            committed: None,
            first_record: 5,
        };
        let c = seg0.committed.clone().unwrap();
        verify_commit_chain(0, &seg0, &c, &seg1).expect("stitched resume boundary verifies");

        // A survivor row that drifted from the anchor breaks the chain.
        seg1.header.resume[1] = published;
        assert!(verify_commit_chain(0, &seg0, &c, &seg1).is_err());

        // Only round 0 may disagree with the digests' max round.
        seg1.header.resume[1] = b;
        let wrong = Commit { round: 2, ..c };
        assert!(verify_commit_chain(0, &seg0, &wrong, &seg1).is_err());
    }

    /// Run a tiny journaled sim session (p=2, τ=8, 16 steps → 2 rounds)
    /// and return its event stream — raw material for rewriting into
    /// elastic journal shapes.
    fn journaled_sim_events() -> Vec<Event> {
        use crate::config::BackendKind;
        let mut cfg = ExperimentConfig::default();
        cfg.backend = BackendKind::Native;
        cfg.fabric = FabricKind::Sim;
        cfg.p = 2;
        cfg.tau = 8;
        cfg.m = 2;
        cfg.c = 1;
        cfg.eval_every = usize::MAX;
        cfg.compute.step_time_s = 1e-3;
        let engine = load_backend(&cfg).unwrap();
        let dataset = DataPipeline::from_config(&cfg).unwrap().load(engine.manifest()).unwrap();
        let mut mem = MemorySink::default();
        {
            let mut tr = Trainer::new(cfg.clone(), engine.as_ref(), &dataset).unwrap();
            tr.set_journal(Box::new(&mut mem));
            tr.run_for(16).unwrap();
        }
        mem.events
    }

    #[test]
    fn stitched_resume_journal_counts_only_committed_steps() {
        use super::super::{EventSink, JournalWriter};
        let events = journaled_sim_events();
        let (f_steps, f_rounds) = events
            .iter()
            .find_map(|e| match e {
                Event::RunFinished { steps, rounds, .. } => Some((*steps, *rounds)),
                _ => None,
            })
            .expect("sim run finished");
        let path =
            std::env::temp_dir().join(format!("wasgd_replay_stitch_{}.jrn", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut w = JournalWriter::create(&path).unwrap();
            // Segment A: the killed session — its RunFinished never
            // landed, but its published rounds are in the journal.
            for ev in &events {
                if !matches!(ev, Event::RunFinished { .. }) {
                    w.emit(ev).unwrap();
                }
            }
            // The resume stitches a round-0 commit (here a fresh-init
            // reseed: no surviving anchor rows) and runs to completion.
            w.emit(&Event::EpochCommitted {
                epoch: 1,
                round: 0,
                members: vec![],
                anchor_digest: 0,
                reason: "resumed from the epoch anchor at step 0".into(),
            })
            .unwrap();
            for ev in &events {
                w.emit(ev).unwrap();
            }
        }
        let report = verify(&path, &ReplayOptions::default()).unwrap();
        assert_eq!(report.segments, 2);
        assert_eq!(report.commits, 1);
        // Segment A's rounds replay bit-exactly (they're counted below)
        // but were discarded by the round-0 commit — only segment B's
        // steps are verified run progress.
        assert_eq!(report.steps, f_steps);
        assert_eq!(report.rounds, 2 * f_rounds);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn final_digest_sentinel_skips_only_the_cohort_comparison() {
        use super::super::{EventSink, JournalWriter};
        let events = journaled_sim_events();
        let write = |path: &Path, digest: u64| {
            let mut w = JournalWriter::create(path).unwrap();
            for ev in &events {
                match ev {
                    Event::RunFinished { steps, rounds, .. } => w
                        .emit(&Event::RunFinished {
                            steps: *steps,
                            rounds: *rounds,
                            final_digest: digest,
                        })
                        .unwrap(),
                    _ => w.emit(ev).unwrap(),
                }
            }
        };
        let path =
            std::env::temp_dir().join(format!("wasgd_replay_sentinel_{}.jrn", std::process::id()));
        let _ = std::fs::remove_file(&path);
        // 0 is the banked-finals sentinel: verification passes without
        // the final cohort comparison…
        write(&path, 0);
        let report = verify(&path, &ReplayOptions::default()).unwrap();
        assert_eq!(report.segments, 1);
        assert!(report.steps > 0);
        // …but any other wrong final digest still fails.
        write(&path, 0xdead_beef);
        assert!(verify(&path, &ReplayOptions::default()).is_err());
        let _ = std::fs::remove_file(&path);
    }
}
