//! Incremental journal tailing — the engine behind `wasgd watch`.
//!
//! A [`WatchState`] remembers how far into a journal file it has read
//! and, on each [`WatchState::poll`], picks up whatever bytes were
//! appended since, draining every *complete* record and buffering the
//! tail of a record still being written. `tail -F` semantics: a journal
//! that does not exist yet simply yields no events (the run may not
//! have opened it), while genuine corruption is a hard error.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::Path;

use anyhow::{Context, Result};

use super::{parse_record, Event};

/// Cursor over a growing journal file.
#[derive(Debug, Default)]
pub struct WatchState {
    offset: u64,
    pending: Vec<u8>,
    records: u64,
}

impl WatchState {
    /// A fresh cursor at the start of the journal.
    pub fn new() -> Self {
        Self::default()
    }

    /// Complete records drained so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Read any newly appended bytes from `path` and return the
    /// complete events they finish. An absent file yields `Ok(vec![])`;
    /// corrupt bytes are an error naming the offending record.
    pub fn poll(&mut self, path: &Path) -> Result<Vec<Event>> {
        let mut file = match File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => {
                return Err(e).with_context(|| format!("opening journal {}", path.display()))
            }
        };
        file.seek(SeekFrom::Start(self.offset))
            .with_context(|| format!("seeking journal {}", path.display()))?;
        let n = file
            .read_to_end(&mut self.pending)
            .with_context(|| format!("reading journal {}", path.display()))?;
        self.offset += n as u64;

        let mut events = Vec::new();
        loop {
            let parsed = parse_record(&self.pending)
                .with_context(|| format!("journal record #{}", self.records))?;
            match parsed {
                Some((ev, consumed)) => {
                    events.push(ev);
                    self.records += 1;
                    self.pending.drain(..consumed);
                }
                None => return Ok(events),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{encode_record, MembershipChange};
    use super::*;
    use std::io::Write;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("wasgd_tail_{name}_{}.jrn", std::process::id()))
    }

    #[test]
    fn missing_file_yields_nothing() {
        let mut w = WatchState::new();
        let path = tmp("missing");
        std::fs::remove_file(&path).ok();
        assert!(w.poll(&path).unwrap().is_empty());
        assert_eq!(w.records(), 0);
    }

    #[test]
    fn drains_records_as_they_are_appended() {
        let path = tmp("grow");
        let ev1 = Event::Membership { epoch: 0, rank: 1, change: MembershipChange::Joined };
        let ev2 = Event::RunFinished { steps: 8, rounds: 2, final_digest: 42 };
        let r1 = encode_record(&ev1);
        let r2 = encode_record(&ev2);

        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(&r1).unwrap();
        // ...and the first half of the next record, mid-write.
        f.write_all(&r2[..r2.len() / 2]).unwrap();
        f.flush().unwrap();

        let mut w = WatchState::new();
        let got = w.poll(&path).unwrap();
        assert_eq!(got, vec![ev1]);
        assert_eq!(w.records(), 1);

        // Nothing new: the half-record stays buffered, not re-read.
        assert!(w.poll(&path).unwrap().is_empty());

        f.write_all(&r2[r2.len() / 2..]).unwrap();
        f.flush().unwrap();
        let got = w.poll(&path).unwrap();
        assert_eq!(got, vec![ev2]);
        assert_eq!(w.records(), 2);

        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_surfaces_as_an_error() {
        let path = tmp("corrupt");
        let mut rec = encode_record(&Event::RunFinished { steps: 1, rounds: 1, final_digest: 7 });
        let mid = rec.len() - 6; // payload byte: CRC must catch it
        rec[mid] ^= 0x01;
        std::fs::write(&path, &rec).unwrap();
        let mut w = WatchState::new();
        let err = w.poll(&path).unwrap_err();
        assert!(format!("{err:#}").contains("record #0"));
        std::fs::remove_file(&path).ok();
    }
}
