//! Event-sourced run journal: an append-only, versioned, CRC-protected
//! record log every run can emit (`--journal PATH`), replayable and
//! verifiable after the fact with `wasgd replay`.
//!
//! The journal turns the repo's bit-exactness contract (`--fabric sim` ≡
//! threaded ≡ multi-process tcp on lossless f32 panels, pinned by
//! `tests/fabric_e2e.rs`) into a *universal* auditable property: every
//! τ-boundary writes one [`Event::PanelDigest`] per rank — an FNV-1a 64
//! digest of the contributed (pre-aggregation) θ plus the windowed loss
//! energy h — and `wasgd replay --verify` re-executes the run from the
//! embedded wire config and diffs every digest bit for bit. Sim runs,
//! threaded ranks, tcp workers, and the rendezvous node all journal the
//! *same* stream for the same run, so any of their journals verifies
//! against a fresh re-execution.
//!
//! Record framing follows the `wire.rs` discipline — magic, schema
//! version, explicit length, validation before allocation — plus a
//! CRC-32 per record (the wire relies on TCP for integrity; a file on
//! disk does not get that for free):
//!
//! ```text
//! ┌────────────┬─────────────┬─────────┬─────────────┬────────────┬─────────┬────────────┐
//! │ magic (4B) │ version u16 │ kind u8 │ reserved u8 │ len u32 LE │ payload │ crc u32 LE │
//! │  "WSGJ"    │   LE, = 1   │  Event  │     = 0     │  ≤ 256 MiB │  len B  │ IEEE, [0..)│
//! └────────────┴─────────────┴─────────┴─────────────┴────────────┴─────────┴────────────┘
//! ```
//!
//! The CRC covers header + payload, so *any* single-bit corruption of a
//! record is detected (CRC-32 catches all 1-bit errors) and reported
//! with the record index and byte offset. A journal truncated mid-record
//! (crash, `kill -9`, full disk) is not corruption: [`read_events`]
//! returns every complete record plus a [`Truncation`] marker, and
//! replay verifies the complete prefix before reporting the cut.
//!
//! Elastic sessions (`--elastic`) advance through *epochs*: each epoch
//! is journaled as its own self-contained segment (a `RunStarted` with
//! the epoch's member count and anchor vectors), [`Event::Membership`]
//! records every join/leave/crash at the epoch-local rank, and
//! [`Event::EpochCommitted`] terminates a non-final epoch's segment
//! with the committed round, the survivors' ranks, and the anchor
//! digest that seeds the next epoch — the chain `wasgd replay` verifies
//! across membership changes (see `docs/FABRIC.md`).

pub mod replay;
pub mod tail;

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::cluster::wire::{Panel, WireEncoding};

/// Journal record magic: the ASCII bytes `WSGJ` (J for journal — kept
/// distinct from the wire protocol's `WSGD` so a journal file is never
/// mistaken for a frame capture).
pub const JOURNAL_MAGIC: [u8; 4] = *b"WSGJ";
/// Journal schema version (bumped on incompatible record changes).
pub const JOURNAL_VERSION: u16 = 1;
/// Bytes of the fixed record header (magic + version + kind + reserved
/// + len); the trailing CRC-32 adds 4 more after the payload.
pub const RECORD_HEADER_LEN: usize = 12;
/// Upper bound on a record payload — rejects hostile/corrupt lengths
/// before any allocation happens. Sized for a `RunStarted` carrying a
/// large cohort's resume vectors (p · D · 4 bytes).
pub const MAX_RECORD_LEN: u32 = 1 << 28;
/// The `rank` a whole-cohort journal writes (the simulated [`Trainer`]
/// and the rendezvous node journal all p ranks' digests from one
/// vantage point); individual fabric workers write their real rank.
///
/// [`Trainer`]: crate::coordinator::Trainer
pub const RANK_COHORT: u32 = u32::MAX;

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial) of `bytes`. Detects all
/// single-bit and all 2-bit errors within a record — the corruption
/// model fault-injection tests exercise.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Incremental FNV-1a 64-bit hasher — the digest function of
/// [`Event::PanelDigest`]. Chosen for being trivially portable (pure
/// integer arithmetic, no dependencies) and stable across platforms.
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    /// The FNV-1a 64 offset basis.
    pub const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    /// The FNV-1a 64 prime.
    pub const PRIME: u64 = 0x100_0000_01b3;

    /// A fresh hasher at the offset basis.
    pub fn new() -> Self {
        Self(Self::OFFSET)
    }

    /// Absorb `bytes`.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(Self::PRIME);
        }
        self.0 = h;
    }

    /// The digest of everything absorbed so far.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut f = Fnv64::new();
    f.update(bytes);
    f.finish()
}

/// Digest of one parameter vector: FNV-1a 64 over the little-endian f32
/// bytes — exactly the bytes a lossless f32 [`Panel`] body carries, so
/// the tcp relay can digest raw wire bytes without decoding θ and land
/// on the identical value. Allocation-free.
pub fn digest_params(params: &[f32]) -> u64 {
    let mut f = Fnv64::new();
    for &x in params {
        f.update(&x.to_le_bytes());
    }
    f.finish()
}

/// Digest of a whole cohort's final state: one chained FNV-1a 64 state
/// over every rank's parameters in rank order (NOT a hash of per-rank
/// hashes — rank boundaries are implicit in the fixed element count).
pub fn digest_cohort<'a, I>(workers: I) -> u64
where
    I: IntoIterator<Item = &'a [f32]>,
{
    let mut f = Fnv64::new();
    for row in workers {
        for &x in row {
            f.update(&x.to_le_bytes());
        }
    }
    f.finish()
}

/// The canonical cumulative communication-byte count after `round`
/// collective rounds of `d`-parameter panels: `round` lossless f32
/// panel frames. Deterministic across fabrics and encodings by
/// construction (real measured traffic differs per substrate and rides
/// in [`CommCounters`](crate::metrics::CommCounters), not the journal),
/// which is what lets a sim re-execution verify a tcp journal's
/// `comm_bytes` field bit for bit.
pub fn canonical_comm_bytes(round: u64, d: usize) -> u64 {
    round * Panel::wire_len(WireEncoding::F32, d) as u64
}

/// How a participant's membership changed. Fixed-cohort sessions only
/// ever write `Joined` at epoch 0; elastic sessions write the full
/// join/leave/crash/finish stream at epoch-local ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipChange {
    /// The rank joined the cohort at this epoch.
    Joined,
    /// The rank left cleanly.
    Left,
    /// The rank was declared dead.
    Crashed,
    /// The rank exhausted its step budget and sent its `Final` panel.
    /// In an elastic session this cuts the epoch (a finished rank can
    /// join no further collectives); the rendezvous banks the final and
    /// re-forms the remaining ranks if any still owe theirs.
    Finished,
}

impl MembershipChange {
    fn as_u8(self) -> u8 {
        match self {
            MembershipChange::Joined => 0,
            MembershipChange::Left => 1,
            MembershipChange::Crashed => 2,
            MembershipChange::Finished => 3,
        }
    }

    fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            0 => MembershipChange::Joined,
            1 => MembershipChange::Left,
            2 => MembershipChange::Crashed,
            3 => MembershipChange::Finished,
            _ => return None,
        })
    }

    /// Human-readable name.
    pub fn name(&self) -> &'static str {
        match self {
            MembershipChange::Joined => "joined",
            MembershipChange::Left => "left",
            MembershipChange::Crashed => "crashed",
            MembershipChange::Finished => "finished",
        }
    }
}

/// One journal record — the event vocabulary of a run.
#[derive(Clone, Debug)]
pub enum Event {
    /// A run (or a resumed segment of one) began. Self-contained: the
    /// embedded wire config plus the resume vectors are everything
    /// `wasgd replay` needs to re-execute the segment.
    RunStarted {
        /// Writer's vantage point: a worker rank, or [`RANK_COHORT`]
        /// for a whole-cohort journal (sim trainer / rendezvous node).
        rank: u32,
        /// Cohort size whose digests this journal carries.
        p: u32,
        /// The run's base seed (duplicated from the config for cheap
        /// inspection).
        seed: u64,
        /// Panel encoding of the underlying session. Deterministic
        /// encodings (lossless `f32`, deterministically lossy `topk`)
        /// journal bit-exactly replayable digests; `qi8` journals are
        /// inspect-only.
        encoding: WireEncoding,
        /// `git rev-parse --short HEAD` at record time ("unknown"
        /// outside a work tree).
        git_rev: String,
        /// The full [`ExperimentConfig`](crate::config::ExperimentConfig)
        /// wire JSON — what replay re-executes from.
        config_json: String,
        /// Initial parameter vectors when the segment resumed from a
        /// checkpoint (all p ranks for a cohort journal; empty for a
        /// fresh start). Worker-scope journals of resumed sessions only
        /// know their own vector and are rejected by `--verify` with a
        /// pointer at the cohort journal.
        resume: Vec<Vec<f32>>,
    },
    /// One rank's contributed panel at one τ-boundary, as digested at
    /// the collective's entry (pre-aggregation).
    PanelDigest {
        /// 1-based collective round (boundary index).
        round: u64,
        /// The digested rank.
        rank: u32,
        /// [`digest_params`] of the rank's contributed θ.
        digest: u64,
        /// The rank's windowed loss energy h (raw bits preserved,
        /// NaN/∞ included).
        loss: f32,
        /// [`canonical_comm_bytes`] through this round.
        comm_bytes: u64,
    },
    /// A checkpoint directory was written (informational; replay does
    /// not diff these).
    CheckpointWritten {
        /// Local steps the checkpoint captures.
        steps: u64,
        /// [`digest_cohort`] of the checkpointed worker vectors.
        digest: u64,
        /// Where the checkpoint was saved.
        path: String,
    },
    /// One membership change (see [`MembershipChange`]). Fixed cohorts
    /// write `Joined` at epoch 0 per rank; elastic sessions write the
    /// full stream, with `rank` epoch-local.
    Membership {
        /// Membership epoch the change belongs to.
        epoch: u64,
        /// The (epoch-local) rank whose membership changed.
        rank: u32,
        /// What happened.
        change: MembershipChange,
    },
    /// The run segment completed.
    RunFinished {
        /// Total local SGD steps per worker.
        steps: u64,
        /// Collective rounds crossed.
        rounds: u64,
        /// Cohort journals: [`digest_cohort`] of every rank's final θ.
        /// Worker journals: [`digest_params`] of the writer's own θ.
        /// **0 is a sentinel**: an elastic session that completed from
        /// banked finals (every remaining rank crashed or left after
        /// the first `Final` panel of a partial finale) has no live
        /// cohort left to digest; verification checks steps, rounds,
        /// and every per-round digest but skips the final cohort
        /// comparison for such a segment.
        final_digest: u64,
    },
    /// An elastic epoch ended at a boundary: its segment is complete
    /// (this is a segment terminator, like [`Event::RunFinished`], but
    /// the run continues in the next segment at the new member set).
    EpochCommitted {
        /// Id of the epoch being *opened* (the terminated epoch + 1).
        epoch: u64,
        /// The collective round the ending epoch committed at (0 when
        /// it never completed a round).
        round: u64,
        /// Survivors' ranks *in the epoch that just ended*, in the rank
        /// order they take in the next epoch. New ranks ≥ `members.len()`
        /// in the next segment are fresh joiners.
        members: Vec<u32>,
        /// [`digest_cohort`] of the anchor the next epoch resumes from
        /// (0 when there is no anchor — a fresh-init restart).
        anchor_digest: u64,
        /// Human-readable reason (who died/left/joined, at what round).
        reason: String,
    },
}

/// Bitwise equality: f32 fields compare by bit pattern so NaN losses
/// and resume vectors round-trip as equal (the property proptests pin).
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        use Event::*;
        match (self, other) {
            (
                RunStarted { rank, p, seed, encoding, git_rev, config_json, resume },
                RunStarted {
                    rank: r2,
                    p: p2,
                    seed: s2,
                    encoding: e2,
                    git_rev: g2,
                    config_json: c2,
                    resume: v2,
                },
            ) => {
                rank == r2
                    && p == p2
                    && seed == s2
                    && encoding == e2
                    && git_rev == g2
                    && config_json == c2
                    && resume.len() == v2.len()
                    && resume.iter().zip(v2).all(|(a, b)| f32_bits_eq(a, b))
            }
            (
                PanelDigest { round, rank, digest, loss, comm_bytes },
                PanelDigest { round: r2, rank: k2, digest: d2, loss: l2, comm_bytes: b2 },
            ) => {
                round == r2
                    && rank == k2
                    && digest == d2
                    && loss.to_bits() == l2.to_bits()
                    && comm_bytes == b2
            }
            (
                CheckpointWritten { steps, digest, path },
                CheckpointWritten { steps: s2, digest: d2, path: p2 },
            ) => steps == s2 && digest == d2 && path == p2,
            (
                Membership { epoch, rank, change },
                Membership { epoch: e2, rank: r2, change: c2 },
            ) => epoch == e2 && rank == r2 && change == c2,
            (
                RunFinished { steps, rounds, final_digest },
                RunFinished { steps: s2, rounds: r2, final_digest: d2 },
            ) => steps == s2 && rounds == r2 && final_digest == d2,
            (
                EpochCommitted { epoch, round, members, anchor_digest, reason },
                EpochCommitted { epoch: e2, round: r2, members: m2, anchor_digest: a2, reason: s2 },
            ) => epoch == e2 && round == r2 && members == m2 && anchor_digest == a2 && reason == s2,
            _ => false,
        }
    }
}

fn f32_bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

impl Event {
    /// Human-readable event name (the record-kind vocabulary).
    pub fn name(&self) -> &'static str {
        match self {
            Event::RunStarted { .. } => "RunStarted",
            Event::PanelDigest { .. } => "PanelDigest",
            Event::CheckpointWritten { .. } => "CheckpointWritten",
            Event::Membership { .. } => "Membership",
            Event::RunFinished { .. } => "RunFinished",
            Event::EpochCommitted { .. } => "EpochCommitted",
        }
    }
}

fn put_str(s: &str, out: &mut Vec<u8>) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(v: &[f32], out: &mut Vec<u8>) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Little-endian payload cursor with truncation checks (the journal's
/// twin of the wire cursor; kept local so the two formats can evolve
/// independently).
struct Cur<'a> {
    b: &'a [u8],
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.b.len() >= n, "truncated payload: wanted {n} bytes, have {}", self.b.len());
        let (head, tail) = self.b.split_at(n);
        self.b = tail;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?).context("payload string is not UTF-8")?.to_string())
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let b = self.take(n.checked_mul(4).context("f32 vector length overflows")?)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn finish(&self) -> Result<()> {
        ensure!(self.b.is_empty(), "{} trailing bytes in payload", self.b.len());
        Ok(())
    }
}

fn encode_payload(ev: &Event) -> (u8, Vec<u8>) {
    match ev {
        Event::RunStarted { rank, p, seed, encoding, git_rev, config_json, resume } => {
            let resume_len: usize = resume.iter().map(|v| 4 + 4 * v.len()).sum();
            let mut out = Vec::with_capacity(24 + git_rev.len() + config_json.len() + resume_len);
            out.extend_from_slice(&rank.to_le_bytes());
            out.extend_from_slice(&p.to_le_bytes());
            out.extend_from_slice(&seed.to_le_bytes());
            match encoding {
                WireEncoding::F32 => out.push(0),
                WireEncoding::Qi8 => out.push(1),
                // Rate-bearing: the tag byte is followed by k_ppm, so a
                // replayed session reconstructs the exact sparsifier.
                WireEncoding::TopK { k_ppm } => {
                    out.push(2);
                    out.extend_from_slice(&k_ppm.to_le_bytes());
                }
            }
            put_str(git_rev, &mut out);
            put_str(config_json, &mut out);
            out.extend_from_slice(&(resume.len() as u32).to_le_bytes());
            for v in resume {
                put_f32s(v, &mut out);
            }
            (1, out)
        }
        Event::PanelDigest { round, rank, digest, loss, comm_bytes } => {
            let mut out = Vec::with_capacity(32);
            out.extend_from_slice(&round.to_le_bytes());
            out.extend_from_slice(&rank.to_le_bytes());
            out.extend_from_slice(&digest.to_le_bytes());
            out.extend_from_slice(&loss.to_le_bytes());
            out.extend_from_slice(&comm_bytes.to_le_bytes());
            (2, out)
        }
        Event::CheckpointWritten { steps, digest, path } => {
            let mut out = Vec::with_capacity(20 + path.len());
            out.extend_from_slice(&steps.to_le_bytes());
            out.extend_from_slice(&digest.to_le_bytes());
            put_str(path, &mut out);
            (3, out)
        }
        Event::Membership { epoch, rank, change } => {
            let mut out = Vec::with_capacity(13);
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&rank.to_le_bytes());
            out.push(change.as_u8());
            (4, out)
        }
        Event::RunFinished { steps, rounds, final_digest } => {
            let mut out = Vec::with_capacity(24);
            out.extend_from_slice(&steps.to_le_bytes());
            out.extend_from_slice(&rounds.to_le_bytes());
            out.extend_from_slice(&final_digest.to_le_bytes());
            (5, out)
        }
        Event::EpochCommitted { epoch, round, members, anchor_digest, reason } => {
            let mut out = Vec::with_capacity(32 + 4 * members.len() + reason.len());
            out.extend_from_slice(&epoch.to_le_bytes());
            out.extend_from_slice(&round.to_le_bytes());
            out.extend_from_slice(&(members.len() as u32).to_le_bytes());
            for &m in members {
                out.extend_from_slice(&m.to_le_bytes());
            }
            out.extend_from_slice(&anchor_digest.to_le_bytes());
            put_str(reason, &mut out);
            (6, out)
        }
    }
}

fn decode_payload(kind: u8, payload: &[u8]) -> Result<Event> {
    let mut cur = Cur::new(payload);
    let ev = match kind {
        1 => {
            let rank = cur.u32()?;
            let p = cur.u32()?;
            let seed = cur.u64()?;
            let encoding = match cur.u8()? {
                0 => WireEncoding::F32,
                1 => WireEncoding::Qi8,
                2 => WireEncoding::TopK { k_ppm: cur.u32()? },
                other => bail!("RunStarted names unknown panel encoding {other}"),
            };
            let git_rev = cur.str()?;
            let config_json = cur.str()?;
            let count = cur.u32()? as usize;
            ensure!(count <= 1 << 20, "implausible resume cohort size {count}");
            let mut resume = Vec::with_capacity(count.min(payload.len() / 4));
            for _ in 0..count {
                resume.push(cur.f32s()?);
            }
            Event::RunStarted { rank, p, seed, encoding, git_rev, config_json, resume }
        }
        2 => Event::PanelDigest {
            round: cur.u64()?,
            rank: cur.u32()?,
            digest: cur.u64()?,
            loss: cur.f32()?,
            comm_bytes: cur.u64()?,
        },
        3 => Event::CheckpointWritten {
            steps: cur.u64()?,
            digest: cur.u64()?,
            path: cur.str()?,
        },
        4 => Event::Membership {
            epoch: cur.u64()?,
            rank: cur.u32()?,
            change: MembershipChange::from_u8(cur.u8()?)
                .ok_or_else(|| anyhow::anyhow!("unknown membership change"))?,
        },
        5 => Event::RunFinished {
            steps: cur.u64()?,
            rounds: cur.u64()?,
            final_digest: cur.u64()?,
        },
        6 => {
            let epoch = cur.u64()?;
            let round = cur.u64()?;
            let count = cur.u32()? as usize;
            ensure!(count <= 1 << 20, "implausible committed member count {count}");
            let mut members = Vec::with_capacity(count.min(payload.len() / 4));
            for _ in 0..count {
                members.push(cur.u32()?);
            }
            let anchor_digest = cur.u64()?;
            let reason = cur.str()?;
            Event::EpochCommitted { epoch, round, members, anchor_digest, reason }
        }
        other => bail!("unknown journal event kind {other}"),
    };
    cur.finish()?;
    Ok(ev)
}

/// Serialise one event as a complete journal record (header + payload
/// + CRC-32 trailer).
pub fn encode_record(ev: &Event) -> Vec<u8> {
    let (kind, payload) = encode_payload(ev);
    let mut out = Vec::with_capacity(RECORD_HEADER_LEN + payload.len() + 4);
    out.extend_from_slice(&JOURNAL_MAGIC);
    out.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
    out.push(kind);
    out.push(0); // reserved
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Parse one record from the front of `buf`. Tri-state:
///
/// * `Ok(Some((event, consumed)))` — a complete, CRC-valid record;
/// * `Ok(None)` — `buf` holds a (possibly empty) strict prefix of a
///   record: more bytes are needed (tailing a growing file, or a
///   truncated journal);
/// * `Err` — the bytes are *corrupt*: bad magic / version / kind /
///   reserved byte / oversized length / CRC mismatch / malformed
///   payload. All header checks and the CRC run before the payload is
///   decoded, so nothing is allocated from attacker- or
///   corruption-controlled lengths.
pub fn parse_record(buf: &[u8]) -> Result<Option<(Event, usize)>> {
    if buf.len() < RECORD_HEADER_LEN {
        return Ok(None);
    }
    ensure!(
        buf[0..4] == JOURNAL_MAGIC,
        "bad record magic {:02x?} — not a wasgd journal record",
        &buf[0..4]
    );
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    ensure!(
        version == JOURNAL_VERSION,
        "journal schema v{version}, this build reads v{JOURNAL_VERSION}"
    );
    let kind = buf[6];
    ensure!((1..=6).contains(&kind), "unknown journal event kind {kind}");
    ensure!(buf[7] == 0, "reserved header byte is {:#04x}, expected 0", buf[7]);
    let len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]);
    ensure!(
        len <= MAX_RECORD_LEN,
        "record payload of {len} bytes exceeds the {MAX_RECORD_LEN} byte cap"
    );
    let total = RECORD_HEADER_LEN + len as usize + 4;
    if buf.len() < total {
        return Ok(None);
    }
    let crc_stored =
        u32::from_le_bytes([buf[total - 4], buf[total - 3], buf[total - 2], buf[total - 1]]);
    let crc_actual = crc32(&buf[..RECORD_HEADER_LEN + len as usize]);
    ensure!(
        crc_stored == crc_actual,
        "CRC mismatch (stored {crc_stored:#010x}, computed {crc_actual:#010x})"
    );
    let ev = decode_payload(kind, &buf[RECORD_HEADER_LEN..RECORD_HEADER_LEN + len as usize])?;
    Ok(Some((ev, total)))
}

/// Anything events can be emitted into: a [`JournalWriter`] on disk, a
/// [`MemorySink`] during replay.
pub trait EventSink {
    /// Record one event.
    fn emit(&mut self, ev: &Event) -> Result<()>;
}

impl<S: EventSink + ?Sized> EventSink for &mut S {
    fn emit(&mut self, ev: &Event) -> Result<()> {
        (**self).emit(ev)
    }
}

/// An append-only journal file. Every record is flushed on emit so a
/// crashed run leaves at worst one truncated record at the tail — the
/// case [`read_events`] reports as a [`Truncation`], not corruption.
pub struct JournalWriter {
    file: File,
    path: PathBuf,
}

impl JournalWriter {
    /// Start a fresh journal at `path` (truncating any existing file).
    pub fn create(path: &Path) -> Result<Self> {
        let file = File::create(path)
            .with_context(|| format!("creating journal {}", path.display()))?;
        Ok(Self { file, path: path.to_path_buf() })
    }

    /// Open `path` for appending (creating it if absent) — how a
    /// resumed session stitches its segment onto the original journal.
    ///
    /// A SIGKILLed writer can leave one torn record at the tail; its
    /// header's length field would otherwise swallow the first appended
    /// record and turn a clean [`Truncation`] into hard corruption. The
    /// torn tail is truncated away before appending, so the stitched
    /// file stays parseable end to end.
    pub fn append_to(path: &Path) -> Result<Self> {
        if let Ok(buf) = std::fs::read(path) {
            if let Ok((_, Some(t))) = read_events_bytes(&buf) {
                let f = OpenOptions::new()
                    .write(true)
                    .open(path)
                    .with_context(|| format!("opening journal {} to trim", path.display()))?;
                f.set_len(t.offset).with_context(|| {
                    format!("trimming torn record #{} in {}", t.record, path.display())
                })?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {} for append", path.display()))?;
        Ok(Self { file, path: path.to_path_buf() })
    }

    /// Where this journal lives.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl EventSink for JournalWriter {
    fn emit(&mut self, ev: &Event) -> Result<()> {
        let rec = encode_record(ev);
        self.file
            .write_all(&rec)
            .and_then(|()| self.file.flush())
            .with_context(|| format!("appending to journal {}", self.path.display()))
    }
}

/// An in-memory sink — what `wasgd replay` attaches to the re-executed
/// trainer so the fresh event stream can be diffed against the journal.
#[derive(Debug, Default)]
pub struct MemorySink {
    /// Every event emitted, in order.
    pub events: Vec<Event>,
}

impl EventSink for MemorySink {
    fn emit(&mut self, ev: &Event) -> Result<()> {
        self.events.push(ev.clone());
        Ok(())
    }
}

/// Where a journal stops being parseable: a record cut mid-write (crash
/// or copy truncation). Everything before `offset` parsed cleanly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Truncation {
    /// Byte offset of the first incomplete record.
    pub offset: u64,
    /// Index of the incomplete record (= number of complete records).
    pub record: u64,
}

/// Parse a whole journal byte buffer. Corruption (bad magic / CRC /
/// payload) is a hard error naming the record index and byte offset; a
/// *trailing* incomplete record is reported as a [`Truncation`]
/// alongside every complete event before it.
pub fn read_events_bytes(buf: &[u8]) -> Result<(Vec<Event>, Option<Truncation>)> {
    let mut events = Vec::new();
    let mut off = 0usize;
    loop {
        let parsed = parse_record(&buf[off..])
            .with_context(|| format!("journal record #{} at byte {off}", events.len()))?;
        match parsed {
            Some((ev, n)) => {
                events.push(ev);
                off += n;
            }
            None => {
                if off == buf.len() {
                    return Ok((events, None));
                }
                return Ok((
                    events,
                    Some(Truncation { offset: off as u64, record: events.len() as u64 }),
                ));
            }
        }
    }
}

/// [`read_events_bytes`] over a journal file.
pub fn read_events(path: &Path) -> Result<(Vec<Event>, Option<Truncation>)> {
    let buf = std::fs::read(path).with_context(|| format!("reading journal {}", path.display()))?;
    read_events_bytes(&buf).with_context(|| format!("journal {}", path.display()))
}

/// The per-rank journal path a fabric worker writes when the session
/// journals to `base`: `base.rank{r}` (the rendezvous/cohort journal
/// keeps `base` itself).
pub fn rank_journal_path(base: &Path, rank: usize) -> PathBuf {
    PathBuf::from(format!("{}.rank{rank}", base.display()))
}

/// One human-readable timeline line per event — shared by
/// `wasgd replay --inspect` and `wasgd watch`.
pub fn format_event(ev: &Event) -> String {
    fn rank_name(rank: u32) -> String {
        if rank == RANK_COHORT {
            "cohort".to_string()
        } else {
            rank.to_string()
        }
    }
    match ev {
        Event::RunStarted { rank, p, seed, encoding, git_rev, config_json, resume } => format!(
            "RunStarted        scope={} p={p} seed={seed} encoding={} rev={git_rev} \
             resume={} vector(s) config={} B",
            rank_name(*rank),
            encoding.name(),
            resume.len(),
            config_json.len()
        ),
        Event::PanelDigest { round, rank, digest, loss, comm_bytes } => format!(
            "PanelDigest       round={round} rank={rank} digest={digest:#018x} loss={loss} \
             comm_bytes={comm_bytes}"
        ),
        Event::CheckpointWritten { steps, digest, path } => format!(
            "CheckpointWritten steps={steps} digest={digest:#018x} path={path}"
        ),
        Event::Membership { epoch, rank, change } => format!(
            "Membership        epoch={epoch} rank={} {}",
            rank_name(*rank),
            change.name()
        ),
        Event::RunFinished { steps, rounds, final_digest } => format!(
            "RunFinished       steps={steps} rounds={rounds} final_digest={final_digest:#018x}"
        ),
        Event::EpochCommitted { epoch, round, members, anchor_digest, reason } => format!(
            "EpochCommitted    epoch={epoch} members={} round={round} \
             anchor={anchor_digest:#018x} reason={reason:?}",
            members.len()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // The CRC-32/IEEE check value (zlib, PNG, 802.3).
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn fnv64_known_vectors() {
        assert_eq!(fnv64(b""), Fnv64::OFFSET);
        assert_eq!(fnv64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn digest_params_matches_wire_bytes() {
        // digest_params over θ == fnv64 over the f32 wire body — the
        // identity the tcp relay's numerics-free digesting relies on.
        let theta = vec![1.5f32, -0.0, f32::NAN, 2.25e-17];
        let bytes: Vec<u8> = theta.iter().flat_map(|x| x.to_le_bytes()).collect();
        assert_eq!(digest_params(&theta), fnv64(&bytes));
        // And the cohort digest chains rank order.
        let cohort = [vec![1.0f32, 2.0], vec![3.0f32]];
        let flat: Vec<f32> = cohort.iter().flatten().copied().collect();
        assert_eq!(digest_cohort(cohort.iter().map(|v| v.as_slice())), digest_params(&flat));
    }

    #[test]
    fn canonical_comm_bytes_is_round_times_f32_panel() {
        let d = 1234;
        assert_eq!(
            canonical_comm_bytes(3, d),
            3 * Panel::wire_len(WireEncoding::F32, d) as u64
        );
        assert_eq!(canonical_comm_bytes(0, d), 0);
    }

    fn sample_events() -> Vec<Event> {
        vec![
            Event::RunStarted {
                rank: RANK_COHORT,
                p: 4,
                seed: 17,
                encoding: WireEncoding::F32,
                git_rev: "abc1234".into(),
                config_json: "{\"p\": 4}".into(),
                resume: vec![vec![1.0, f32::NAN], vec![-0.0, f32::INFINITY]],
            },
            Event::RunStarted {
                rank: 1,
                p: 2,
                seed: 5,
                encoding: WireEncoding::TopK { k_ppm: 10_000 },
                git_rev: "abc1234".into(),
                config_json: "{}".into(),
                resume: vec![],
            },
            Event::Membership { epoch: 0, rank: 0, change: MembershipChange::Joined },
            Event::PanelDigest {
                round: 1,
                rank: 2,
                digest: 0xdead_beef_cafe_f00d,
                loss: f32::NAN,
                comm_bytes: 16640,
            },
            Event::CheckpointWritten { steps: 32, digest: 7, path: "/tmp/ck".into() },
            Event::Membership { epoch: 0, rank: 1, change: MembershipChange::Finished },
            Event::EpochCommitted {
                epoch: 1,
                round: 3,
                members: vec![0, 2, 3],
                anchor_digest: 0x1122_3344_5566_7788,
                reason: "rank 1 died after completing round 3".into(),
            },
            Event::RunFinished { steps: 32, rounds: 4, final_digest: 99 },
        ]
    }

    #[test]
    fn every_event_roundtrips_bitwise() {
        for ev in sample_events() {
            let rec = encode_record(&ev);
            let (back, n) = parse_record(&rec).unwrap().expect("complete record");
            assert_eq!(n, rec.len());
            assert_eq!(back, ev, "{} did not round-trip", ev.name());
        }
    }

    #[test]
    fn read_events_roundtrip_and_truncation() {
        let evs = sample_events();
        let mut buf = Vec::new();
        for ev in &evs {
            buf.extend_from_slice(&encode_record(ev));
        }
        let (back, trunc) = read_events_bytes(&buf).unwrap();
        assert_eq!(back, evs);
        assert!(trunc.is_none());

        // Cut mid-final-record: complete prefix + truncation marker.
        let last_len = encode_record(evs.last().unwrap()).len();
        let cut = buf.len() - last_len + 3;
        let (back, trunc) = read_events_bytes(&buf[..cut]).unwrap();
        assert_eq!(back.len(), evs.len() - 1);
        let t = trunc.expect("mid-record cut must be reported");
        assert_eq!(t.record, (evs.len() - 1) as u64);
        assert_eq!(t.offset as usize, buf.len() - last_len);
    }

    #[test]
    fn corruption_is_a_pointed_error() {
        let mut buf = Vec::new();
        for ev in sample_events() {
            buf.extend_from_slice(&encode_record(&ev));
        }
        // Flip one payload bit in record #2.
        let r0 = encode_record(&sample_events()[0]).len();
        let r1 = encode_record(&sample_events()[1]).len();
        let mut bad = buf.clone();
        bad[r0 + r1 + RECORD_HEADER_LEN + 2] ^= 0x10;
        let err = read_events_bytes(&bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record #2"), "error must name the record: {msg}");
        assert!(msg.contains("CRC"), "bit flips surface as CRC mismatches: {msg}");
    }

    #[test]
    fn journal_writer_appends_and_reads_back() {
        let path = std::env::temp_dir()
            .join(format!("wasgd_journal_unit_{}.jrn", std::process::id()));
        let evs = sample_events();
        {
            let mut w = JournalWriter::create(&path).unwrap();
            for ev in &evs[..3] {
                w.emit(ev).unwrap();
            }
        }
        {
            let mut w = JournalWriter::append_to(&path).unwrap();
            for ev in &evs[3..] {
                w.emit(ev).unwrap();
            }
        }
        let (back, trunc) = read_events(&path).unwrap();
        assert_eq!(back, evs);
        assert!(trunc.is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn append_to_trims_a_torn_tail_before_stitching() {
        let path = std::env::temp_dir()
            .join(format!("wasgd_journal_torn_{}.jrn", std::process::id()));
        let evs = sample_events();
        {
            let mut w = JournalWriter::create(&path).unwrap();
            for ev in &evs[..3] {
                w.emit(ev).unwrap();
            }
        }
        // Simulate a SIGKILL mid-write: leave half a record at the tail.
        let torn = encode_record(&evs[3]);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&torn[..torn.len() / 2]).unwrap();
        drop(f);
        {
            let mut w = JournalWriter::append_to(&path).unwrap();
            for ev in &evs[3..] {
                w.emit(ev).unwrap();
            }
        }
        let (back, trunc) = read_events(&path).unwrap();
        assert_eq!(back, evs, "torn tail must be trimmed, not stitched over");
        assert!(trunc.is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rank_paths_are_disjoint_from_base() {
        let base = Path::new("/tmp/run.jrn");
        assert_eq!(rank_journal_path(base, 0), Path::new("/tmp/run.jrn.rank0"));
        assert_eq!(rank_journal_path(base, 3), Path::new("/tmp/run.jrn.rank3"));
    }

    #[test]
    fn format_event_is_stable_enough_to_grep() {
        for ev in sample_events() {
            let line = format_event(&ev);
            assert!(line.starts_with(ev.name()), "{line}");
        }
    }
}
