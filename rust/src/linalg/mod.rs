//! Host-side dense vector kernels used by the coordinator's hot loop.
//!
//! Parameter vectors are plain `Vec<f32>` (the flat ABI, DESIGN.md §1).
//! Several baselines aggregate on the host (SPSGD's average, EASGD's
//! elastic pull) and WASGD's aggregation has a host fallback used when no
//! PJRT `aggregate_p{p}` artifact matches the cohort size. These loops
//! are written to autovectorise: unit-stride, no bounds checks in the
//! body (chunked iterators), f32 accumulation with an f64 reduction where
//! the value is a statistic rather than a parameter.

/// y ← y + a·x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// y ← (1-t)·y + t·x  (linear interpolation toward x)
pub fn lerp_into(y: &mut [f32], t: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let keep = 1.0 - t;
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi = keep * *yi + t * *xi;
    }
}

/// out ← Σᵢ wᵢ·rows[i]  (weighted combination of worker parameter rows).
///
/// Routed through the blocked kernel subsystem's row-combine on one
/// thread — same per-column accumulation order (i ascending) as the old
/// axpy loop, so results are bit-identical; callers that hold a
/// [`crate::kernels::Gemm`] can use its `combine_rows` directly for the
/// threaded version.
pub fn weighted_sum(out: &mut [f32], rows: &[&[f32]], w: &[f32]) {
    crate::kernels::Gemm::single().combine_rows(out, rows, w);
}

/// The paper's Eq. (10) on the host: xᵢ ← (1-β)xᵢ + β·agg, for every row.
pub fn beta_mix_rows(rows: &mut [Vec<f32>], agg: &[f32], beta: f32) {
    for row in rows.iter_mut() {
        lerp_into(row, beta, agg);
    }
}

/// Euclidean norm (f64 accumulation).
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// ‖a − b‖₂ without materialising the difference.
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Mean of a slice (f64 accumulation).
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|&v| v as f64).sum::<f64>() / x.len() as f64
}

/// Sample standard deviation (n−1 denominator), the paper's `stdv` in
/// Algorithm 2 Function 3 (`Judge`).
pub fn stddev(x: &[f32]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    let ss: f64 = x.iter().map(|&v| (v as f64 - m).powi(2)).sum();
    (ss / (x.len() - 1) as f64).sqrt()
}

/// Boltzmann weights, Eq. (13): θᵢ = exp(−ã·hᵢ/Σh) / Σ exp(·).
/// Numerically stabilised by max-subtraction; this is the host twin of
/// the Pallas `boltzmann_weights` and must match it bit-for-bit in
/// semantics (the proptest suite cross-checks the two).
pub fn boltzmann_weights(h: &[f32], a_tilde: f32) -> Vec<f32> {
    let total: f64 = h.iter().map(|&v| v as f64).sum();
    let p = h.len();
    if total <= 0.0 || !total.is_finite() {
        // Degenerate energies → equal weights (matches ã→0 limit).
        return vec![1.0 / p as f32; p];
    }
    let z: Vec<f64> = h
        .iter()
        .map(|&v| -(a_tilde as f64) * (v as f64) / total)
        .collect();
    let zmax = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vec<f64> = z.iter().map(|&v| (v - zmax).exp()).collect();
    let denom: f64 = e.iter().sum();
    e.iter().map(|&v| (v / denom) as f32).collect()
}

/// Inverse-loss weights — the original WASGD weighting (Algorithm 3):
/// θᵢ = (1/hᵢ) / Σⱼ (1/hⱼ).
pub fn inverse_loss_weights(h: &[f32]) -> Vec<f32> {
    let inv: Vec<f64> = h.iter().map(|&v| 1.0 / (v.max(1e-12) as f64)).collect();
    let denom: f64 = inv.iter().sum();
    inv.iter().map(|&v| (v / denom) as f32).collect()
}

/// argmax over f32 (first maximal index).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
    }

    #[test]
    fn lerp_endpoints() {
        let mut y = vec![1.0, 2.0];
        lerp_into(&mut y, 0.0, &[5.0, 5.0]);
        assert_eq!(y, vec![1.0, 2.0]);
        lerp_into(&mut y, 1.0, &[5.0, 6.0]);
        assert_eq!(y, vec![5.0, 6.0]);
    }

    #[test]
    fn weighted_sum_recovers_average() {
        let a = vec![2.0f32; 4];
        let b = vec![4.0f32; 4];
        let mut out = vec![0.0f32; 4];
        weighted_sum(&mut out, &[&a, &b], &[0.5, 0.5]);
        assert_eq!(out, vec![3.0; 4]);
    }

    #[test]
    fn boltzmann_equal_limit() {
        let th = boltzmann_weights(&[0.3, 2.0, 1.1], 0.0);
        for &t in &th {
            assert!((t - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn boltzmann_argmin_limit() {
        let th = boltzmann_weights(&[0.3, 2.0, 1.1], 1e5);
        assert!(th[0] > 0.999, "{th:?}");
    }

    #[test]
    fn boltzmann_sums_to_one() {
        for a in [0.0, 0.5, 1.0, 10.0, 1e4] {
            let th = boltzmann_weights(&[0.9, 0.1, 0.5, 3.0], a);
            let s: f32 = th.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn boltzmann_degenerate_energies() {
        let th = boltzmann_weights(&[0.0, 0.0], 1.0);
        assert_eq!(th, vec![0.5, 0.5]);
    }

    #[test]
    fn inverse_weights_prefer_low_loss() {
        let th = inverse_loss_weights(&[0.5, 1.0]);
        assert!((th[0] - 2.0 / 3.0).abs() < 1e-6);
        assert!((th[1] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn stddev_matches_known() {
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.138089935).abs() < 1e-6, "{s}");
    }

    #[test]
    fn dist_and_norm() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((dist2(&[1.0, 1.0], &[4.0, 5.0]) - 5.0).abs() < 1e-9);
    }
}
