//! Metrics substrate: training records, curve summaries, CSV/JSON sinks.
//!
//! Every experiment emits a stream of [`Record`]s (one per evaluation
//! point) tagged with both clocks: the *simulated* cluster time that the
//! figures plot, and the real wall time of this host (reported in
//! EXPERIMENTS.md for transparency). The bench harness writes one CSV per
//! figure so the paper's plots can be regenerated with any plotting tool.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::cluster::FabricConfig;

/// One evaluation point of one run.
#[derive(Clone, Debug)]
pub struct Record {
    /// Total local SGD iterations per worker so far.
    pub iteration: u64,
    /// Epochs completed (fractional).
    pub epoch: f64,
    /// Simulated cluster seconds (the figures' x-axis).
    pub sim_time_s: f64,
    /// Real wall seconds on this host.
    pub wall_time_s: f64,
    /// Mean train loss over the evaluation sample.
    pub train_loss: f64,
    /// Train error rate (1 − accuracy) over the sample.
    pub train_error: f64,
    /// Mean test loss over the evaluation sample.
    pub test_loss: f64,
    /// Test error rate over the sample.
    pub test_error: f64,
}

/// A labelled run: algorithm + parameters + its record stream.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    /// Human-readable run label ("wasgd+ p=4 tau=50").
    pub label: String,
    /// The record stream, in evaluation order.
    pub records: Vec<Record>,
    /// Free-form key=value annotations (p, τ, β, ã, dataset, …).
    pub tags: Vec<(String, String)>,
}

impl RunLog {
    /// A fresh empty log with the given label.
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), records: Vec::new(), tags: Vec::new() }
    }

    /// Attach a `key=value` annotation (builder style).
    pub fn tag(mut self, k: &str, v: impl ToString) -> Self {
        self.tags.push((k.to_string(), v.to_string()));
        self
    }

    /// Append one evaluation record.
    pub fn push(&mut self, r: Record) {
        self.records.push(r);
    }

    /// The most recent record, if any.
    pub fn last(&self) -> Option<&Record> {
        self.records.last()
    }

    /// Final training loss (∞ if no records — treat as diverged).
    pub fn final_train_loss(&self) -> f64 {
        self.last().map(|r| r.train_loss).unwrap_or(f64::INFINITY)
    }

    /// First simulated time at which train loss ≤ target (time-to-loss,
    /// the paper's headline comparison axis). None = never reached.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.train_loss.is_finite() && r.train_loss <= target)
            .map(|r| r.sim_time_s)
    }

    /// Area under the train-loss curve over sim time — a scalar summary
    /// used by the sweeps (lower = converges faster), Eq. 47-flavoured.
    pub fn loss_auc(&self) -> f64 {
        if self.records.len() < 2 {
            return self.final_train_loss();
        }
        let mut auc = 0.0;
        for w in self.records.windows(2) {
            let dt = w[1].sim_time_s - w[0].sim_time_s;
            auc += 0.5 * (w[0].train_loss + w[1].train_loss) * dt;
        }
        let span = self.records.last().unwrap().sim_time_s - self.records[0].sim_time_s;
        if span > 0.0 {
            auc / span
        } else {
            self.final_train_loss()
        }
    }

    /// Mean of a metric over all records — the paper's Eq. (47) reduces
    /// to mean(baseline metric) − mean(candidate metric) when records are
    /// aligned; sweeps compute that difference from two of these.
    pub fn mean_metric(&self, f: impl Fn(&Record) -> f64) -> f64 {
        if self.records.is_empty() {
            return f64::INFINITY;
        }
        self.records.iter().map(&f).sum::<f64>() / self.records.len() as f64
    }

    /// CSV rows (no header) for this run.
    pub fn to_csv_rows(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            let _ = writeln!(
                s,
                "{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6}",
                self.label,
                r.iteration,
                r.epoch,
                r.sim_time_s,
                r.wall_time_s,
                r.train_loss,
                r.train_error,
                r.test_loss,
                r.test_error
            );
        }
        s
    }
}

/// Header row matching [`RunLog::to_csv_rows`].
pub const CSV_HEADER: &str =
    "label,iteration,epoch,sim_time_s,wall_time_s,train_loss,train_error,test_loss,test_error";

/// Write a set of runs to one CSV file (creating parent dirs).
pub fn write_csv(path: impl AsRef<Path>, runs: &[RunLog]) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut f = fs::File::create(path)?;
    writeln!(f, "{CSV_HEADER}")?;
    for run in runs {
        f.write_all(run.to_csv_rows().as_bytes())?;
    }
    Ok(())
}

/// One peer's traffic totals, as seen from the rendezvous node.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PeerComm {
    /// Bytes pushed to this peer (welcome + relayed cohorts).
    pub sent: u64,
    /// Bytes received from this peer (hello + panels + final).
    pub received: u64,
}

/// Per-peer communication byte counters for the real (TCP) worker
/// fabric. The measured traffic feeds the *same* cost model the
/// simulated cluster uses ([`FabricConfig`]), so "what would this run
/// have cost on the modelled interconnect?" is answerable for both
/// substrates.
#[derive(Clone, Debug, Default)]
pub struct CommCounters {
    /// One entry per peer, indexed by rank.
    pub peers: Vec<PeerComm>,
}

impl CommCounters {
    /// Zeroed counters for `p` peers.
    pub fn new(p: usize) -> Self {
        Self { peers: vec![PeerComm::default(); p] }
    }

    /// Accumulate traffic for one peer.
    pub fn add(&mut self, rank: usize, sent: u64, received: u64) {
        let peer = &mut self.peers[rank];
        peer.sent += sent;
        peer.received += received;
    }

    /// Total bytes pushed to all peers.
    pub fn total_sent(&self) -> u64 {
        self.peers.iter().map(|p| p.sent).sum()
    }

    /// Total bytes received from all peers.
    pub fn total_received(&self) -> u64 {
        self.peers.iter().map(|p| p.received).sum()
    }

    /// Estimated seconds the measured per-round contribution would cost
    /// as `rounds` ring all-gathers on the modelled link — the bridge
    /// from real wire bytes back into the simulated cost model.
    ///
    /// Assumes the rendezvous counter convention: each peer's received
    /// bytes cover its `rounds` panels *plus one final panel* (the
    /// 12-byte hello is noise), so the per-round panel size is the
    /// total divided by `rounds + 1` contributions per peer.
    pub fn estimated_allgather_s(&self, link: &FabricConfig, rounds: u64) -> f64 {
        let p = self.peers.len();
        if p == 0 || rounds == 0 {
            return 0.0;
        }
        let contributed = self.total_received() as f64 / ((rounds + 1) as f64 * p as f64);
        rounds as f64 * link.allgather_time(p, contributed.ceil() as usize)
    }
}

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    /// Start timing now.
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Pretty-print a comparison table (label → scalar) in paper-row style.
pub fn format_table(title: &str, rows: &[(String, f64)], unit: &str) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "== {title} ==");
    let width = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(8).max(8);
    for (label, v) in rows {
        let _ = writeln!(s, "  {label:<width$}  {v:>12.6} {unit}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: f64, loss: f64) -> Record {
        Record {
            iteration: (t * 100.0) as u64,
            epoch: t,
            sim_time_s: t,
            wall_time_s: t,
            train_loss: loss,
            train_error: loss / 10.0,
            test_loss: loss * 1.1,
            test_error: loss / 9.0,
        }
    }

    #[test]
    fn time_to_loss_finds_first_crossing() {
        let mut run = RunLog::new("x");
        for (t, l) in [(0.0, 2.0), (1.0, 1.0), (2.0, 0.5), (3.0, 0.4)] {
            run.push(rec(t, l));
        }
        assert_eq!(run.time_to_loss(1.0), Some(1.0));
        assert_eq!(run.time_to_loss(0.45), Some(3.0));
        assert_eq!(run.time_to_loss(0.1), None);
    }

    #[test]
    fn auc_of_constant_curve_is_constant() {
        let mut run = RunLog::new("c");
        for t in 0..5 {
            run.push(rec(t as f64, 2.0));
        }
        assert!((run.loss_auc() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut run = RunLog::new("alg").tag("p", 4);
        run.push(rec(0.0, 1.0));
        run.push(rec(1.0, 0.5));
        let rows = run.to_csv_rows();
        assert_eq!(rows.lines().count(), 2);
        assert!(rows.starts_with("alg,"));
        assert_eq!(CSV_HEADER.split(',').count(), rows.lines().next().unwrap().split(',').count());
    }

    #[test]
    fn comm_counters_accumulate_and_price_traffic() {
        let mut c = CommCounters::new(2);
        assert_eq!(c.total_sent(), 0);
        c.add(0, 100, 40);
        c.add(1, 300, 60);
        c.add(0, 0, 20);
        assert_eq!(c.peers[0], PeerComm { sent: 100, received: 60 });
        assert_eq!(c.total_sent(), 400);
        assert_eq!(c.total_received(), 120);

        // 2 rounds + 1 final contribution each, 2 peers → 120 B over
        // 6 contributions = 20 B per panel.
        let link = FabricConfig::default();
        let est = c.estimated_allgather_s(&link, 2);
        let want = 2.0 * link.allgather_time(2, 20);
        assert!((est - want).abs() < 1e-12, "{est} vs {want}");
        assert_eq!(c.estimated_allgather_s(&link, 0), 0.0);
        assert_eq!(CommCounters::new(0).estimated_allgather_s(&link, 5), 0.0);
    }

    #[test]
    fn write_csv_creates_dirs() {
        let dir = std::env::temp_dir().join("wasgd_metrics_test");
        let _ = fs::remove_dir_all(&dir);
        let path = dir.join("a/b/run.csv");
        let mut run = RunLog::new("z");
        run.push(rec(0.0, 1.0));
        write_csv(&path, &[run]).unwrap();
        let body = fs::read_to_string(&path).unwrap();
        assert!(body.contains("train_loss"));
        let _ = fs::remove_dir_all(&dir);
    }
}
