//! Naive (unblocked, single-threaded) kernels — the *reference
//! semantics* of the GEMM subsystem.
//!
//! These are the original `runtime::native` triple loops, kept verbatim
//! as the ground truth the blocked/threaded [`Gemm`](super::Gemm) paths
//! are property-tested against (`tests/gemm_props.rs` asserts ≤1e-5
//! agreement across random shapes, and the blocked kernels preserve the
//! reference's per-element accumulation order — ascending k — so the
//! agreement is in practice bit-exact). They are also what the
//! `benches/gemm.rs` trajectory measures speedups *against*, so do not
//! optimise them: their value is being obviously correct and stable
//! across PRs.

/// z[r,c] = Σⱼ a[r,j]·w[j,c] + b[c] — unit-stride inner loops so the
/// autovectoriser gets contiguous rows of `w`.
pub fn matmul_bias(a: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize, z: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(z.len(), m * n);
    for r in 0..m {
        let zrow = &mut z[r * n..(r + 1) * n];
        zrow.copy_from_slice(b);
        let arow = &a[r * k..(r + 1) * k];
        for (j, &aj) in arow.iter().enumerate() {
            if aj == 0.0 {
                continue; // ReLU/padding sparsity: skip dead activations
            }
            let wrow = &w[j * n..(j + 1) * n];
            for (zc, &wc) in zrow.iter_mut().zip(wrow.iter()) {
                *zc += aj * wc;
            }
        }
    }
}

/// gw[j,c] += Σᵣ a[r,j]·dz[r,c] — the Aᵀ·dZ weight-gradient product,
/// accumulating into `gw` (the flat gradient vector is zeroed once by
/// the caller and each layer deposits its block exactly once).
pub fn matmul_tn_acc(a: &[f32], dz: &[f32], rows: usize, din: usize, dout: usize, gw: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * din);
    debug_assert_eq!(dz.len(), rows * dout);
    debug_assert_eq!(gw.len(), din * dout);
    for r in 0..rows {
        let arow = &a[r * din..(r + 1) * din];
        let dzrow = &dz[r * dout..(r + 1) * dout];
        for (j, &aj) in arow.iter().enumerate() {
            if aj == 0.0 {
                continue;
            }
            let grow = &mut gw[j * dout..(j + 1) * dout];
            for (g, &d) in grow.iter_mut().zip(dzrow.iter()) {
                *g += aj * d;
            }
        }
    }
}

/// da[r,j] = Σ꜀ dz[r,c]·w[j,c] — the dZ·Wᵀ input-gradient product
/// (overwrites `da`). Both operands are read along contiguous rows.
pub fn matmul_nt(dz: &[f32], w: &[f32], rows: usize, dout: usize, din: usize, da: &mut [f32]) {
    debug_assert_eq!(dz.len(), rows * dout);
    debug_assert_eq!(w.len(), din * dout);
    debug_assert_eq!(da.len(), rows * din);
    for r in 0..rows {
        let dzrow = &dz[r * dout..(r + 1) * dout];
        let darow = &mut da[r * din..(r + 1) * din];
        for (j, dv) in darow.iter_mut().enumerate() {
            let wrow = &w[j * dout..(j + 1) * dout];
            let mut acc = 0.0f32;
            for (&d, &wc) in dzrow.iter().zip(wrow.iter()) {
                acc += d * wc;
            }
            *dv = acc;
        }
    }
}

/// out[c] = Σᵢ wts[i]·rows[i][c] — the aggregation row-combine
/// ((1×p)·(p×D) GEMM), overwriting `out`. Accumulation runs over `i`
/// ascending per column, the order the blocked path must reproduce.
pub fn combine_rows(out: &mut [f32], rows: &[&[f32]], wts: &[f32]) {
    debug_assert_eq!(rows.len(), wts.len());
    out.fill(0.0);
    for (row, &wi) in rows.iter().zip(wts.iter()) {
        debug_assert_eq!(row.len(), out.len());
        for (o, &x) in out.iter_mut().zip(row.iter()) {
            *o += wi * x;
        }
    }
}

/// gb[c] += Σᵣ dz[r,c] — bias-gradient column sum.
pub fn col_sum_acc(dz: &[f32], rows: usize, dout: usize, gb: &mut [f32]) {
    debug_assert_eq!(dz.len(), rows * dout);
    debug_assert_eq!(gb.len(), dout);
    for r in 0..rows {
        let dzrow = &dz[r * dout..(r + 1) * dout];
        for (g, &d) in gb.iter_mut().zip(dzrow.iter()) {
            *g += d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_bias_known_values() {
        // [1 2; 3 4] · [1 0; 0 1] + [10, 20]
        let a = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 0.0, 0.0, 1.0];
        let b = [10.0, 20.0];
        let mut z = [0.0f32; 4];
        matmul_bias(&a, &w, &b, 2, 2, 2, &mut z);
        assert_eq!(z, [11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn tn_acc_accumulates() {
        // aᵀ·dz for a = [1;2] (2 rows, 1 col), dz = [3; 5] → gw = [13].
        let a = [1.0, 2.0];
        let dz = [3.0, 5.0];
        let mut gw = [100.0f32];
        matmul_tn_acc(&a, &dz, 2, 1, 1, &mut gw);
        assert_eq!(gw, [113.0]);
    }

    #[test]
    fn nt_overwrites() {
        // dz·wᵀ for dz = [1 2] (1×2), w = [[3 4],[5 6]] (din=2 × dout=2).
        let dz = [1.0, 2.0];
        let w = [3.0, 4.0, 5.0, 6.0];
        let mut da = [9.0f32, 9.0];
        matmul_nt(&dz, &w, 1, 2, 2, &mut da);
        assert_eq!(da, [11.0, 17.0]);
    }

    #[test]
    fn combine_and_col_sum() {
        let r0 = [2.0f32, 0.0];
        let r1 = [4.0f32, 8.0];
        let mut out = [1.0f32, 1.0];
        combine_rows(&mut out, &[&r0, &r1], &[0.5, 0.25]);
        assert_eq!(out, [2.0, 2.0]);
        let dz = [1.0f32, 2.0, 3.0, 4.0];
        let mut gb = [1.0f32, 1.0];
        col_sum_acc(&dz, 2, 2, &mut gb);
        assert_eq!(gb, [5.0, 7.0]);
    }
}
