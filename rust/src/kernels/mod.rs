//! Blocked, multi-threaded f32 GEMM — the compute substrate under every
//! dense/conv forward, every backward matmul, and the aggregation
//! row-combine of the native backend.
//!
//! # Design
//!
//! [`Gemm`] is a cache-blocked (packed-panel, MC×KC×NC tiled) kernel in
//! the BLIS loop order: column blocks of NC, reduction blocks of KC
//! (packing the B panel into NR-wide strips), row blocks of MC (packing
//! the A block into MR-tall strips), and an MR×NR register micro-tile at
//! the core. Intra-op parallelism splits the *output rows* across
//! `threads` OS threads via `std::thread::scope` — no work queue, no
//! extra dependencies, and crucially no change to numerics:
//!
//! * **Bit-determinism across thread counts.** Every output element is
//!   owned by exactly one thread, and its accumulation order over the
//!   reduction dimension is the fixed `pc`-block-then-`kk` sequence —
//!   i.e. ascending k, independent of how rows were partitioned. The
//!   same inputs therefore produce the *identical output bits* at
//!   `threads = 1, 2, 4, 8, …` (pinned by `tests/gemm_props.rs`), so
//!   intra-op parallelism can never silently change the science.
//! * **Reference parity.** Ascending-k accumulation is also exactly the
//!   [`reference`] loop's order, so the blocked path agrees with the
//!   naive one to ≤1e-5 (in practice bit-exactly, modulo the reference's
//!   exact-by-construction zero-skip).
//!
//! Small problems are handled in two tiers, both decided purely by
//! shape (never by the thread budget, so a given input always takes the
//! same path and stays bit-stable): below `SMALL_GEMM_WORK` the entry
//! points dispatch straight to the [`reference`] loops — packing panels
//! would cost more than the multiply, and the tiny-variant hot loops
//! must not regress — and below `PAR_MIN_WORK` the blocked kernel runs
//! inline on the calling thread, because spawning costs more than the
//! whole GEMM down there. The `threads` knob plumbs down from
//! [`ExperimentConfig::threads`](crate::config::ExperimentConfig) /
//! `wasgd run --threads N` through backend construction; `0` means "all
//! available cores".

pub mod reference;

/// Row-block size (packed A height per block).
const MC: usize = 64;
/// Reduction-block size (packed panel depth); multiples keep panels in L1.
const KC: usize = 256;
/// Column-block size (packed B width per block).
const NC: usize = 256;
/// Micro-tile rows (register accumulators per tile: MR×NR).
const MR: usize = 4;
/// Micro-tile columns — one or two SIMD vectors wide on current targets.
const NR: usize = 16;
/// Below this many multiply-adds the problem runs single-threaded:
/// thread spawn costs more than the whole GEMM down there.
const PAR_MIN_WORK: usize = 1 << 17;
/// Below this many multiply-adds the blocked machinery itself is not
/// worth it — allocating and packing panels would dominate — so the
/// entry points dispatch straight to the [`reference`] loops. The cut
/// depends only on the problem shape, never on the thread budget, so a
/// given input always takes the same path (bit-stability preserved).
const SMALL_GEMM_WORK: usize = 1 << 15;
/// Column-panel width of the row-combine — keeps the accumulator panel
/// L1/L2-resident while worker rows stream past (the former native
/// `AGG_PANEL`, mirroring the Pallas kernel's VMEM tiling).
const COMBINE_PANEL: usize = 8192;

/// A strided read-only matrix view: element (r, c) lives at
/// `data[r·rs + c·cs]`. Lets one blocked driver serve A·B, Aᵀ·B and
/// A·Bᵀ without materialising transposes (the packing step absorbs the
/// stride).
#[derive(Clone, Copy)]
struct View<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
}

impl View<'_> {
    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c * self.cs]
    }
}

/// How the first reduction block seeds the output tile.
#[derive(Clone, Copy)]
enum Init<'a> {
    /// Start from a broadcast bias row (forward affine).
    Bias(&'a [f32]),
    /// Start from zero (input gradients).
    Zero,
    /// Start from the existing output (accumulating weight gradients).
    Acc,
}

/// The blocked GEMM entry point. Cheap to construct and `Copy`; the only
/// state is the thread budget.
#[derive(Clone, Copy, Debug)]
pub struct Gemm {
    threads: usize,
}

impl Default for Gemm {
    fn default() -> Self {
        Self::single()
    }
}

impl Gemm {
    /// `threads = 0` resolves to all available cores at construction.
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    /// Single-threaded instance (the deterministic-simulation default).
    pub fn single() -> Self {
        Self { threads: 1 }
    }

    /// Resolved thread budget (never 0).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// How many threads this problem actually gets: capped by the row
    /// count (each thread needs ≥1 micro-row panel) and gated on total
    /// work. Affects scheduling only — never output bits.
    fn plan_threads(&self, m: usize, k: usize, n: usize) -> usize {
        if self.threads <= 1 {
            return 1;
        }
        let work = m.saturating_mul(k).saturating_mul(n);
        if work < PAR_MIN_WORK {
            return 1;
        }
        self.threads.min(m.div_ceil(MR)).max(1)
    }

    #[allow(clippy::too_many_arguments)]
    fn run(
        &self,
        a: View<'_>,
        b: View<'_>,
        init: Init<'_>,
        m: usize,
        k: usize,
        n: usize,
        out: &mut [f32],
    ) {
        assert_eq!(out.len(), m * n, "gemm output buffer length ≠ {m}×{n}");
        let t = self.plan_threads(m, k, n);
        if t <= 1 {
            gemm_rows(a, b, init, 0, m, k, n, out);
            return;
        }
        // Contiguous row ranges, rounded up to whole micro-panels so
        // every thread packs aligned tiles. Partitioning is a scheduling
        // choice only: per-element accumulation order is fixed (see
        // module docs), so any split yields identical bits.
        let chunk_rows = m.div_ceil(t).div_ceil(MR) * MR;
        std::thread::scope(|s| {
            for (ci, oc) in out.chunks_mut(chunk_rows * n).enumerate() {
                let r0 = ci * chunk_rows;
                s.spawn(move || gemm_rows(a, b, init, r0, oc.len() / n, k, n, oc));
            }
        });
    }

    /// Forward affine: `z[r,c] = Σⱼ a[r,j]·w[j,c] + bias[c]` with `a`
    /// row-major `m×k`, `w` row-major `k×n`. Serves the dense layers
    /// (rows = batch) and the im2col conv path (rows = batch·H·W).
    ///
    /// ```
    /// use wasgd::kernels::Gemm;
    ///
    /// // 2×2 activations through an identity weight matrix plus bias.
    /// let a = [1.0f32, 2.0, 3.0, 4.0];
    /// let w = [1.0f32, 0.0, 0.0, 1.0];
    /// let bias = [0.5f32, -0.5];
    /// let mut z = [0.0f32; 4];
    /// Gemm::single().matmul_bias(&a, &w, &bias, 2, 2, 2, &mut z);
    /// assert_eq!(z, [1.5, 1.5, 3.5, 3.5]);
    ///
    /// // Any thread count computes the identical bits.
    /// let mut z4 = [0.0f32; 4];
    /// Gemm::new(4).matmul_bias(&a, &w, &bias, 2, 2, 2, &mut z4);
    /// assert_eq!(z, z4);
    /// ```
    #[allow(clippy::too_many_arguments)]
    pub fn matmul_bias(
        &self,
        a: &[f32],
        w: &[f32],
        bias: &[f32],
        m: usize,
        k: usize,
        n: usize,
        z: &mut [f32],
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(bias.len(), n);
        if m.saturating_mul(k).saturating_mul(n) < SMALL_GEMM_WORK {
            return reference::matmul_bias(a, w, bias, m, k, n, z);
        }
        self.run(
            View { data: a, rs: k, cs: 1 },
            View { data: w, rs: n, cs: 1 },
            Init::Bias(bias),
            m,
            k,
            n,
            z,
        );
    }

    /// Weight gradient: `gw[j,c] += Σᵣ a[r,j]·dz[r,c]` (Aᵀ·dZ,
    /// accumulated into the caller's flat gradient block).
    pub fn matmul_tn_acc(
        &self,
        a: &[f32],
        dz: &[f32],
        rows: usize,
        din: usize,
        dout: usize,
        gw: &mut [f32],
    ) {
        debug_assert_eq!(a.len(), rows * din);
        debug_assert_eq!(dz.len(), rows * dout);
        if rows.saturating_mul(din).saturating_mul(dout) < SMALL_GEMM_WORK {
            return reference::matmul_tn_acc(a, dz, rows, din, dout, gw);
        }
        self.run(
            View { data: a, rs: 1, cs: din },
            View { data: dz, rs: dout, cs: 1 },
            Init::Acc,
            din,
            rows,
            dout,
            gw,
        );
    }

    /// Input gradient: `da[r,j] = Σ꜀ dz[r,c]·w[j,c]` (dZ·Wᵀ, overwrite).
    pub fn matmul_nt(
        &self,
        dz: &[f32],
        w: &[f32],
        rows: usize,
        dout: usize,
        din: usize,
        da: &mut [f32],
    ) {
        debug_assert_eq!(dz.len(), rows * dout);
        debug_assert_eq!(w.len(), din * dout);
        if rows.saturating_mul(dout).saturating_mul(din) < SMALL_GEMM_WORK {
            return reference::matmul_nt(dz, w, rows, dout, din, da);
        }
        self.run(
            View { data: dz, rs: dout, cs: 1 },
            View { data: w, rs: 1, cs: dout },
            Init::Zero,
            rows,
            dout,
            din,
            da,
        );
    }

    /// Aggregation row-combine: `out[c] = Σᵢ wts[i]·rows[i][c]` — the
    /// (1×p)·(p×D) GEMM at every communication boundary. Threads split
    /// the *columns*; each column's accumulation runs over `i` ascending,
    /// so bits match [`reference::combine_rows`] at any thread count.
    pub fn combine_rows(&self, out: &mut [f32], rows: &[&[f32]], wts: &[f32]) {
        assert_eq!(rows.len(), wts.len(), "rows/weights length mismatch");
        for row in rows {
            assert_eq!(row.len(), out.len(), "ragged aggregation row");
        }
        let d = out.len();
        if d == 0 {
            return;
        }
        if rows.is_empty() {
            out.fill(0.0);
            return;
        }
        let t = {
            let work = rows.len().saturating_mul(d);
            if self.threads <= 1 || work < PAR_MIN_WORK {
                1
            } else {
                self.threads.min(d)
            }
        };
        if t <= 1 {
            combine_cols(out, rows, wts, 0);
            return;
        }
        let chunk = d.div_ceil(t);
        std::thread::scope(|s| {
            for (ci, oc) in out.chunks_mut(chunk).enumerate() {
                s.spawn(move || combine_cols(oc, rows, wts, ci * chunk));
            }
        });
    }

    /// Bias gradient: `gb[c] += Σᵣ dz[r,c]`. Column sums are cheap next
    /// to the matmuls; runs on the calling thread.
    pub fn col_sum_acc(&self, dz: &[f32], rows: usize, dout: usize, gb: &mut [f32]) {
        reference::col_sum_acc(dz, rows, dout, gb);
    }

    /// Eq. 10's β-mix over a stacked `p×D` cohort:
    /// `out[i·D+c] = (1−β)·xs[i·D+c] + β·agg[c]`. Elementwise, so the
    /// row split across threads is trivially bit-stable.
    pub fn blend_rows(&self, out: &mut [f32], xs: &[f32], agg: &[f32], beta: f32) {
        let d = agg.len();
        assert!(d > 0, "empty aggregate row");
        assert_eq!(out.len(), xs.len());
        assert_eq!(xs.len() % d, 0, "stacked len not a multiple of D");
        let p = xs.len() / d;
        let keep = 1.0 - beta;
        let t = {
            let work = p.saturating_mul(d);
            if self.threads <= 1 || work < PAR_MIN_WORK {
                1
            } else {
                self.threads.min(p)
            }
        };
        if t <= 1 {
            blend_range(out, xs, agg, keep, beta);
            return;
        }
        let chunk = p.div_ceil(t) * d;
        std::thread::scope(|s| {
            for (oc, xc) in out.chunks_mut(chunk).zip(xs.chunks(chunk)) {
                s.spawn(move || blend_range(oc, xc, agg, keep, beta));
            }
        });
    }
}

fn combine_cols(out: &mut [f32], rows: &[&[f32]], wts: &[f32], c0: usize) {
    out.fill(0.0);
    let mut off = 0;
    for panel in out.chunks_mut(COMBINE_PANEL) {
        let lo = c0 + off;
        for (row, &wi) in rows.iter().zip(wts.iter()) {
            let src = &row[lo..lo + panel.len()];
            for (o, &x) in panel.iter_mut().zip(src.iter()) {
                *o += wi * x;
            }
        }
        off += panel.len();
    }
}

fn blend_range(out: &mut [f32], xs: &[f32], agg: &[f32], keep: f32, beta: f32) {
    let d = agg.len();
    for (orow, xrow) in out.chunks_mut(d).zip(xs.chunks(d)) {
        for ((o, &x), &a) in orow.iter_mut().zip(xrow.iter()).zip(agg.iter()) {
            *o = keep * x + beta * a;
        }
    }
}

/// One thread's share of the blocked GEMM: output rows `[r0, r0+rows)`
/// of the `m×n` product, with `out` the contiguous row-major sub-slice
/// for exactly that range. The loop nest is jc (NC) → pc (KC, pack B) →
/// ic (MC, pack A) → jr (NR) → ir (MR) → micro-kernel.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    a: View<'_>,
    b: View<'_>,
    init: Init<'_>,
    r0: usize,
    rows: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * n);
    if rows == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // Degenerate reduction: the product term is empty; only the
        // seeding remains.
        match init {
            Init::Bias(bias) => {
                for zrow in out.chunks_mut(n) {
                    zrow.copy_from_slice(bias);
                }
            }
            Init::Zero => out.fill(0.0),
            Init::Acc => {}
        }
        return;
    }
    // Pack buffers sized to what the block loops can actually touch —
    // full MC×KC / NC×KC only for problems that fill the blocks.
    let kcap = KC.min(k);
    let mut ap = vec![0.0f32; MC.min(rows.div_ceil(MR) * MR) * kcap];
    let mut bp = vec![0.0f32; NC.min(n.div_ceil(NR) * NR) * kcap];
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(b, &mut bp, pc, jc, kc, nc);
            let first = pc == 0;
            let mut ic = 0;
            while ic < rows {
                let mc = MC.min(rows - ic);
                pack_a(a, &mut ap, r0 + ic, pc, mc, kc);
                let mut jr = 0;
                while jr < nc {
                    let nr = NR.min(nc - jr);
                    let bpanel = &bp[(jr / NR) * kc * NR..][..kc * NR];
                    let mut ir = 0;
                    while ir < mc {
                        let mr = MR.min(mc - ir);
                        let apanel = &ap[(ir / MR) * kc * MR..][..kc * MR];
                        let mut acc = [[0.0f32; NR]; MR];
                        if first {
                            match init {
                                Init::Bias(bias) => {
                                    for row in acc.iter_mut().take(mr) {
                                        let src = &bias[jc + jr..jc + jr + nr];
                                        row[..nr].copy_from_slice(src);
                                    }
                                }
                                Init::Zero => {}
                                Init::Acc => load_tile(out, &mut acc, ic + ir, jc + jr, mr, nr, n),
                            }
                        } else {
                            load_tile(out, &mut acc, ic + ir, jc + jr, mr, nr, n);
                        }
                        micro_kernel(kc, apanel, bpanel, &mut acc);
                        store_tile(out, &acc, ic + ir, jc + jr, mr, nr, n);
                        ir += MR;
                    }
                    jr += NR;
                }
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// MR×NR register tile: `acc[i][j] += Σ_kk ap[kk,i]·bp[kk,j]`, kk
/// ascending — the accumulation order every path in this module
/// preserves. Padded lanes (packed zeros) contribute exact zeros and
/// are never stored.
#[inline(always)]
fn micro_kernel(kc: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert_eq!(ap.len(), kc * MR);
    debug_assert_eq!(bp.len(), kc * NR);
    for (avec, bvec) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for (row, &ai) in acc.iter_mut().zip(avec.iter()) {
            for (c, &bj) in row.iter_mut().zip(bvec.iter()) {
                *c += ai * bj;
            }
        }
    }
}

/// Pack the `mc×kc` block of A at (r0, c0) into MR-tall panels:
/// `ap[panel·kc·MR + kk·MR + i]`, zero-padding the ragged tail panel.
#[allow(clippy::needless_range_loop)]
fn pack_a(a: View<'_>, ap: &mut [f32], r0: usize, c0: usize, mc: usize, kc: usize) {
    let panels = mc.div_ceil(MR);
    for p in 0..panels {
        let dst = &mut ap[p * kc * MR..][..kc * MR];
        let base = p * MR;
        for kk in 0..kc {
            for i in 0..MR {
                let r = base + i;
                dst[kk * MR + i] = if r < mc { a.at(r0 + r, c0 + kk) } else { 0.0 };
            }
        }
    }
}

/// Pack the `kc×nc` block of B at (r0, c0) into NR-wide panels:
/// `bp[panel·kc·NR + kk·NR + j]`, zero-padding the ragged tail panel.
#[allow(clippy::needless_range_loop)]
fn pack_b(b: View<'_>, bp: &mut [f32], r0: usize, c0: usize, kc: usize, nc: usize) {
    let panels = nc.div_ceil(NR);
    for p in 0..panels {
        let dst = &mut bp[p * kc * NR..][..kc * NR];
        let base = p * NR;
        for kk in 0..kc {
            for j in 0..NR {
                let c = base + j;
                dst[kk * NR + j] = if c < nc { b.at(r0 + kk, c0 + c) } else { 0.0 };
            }
        }
    }
}

#[inline]
fn load_tile(
    out: &[f32],
    acc: &mut [[f32; NR]; MR],
    r: usize,
    c: usize,
    mr: usize,
    nr: usize,
    ldc: usize,
) {
    for (i, row) in acc.iter_mut().take(mr).enumerate() {
        let src = &out[(r + i) * ldc + c..][..nr];
        row[..nr].copy_from_slice(src);
    }
}

#[inline]
fn store_tile(
    out: &mut [f32],
    acc: &[[f32; NR]; MR],
    r: usize,
    c: usize,
    mr: usize,
    nr: usize,
    ldc: usize,
) {
    for (i, row) in acc.iter().take(mr).enumerate() {
        let dst = &mut out[(r + i) * ldc + c..][..nr];
        dst.copy_from_slice(&row[..nr]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn matmul_bias_matches_reference_across_threads() {
        let mut rng = Rng::new(5);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 9, 5), (33, 47, 29), (64, 64, 64)] {
            let a = fill(&mut rng, m * k);
            let w = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            let mut want = vec![0.0f32; m * n];
            reference::matmul_bias(&a, &w, &bias, m, k, n, &mut want);
            for threads in [1usize, 2, 4, 8] {
                let mut got = vec![0.0f32; m * n];
                Gemm::new(threads).matmul_bias(&a, &w, &bias, m, k, n, &mut got);
                assert!(
                    max_abs_diff(&got, &want) <= 1e-5,
                    "m={m} k={k} n={n} t={threads}"
                );
            }
        }
    }

    #[test]
    fn backward_products_match_reference() {
        let mut rng = Rng::new(9);
        // Above SMALL_GEMM_WORK so the *blocked* backward paths run
        // (below it the entry points dispatch to reference directly).
        let (rows, din, dout) = (40, 33, 29);
        let a = fill(&mut rng, rows * din);
        let dz = fill(&mut rng, rows * dout);
        let w = fill(&mut rng, din * dout);
        let seed = fill(&mut rng, din * dout);

        let mut gw_want = seed.clone();
        reference::matmul_tn_acc(&a, &dz, rows, din, dout, &mut gw_want);
        let mut da_want = vec![0.0f32; rows * din];
        reference::matmul_nt(&dz, &w, rows, dout, din, &mut da_want);

        for threads in [1usize, 3, 8] {
            let g = Gemm::new(threads);
            let mut gw = seed.clone();
            g.matmul_tn_acc(&a, &dz, rows, din, dout, &mut gw);
            assert!(max_abs_diff(&gw, &gw_want) <= 1e-5, "tn t={threads}");
            let mut da = vec![1.0f32; rows * din];
            g.matmul_nt(&dz, &w, rows, dout, din, &mut da);
            assert!(max_abs_diff(&da, &da_want) <= 1e-5, "nt t={threads}");
        }
    }

    #[test]
    fn empty_dims_are_well_defined() {
        let g = Gemm::new(4);
        // K = 0: the product term is empty; bias broadcast remains.
        let bias = [1.5f32, -2.0];
        let mut z = vec![0.0f32; 3 * 2];
        g.matmul_bias(&[], &[], &bias, 3, 0, 2, &mut z);
        assert_eq!(z, vec![1.5, -2.0, 1.5, -2.0, 1.5, -2.0]);
        // K = 0 under Zero / Acc seeding.
        let mut da = vec![7.0f32; 4];
        g.matmul_nt(&[], &[], 2, 0, 2, &mut da);
        assert_eq!(da, vec![0.0; 4]);
        let mut gw = vec![3.0f32; 4];
        g.matmul_tn_acc(&[], &[], 0, 2, 2, &mut gw);
        assert_eq!(gw, vec![3.0; 4]);
        // M = 0 / N = 0: nothing to write.
        let mut empty: Vec<f32> = Vec::new();
        g.matmul_bias(&[], &[1.0, 2.0], &[0.5, 0.5], 0, 1, 2, &mut []);
        g.matmul_bias(&[1.0], &[], &[], 1, 1, 0, &mut empty);
    }

    #[test]
    fn combine_and_blend_match_reference() {
        let mut rng = Rng::new(3);
        let d = 1000;
        let rows: Vec<Vec<f32>> = (0..5).map(|_| fill(&mut rng, d)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let wts = [0.1f32, 0.4, 0.2, 0.05, 0.25];
        let mut want = vec![0.0f32; d];
        reference::combine_rows(&mut want, &refs, &wts);
        for threads in [1usize, 2, 8] {
            let mut got = vec![1.0f32; d];
            Gemm::new(threads).combine_rows(&mut got, &refs, &wts);
            assert!(max_abs_diff(&got, &want) <= 1e-5, "combine t={threads}");
        }

        let stacked = fill(&mut rng, 3 * d);
        let agg = fill(&mut rng, d);
        let mut out = vec![0.0f32; 3 * d];
        Gemm::new(4).blend_rows(&mut out, &stacked, &agg, 0.9);
        for i in 0..3 {
            for c in (0..d).step_by(97) {
                let want = 0.1 * stacked[i * d + c] + 0.9 * agg[c];
                assert!((out[i * d + c] - want).abs() < 1e-5, "row {i} col {c}");
            }
        }
    }

    #[test]
    fn threaded_combine_and_blend_engage_and_stay_bit_stable() {
        // Above PAR_MIN_WORK the column/row splits genuinely spawn;
        // results must still match the single-thread bits exactly.
        let mut rng = Rng::new(29);
        let d = 120_000; // p·d ≫ PAR_MIN_WORK
        let rows: Vec<Vec<f32>> = (0..3).map(|_| fill(&mut rng, d)).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let wts = [0.5f32, 0.3, 0.2];
        let mut base = vec![0.0f32; d];
        Gemm::single().combine_rows(&mut base, &refs, &wts);
        for threads in [2usize, 5] {
            let mut got = vec![0.0f32; d];
            Gemm::new(threads).combine_rows(&mut got, &refs, &wts);
            let same = base.iter().zip(got.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "combine_rows bits changed at t={threads}");
        }

        let stacked = fill(&mut rng, 3 * d);
        let agg = fill(&mut rng, d);
        let mut b1 = vec![0.0f32; 3 * d];
        Gemm::single().blend_rows(&mut b1, &stacked, &agg, 0.7);
        let mut b4 = vec![0.0f32; 3 * d];
        Gemm::new(4).blend_rows(&mut b4, &stacked, &agg, 0.7);
        let same = b1.iter().zip(b4.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "blend_rows bits changed under threading");
    }

    #[test]
    fn thread_counts_do_not_change_bits() {
        let mut rng = Rng::new(11);
        // Big enough to clear PAR_MIN_WORK so threads genuinely engage.
        let (m, k, n) = (97, 53, 61);
        let a = fill(&mut rng, m * k);
        let w = fill(&mut rng, k * n);
        let bias = fill(&mut rng, n);
        let mut base = vec![0.0f32; m * n];
        Gemm::single().matmul_bias(&a, &w, &bias, m, k, n, &mut base);
        for threads in [2usize, 4, 8] {
            let mut z = vec![0.0f32; m * n];
            Gemm::new(threads).matmul_bias(&a, &w, &bias, m, k, n, &mut z);
            let same = base.iter().zip(z.iter()).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "threads={threads} changed output bits");
        }
    }

    #[test]
    fn zero_threads_resolves_to_available_cores() {
        let g = Gemm::new(0);
        assert!(g.threads() >= 1);
        assert_eq!(Gemm::single().threads(), 1);
    }

    #[test]
    fn blocked_gemm_outpaces_naive_reference() {
        // Loose perf smoke for the acceptance bar "blocked ≥ 2× naive at
        // threads=2 on 256³". This runs inside `cargo test` (dev profile,
        // cores shared with other tests), so it only gates a much weaker
        // ratio; the precise speedup is measured and recorded in
        // BENCH_native.json by `cargo bench --bench gemm`.
        use std::time::Instant;
        let (m, k, n) = (256usize, 256usize, 256usize);
        let mut rng = Rng::new(1);
        let a = fill(&mut rng, m * k);
        let w = fill(&mut rng, k * n);
        let bias = fill(&mut rng, n);
        let mut z = vec![0.0f32; m * n];
        let time_min = |f: &mut dyn FnMut()| {
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let t0 = Instant::now();
                f();
                best = best.min(t0.elapsed().as_secs_f64());
            }
            best
        };
        let naive = time_min(&mut || reference::matmul_bias(&a, &w, &bias, m, k, n, &mut z));
        let g = Gemm::new(2);
        let blocked = time_min(&mut || g.matmul_bias(&a, &w, &bias, m, k, n, &mut z));
        let ratio = naive / blocked;
        assert!(
            ratio > 1.1,
            "blocked t=2 should clearly beat naive on 256³: {ratio:.2}× \
             (naive {naive:.4}s, blocked {blocked:.4}s)"
        );
    }
}
