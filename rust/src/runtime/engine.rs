//! The PJRT execution engine (feature `pjrt`): loads HLO-text artifacts
//! and runs them. One of the two [`Backend`] implementations — the
//! artifact-backed deployment path; `runtime::native` is the hermetic
//! twin.
//!
//! One [`Engine`] wraps one PJRT CPU client plus the compiled
//! executables of a model variant (`train_step`, `eval_step`, and one
//! `aggregate_p{p}` per cohort size). The engine is deliberately
//! *single-threaded* (`PjRtClient` is `Rc`-based); the threaded example
//! constructs one engine per worker thread, while the deterministic
//! simulation shares one engine across the round-robin worker schedule.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`) — see
//! DESIGN.md §1 for why serialized protos from jax ≥ 0.5 are rejected by
//! xla_extension 0.5.1.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{Backend, EvalOut, StepOut};
use super::manifest::Manifest;

/// PJRT artifact executor (the Pallas/TPU deployment path).
pub struct Engine {
    client: PjRtClient,
    /// The variant's flat ABI and baked shapes.
    pub manifest: Manifest,
    dir: PathBuf,
    train: PjRtLoadedExecutable,
    eval: PjRtLoadedExecutable,
    /// Aggregation executables per cohort size, compiled on demand.
    agg: RefCell<HashMap<usize, PjRtLoadedExecutable>>,
    /// Executions performed (telemetry for the perf pass).
    pub exec_count: RefCell<u64>,
}

impl Engine {
    /// Load and compile the artifacts of `variant` under `artifacts_root`.
    pub fn load(artifacts_root: &Path, variant: &str) -> Result<Self> {
        let dir = artifacts_root.join(variant);
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let train = Self::compile_file(&client, &dir.join("train_step.hlo.txt"))?;
        let eval = Self::compile_file(&client, &dir.join("eval_step.hlo.txt"))?;
        Ok(Self {
            client,
            manifest,
            dir,
            train,
            eval,
            agg: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
        })
    }

    fn compile_file(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    fn bump(&self) {
        *self.exec_count.borrow_mut() += 1;
    }

    /// Host → device transfer producing an *owned* buffer.
    ///
    /// We never use `PjRtLoadedExecutable::execute` (literal inputs): the
    /// crate's C shim leaks every input device buffer it creates
    /// (`buffer.release()` without a matching delete — ~2·D bytes per
    /// step at mnist_mlp scale, gigabytes per run). `execute_b` takes
    /// caller-owned buffers, and `PjRtBuffer`'s Drop frees them.
    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host→device f32 {dims:?}: {e:?}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host→device i32 {dims:?}: {e:?}"))
    }

    /// Run an executable over owned device buffers, fetch the (single,
    /// `return_tuple=True`) output literal.
    fn exec(&self, exe: &PjRtLoadedExecutable, bufs: &[PjRtBuffer]) -> Result<Literal> {
        let out = exe
            .execute_b::<PjRtBuffer>(bufs)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        self.bump();
        Ok(out)
    }

    /// One SGD step: consumes `params`, returns the updated vector plus
    /// the loss outputs. `x` is row-major [batch × input_dim], `y` holds
    /// the integer labels.
    pub fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, StepOut)> {
        let b = self.manifest.batch;
        let d = self.manifest.param_count;
        anyhow::ensure!(params.len() == d, "params len {} ≠ D {}", params.len(), d);
        anyhow::ensure!(
            x.len() == b * self.manifest.input_dim,
            "x len {} ≠ B·dim {}",
            x.len(),
            b * self.manifest.input_dim
        );
        anyhow::ensure!(y.len() == b, "y len {} ≠ B {}", y.len(), b);

        let bufs = [
            self.buf_f32(params, &[d])?,
            self.buf_f32(x, &[b, self.manifest.input_dim])?,
            self.buf_i32(y, &[b])?,
            self.buf_f32(&[lr], &[1])?,
        ];
        let out = self.exec(&self.train, &bufs)?;
        let (new_params, loss, per_ex) = out
            .to_tuple3()
            .map_err(|e| anyhow!("train_step tuple: {e:?}"))?;
        let new_params = new_params.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let loss = loss
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("{e:?}"))?;
        let per_example = per_ex.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok((new_params, StepOut { loss, per_example }))
    }

    /// One evaluation batch: summed loss + correct count.
    pub fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        let b = self.manifest.batch;
        let bufs = [
            self.buf_f32(params, &[self.manifest.param_count])?,
            self.buf_f32(x, &[b, self.manifest.input_dim])?,
            self.buf_i32(y, &[b])?,
        ];
        let out = self.exec(&self.eval, &bufs)?;
        let (sum_loss, correct) = out.to_tuple2().map_err(|e| anyhow!("{e:?}"))?;
        Ok(EvalOut {
            sum_loss: sum_loss.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            correct: correct.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    /// The paper's communication step via the Pallas aggregation artifact:
    /// `stacked` is row-major [p × D]; returns the β-mixed rows.
    /// Falls back with an error if no `aggregate_p{p}` artifact exists —
    /// callers may then use the host path (`linalg`).
    pub fn aggregate(
        &self,
        stacked: &[f32],
        h: &[f32],
        a_tilde: f32,
        beta: f32,
    ) -> Result<Vec<f32>> {
        let p = h.len();
        let d = self.manifest.param_count;
        anyhow::ensure!(stacked.len() == p * d, "stacked len {} ≠ p·D", stacked.len());
        self.ensure_agg(p)?;
        let agg_map = self.agg.borrow();
        let exe = agg_map.get(&p).unwrap();

        let bufs = [
            self.buf_f32(stacked, &[p, d])?,
            self.buf_f32(h, &[p])?,
            self.buf_f32(&[a_tilde], &[1])?,
            self.buf_f32(&[beta], &[1])?,
        ];
        let out = self.exec(exe, &bufs)?;
        let out = out.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Does an aggregation artifact exist for cohort size p?
    pub fn has_aggregate(&self, p: usize) -> bool {
        self.agg.borrow().contains_key(&p)
            || self.dir.join(format!("aggregate_p{p}.hlo.txt")).exists()
    }

    fn ensure_agg(&self, p: usize) -> Result<()> {
        if self.agg.borrow().contains_key(&p) {
            return Ok(());
        }
        let path = self.dir.join(format!("aggregate_p{p}.hlo.txt"));
        anyhow::ensure!(
            path.exists(),
            "no aggregation artifact for p={p} (looked at {}); regenerate with \
             `python -m compile.aot --workers …`",
            path.display()
        );
        let exe = Self::compile_file(&self.client, &path)
            .with_context(|| format!("compiling aggregate_p{p}"))?;
        self.agg.borrow_mut().insert(p, exe);
        Ok(())
    }

}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, StepOut)> {
        Engine::train_step(self, params, x, y, lr)
    }

    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        Engine::eval_batch(self, params, x, y)
    }

    fn aggregate(&self, stacked: &[f32], h: &[f32], a_tilde: f32, beta: f32) -> Result<Vec<f32>> {
        Engine::aggregate(self, stacked, h, a_tilde, beta)
    }

    fn has_aggregate(&self, p: usize) -> bool {
        Engine::has_aggregate(self, p)
    }

    fn exec_count(&self) -> u64 {
        *self.exec_count.borrow()
    }
}
