//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. One `manifest.json` per model variant describes the
//! flat-parameter ABI (so rust can He-initialise without python) and the
//! baked shapes of every HLO artifact in the directory.

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::rng::Rng;
use crate::util::json::Json;

/// One entry of the flat-parameter layout.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Fan-in for He initialisation: product of all but the last dim.
    pub fn fan_in(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    pub fn is_bias(&self) -> bool {
        self.name.ends_with("_b")
    }
}

/// `manifest.json` as written by `compile.aot.lower_variant`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub param_count: usize,
    pub batch: usize,
    pub input_dim: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub worker_counts: Vec<usize>,
    pub param_layout: Vec<ParamEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let body = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let m = Self::parse(&body).with_context(|| format!("parsing {}", path.display()))?;
        m.check()?;
        Ok(m)
    }

    /// Parse from JSON text (exposed for tests).
    pub fn parse(body: &str) -> Result<Self> {
        let j = Json::parse(body).map_err(|e| anyhow::anyhow!("{e}"))?;
        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            j.req_arr(key)?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("{key}: non-integer element"))
                })
                .collect()
        };
        let mut param_layout = Vec::new();
        for entry in j.req_arr("param_layout")? {
            let name = entry.req_str("name")?.to_string();
            let shape = entry
                .req_arr("shape")?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("param {name}: bad shape"))
                })
                .collect::<Result<Vec<_>>>()?;
            param_layout.push(ParamEntry { name, shape });
        }
        Ok(Manifest {
            name: j.req_str("name")?.to_string(),
            param_count: j.req_usize("param_count")?,
            batch: j.req_usize("batch")?,
            input_dim: j.req_usize("input_dim")?,
            input_shape: usize_arr("input_shape")?,
            num_classes: j.req_usize("num_classes")?,
            worker_counts: usize_arr("worker_counts")?,
            param_layout,
        })
    }

    /// Internal consistency: layout must tile `param_count` exactly.
    pub fn check(&self) -> Result<()> {
        let total: usize = self.param_layout.iter().map(|p| p.numel()).sum();
        anyhow::ensure!(
            total == self.param_count,
            "param layout sums to {total}, manifest says {}",
            self.param_count
        );
        let shape_prod: usize = self.input_shape.iter().product();
        anyhow::ensure!(
            shape_prod == self.input_dim,
            "input_shape {:?} does not match input_dim {}",
            self.input_shape,
            self.input_dim
        );
        Ok(())
    }

    /// He-normal init of the flat parameter vector (weights N(0, √(2/fan)),
    /// biases zero) — mirrors `compile.model.init_params`.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0x1417);
        let mut flat = Vec::with_capacity(self.param_count);
        for entry in &self.param_layout {
            if entry.is_bias() {
                flat.extend(std::iter::repeat(0.0f32).take(entry.numel()));
            } else {
                let std = (2.0 / entry.fan_in().max(1) as f32).sqrt();
                for _ in 0..entry.numel() {
                    flat.push(rng.normal_f32(0.0, std));
                }
            }
        }
        debug_assert_eq!(flat.len(), self.param_count);
        flat
    }

    /// Bytes of one parameter message on the wire (f32 payload + h + tag).
    pub fn message_bytes(&self) -> usize {
        self.param_count * 4 + 4 + 8
    }

    /// Build an MLP manifest programmatically — the native backend's
    /// artifact-free path. Layout mirrors `compile.model.param_shapes`:
    /// alternating `dense{i}_w [din, dout]` / `dense{i}_b [dout]`.
    pub fn mlp(
        name: &str,
        input_dim: usize,
        hidden: &[usize],
        classes: usize,
        batch: usize,
    ) -> Self {
        let mut dims = Vec::with_capacity(hidden.len() + 2);
        dims.push(input_dim);
        dims.extend_from_slice(hidden);
        dims.push(classes);
        let mut param_layout = Vec::new();
        for i in 0..dims.len() - 1 {
            param_layout.push(ParamEntry {
                name: format!("dense{i}_w"),
                shape: vec![dims[i], dims[i + 1]],
            });
            param_layout.push(ParamEntry { name: format!("dense{i}_b"), shape: vec![dims[i + 1]] });
        }
        let param_count = param_layout.iter().map(|p| p.numel()).sum();
        Manifest {
            name: name.to_string(),
            param_count,
            batch,
            input_dim,
            input_shape: vec![input_dim],
            num_classes: classes,
            worker_counts: vec![2, 4, 8, 16],
            param_layout,
        }
    }

    /// Built-in manifests for the MLP variants — shape-identical to the
    /// registry in `python/compile/model.py` (`VARIANTS`), so the native
    /// backend speaks the same flat ABI the PJRT artifacts would.
    pub fn native_variant(variant: &str) -> Option<Self> {
        Some(match variant {
            "tiny_mlp" => Self::mlp("tiny_mlp", 16, &[8], 2, 8),
            "mnist_mlp" => Self::mlp("mnist_mlp", 784, &[256, 128], 10, 32),
            "fashion_mlp" => Self::mlp("fashion_mlp", 784, &[256, 128], 10, 32),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "name": "toy", "param_count": 14, "batch": 2,
              "input_dim": 3, "input_shape": [3], "num_classes": 2,
              "worker_counts": [2, 4],
              "param_layout": [
                {"name": "dense0_w", "shape": [3, 4]},
                {"name": "dense0_b", "shape": [2]}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_extracts_fields() {
        let m = toy_manifest();
        assert_eq!(m.name, "toy");
        assert_eq!(m.worker_counts, vec![2, 4]);
        assert_eq!(m.param_layout.len(), 2);
        assert_eq!(m.param_layout[0].shape, vec![3, 4]);
        assert!(m.param_layout[1].is_bias());
    }

    #[test]
    fn check_passes_consistent() {
        assert!(toy_manifest().check().is_ok());
    }

    #[test]
    fn check_rejects_bad_total() {
        let mut m = toy_manifest();
        m.param_count = 99;
        assert!(m.check().is_err());
    }

    #[test]
    fn parse_rejects_missing_field() {
        assert!(Manifest::parse(r#"{"name": "x"}"#).is_err());
    }

    #[test]
    fn mlp_presets_match_python_variants() {
        // Shape math mirrors compile.model.param_count for the registry.
        let tiny = Manifest::native_variant("tiny_mlp").unwrap();
        assert_eq!(tiny.param_count, 16 * 8 + 8 + 8 * 2 + 2);
        assert_eq!(tiny.batch, 8);
        assert!(tiny.check().is_ok());
        let mnist = Manifest::native_variant("mnist_mlp").unwrap();
        assert_eq!(
            mnist.param_count,
            784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10
        );
        assert_eq!(mnist.batch, 32);
        assert!(mnist.check().is_ok());
        assert!(Manifest::native_variant("cifar_cnn10").is_none());
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let m = toy_manifest();
        let a = m.init_params(1);
        let b = m.init_params(1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 14);
        // Bias tail is zero.
        assert!(a[12..].iter().all(|&v| v == 0.0));
        // Weights are not all zero.
        assert!(a[..12].iter().any(|&v| v != 0.0));
    }
}
