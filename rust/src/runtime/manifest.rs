//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. One `manifest.json` per model variant describes the
//! flat-parameter ABI (so rust can He-initialise without python) and the
//! baked shapes of every HLO artifact in the directory.

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::rng::Rng;
use crate::util::json::Json;

/// One entry of the flat-parameter layout.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    /// Parameter tensor name ("dense0_w", "conv1_b", …).
    pub name: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
}

impl ParamEntry {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Fan-in for He initialisation: product of all but the last dim.
    pub fn fan_in(&self) -> usize {
        if self.shape.len() <= 1 {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    /// Is this a bias tensor (zero-initialised)?
    pub fn is_bias(&self) -> bool {
        self.name.ends_with("_b")
    }
}

/// `manifest.json` as written by `compile.aot.lower_variant`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Model variant name.
    pub name: String,
    /// Total flat parameter count D.
    pub param_count: usize,
    /// Baked batch size B.
    pub batch: usize,
    /// Flat input feature count.
    pub input_dim: usize,
    /// Input shape (e.g. `[32, 32, 3]` for NHWC images).
    pub input_shape: Vec<usize>,
    /// Number of output logits.
    pub num_classes: usize,
    /// Cohort sizes the artifact set was lowered for.
    pub worker_counts: Vec<usize>,
    /// The flat-parameter ABI, in layout order.
    pub param_layout: Vec<ParamEntry>,
}

impl Manifest {
    /// Load and validate `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let body = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let m = Self::parse(&body).with_context(|| format!("parsing {}", path.display()))?;
        m.check()?;
        Ok(m)
    }

    /// Parse from JSON text (exposed for tests).
    pub fn parse(body: &str) -> Result<Self> {
        let j = Json::parse(body).map_err(|e| anyhow::anyhow!("{e}"))?;
        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            j.req_arr(key)?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("{key}: non-integer element"))
                })
                .collect()
        };
        let mut param_layout = Vec::new();
        for entry in j.req_arr("param_layout")? {
            let name = entry.req_str("name")?.to_string();
            let shape = entry
                .req_arr("shape")?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("param {name}: bad shape"))
                })
                .collect::<Result<Vec<_>>>()?;
            param_layout.push(ParamEntry { name, shape });
        }
        Ok(Manifest {
            name: j.req_str("name")?.to_string(),
            param_count: j.req_usize("param_count")?,
            batch: j.req_usize("batch")?,
            input_dim: j.req_usize("input_dim")?,
            input_shape: usize_arr("input_shape")?,
            num_classes: j.req_usize("num_classes")?,
            worker_counts: usize_arr("worker_counts")?,
            param_layout,
        })
    }

    /// Internal consistency: layout must tile `param_count` exactly.
    pub fn check(&self) -> Result<()> {
        let total: usize = self.param_layout.iter().map(|p| p.numel()).sum();
        anyhow::ensure!(
            total == self.param_count,
            "param layout sums to {total}, manifest says {}",
            self.param_count
        );
        let shape_prod: usize = self.input_shape.iter().product();
        anyhow::ensure!(
            shape_prod == self.input_dim,
            "input_shape {:?} does not match input_dim {}",
            self.input_shape,
            self.input_dim
        );
        Ok(())
    }

    /// He-normal init of the flat parameter vector (weights N(0, √(2/fan)),
    /// biases zero) — mirrors `compile.model.init_params`.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0x1417);
        let mut flat = Vec::with_capacity(self.param_count);
        for entry in &self.param_layout {
            if entry.is_bias() {
                flat.extend(std::iter::repeat(0.0f32).take(entry.numel()));
            } else {
                let std = (2.0 / entry.fan_in().max(1) as f32).sqrt();
                for _ in 0..entry.numel() {
                    flat.push(rng.normal_f32(0.0, std));
                }
            }
        }
        debug_assert_eq!(flat.len(), self.param_count);
        flat
    }

    /// Bytes of one parameter message on the wire (f32 payload + h + tag).
    pub fn message_bytes(&self) -> usize {
        self.param_count * 4 + 4 + 8
    }

    /// Build an MLP manifest programmatically — the native backend's
    /// artifact-free path. Layout mirrors `compile.model.param_shapes`:
    /// alternating `dense{i}_w [din, dout]` / `dense{i}_b [dout]`.
    pub fn mlp(
        name: &str,
        input_dim: usize,
        hidden: &[usize],
        classes: usize,
        batch: usize,
    ) -> Self {
        let mut dims = Vec::with_capacity(hidden.len() + 2);
        dims.push(input_dim);
        dims.extend_from_slice(hidden);
        dims.push(classes);
        let mut param_layout = Vec::new();
        for i in 0..dims.len() - 1 {
            param_layout.push(ParamEntry {
                name: format!("dense{i}_w"),
                shape: vec![dims[i], dims[i + 1]],
            });
            param_layout.push(ParamEntry { name: format!("dense{i}_b"), shape: vec![dims[i + 1]] });
        }
        let param_count = param_layout.iter().map(|p| p.numel()).sum();
        Manifest {
            name: name.to_string(),
            param_count,
            batch,
            input_dim,
            input_shape: vec![input_dim],
            num_classes: classes,
            worker_counts: vec![2, 4, 8, 16],
            param_layout,
        }
    }

    /// Build a CNN manifest programmatically — mirrors
    /// `compile.model._cnn`: a stack of 3×3 SAME convs (`conv{i}_w
    /// [3, 3, cin, cout]` / `conv{i}_b [cout]`), each followed by a 2×2
    /// max-pool when its `pool` flag is set, then dense layers over the
    /// flattened NHWC activations. Layer indices run over the whole
    /// stack, matching the Python registry's naming.
    pub fn cnn(
        name: &str,
        hw: usize,
        cin: usize,
        convs: &[(usize, bool)],
        hidden: &[usize],
        classes: usize,
        batch: usize,
    ) -> Self {
        // The flat ABI cannot record pool placement; the native engine
        // re-infers the pool count from the head's fan-in and attaches
        // the pools to the *leading* convs. Reject stacks the inference
        // would silently reorder (a pooled conv after an unpooled one)
        // instead of training a different network than the layout's
        // author wrote. Every registry variant pools after every conv.
        let mut seen_unpooled = false;
        for &(_, pool) in convs {
            assert!(
                !(pool && seen_unpooled),
                "{name}: pool flags must be leading (pooled convs before unpooled ones) — \
                 the flat ABI cannot represent trailing pools"
            );
            seen_unpooled |= !pool;
        }
        let mut param_layout = Vec::new();
        let (mut c, mut side) = (cin, hw);
        for (i, &(cout, pool)) in convs.iter().enumerate() {
            param_layout.push(ParamEntry { name: format!("conv{i}_w"), shape: vec![3, 3, c, cout] });
            param_layout.push(ParamEntry { name: format!("conv{i}_b"), shape: vec![cout] });
            c = cout;
            if pool {
                side /= 2;
            }
        }
        let mut dims = Vec::with_capacity(hidden.len() + 2);
        dims.push(side * side * c);
        dims.extend_from_slice(hidden);
        dims.push(classes);
        for i in 0..dims.len() - 1 {
            let li = convs.len() + i;
            param_layout.push(ParamEntry {
                name: format!("dense{li}_w"),
                shape: vec![dims[i], dims[i + 1]],
            });
            param_layout.push(ParamEntry { name: format!("dense{li}_b"), shape: vec![dims[i + 1]] });
        }
        let param_count = param_layout.iter().map(|p| p.numel()).sum();
        Manifest {
            name: name.to_string(),
            param_count,
            batch,
            input_dim: hw * hw * cin,
            input_shape: vec![hw, hw, cin],
            num_classes: classes,
            worker_counts: vec![2, 4, 8, 16],
            param_layout,
        }
    }

    /// Variant names with a built-in native preset (what the native
    /// backend runs with zero artifacts) — kept in registry order.
    pub const NATIVE_VARIANTS: [&'static str; 8] = [
        "tiny_mlp",
        "mnist_mlp",
        "fashion_mlp",
        "tiny_cnn",
        "mnist_cnn",
        "cifar_cnn10",
        "cifar_cnn100",
        "cifar_cnn_paper",
    ];

    /// Built-in manifests for the model variants — shape-identical to the
    /// registry in `python/compile/model.py` (`VARIANTS`), so the native
    /// backend speaks the same flat ABI the PJRT artifacts would.
    pub fn native_variant(variant: &str) -> Option<Self> {
        Some(match variant {
            "tiny_mlp" => Self::mlp("tiny_mlp", 16, &[8], 2, 8),
            "mnist_mlp" => Self::mlp("mnist_mlp", 784, &[256, 128], 10, 32),
            "fashion_mlp" => Self::mlp("fashion_mlp", 784, &[256, 128], 10, 32),
            "tiny_cnn" => Self::cnn("tiny_cnn", 8, 1, &[(4, true), (8, true)], &[], 2, 4),
            "mnist_cnn" => Self::cnn("mnist_cnn", 28, 1, &[(16, true), (32, true)], &[], 10, 32),
            "cifar_cnn10" => {
                Self::cnn("cifar_cnn10", 32, 3, &[(16, true), (32, true), (64, true)], &[128], 10, 32)
            }
            "cifar_cnn100" => Self::cnn(
                "cifar_cnn100",
                32,
                3,
                &[(16, true), (32, true), (64, true)],
                &[128],
                100,
                32,
            ),
            // §5.2.1's 8-layer stack: (3,32)C(64,32)M … M(512,2).
            "cifar_cnn_paper" => Self::cnn(
                "cifar_cnn_paper",
                32,
                3,
                &[(64, true), (128, true), (256, true), (512, true)],
                &[128, 256, 512, 1024],
                10,
                16,
            ),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            r#"{
              "name": "toy", "param_count": 14, "batch": 2,
              "input_dim": 3, "input_shape": [3], "num_classes": 2,
              "worker_counts": [2, 4],
              "param_layout": [
                {"name": "dense0_w", "shape": [3, 4]},
                {"name": "dense0_b", "shape": [2]}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_extracts_fields() {
        let m = toy_manifest();
        assert_eq!(m.name, "toy");
        assert_eq!(m.worker_counts, vec![2, 4]);
        assert_eq!(m.param_layout.len(), 2);
        assert_eq!(m.param_layout[0].shape, vec![3, 4]);
        assert!(m.param_layout[1].is_bias());
    }

    #[test]
    fn check_passes_consistent() {
        assert!(toy_manifest().check().is_ok());
    }

    #[test]
    fn check_rejects_bad_total() {
        let mut m = toy_manifest();
        m.param_count = 99;
        assert!(m.check().is_err());
    }

    #[test]
    fn parse_rejects_missing_field() {
        assert!(Manifest::parse(r#"{"name": "x"}"#).is_err());
    }

    #[test]
    fn mlp_presets_match_python_variants() {
        // Shape math mirrors compile.model.param_count for the registry.
        let tiny = Manifest::native_variant("tiny_mlp").unwrap();
        assert_eq!(tiny.param_count, 16 * 8 + 8 + 8 * 2 + 2);
        assert_eq!(tiny.batch, 8);
        assert!(tiny.check().is_ok());
        let mnist = Manifest::native_variant("mnist_mlp").unwrap();
        assert_eq!(
            mnist.param_count,
            784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10
        );
        assert_eq!(mnist.batch, 32);
        assert!(mnist.check().is_ok());
        assert!(Manifest::native_variant("not_a_variant").is_none());
    }

    #[test]
    fn cnn_presets_match_python_variants() {
        // Shape math mirrors compile.model.param_count for the registry.
        let tiny = Manifest::native_variant("tiny_cnn").unwrap();
        // conv0: 3·3·1·4+4, conv1: 3·3·4·8+8, dense2: (2·2·8)·2+2.
        assert_eq!(tiny.param_count, 36 + 4 + 288 + 8 + 64 + 2);
        assert_eq!(tiny.input_shape, vec![8, 8, 1]);
        assert_eq!(tiny.input_dim, 64);
        assert!(tiny.check().is_ok());

        let c10 = Manifest::native_variant("cifar_cnn10").unwrap();
        // convs: 3·3·3·16+16, 3·3·16·32+32, 3·3·32·64+64;
        // dense3: (4·4·64)·128+128; dense4: 128·10+10.
        assert_eq!(
            c10.param_count,
            432 + 16 + 4608 + 32 + 18432 + 64 + 1024 * 128 + 128 + 1280 + 10
        );
        assert_eq!(c10.input_dim, 3072);
        assert_eq!(c10.input_shape, vec![32, 32, 3]);
        assert_eq!(c10.batch, 32);
        assert!(c10.check().is_ok());
        // Layout names follow the Python layer enumeration (convs first).
        assert_eq!(c10.param_layout[0].name, "conv0_w");
        assert_eq!(c10.param_layout[6].name, "dense3_w");
        assert!(c10.param_layout[1].is_bias());

        let c100 = Manifest::native_variant("cifar_cnn100").unwrap();
        assert_eq!(c100.num_classes, 100);
        assert_eq!(c100.param_count, c10.param_count - 1290 + 128 * 100 + 100);
        assert!(c100.check().is_ok());

        let mn = Manifest::native_variant("mnist_cnn").unwrap();
        assert_eq!(mn.param_count, 144 + 16 + 4608 + 32 + 7 * 7 * 32 * 10 + 10);
        assert!(mn.check().is_ok());

        let paper = Manifest::native_variant("cifar_cnn_paper").unwrap();
        assert_eq!(paper.batch, 16);
        assert!(paper.check().is_ok());
        // Leading-pool stacks other than all-pool are representable too.
        let partial = Manifest::cnn("partial", 8, 1, &[(4, true), (8, false)], &[], 2, 4);
        assert!(partial.check().is_ok());
        assert_eq!(partial.param_layout[4].shape, vec![4 * 4 * 8, 2]);
        // Every listed native variant must build and be self-consistent.
        for v in Manifest::NATIVE_VARIANTS {
            assert!(Manifest::native_variant(v).unwrap().check().is_ok(), "{v}");
        }
    }

    #[test]
    #[should_panic(expected = "pool flags must be leading")]
    fn cnn_rejects_trailing_pools() {
        // The flat ABI cannot say *where* pools sit; the native engine
        // re-infers them onto the leading convs, so a trailing-pool stack
        // would silently train a different network — reject at build.
        let _ = Manifest::cnn("trailing", 8, 1, &[(4, false), (8, true)], &[], 2, 4);
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        let m = toy_manifest();
        let a = m.init_params(1);
        let b = m.init_params(1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 14);
        // Bias tail is zero.
        assert!(a[12..].iter().all(|&v| v == 0.0));
        // Weights are not all zero.
        assert!(a[..12].iter().any(|&v| v != 0.0));
    }
}
