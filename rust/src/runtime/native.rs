//! The native execution engine: pure-Rust forward/backward for the MLP
//! variants plus the paper's Boltzmann aggregation kernel — no Python,
//! no JAX, no HLO artifacts.
//!
//! This is the hermetic twin of the PJRT [`Engine`](super::engine::Engine):
//! it implements the same flat-parameter ABI ([`Manifest`]) and the same
//! three entry points (`train_step`, `eval_step`, `aggregate`) with the
//! same semantics as `python/compile/model.py` and
//! `python/compile/kernels/aggregate.py`:
//!
//! * `train_step` — dense layers `a ← relu(a·W + b)`, fused softmax
//!   cross-entropy with per-example losses (the free Eq. 26 byproduct),
//!   exact reverse-mode gradients, plain SGD update `θ ← θ − η·∇`;
//! * `eval_step` — summed loss + correct count (first-max argmax, like
//!   `jnp.argmax`);
//! * `aggregate` — Eq. 10+13: θ = softmax(−ã·h/Σh), then
//!   `xᵢ ← (1−β)xᵢ + β·Σⱼθⱼxⱼ`, computed over column panels exactly like
//!   the Pallas kernel tiles VMEM (the `tests/native_parity.rs` fixture
//!   pins it against the Python reference kernels at ≤1e-5).
//!
//! All state is a pure function of the [`Manifest`] and the caller's
//! parameter vector; initialisation runs through [`crate::rng::Rng`]
//! (`Manifest::init_params`), so runs are bit-deterministic across hosts
//! without any artifacts on disk.

use std::cell::Cell;

use anyhow::{ensure, Result};

use crate::linalg;

use super::backend::{Backend, EvalOut, StepOut};
use super::manifest::Manifest;

/// Column-panel width of the aggregation loop — mirrors the Pallas
/// kernel's VMEM tiling (`DEFAULT_BD` in `aggregate.py`); here it keeps
/// the θ·X panel resident in L1/L2.
const AGG_PANEL: usize = 8192;

/// One dense layer's slice of the flat parameter vector.
#[derive(Clone, Copy, Debug)]
struct DenseLayer {
    din: usize,
    dout: usize,
    /// Offset of the [din × dout] weight block in the flat vector.
    w_off: usize,
    /// Offset of the [dout] bias block.
    b_off: usize,
    /// ReLU after the affine map (false for the logits layer).
    relu: bool,
}

/// Pure-Rust MLP engine implementing [`Backend`].
pub struct NativeEngine {
    manifest: Manifest,
    layers: Vec<DenseLayer>,
    exec_count: Cell<u64>,
}

impl NativeEngine {
    /// Build from a manifest. Fails for non-MLP layouts (conv weights are
    /// 4-D — those variants need the PJRT backend).
    pub fn new(manifest: Manifest) -> Result<Self> {
        manifest.check()?;
        let entries = &manifest.param_layout;
        ensure!(
            entries.len() >= 2 && entries.len() % 2 == 0,
            "native backend expects (weight, bias) pairs, got {} layout entries",
            entries.len()
        );
        let mut layers = Vec::with_capacity(entries.len() / 2);
        let mut off = 0usize;
        for pair in entries.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(
                w.shape.len() == 2 && !w.is_bias() && b.shape.len() == 1 && b.is_bias(),
                "native backend supports dense (w[din,dout], b[dout]) pairs only; \
                 got {:?}{:?} / {:?}{:?} — use the pjrt backend for CNN variants",
                w.name,
                w.shape,
                b.name,
                b.shape
            );
            let (din, dout) = (w.shape[0], w.shape[1]);
            ensure!(b.shape[0] == dout, "bias {} does not match weight {}", b.name, w.name);
            let w_off = off;
            off += w.numel();
            let b_off = off;
            off += b.numel();
            layers.push(DenseLayer { din, dout, w_off, b_off, relu: true });
        }
        ensure!(
            layers.first().unwrap().din == manifest.input_dim,
            "first layer din {} ≠ input_dim {}",
            layers[0].din,
            manifest.input_dim
        );
        ensure!(
            layers.last().unwrap().dout == manifest.num_classes,
            "last layer dout {} ≠ num_classes {}",
            layers.last().unwrap().dout,
            manifest.num_classes
        );
        for w in layers.windows(2) {
            ensure!(w[0].dout == w[1].din, "layer dims do not chain");
        }
        layers.last_mut().unwrap().relu = false;
        Ok(Self { manifest, layers, exec_count: Cell::new(0) })
    }

    /// Build for a built-in variant preset (`tiny_mlp`, `mnist_mlp`, …).
    pub fn for_variant(variant: &str) -> Result<Self> {
        let m = Manifest::native_variant(variant)
            .ok_or_else(|| anyhow::anyhow!("no native preset for variant {variant:?}"))?;
        Self::new(m)
    }

    fn bump(&self) {
        self.exec_count.set(self.exec_count.get() + 1);
    }

    fn check_shapes(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<()> {
        let m = &self.manifest;
        ensure!(
            params.len() == m.param_count,
            "params len {} ≠ D {}",
            params.len(),
            m.param_count
        );
        ensure!(
            x.len() == m.batch * m.input_dim,
            "x len {} ≠ B·dim {}",
            x.len(),
            m.batch * m.input_dim
        );
        ensure!(y.len() == m.batch, "y len {} ≠ B {}", y.len(), m.batch);
        for &label in y {
            ensure!(
                (0..m.num_classes as i32).contains(&label),
                "label {label} out of range [0, {})",
                m.num_classes
            );
        }
        Ok(())
    }

    /// Forward pass: returns the per-layer activations (a₀ = x, …,
    /// a_L = logits), post-ReLU for hidden layers.
    fn forward(&self, params: &[f32], x: &[f32], batch: usize) -> Vec<Vec<f32>> {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.layers.len() + 1);
        acts.push(x.to_vec());
        for layer in &self.layers {
            let a_prev = acts.last().unwrap();
            let w = &params[layer.w_off..layer.w_off + layer.din * layer.dout];
            let b = &params[layer.b_off..layer.b_off + layer.dout];
            let mut z = vec![0.0f32; batch * layer.dout];
            matmul_bias(a_prev, w, b, batch, layer.din, layer.dout, &mut z);
            if layer.relu {
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            acts.push(z);
        }
        acts
    }

    /// Fused softmax cross-entropy over logits: per-example losses and,
    /// optionally, dlogits = softmax − onehot (gradient of the *sum*).
    fn softmax_xent(
        logits: &[f32],
        y: &[i32],
        classes: usize,
        mut dlogits: Option<&mut [f32]>,
    ) -> Vec<f32> {
        let batch = y.len();
        let mut per_ex = vec![0.0f32; batch];
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for &v in row {
                denom += (v - m).exp();
            }
            let ln_denom = denom.ln();
            let label = y[n] as usize;
            per_ex[n] = ln_denom - (row[label] - m);
            if let Some(dl) = dlogits.as_deref_mut() {
                let drow = &mut dl[n * classes..(n + 1) * classes];
                for (k, &v) in row.iter().enumerate() {
                    drow[k] = (v - m).exp() / denom;
                }
                drow[label] -= 1.0;
            }
        }
        per_ex
    }
}

/// z[n,k] = Σⱼ a[n,j]·w[j,k] + b[k] — unit-stride inner loops so the
/// autovectoriser gets contiguous rows of `w`.
fn matmul_bias(
    a: &[f32],
    w: &[f32],
    b: &[f32],
    batch: usize,
    din: usize,
    dout: usize,
    z: &mut [f32],
) {
    for n in 0..batch {
        let zrow = &mut z[n * dout..(n + 1) * dout];
        zrow.copy_from_slice(b);
        let arow = &a[n * din..(n + 1) * din];
        for (j, &aj) in arow.iter().enumerate() {
            if aj == 0.0 {
                continue; // ReLU sparsity: skip dead activations
            }
            let wrow = &w[j * dout..(j + 1) * dout];
            for (zk, &wk) in zrow.iter_mut().zip(wrow.iter()) {
                *zk += aj * wk;
            }
        }
    }
}

impl Backend for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, StepOut)> {
        self.check_shapes(params, x, y)?;
        let batch = self.manifest.batch;
        let classes = self.manifest.num_classes;

        let acts = self.forward(params, x, batch);
        let logits = acts.last().unwrap();
        let mut dlogits = vec![0.0f32; batch * classes];
        let per_example = Self::softmax_xent(logits, y, classes, Some(&mut dlogits));
        let loss = per_example.iter().sum::<f32>() / batch as f32;

        // Gradient of the *mean* loss.
        let inv_b = 1.0 / batch as f32;
        for v in dlogits.iter_mut() {
            *v *= inv_b;
        }

        // Reverse pass. dz starts as dlogits; per layer:
        //   dW[j,k] = Σₙ a_prev[n,j]·dz[n,k]     db[k] = Σₙ dz[n,k]
        //   da_prev[n,j] = Σₖ dz[n,k]·W[j,k], masked by ReLU (a_prev > 0).
        let mut grad = vec![0.0f32; params.len()];
        let mut dz = dlogits;
        for (li, layer) in self.layers.iter().enumerate().rev() {
            let a_prev = &acts[li];
            {
                let gw = &mut grad[layer.w_off..layer.w_off + layer.din * layer.dout];
                for n in 0..batch {
                    let arow = &a_prev[n * layer.din..(n + 1) * layer.din];
                    let dzrow = &dz[n * layer.dout..(n + 1) * layer.dout];
                    for (j, &aj) in arow.iter().enumerate() {
                        if aj == 0.0 {
                            continue;
                        }
                        let grow = &mut gw[j * layer.dout..(j + 1) * layer.dout];
                        for (g, &d) in grow.iter_mut().zip(dzrow.iter()) {
                            *g += aj * d;
                        }
                    }
                }
            }
            {
                let gb = &mut grad[layer.b_off..layer.b_off + layer.dout];
                for n in 0..batch {
                    let dzrow = &dz[n * layer.dout..(n + 1) * layer.dout];
                    for (g, &d) in gb.iter_mut().zip(dzrow.iter()) {
                        *g += d;
                    }
                }
            }
            if li > 0 {
                let w = &params[layer.w_off..layer.w_off + layer.din * layer.dout];
                let mut da = vec![0.0f32; batch * layer.din];
                for n in 0..batch {
                    let dzrow = &dz[n * layer.dout..(n + 1) * layer.dout];
                    let darow = &mut da[n * layer.din..(n + 1) * layer.din];
                    let arow = &a_prev[n * layer.din..(n + 1) * layer.din];
                    for (j, dv) in darow.iter_mut().enumerate() {
                        if arow[j] <= 0.0 {
                            continue; // ReLU gate (hidden activations are post-ReLU)
                        }
                        let wrow = &w[j * layer.dout..(j + 1) * layer.dout];
                        let mut acc = 0.0f32;
                        for (&d, &wk) in dzrow.iter().zip(wrow.iter()) {
                            acc += d * wk;
                        }
                        *dv = acc;
                    }
                }
                dz = da;
            }
        }

        let mut new_params = params.to_vec();
        linalg::axpy(&mut new_params, -lr, &grad);
        self.bump();
        Ok((new_params, StepOut { loss, per_example }))
    }

    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        self.check_shapes(params, x, y)?;
        let batch = self.manifest.batch;
        let classes = self.manifest.num_classes;
        let acts = self.forward(params, x, batch);
        let logits = acts.last().unwrap();
        let per_ex = Self::softmax_xent(logits, y, classes, None);
        let mut correct = 0.0f32;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            if linalg::argmax(row) as i32 == y[n] {
                correct += 1.0;
            }
        }
        self.bump();
        Ok(EvalOut { sum_loss: per_ex.iter().sum(), correct })
    }

    fn aggregate(&self, stacked: &[f32], h: &[f32], a_tilde: f32, beta: f32) -> Result<Vec<f32>> {
        let p = h.len();
        ensure!(p > 0, "empty cohort");
        ensure!(stacked.len() % p == 0, "stacked len {} not divisible by p={p}", stacked.len());
        let d = stacked.len() / p;
        let theta = linalg::boltzmann_weights(h, a_tilde);
        let keep = 1.0 - beta;

        let mut out = vec![0.0f32; p * d];
        let mut agg = vec![0.0f32; AGG_PANEL.min(d)];
        // Column panels, mirroring the Pallas kernel's grid over D.
        let mut col = 0;
        while col < d {
            let w = AGG_PANEL.min(d - col);
            let agg = &mut agg[..w];
            agg.fill(0.0);
            for (i, &th) in theta.iter().enumerate() {
                let row = &stacked[i * d + col..i * d + col + w];
                linalg::axpy(agg, th, row);
            }
            for i in 0..p {
                let src = &stacked[i * d + col..i * d + col + w];
                let dst = &mut out[i * d + col..i * d + col + w];
                for ((o, &x), &a) in dst.iter_mut().zip(src.iter()).zip(agg.iter()) {
                    *o = keep * x + beta * a;
                }
            }
            col += w;
        }
        self.bump();
        Ok(out)
    }

    fn has_aggregate(&self, _p: usize) -> bool {
        true
    }

    fn exec_count(&self) -> u64 {
        self.exec_count.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny() -> NativeEngine {
        NativeEngine::for_variant("tiny_mlp").unwrap()
    }

    fn rand_batch(e: &NativeEngine, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let m = e.manifest();
        let mut rng = Rng::new(seed);
        let params = m.init_params(seed);
        let mut x = vec![0.0f32; m.batch * m.input_dim];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y: Vec<i32> = (0..m.batch).map(|_| rng.below(m.num_classes) as i32).collect();
        (params, x, y)
    }

    #[test]
    fn lr_zero_is_identity_and_counts_execs() {
        let e = tiny();
        let (params, x, y) = rand_batch(&e, 1);
        let (next, out) = e.train_step(&params, &x, &y, 0.0).unwrap();
        assert_eq!(next, params);
        assert!(out.loss.is_finite());
        assert_eq!(out.per_example.len(), e.manifest().batch);
        let mean: f32 = out.per_example.iter().sum::<f32>() / out.per_example.len() as f32;
        assert!((mean - out.loss).abs() < 1e-5);
        assert_eq!(e.exec_count(), 1);
    }

    #[test]
    fn overfitting_one_batch_reduces_loss() {
        let e = tiny();
        let (mut params, x, y) = rand_batch(&e, 3);
        let (_, first) = e.train_step(&params, &x, &y, 0.0).unwrap();
        let mut last = first.loss;
        for _ in 0..80 {
            let (next, out) = e.train_step(&params, &x, &y, 0.1).unwrap();
            params = next;
            last = out.loss;
        }
        assert!(last < first.loss * 0.7, "{} → {last}", first.loss);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let e = tiny();
        let (params, x, y) = rand_batch(&e, 5);
        let d = params.len();
        // Analytic gradient, recovered from one lr=1 step.
        let (stepped, base) = e.train_step(&params, &x, &y, 1.0).unwrap();
        let grad: Vec<f32> = params.iter().zip(stepped.iter()).map(|(p, s)| p - s).collect();
        let loss_at = |th: &[f32]| -> f64 {
            let (_, out) = e.train_step(th, &x, &y, 0.0).unwrap();
            out.loss as f64
        };
        assert!((loss_at(&params) - base.loss as f64).abs() < 1e-6);
        // Spot-check coordinates across the whole vector.
        let eps = 1e-3f32;
        let mut rng = Rng::new(17);
        for _ in 0..24 {
            let k = rng.below(d);
            let mut plus = params.clone();
            plus[k] += eps;
            let mut minus = params.clone();
            minus[k] -= eps;
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps as f64);
            let analytic = grad[k] as f64;
            assert!(
                (numeric - analytic).abs() < 2e-3,
                "coord {k}: numeric {numeric:.6} vs analytic {analytic:.6}"
            );
        }
    }

    #[test]
    fn eval_matches_train_loss_semantics() {
        let e = tiny();
        let (params, x, y) = rand_batch(&e, 7);
        let (_, step) = e.train_step(&params, &x, &y, 0.0).unwrap();
        let ev = e.eval_batch(&params, &x, &y).unwrap();
        let sum: f32 = step.per_example.iter().sum();
        assert!((ev.sum_loss - sum).abs() < 1e-4);
        assert!(ev.correct >= 0.0 && ev.correct <= e.manifest().batch as f32);
    }

    #[test]
    fn aggregate_matches_host_linalg() {
        let e = tiny();
        let d = e.manifest().param_count;
        let mut rng = Rng::new(11);
        for &p in &[2usize, 4, 8] {
            let mut stacked = vec![0.0f32; p * d];
            rng.fill_normal(&mut stacked, 0.0, 0.5);
            let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.05, 2.0)).collect();
            for &(a_tilde, beta) in &[(0.0f32, 1.0f32), (1.0, 0.9), (10.0, 0.5), (0.5, 0.0)] {
                let got = e.aggregate(&stacked, &h, a_tilde, beta).unwrap();
                let theta = linalg::boltzmann_weights(&h, a_tilde);
                let rows: Vec<&[f32]> = stacked.chunks(d).collect();
                let mut agg = vec![0.0f32; d];
                linalg::weighted_sum(&mut agg, &rows, &theta);
                for i in 0..p {
                    for k in (0..d).step_by(7) {
                        let want = (1.0 - beta) * stacked[i * d + k] + beta * agg[k];
                        assert!(
                            (got[i * d + k] - want).abs() < 1e-5,
                            "p={p} ã={a_tilde} β={beta} row {i} col {k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn aggregate_beta1_reaches_consensus() {
        let e = tiny();
        let d = e.manifest().param_count;
        let p = 4;
        let mut rng = Rng::new(9);
        let mut stacked = vec![0.0f32; p * d];
        rng.fill_normal(&mut stacked, 0.0, 1.0);
        let out = e.aggregate(&stacked, &[0.3, 0.9, 0.5, 1.5], 1.0, 1.0).unwrap();
        for i in 1..p {
            for k in 0..d {
                assert!((out[i * d + k] - out[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn shape_checks_reject_bad_inputs() {
        let e = tiny();
        let (params, x, y) = rand_batch(&e, 13);
        assert!(e.train_step(&params[..10], &x, &y, 0.1).is_err());
        assert!(e.train_step(&params, &x[..4], &y, 0.1).is_err());
        assert!(e.train_step(&params, &x, &y[..1], 0.1).is_err());
        let mut bad_y = y.clone();
        bad_y[0] = 99;
        assert!(e.train_step(&params, &x, &bad_y, 0.1).is_err());
    }

    #[test]
    fn rejects_conv_layout() {
        let m = Manifest::parse(
            r#"{
              "name": "convish", "param_count": 294, "batch": 2,
              "input_dim": 16, "input_shape": [4, 4, 1], "num_classes": 2,
              "worker_counts": [2],
              "param_layout": [
                {"name": "conv0_w", "shape": [3, 3, 1, 4]},
                {"name": "conv0_b", "shape": [4]},
                {"name": "dense1_w", "shape": [126, 2]},
                {"name": "dense1_b", "shape": [2]}
              ]
            }"#,
        )
        .unwrap();
        assert!(NativeEngine::new(m).is_err());
    }
}
