//! The native execution engine: pure-Rust forward/backward for the MLP
//! *and* CNN variants plus the paper's Boltzmann aggregation kernel — no
//! Python, no JAX, no HLO artifacts.
//!
//! This is the hermetic twin of the PJRT `Engine` (feature `pjrt`):
//! it implements the same flat-parameter ABI ([`Manifest`]) and the same
//! three entry points (`train_step`, `eval_step`, `aggregate`) with the
//! same semantics as `python/compile/model.py` and
//! `python/compile/kernels/aggregate.py`:
//!
//! * `train_step` — the model is a small layer IR (`Op`) parsed from the
//!   manifest's flat layout: `Dense` (`a ← relu(a·W + b)`), `Conv2d`
//!   (3×3 SAME + ReLU over NHWC, lowered to im2col + the same blocked
//!   [`crate::kernels::Gemm`] the dense path uses), `MaxPool2x2`
//!   (stride-2 VALID, first-max argmax like `jnp.argmax`) and `Flatten`;
//!   fused
//!   softmax cross-entropy with per-example losses (the free Eq. 26
//!   byproduct), exact reverse-mode gradients (col2im scatter for conv,
//!   argmax routing for pool), plain SGD update `θ ← θ − η·∇`;
//! * `eval_step` — summed loss + correct count (first-max argmax);
//! * `aggregate` — Eq. 10+13: θ = softmax(−ã·h/Σh), then
//!   `xᵢ ← (1−β)xᵢ + β·Σⱼθⱼxⱼ`, computed over column panels exactly like
//!   the Pallas kernel tiles VMEM (the `tests/native_parity.rs` fixture
//!   pins both the MLP and conv paths against the Python reference
//!   kernels at ≤1e-5).
//!
//! All state is a pure function of the [`Manifest`] and the caller's
//! parameter vector; initialisation runs through [`crate::rng::Rng`]
//! (`Manifest::init_params`), so runs are bit-deterministic across hosts
//! without any artifacts on disk.

use std::cell::Cell;

use anyhow::{ensure, Result};

use crate::kernels::Gemm;
use crate::linalg;

use super::backend::{Backend, EvalOut, StepOut};
use super::manifest::Manifest;

/// One op of the executable layer IR, parsed from the manifest's flat
/// parameter layout (2-D weights → `Dense`, 4-D `[3,3,cin,cout]`
/// weights → `Conv2d`). Spatial ops carry their *input* NHWC dims.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Affine map + optional ReLU (off for the logits layer).
    Dense { din: usize, dout: usize, w_off: usize, b_off: usize, relu: bool },
    /// 3×3 SAME convolution + bias + ReLU, NHWC, HWIO weights — matches
    /// `compile.model._conv3x3` followed by `jax.nn.relu`.
    Conv2d { h: usize, w: usize, cin: usize, cout: usize, w_off: usize, b_off: usize },
    /// 2×2 max-pool, stride 2, VALID; first max wins ties.
    MaxPool2x2 { h: usize, w: usize, c: usize },
    /// NHWC → flat. Row-major NHWC is already flat, so this is a logical
    /// reshape; it stays in the IR so tapes line up one-to-one with ops.
    Flatten { dim: usize },
}

impl Op {
    /// Did this op apply a ReLU to its own output? (Backward gates the
    /// incoming gradient by `output > 0` exactly where ReLU ran.)
    fn applies_relu(&self) -> bool {
        matches!(self, Op::Conv2d { .. } | Op::Dense { relu: true, .. })
    }
}

/// One (weight, bias) pair of the layout with resolved offsets.
struct LayerPair {
    shape: Vec<usize>,
    w_off: usize,
    b_off: usize,
    b_len: usize,
}

/// Pure-Rust MLP/CNN engine implementing [`Backend`].
pub struct NativeEngine {
    manifest: Manifest,
    ops: Vec<Op>,
    /// Blocked GEMM instance every matmul routes through (forward,
    /// backward and aggregation). Bit-deterministic across thread
    /// counts, so `threads` is pure throughput.
    gemm: Gemm,
    exec_count: Cell<u64>,
}

impl NativeEngine {
    /// Build from a manifest, classifying the flat layout by weight rank:
    /// 2-D `[din, dout]` entries become `Dense` layers, 4-D
    /// `[3, 3, cin, cout]` entries become `Conv2d` layers. Max-pools are
    /// not part of the flat ABI, so their count is inferred from the
    /// first dense layer's fan-in (each pool halves H and W) and they are
    /// assigned to the leading convs — the registry variants pool after
    /// every conv, for which the assignment is exact.
    pub fn new(manifest: Manifest) -> Result<Self> {
        Self::with_threads(manifest, 1)
    }

    /// Build with an intra-op GEMM thread budget (0 = all cores). The
    /// thread count never changes output bits — see [`crate::kernels`] —
    /// only step throughput.
    pub fn with_threads(manifest: Manifest, threads: usize) -> Result<Self> {
        manifest.check()?;
        let entries = &manifest.param_layout;
        ensure!(
            entries.len() >= 2 && entries.len() % 2 == 0,
            "native backend expects (weight, bias) pairs, got {} layout entries",
            entries.len()
        );

        // Pass 1: resolve offsets and split the pairs by weight rank.
        let mut convs: Vec<LayerPair> = Vec::new();
        let mut denses: Vec<LayerPair> = Vec::new();
        let mut off = 0usize;
        for pair in entries.chunks(2) {
            let (w, b) = (&pair[0], &pair[1]);
            ensure!(
                !w.is_bias() && b.is_bias() && b.shape.len() == 1,
                "layout pair {:?}{:?} / {:?}{:?} is not a (weight, bias[n]) pair",
                w.name,
                w.shape,
                b.name,
                b.shape
            );
            let w_off = off;
            off += w.numel();
            let b_off = off;
            off += b.numel();
            let lp = LayerPair { shape: w.shape.clone(), w_off, b_off, b_len: b.shape[0] };
            match w.shape.len() {
                2 => denses.push(lp),
                4 => {
                    ensure!(
                        denses.is_empty(),
                        "conv weight {} appears after a dense layer — conv stacks must precede \
                         the classifier head",
                        w.name
                    );
                    ensure!(
                        w.shape[0] == 3 && w.shape[1] == 3,
                        "conv weight {} has kernel {}×{}; the native backend implements 3×3 \
                         SAME convs only",
                        w.name,
                        w.shape[0],
                        w.shape[1]
                    );
                    convs.push(lp);
                }
                n => anyhow::bail!(
                    "weight {} has rank {n}; the native backend supports dense [din,dout] and \
                     conv [3,3,cin,cout] weights",
                    w.name
                ),
            }
        }
        ensure!(
            !denses.is_empty(),
            "layout has no dense layer — every variant ends in a classifier head"
        );

        // Pass 2: chain shapes into the op list.
        let mut ops: Vec<Op> = Vec::new();
        let mut flat_dim = manifest.input_dim;
        if !convs.is_empty() {
            ensure!(
                manifest.input_shape.len() == 3,
                "conv layout needs an [H, W, C] input_shape, got {:?}",
                manifest.input_shape
            );
            let (mut h, mut w) = (manifest.input_shape[0], manifest.input_shape[1]);
            let mut c = manifest.input_shape[2];
            for (i, conv) in convs.iter().enumerate() {
                let (cin, cout) = (conv.shape[2], conv.shape[3]);
                ensure!(
                    cin == c,
                    "conv layer {i} expects {cin} input channels, activations have {c}"
                );
                ensure!(conv.b_len == cout, "conv layer {i} bias ≠ {cout} output channels");
                c = cout;
            }
            // Infer the pool count from the head's fan-in: k pools halve
            // H and W k times. Exactly one k can match (strictly
            // monotone), and the registry stacks pool after every conv.
            let din0 = denses[0].shape[0];
            let mut pools = None;
            let (mut ph, mut pw) = (h, w);
            for k in 0..=convs.len() {
                if ph * pw * c == din0 {
                    pools = Some(k);
                    break;
                }
                if ph % 2 != 0 || pw % 2 != 0 {
                    break;
                }
                ph /= 2;
                pw /= 2;
            }
            let pools = pools.ok_or_else(|| {
                anyhow::anyhow!(
                    "cannot tile input {:?} through {} convs into the head's fan-in {din0}: \
                     no 2×2 max-pool count matches (layout is not a conv→pool→dense stack \
                     this backend understands)",
                    manifest.input_shape,
                    convs.len()
                )
            })?;
            for (i, conv) in convs.iter().enumerate() {
                let (cin, cout) = (conv.shape[2], conv.shape[3]);
                ops.push(Op::Conv2d { h, w, cin, cout, w_off: conv.w_off, b_off: conv.b_off });
                if i < pools {
                    ops.push(Op::MaxPool2x2 { h, w, c: cout });
                    h /= 2;
                    w /= 2;
                }
            }
            flat_dim = h * w * c;
            ops.push(Op::Flatten { dim: flat_dim });
        }
        for (i, dense) in denses.iter().enumerate() {
            let (din, dout) = (dense.shape[0], dense.shape[1]);
            ensure!(
                din == flat_dim,
                "dense layer {i} fan-in {din} ≠ incoming activation dim {flat_dim}"
            );
            ensure!(dense.b_len == dout, "dense layer {i} bias ≠ {dout} outputs");
            ops.push(Op::Dense {
                din,
                dout,
                w_off: dense.w_off,
                b_off: dense.b_off,
                relu: i + 1 < denses.len(),
            });
            flat_dim = dout;
        }
        ensure!(
            flat_dim == manifest.num_classes,
            "head emits {flat_dim} logits ≠ num_classes {}",
            manifest.num_classes
        );
        Ok(Self { manifest, ops, gemm: Gemm::new(threads), exec_count: Cell::new(0) })
    }

    /// Build for a built-in variant preset (`tiny_mlp`, `cifar_cnn10`, …).
    pub fn for_variant(variant: &str) -> Result<Self> {
        let m = Manifest::native_variant(variant).ok_or_else(|| {
            anyhow::anyhow!(
                "no native preset for variant {variant:?} — built-ins: {}",
                Manifest::NATIVE_VARIANTS.join(", ")
            )
        })?;
        Self::new(m)
    }

    fn bump(&self) {
        self.exec_count.set(self.exec_count.get() + 1);
    }

    fn check_shapes(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<()> {
        let m = &self.manifest;
        ensure!(
            params.len() == m.param_count,
            "params len {} ≠ D {}",
            params.len(),
            m.param_count
        );
        ensure!(
            x.len() == m.batch * m.input_dim,
            "x len {} ≠ B·dim {}",
            x.len(),
            m.batch * m.input_dim
        );
        ensure!(y.len() == m.batch, "y len {} ≠ B {}", y.len(), m.batch);
        for &label in y {
            ensure!(
                (0..m.num_classes as i32).contains(&label),
                "label {label} out of range [0, {})",
                m.num_classes
            );
        }
        Ok(())
    }

    /// Forward pass: returns per-op output tapes (`acts[0] = x`,
    /// `acts[i+1] =` output of op i, post-ReLU where the op applies one),
    /// the argmax tape of every pool op, and — when `keep_patches` is set
    /// (the training path) — each conv's im2col patch matrix so the
    /// backward pass does not re-extract it (empty tapes otherwise).
    fn forward(
        &self,
        params: &[f32],
        x: &[f32],
        batch: usize,
        keep_patches: bool,
    ) -> (Vec<Vec<f32>>, Vec<Vec<u32>>, Vec<Vec<f32>>) {
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.ops.len() + 1);
        let mut pool_idx: Vec<Vec<u32>> = Vec::with_capacity(self.ops.len());
        let mut patch_tape: Vec<Vec<f32>> = Vec::with_capacity(self.ops.len());
        acts.push(x.to_vec());
        for op in &self.ops {
            let a_prev = acts.last().unwrap();
            let (out, idx, patches) = match *op {
                Op::Dense { din, dout, w_off, b_off, relu } => {
                    let mut z = vec![0.0f32; batch * dout];
                    self.gemm.matmul_bias(
                        a_prev,
                        &params[w_off..w_off + din * dout],
                        &params[b_off..b_off + dout],
                        batch,
                        din,
                        dout,
                        &mut z,
                    );
                    if relu {
                        relu_inplace(&mut z);
                    }
                    (z, Vec::new(), Vec::new())
                }
                Op::Conv2d { h, w, cin, cout, w_off, b_off } => {
                    let rows = batch * h * w;
                    let patches = im2col(a_prev, batch, h, w, cin, self.gemm.threads());
                    let mut z = vec![0.0f32; rows * cout];
                    self.gemm.matmul_bias(
                        &patches,
                        &params[w_off..w_off + 9 * cin * cout],
                        &params[b_off..b_off + cout],
                        rows,
                        9 * cin,
                        cout,
                        &mut z,
                    );
                    relu_inplace(&mut z);
                    (z, Vec::new(), if keep_patches { patches } else { Vec::new() })
                }
                Op::MaxPool2x2 { h, w, c } => {
                    let (out, idx) = maxpool_fwd(a_prev, batch, h, w, c);
                    (out, idx, Vec::new())
                }
                Op::Flatten { .. } => (a_prev.clone(), Vec::new(), Vec::new()),
            };
            acts.push(out);
            pool_idx.push(idx);
            patch_tape.push(patches);
        }
        (acts, pool_idx, patch_tape)
    }

    /// Fused softmax cross-entropy over logits: per-example losses and,
    /// optionally, dlogits = softmax − onehot (gradient of the *sum*).
    fn softmax_xent(
        logits: &[f32],
        y: &[i32],
        classes: usize,
        mut dlogits: Option<&mut [f32]>,
    ) -> Vec<f32> {
        let batch = y.len();
        let mut per_ex = vec![0.0f32; batch];
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0f32;
            for &v in row {
                denom += (v - m).exp();
            }
            let ln_denom = denom.ln();
            let label = y[n] as usize;
            per_ex[n] = ln_denom - (row[label] - m);
            if let Some(dl) = dlogits.as_deref_mut() {
                let drow = &mut dl[n * classes..(n + 1) * classes];
                for (k, &v) in row.iter().enumerate() {
                    drow[k] = (v - m).exp() / denom;
                }
                drow[label] -= 1.0;
            }
        }
        per_ex
    }
}

fn relu_inplace(z: &mut [f32]) {
    for v in z.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Below this many output elements the patch extraction runs on the
/// calling thread: spawning costs more than the copy loop down there.
/// The cut is a pure function of the problem shape (never the thread
/// budget), so a given input always takes the same path.
const IM2COL_PAR_MIN: usize = 1 << 16;

/// 3×3 SAME patch extraction, NHWC → [B·H·W, 9·C] with (kh, kw, cin)
/// feature order — exactly the row-major flattening of the HWIO weight
/// tensor, so `patches · w.reshape(9·cin, cout)` is the convolution.
///
/// Extraction is threaded across the same `std::thread::scope`
/// row-panel discipline as [`crate::kernels::Gemm`]: the `batch·h`
/// output image-rows are split into contiguous chunks, each owned by
/// exactly one thread. Every output element is written once, by its
/// owning thread, with a value that depends only on the input — so the
/// partitioning is pure scheduling and the result is **bit-identical at
/// every thread count** (pinned by `im2col_threads_do_not_change_bits`
/// below and the engine-level step-bit tests).
fn im2col(x: &[f32], batch: usize, h: usize, w: usize, c: usize, threads: usize) -> Vec<f32> {
    let pf = 9 * c;
    let mut out = vec![0.0f32; batch * h * w * pf];
    let rows = batch * h;
    let t = if threads <= 1 || out.len() < IM2COL_PAR_MIN {
        1
    } else {
        threads.min(rows)
    };
    if t <= 1 {
        im2col_rows(x, 0, h, w, c, &mut out);
        return out;
    }
    let chunk = rows.div_ceil(t);
    std::thread::scope(|s| {
        for (ci, oc) in out.chunks_mut(chunk * w * pf).enumerate() {
            s.spawn(move || im2col_rows(x, ci * chunk, h, w, c, oc));
        }
    });
    out
}

/// One thread's share of [`im2col`]: output image-rows `r0 ..` with
/// `out` the contiguous sub-slice for exactly that range (one row is
/// the `w·9·c` patch features of one (image, oh) pair). Padding
/// positions keep their pre-zeroed value.
fn im2col_rows(x: &[f32], r0: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    let pf = 9 * c;
    debug_assert_eq!(out.len() % (w * pf), 0);
    for (ri, orow) in out.chunks_mut(w * pf).enumerate() {
        let r = r0 + ri;
        let n = r / h;
        let oh = r % h;
        for ow in 0..w {
            let row = ow * pf;
            for kh in 0..3 {
                let ih = oh + kh;
                if ih < 1 || ih > h {
                    continue; // zero padding row
                }
                let ih = ih - 1;
                for kw in 0..3 {
                    let iw = ow + kw;
                    if iw < 1 || iw > w {
                        continue; // zero padding col
                    }
                    let iw = iw - 1;
                    let src = ((n * h + ih) * w + iw) * c;
                    let dst = row + (kh * 3 + kw) * c;
                    orow[dst..dst + c].copy_from_slice(&x[src..src + c]);
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add patch gradients back onto the
/// input image (padding positions are dropped).
fn col2im(dpatches: &[f32], batch: usize, h: usize, w: usize, c: usize, dx: &mut [f32]) {
    let pf = 9 * c;
    for n in 0..batch {
        for oh in 0..h {
            for ow in 0..w {
                let row = ((n * h + oh) * w + ow) * pf;
                for kh in 0..3 {
                    let ih = oh + kh;
                    if ih < 1 || ih > h {
                        continue;
                    }
                    let ih = ih - 1;
                    for kw in 0..3 {
                        let iw = ow + kw;
                        if iw < 1 || iw > w {
                            continue;
                        }
                        let iw = iw - 1;
                        let dst = ((n * h + ih) * w + iw) * c;
                        let src = row + (kh * 3 + kw) * c;
                        for (d, &g) in dx[dst..dst + c].iter_mut().zip(&dpatches[src..src + c]) {
                            *d += g;
                        }
                    }
                }
            }
        }
    }
}

/// 2×2 stride-2 max-pool over NHWC; returns the pooled map and, per
/// output element, the flat index of its max in the input buffer (scan
/// order (0,0),(0,1),(1,0),(1,1); first max wins ties, like
/// `jnp.argmax`).
fn maxpool_fwd(x: &[f32], batch: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; batch * oh * ow * c];
    let mut idx = vec![0u32; batch * oh * ow * c];
    for n in 0..batch {
        for i in 0..oh {
            for j in 0..ow {
                let dst = ((n * oh + i) * ow + j) * c;
                for k in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_at = 0u32;
                    for di in 0..2 {
                        for dj in 0..2 {
                            let src = ((n * h + 2 * i + di) * w + 2 * j + dj) * c + k;
                            let v = x[src];
                            if v > best {
                                best = v;
                                best_at = src as u32;
                            }
                        }
                    }
                    out[dst + k] = best;
                    idx[dst + k] = best_at;
                }
            }
        }
    }
    (out, idx)
}

impl Backend for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> Result<(Vec<f32>, StepOut)> {
        self.check_shapes(params, x, y)?;
        let batch = self.manifest.batch;
        let classes = self.manifest.num_classes;

        let (acts, pool_idx, patch_tape) = self.forward(params, x, batch, true);
        let logits = acts.last().unwrap();
        let mut dlogits = vec![0.0f32; batch * classes];
        let per_example = Self::softmax_xent(logits, y, classes, Some(&mut dlogits));
        let loss = per_example.iter().sum::<f32>() / batch as f32;

        // Gradient of the *mean* loss.
        let inv_b = 1.0 / batch as f32;
        for v in dlogits.iter_mut() {
            *v *= inv_b;
        }

        // Reverse pass over the op tape. `dz` always matches op i's
        // output; the ReLU gate is applied where the forward applied one
        // (the tape stores post-ReLU outputs, so `out <= 0` ⇔ dead).
        let mut grad = vec![0.0f32; params.len()];
        let mut dz = dlogits;
        for (oi, op) in self.ops.iter().enumerate().rev() {
            let a_prev = &acts[oi];
            if op.applies_relu() {
                for (d, &o) in dz.iter_mut().zip(acts[oi + 1].iter()) {
                    if o <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            let need_da = oi > 0;
            let da = match *op {
                Op::Dense { din, dout, w_off, b_off, .. } => {
                    let wmat = &params[w_off..w_off + din * dout];
                    {
                        let (gw, gb) = split_grad(&mut grad, w_off, din * dout, b_off, dout);
                        self.gemm.matmul_tn_acc(a_prev, &dz, batch, din, dout, gw);
                        self.gemm.col_sum_acc(&dz, batch, dout, gb);
                    }
                    if need_da {
                        let mut da = vec![0.0f32; batch * din];
                        self.gemm.matmul_nt(&dz, wmat, batch, dout, din, &mut da);
                        Some(da)
                    } else {
                        None
                    }
                }
                Op::Conv2d { h, w, cin, cout, w_off, b_off } => {
                    let rows = batch * h * w;
                    let din = 9 * cin;
                    // Patch matrix saved by the forward pass — no re-extraction.
                    let patches = &patch_tape[oi];
                    let wmat = &params[w_off..w_off + din * cout];
                    {
                        let (gw, gb) = split_grad(&mut grad, w_off, din * cout, b_off, cout);
                        self.gemm.matmul_tn_acc(patches, &dz, rows, din, cout, gw);
                        self.gemm.col_sum_acc(&dz, rows, cout, gb);
                    }
                    if need_da {
                        let mut dpatches = vec![0.0f32; rows * din];
                        self.gemm.matmul_nt(&dz, wmat, rows, cout, din, &mut dpatches);
                        let mut da = vec![0.0f32; batch * h * w * cin];
                        col2im(&dpatches, batch, h, w, cin, &mut da);
                        Some(da)
                    } else {
                        None
                    }
                }
                Op::MaxPool2x2 { h, w, c } => {
                    if need_da {
                        let mut da = vec![0.0f32; batch * h * w * c];
                        for (&d, &i) in dz.iter().zip(pool_idx[oi].iter()) {
                            da[i as usize] += d;
                        }
                        Some(da)
                    } else {
                        None
                    }
                }
                Op::Flatten { .. } => {
                    if need_da {
                        Some(std::mem::take(&mut dz))
                    } else {
                        None
                    }
                }
            };
            if let Some(da) = da {
                dz = da;
            }
        }

        let mut new_params = params.to_vec();
        linalg::axpy(&mut new_params, -lr, &grad);
        self.bump();
        Ok((new_params, StepOut { loss, per_example }))
    }

    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOut> {
        self.check_shapes(params, x, y)?;
        let batch = self.manifest.batch;
        let classes = self.manifest.num_classes;
        let (acts, _, _) = self.forward(params, x, batch, false);
        let logits = acts.last().unwrap();
        let per_ex = Self::softmax_xent(logits, y, classes, None);
        let mut correct = 0.0f32;
        for n in 0..batch {
            let row = &logits[n * classes..(n + 1) * classes];
            if linalg::argmax(row) as i32 == y[n] {
                correct += 1.0;
            }
        }
        self.bump();
        Ok(EvalOut { sum_loss: per_ex.iter().sum(), correct })
    }

    fn aggregate(&self, stacked: &[f32], h: &[f32], a_tilde: f32, beta: f32) -> Result<Vec<f32>> {
        let p = h.len();
        ensure!(p > 0, "empty cohort");
        ensure!(stacked.len() % p == 0, "stacked len {} not divisible by p={p}", stacked.len());
        // A single non-finite loss energy would poison every worker's
        // parameters through the softmax — reject with the culprit named.
        for (i, &hi) in h.iter().enumerate() {
            ensure!(
                hi.is_finite(),
                "worker {i}: non-finite loss energy h = {hi} (diverged before aggregation?)"
            );
        }
        ensure!(a_tilde.is_finite(), "non-finite ã = {a_tilde}");
        ensure!(beta.is_finite(), "non-finite β = {beta}");
        let d = stacked.len() / p;
        ensure!(d > 0, "empty parameter rows");
        let theta = linalg::boltzmann_weights(h, a_tilde);

        // θ·X row-combine then the β-mix, both through the kernel
        // subsystem (columns panelled like the Pallas kernel's grid over
        // D, threads splitting the panels — bit-stable at any count).
        let rows: Vec<&[f32]> = stacked.chunks(d).collect();
        let mut agg = vec![0.0f32; d];
        self.gemm.combine_rows(&mut agg, &rows, &theta);
        let mut out = vec![0.0f32; p * d];
        self.gemm.blend_rows(&mut out, stacked, &agg, beta);
        self.bump();
        Ok(out)
    }

    fn has_aggregate(&self, _p: usize) -> bool {
        true
    }

    fn exec_count(&self) -> u64 {
        self.exec_count.get()
    }
}

/// Disjoint weight/bias gradient slices out of the flat gradient vector.
fn split_grad(
    grad: &mut [f32],
    w_off: usize,
    w_len: usize,
    b_off: usize,
    b_len: usize,
) -> (&mut [f32], &mut [f32]) {
    debug_assert_eq!(w_off + w_len, b_off, "bias must follow its weight block");
    let (head, tail) = grad.split_at_mut(b_off);
    (&mut head[w_off..w_off + w_len], &mut tail[..b_len])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny() -> NativeEngine {
        NativeEngine::for_variant("tiny_mlp").unwrap()
    }

    fn tiny_cnn() -> NativeEngine {
        NativeEngine::for_variant("tiny_cnn").unwrap()
    }

    fn rand_batch(e: &NativeEngine, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let m = e.manifest();
        let mut rng = Rng::new(seed);
        let params = m.init_params(seed);
        let mut x = vec![0.0f32; m.batch * m.input_dim];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let y: Vec<i32> = (0..m.batch).map(|_| rng.below(m.num_classes) as i32).collect();
        (params, x, y)
    }

    /// Finite-difference check shared by the MLP and CNN variants.
    fn check_gradient(e: &NativeEngine, seed: u64, coords: usize, tol: f64) {
        let (params, x, y) = rand_batch(e, seed);
        let d = params.len();
        // Analytic gradient, recovered from one lr=1 step.
        let (stepped, base) = e.train_step(&params, &x, &y, 1.0).unwrap();
        let grad: Vec<f32> = params.iter().zip(stepped.iter()).map(|(p, s)| p - s).collect();
        let loss_at = |th: &[f32]| -> f64 {
            let (_, out) = e.train_step(th, &x, &y, 0.0).unwrap();
            out.loss as f64
        };
        assert!((loss_at(&params) - base.loss as f64).abs() < 1e-6);
        // Spot-check coordinates across the whole vector.
        let eps = 1e-3f32;
        let mut rng = Rng::new(17);
        for _ in 0..coords {
            let k = rng.below(d);
            let mut plus = params.clone();
            plus[k] += eps;
            let mut minus = params.clone();
            minus[k] -= eps;
            let numeric = (loss_at(&plus) - loss_at(&minus)) / (2.0 * eps as f64);
            let analytic = grad[k] as f64;
            assert!(
                (numeric - analytic).abs() < tol,
                "coord {k}: numeric {numeric:.6} vs analytic {analytic:.6}"
            );
        }
    }

    #[test]
    fn lr_zero_is_identity_and_counts_execs() {
        let e = tiny();
        let (params, x, y) = rand_batch(&e, 1);
        let (next, out) = e.train_step(&params, &x, &y, 0.0).unwrap();
        assert_eq!(next, params);
        assert!(out.loss.is_finite());
        assert_eq!(out.per_example.len(), e.manifest().batch);
        let mean: f32 = out.per_example.iter().sum::<f32>() / out.per_example.len() as f32;
        assert!((mean - out.loss).abs() < 1e-5);
        assert_eq!(e.exec_count(), 1);
    }

    #[test]
    fn overfitting_one_batch_reduces_loss() {
        let e = tiny();
        let (mut params, x, y) = rand_batch(&e, 3);
        let (_, first) = e.train_step(&params, &x, &y, 0.0).unwrap();
        let mut last = first.loss;
        for _ in 0..80 {
            let (next, out) = e.train_step(&params, &x, &y, 0.1).unwrap();
            params = next;
            last = out.loss;
        }
        assert!(last < first.loss * 0.7, "{} → {last}", first.loss);
    }

    #[test]
    fn conv_overfitting_one_batch_reduces_loss() {
        let e = tiny_cnn();
        let (mut params, x, y) = rand_batch(&e, 3);
        let (_, first) = e.train_step(&params, &x, &y, 0.0).unwrap();
        let mut last = first.loss;
        for _ in 0..80 {
            let (next, out) = e.train_step(&params, &x, &y, 0.1).unwrap();
            params = next;
            last = out.loss;
        }
        assert!(last < first.loss * 0.7, "{} → {last}", first.loss);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        check_gradient(&tiny(), 5, 24, 2e-3);
    }

    #[test]
    fn conv_gradient_matches_finite_differences() {
        // Covers Conv2d (im2col/col2im), MaxPool2x2 (argmax routing) and
        // the ReLU gates between them.
        check_gradient(&tiny_cnn(), 5, 32, 5e-3);
    }

    #[test]
    fn conv_ir_has_expected_ops() {
        let e = tiny_cnn();
        // conv → pool → conv → pool → flatten → dense(logits).
        assert_eq!(e.ops.len(), 6);
        assert!(matches!(e.ops[0], Op::Conv2d { h: 8, w: 8, cin: 1, cout: 4, .. }));
        assert!(matches!(e.ops[1], Op::MaxPool2x2 { h: 8, w: 8, c: 4 }));
        assert!(matches!(e.ops[2], Op::Conv2d { h: 4, w: 4, cin: 4, cout: 8, .. }));
        assert!(matches!(e.ops[3], Op::MaxPool2x2 { h: 4, w: 4, c: 8 }));
        assert!(matches!(e.ops[4], Op::Flatten { dim: 32 }));
        assert!(matches!(e.ops[5], Op::Dense { din: 32, dout: 2, relu: false, .. }));
    }

    #[test]
    fn cifar_presets_build_natively() {
        for v in ["cifar_cnn10", "cifar_cnn100", "mnist_cnn"] {
            let e = NativeEngine::for_variant(v).unwrap();
            assert_eq!(e.manifest().name, v);
            assert!(e.ops.iter().any(|o| matches!(o, Op::Conv2d { .. })), "{v}");
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_first_max() {
        // 2×2 input, 1 channel, batch 1: max at (0,1); ties break first.
        let x = [1.0f32, 7.0, 3.0, 5.0];
        let (out, idx) = maxpool_fwd(&x, 1, 2, 2, 1);
        assert_eq!(out, vec![7.0]);
        assert_eq!(idx, vec![1]);
        let tied = [2.0f32, 2.0, 2.0, 2.0];
        let (_, idx) = maxpool_fwd(&tied, 1, 2, 2, 1);
        assert_eq!(idx, vec![0], "first max must win ties");
    }

    #[test]
    fn im2col_col2im_are_adjoint() {
        // <im2col(x), p> == <x, col2im(p)> for random x, p — the defining
        // property of the pair used by the conv backward.
        let (b, h, w, c) = (2usize, 4usize, 3usize, 2usize);
        let mut rng = Rng::new(23);
        let mut x = vec![0.0f32; b * h * w * c];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let patches = im2col(&x, b, h, w, c, 1);
        let mut p = vec![0.0f32; patches.len()];
        rng.fill_normal(&mut p, 0.0, 1.0);
        let mut back = vec![0.0f32; x.len()];
        col2im(&p, b, h, w, c, &mut back);
        let lhs: f64 = patches.iter().zip(p.iter()).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = x.iter().zip(back.iter()).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn im2col_threads_do_not_change_bits() {
        // Patch extraction is threaded across the row-panel pool; the
        // partitioning is scheduling only, so every thread count must
        // produce identical bits — both below and above the parallel
        // work gate.
        let mut rng = Rng::new(31);
        for &(b, h, w, c) in &[(2usize, 4usize, 4usize, 3usize), (4, 16, 16, 8)] {
            let mut x = vec![0.0f32; b * h * w * c];
            rng.fill_normal(&mut x, 0.0, 1.0);
            let base = im2col(&x, b, h, w, c, 1);
            for threads in [2usize, 3, 8] {
                let got = im2col(&x, b, h, w, c, threads);
                let same = base.iter().zip(got.iter()).all(|(a, g)| a.to_bits() == g.to_bits());
                assert!(same, "im2col bits changed at t={threads} for {b}×{h}×{w}×{c}");
            }
        }
        // The second shape genuinely clears the parallel gate
        // (out.len() = b·h·w·9·c).
        assert!(4 * 16 * 16 * 9 * 8 >= super::IM2COL_PAR_MIN);
    }

    #[test]
    fn intra_op_threads_do_not_change_step_bits() {
        // The engine-level face of the kernel guarantee: a threaded
        // engine takes the *identical* SGD step, bit for bit — dense and
        // conv paths, forward and backward.
        for variant in ["tiny_mlp", "tiny_cnn"] {
            let m = Manifest::native_variant(variant).unwrap();
            let e1 = NativeEngine::with_threads(m.clone(), 1).unwrap();
            let e4 = NativeEngine::with_threads(m, 4).unwrap();
            let (params, x, y) = rand_batch(&e1, 21);
            let (p1, o1) = e1.train_step(&params, &x, &y, 0.1).unwrap();
            let (p4, o4) = e4.train_step(&params, &x, &y, 0.1).unwrap();
            assert_eq!(o1.loss.to_bits(), o4.loss.to_bits(), "{variant}: loss bits");
            let same = p1.iter().zip(p4.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "{variant}: threads changed the parameter bits");
            let agg1 = e1.aggregate(&params[..64.min(params.len())], &[0.4, 0.6], 1.0, 0.9);
            let agg4 = e4.aggregate(&params[..64.min(params.len())], &[0.4, 0.6], 1.0, 0.9);
            assert_eq!(agg1.unwrap(), agg4.unwrap(), "{variant}: aggregate");
        }
    }

    #[test]
    fn eval_matches_train_loss_semantics() {
        for e in [tiny(), tiny_cnn()] {
            let (params, x, y) = rand_batch(&e, 7);
            let (_, step) = e.train_step(&params, &x, &y, 0.0).unwrap();
            let ev = e.eval_batch(&params, &x, &y).unwrap();
            let sum: f32 = step.per_example.iter().sum();
            assert!((ev.sum_loss - sum).abs() < 1e-4);
            assert!(ev.correct >= 0.0 && ev.correct <= e.manifest().batch as f32);
        }
    }

    #[test]
    fn aggregate_matches_host_linalg() {
        let e = tiny();
        let d = e.manifest().param_count;
        let mut rng = Rng::new(11);
        for &p in &[2usize, 4, 8] {
            let mut stacked = vec![0.0f32; p * d];
            rng.fill_normal(&mut stacked, 0.0, 0.5);
            let h: Vec<f32> = (0..p).map(|_| rng.uniform_in(0.05, 2.0)).collect();
            for &(a_tilde, beta) in &[(0.0f32, 1.0f32), (1.0, 0.9), (10.0, 0.5), (0.5, 0.0)] {
                let got = e.aggregate(&stacked, &h, a_tilde, beta).unwrap();
                let theta = linalg::boltzmann_weights(&h, a_tilde);
                let rows: Vec<&[f32]> = stacked.chunks(d).collect();
                let mut agg = vec![0.0f32; d];
                linalg::weighted_sum(&mut agg, &rows, &theta);
                for i in 0..p {
                    for k in (0..d).step_by(7) {
                        let want = (1.0 - beta) * stacked[i * d + k] + beta * agg[k];
                        assert!(
                            (got[i * d + k] - want).abs() < 1e-5,
                            "p={p} ã={a_tilde} β={beta} row {i} col {k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn aggregate_beta1_reaches_consensus() {
        let e = tiny();
        let d = e.manifest().param_count;
        let p = 4;
        let mut rng = Rng::new(9);
        let mut stacked = vec![0.0f32; p * d];
        rng.fill_normal(&mut stacked, 0.0, 1.0);
        let out = e.aggregate(&stacked, &[0.3, 0.9, 0.5, 1.5], 1.0, 1.0).unwrap();
        for i in 1..p {
            for k in 0..d {
                assert!((out[i * d + k] - out[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn aggregate_rejects_non_finite_inputs() {
        let e = tiny();
        let d = e.manifest().param_count;
        let stacked = vec![0.5f32; 3 * d];
        let err = e.aggregate(&stacked, &[0.5, f32::NAN, 0.5], 1.0, 0.9).unwrap_err();
        assert!(err.to_string().contains("worker 1"), "{err}");
        assert!(e.aggregate(&stacked, &[0.5, f32::INFINITY, 0.5], 1.0, 0.9).is_err());
        assert!(e.aggregate(&stacked, &[0.5, 0.5, 0.5], f32::NAN, 0.9).is_err());
        assert!(e.aggregate(&stacked, &[0.5, 0.5, 0.5], 1.0, f32::NAN).is_err());
        assert!(e.aggregate(&stacked, &[0.5, 0.5, 0.5], 1.0, 0.9).is_ok());
    }

    #[test]
    fn shape_checks_reject_bad_inputs() {
        let e = tiny();
        let (params, x, y) = rand_batch(&e, 13);
        assert!(e.train_step(&params[..10], &x, &y, 0.1).is_err());
        assert!(e.train_step(&params, &x[..4], &y, 0.1).is_err());
        assert!(e.train_step(&params, &x, &y[..1], 0.1).is_err());
        let mut bad_y = y.clone();
        bad_y[0] = 99;
        assert!(e.train_step(&params, &x, &bad_y, 0.1).is_err());
    }

    #[test]
    fn rejects_inconsistent_conv_layout() {
        // Dense fan-in 126 matches no pool count of a 4×4×4 conv output
        // (64 with none, 16 with one) — the parser must say so.
        let m = Manifest::parse(
            r#"{
              "name": "convish", "param_count": 294, "batch": 2,
              "input_dim": 16, "input_shape": [4, 4, 1], "num_classes": 2,
              "worker_counts": [2],
              "param_layout": [
                {"name": "conv0_w", "shape": [3, 3, 1, 4]},
                {"name": "conv0_b", "shape": [4]},
                {"name": "dense1_w", "shape": [126, 2]},
                {"name": "dense1_b", "shape": [2]}
              ]
            }"#,
        )
        .unwrap();
        let err = NativeEngine::new(m).unwrap_err();
        assert!(err.to_string().contains("max-pool count"), "{err}");
    }

    #[test]
    fn rejects_non_3x3_kernels_and_conv_after_dense() {
        let m = Manifest::parse(
            r#"{
              "name": "fivebyfive", "param_count": 134, "batch": 2,
              "input_dim": 16, "input_shape": [4, 4, 1], "num_classes": 2,
              "worker_counts": [2],
              "param_layout": [
                {"name": "conv0_w", "shape": [5, 5, 1, 4]},
                {"name": "conv0_b", "shape": [4]},
                {"name": "dense1_w", "shape": [14, 2]},
                {"name": "dense1_b", "shape": [2]}
              ]
            }"#,
        )
        .unwrap();
        assert!(NativeEngine::new(m).unwrap_err().to_string().contains("3×3"));

        let m = Manifest::parse(
            r#"{
              "name": "backwards", "param_count": 340, "batch": 2,
              "input_dim": 16, "input_shape": [4, 4, 1], "num_classes": 2,
              "worker_counts": [2],
              "param_layout": [
                {"name": "dense0_w", "shape": [16, 18]},
                {"name": "dense0_b", "shape": [18]},
                {"name": "conv1_w", "shape": [3, 3, 1, 3]},
                {"name": "conv1_b", "shape": [3]},
                {"name": "dense2_w", "shape": [1, 2]},
                {"name": "dense2_b", "shape": [2]}
              ]
            }"#,
        )
        .unwrap();
        assert!(NativeEngine::new(m).unwrap_err().to_string().contains("precede"));
    }
}
