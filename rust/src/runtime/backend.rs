//! The execution-backend seam.
//!
//! The paper's weighted-aggregation protocol (Eqs. 10/13/26) is
//! numerics-agnostic: its correctness claims are about *what the workers
//! exchange*, not about which kernel provider computed the gradients. The
//! [`Backend`] trait captures exactly the surface the coordinator needs —
//! one SGD step, one eval batch, the Boltzmann aggregation, and the model
//! manifest — so the trainer, the threaded cluster, the harness and the
//! benches can run against any provider:
//!
//! * [`NativeEngine`](super::native::NativeEngine) — pure-Rust
//!   forward/backward for the MLP *and* CNN variants (dense, 3×3 SAME
//!   conv via im2col, 2×2 max-pool). Hermetic: no Python, no JAX, no HLO
//!   artifacts; this is what CI and a clean checkout run.
//! * `Engine` (`runtime::engine`, feature `pjrt`) — the PJRT executor
//!   for the Pallas-backed AOT artifacts; the TPU-deployment path,
//!   available when artifacts exist on disk.
//!
//! Selection happens through [`BackendKind`](crate::config::BackendKind)
//! on the experiment config: `Auto` prefers PJRT when the build has the
//! feature *and* the artifact directory exists, and falls back to the
//! native engine otherwise.

use std::path::Path;

use anyhow::Result;

use crate::config::{BackendKind, ExperimentConfig};

use super::manifest::Manifest;
use super::native::NativeEngine;

/// Outputs of one training step.
#[derive(Clone, Debug)]
pub struct StepOut {
    /// Mean batch loss.
    pub loss: f32,
    /// Per-example losses (length = batch) — feeds the paper's free
    /// loss-estimation windows (Eq. 26).
    pub per_example: Vec<f32>,
}

/// Outputs of one evaluation batch.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    /// Summed loss over the batch.
    pub sum_loss: f32,
    /// Number of correctly classified examples.
    pub correct: f32,
}

/// One model-execution provider: everything the coordinator calls into.
///
/// Implementations are *single-threaded* (the PJRT client is `Rc`-based);
/// concurrent modes construct one backend per worker thread via
/// [`load_backend`], exactly the process topology of a multi-host
/// deployment.
pub trait Backend {
    /// Short provider name for logs/telemetry ("native", "pjrt").
    fn name(&self) -> &'static str;

    /// The model variant's flat-parameter ABI and baked shapes.
    fn manifest(&self) -> &Manifest;

    /// One SGD step: consumes `params`, returns the updated vector plus
    /// the loss outputs. `x` is row-major [batch × input_dim], `y` holds
    /// the integer labels.
    fn train_step(&self, params: &[f32], x: &[f32], y: &[i32], lr: f32)
        -> Result<(Vec<f32>, StepOut)>;

    /// One evaluation batch: summed loss + correct count.
    fn eval_batch(&self, params: &[f32], x: &[f32], y: &[i32]) -> Result<EvalOut>;

    /// The paper's communication step (Eq. 10+13): `stacked` is row-major
    /// [p × D]; returns the β-mixed rows.
    fn aggregate(&self, stacked: &[f32], h: &[f32], a_tilde: f32, beta: f32) -> Result<Vec<f32>>;

    /// Can this backend aggregate a cohort of size `p`? (The PJRT engine
    /// needs a lowered `aggregate_p{p}` artifact; the native engine
    /// handles any p.)
    fn has_aggregate(&self, p: usize) -> bool;

    /// Kernel executions performed so far (telemetry for the perf pass).
    fn exec_count(&self) -> u64;

    /// Measure mean seconds per train step over `n` reps (for calibrating
    /// the simulated cluster's compute model).
    fn calibrate_step_time(&self, n: usize) -> Result<f64> {
        let m = self.manifest();
        let params = m.init_params(7);
        let x = vec![0.1f32; m.batch * m.input_dim];
        let y = vec![0i32; m.batch];
        // Warm-up.
        let _ = self.train_step(&params, &x, &y, 0.0)?;
        let t0 = std::time::Instant::now();
        let mut cur = params;
        for _ in 0..n.max(1) {
            let (next, _) = self.train_step(&cur, &x, &y, 0.0)?;
            cur = next;
        }
        Ok(t0.elapsed().as_secs_f64() / n.max(1) as f64)
    }
}

/// Build the backend an experiment config asks for (including its
/// intra-op `threads` budget).
pub fn load_backend(cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    backend_for_variant(&cfg.artifacts_root, &cfg.variant, cfg.backend, cfg.threads)
}

/// Build a backend for one model variant directly (benches, calibration).
/// `threads` is the intra-op GEMM budget of the native engine (0 = all
/// cores; kernel outputs are bit-identical at every value); the PJRT
/// engine manages its own device parallelism and ignores it.
pub fn backend_for_variant(
    artifacts_root: &Path,
    variant: &str,
    kind: BackendKind,
    threads: usize,
) -> Result<Box<dyn Backend>> {
    use anyhow::Context as _;
    match kind {
        BackendKind::Native => native_backend(artifacts_root, variant, threads)
            .with_context(|| format!("--backend native failed for variant {variant:?}")),
        BackendKind::Pjrt => pjrt_backend(artifacts_root, variant)
            .with_context(|| format!("--backend pjrt failed for variant {variant:?}")),
        BackendKind::Auto => {
            if pjrt_available() && artifacts_root.join(variant).join("manifest.json").exists() {
                pjrt_backend(artifacts_root, variant).with_context(|| {
                    format!("--backend auto selected pjrt (artifacts found) for variant {variant:?}")
                })
            } else {
                native_backend(artifacts_root, variant, threads).with_context(|| {
                    format!(
                        "--backend auto fell back to native (pjrt {}) for variant {variant:?}",
                        if pjrt_available() { "artifacts missing" } else { "not compiled in" }
                    )
                })
            }
        }
    }
}

/// Was this build compiled with PJRT support?
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

fn native_backend(
    artifacts_root: &Path,
    variant: &str,
    threads: usize,
) -> Result<Box<dyn Backend>> {
    let dir = artifacts_root.join(variant);
    // An on-disk manifest (if artifacts were generated) is authoritative;
    // otherwise the built-in MLP presets make the backend fully hermetic.
    let manifest = if dir.join("manifest.json").exists() {
        Manifest::load(&dir)?
    } else {
        Manifest::native_variant(variant).ok_or_else(|| {
            anyhow::anyhow!(
                "variant {variant:?} has no built-in native preset and no manifest.json \
                 under {} — native presets: {}; for anything else generate artifacts \
                 (`python -m compile.aot`) and rebuild with `--features pjrt`",
                dir.display(),
                Manifest::NATIVE_VARIANTS.join(", ")
            )
        })?
    };
    Ok(Box::new(NativeEngine::with_threads(manifest, threads)?))
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(artifacts_root: &Path, variant: &str) -> Result<Box<dyn Backend>> {
    Ok(Box::new(super::engine::Engine::load(artifacts_root, variant)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_artifacts_root: &Path, _variant: &str) -> Result<Box<dyn Backend>> {
    anyhow::bail!(
        "this build has no PJRT support — uncomment the `xla` dependency in \
         rust/Cargo.toml, rebuild with `--features pjrt`, and generate \
         artifacts with `python -m compile.aot`"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn auto_falls_back_to_native_without_artifacts() {
        let cfg = ExperimentConfig::default(); // artifacts/ does not exist
        let b = load_backend(&cfg).unwrap();
        assert_eq!(b.name(), "native");
        assert_eq!(b.manifest().name, "tiny_mlp");
    }

    #[test]
    fn explicit_native_works_for_all_preset_variants() {
        for v in Manifest::NATIVE_VARIANTS {
            let b = backend_for_variant(Path::new("artifacts"), v, BackendKind::Native, 2).unwrap();
            assert_eq!(b.manifest().name, v);
            assert!(b.has_aggregate(4));
        }
    }

    #[test]
    fn auto_runs_cifar_variants_natively() {
        // The paper's CIFAR presets must work out of the box on a clean
        // checkout: `--backend auto` with no artifacts anywhere.
        for v in ["cifar_cnn10", "cifar_cnn100"] {
            let b = backend_for_variant(Path::new("artifacts"), v, BackendKind::Auto, 1).unwrap();
            assert_eq!(b.name(), "native");
            assert_eq!(b.manifest().name, v);
        }
    }

    #[test]
    fn unknown_variant_error_names_variant_backend_and_remedy() {
        let err = backend_for_variant(Path::new("artifacts"), "resnet152", BackendKind::Auto, 1)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("resnet152"), "{msg}");
        assert!(msg.contains("native"), "{msg}");
        assert!(msg.contains("tiny_mlp"), "should list native presets: {msg}");
        assert!(msg.contains("--features pjrt"), "should name the remedy: {msg}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_kind_errors_without_feature() {
        let r = backend_for_variant(Path::new("artifacts"), "tiny_mlp", BackendKind::Pjrt, 1);
        assert!(r.is_err());
    }
}
