//! Execution runtime: the [`Backend`] seam, the model manifests, and the
//! pluggable engines behind them.
//!
//! ```text
//!                         coordinator / cluster / harness / benches
//!                                        │  (dyn Backend)
//!                 ┌──────────────────────┴──────────────────────┐
//!  NativeEngine (always built)                    Engine (feature "pjrt")
//!  pure-Rust MLP+CNN fwd/bwd (dense, im2col       HLO text → XlaComputation
//!  conv, max-pool) + Eq. 10+13 kernel;            → client.compile → PJRT
//!  hermetic, bit-deterministic
//!                 └──────────── Manifest (flat ABI, shapes) ─────┘
//!                    on disk (manifest.json) or built-in preset
//! ```
//!
//! `BackendKind::Auto` (the default) picks PJRT when this build has the
//! `pjrt` feature *and* artifacts exist under the configured root, and
//! the native engine otherwise — so a clean checkout trains with zero
//! Python/JAX/artifact dependencies.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod native;

pub use backend::{backend_for_variant, load_backend, pjrt_available, Backend, EvalOut, StepOut};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::{Manifest, ParamEntry};
pub use native::NativeEngine;
