//! PJRT runtime layer: artifact manifests + the execution engine.
//!
//! ```text
//! python (build time)              rust (run time)
//! ─────────────────────            ─────────────────────────────
//! compile/aot.py  ──HLO text──▶    HloModuleProto::from_text_file
//!                                  → XlaComputation → client.compile
//! manifest.json  ──serde──▶        Manifest (flat ABI, shapes)
//! ```

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EvalOut, StepOut};
pub use manifest::{Manifest, ParamEntry};
