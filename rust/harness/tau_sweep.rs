//! Fig. 7 — communication-period sweep (DESIGN.md E5).
//!
//! Positions (train loss) after a fixed epoch budget for
//! τ ∈ {5, 10, 25, 50, 100, 250} × p ∈ {2, 4, 8}
//! (rescaled to this testbed's iterations-per-epoch — DESIGN.md §3), for EASGD vs WASGD vs
//! WASGD+. Paper shape: WASGD+ ≻ WASGD ≻ EASGD at matched (τ, p), and
//! WASGD+ at τ=1000 ≈ EASGD at τ=50 (large-τ robustness). Both the loss
//! and the simulated time are reported — large τ trades convergence for
//! communication.
//!
//! ```bash
//! cargo run --release --bin bench_tau_sweep -- [--dataset mnist]
//!     [--epochs 2.0] [--taus 5,10,25,50,100,250] [--ps 2,4,8]
//! ```
//!
//! Runs hermetically on any dataset (the CIFAR analogues use the native
//! conv path when no artifacts are present).

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::harness::SharedEnv;
use wasgd::data::synth::DatasetKind;
use wasgd::harness::RESULTS_DIR;
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "mnist");
    let epochs = args.num_flag("epochs", 2.0f64)?;
    let taus_s = args.str_flag("taus", "5,10,25,50,100,250");
    let ps_s = args.str_flag("ps", "2,4,8");
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let taus: Vec<usize> = taus_s.split(',').filter(|s| !s.is_empty()).map(|s| s.parse()).collect::<Result<_, _>>()?;
    let ps: Vec<usize> = ps_s.split(',').filter(|s| !s.is_empty()).map(|s| s.parse()).collect::<Result<_, _>>()?;
    let algos = [AlgoKind::Easgd, AlgoKind::Wasgd, AlgoKind::WasgdPlus];

    println!(
        "Fig. 7 τ-sweep — {} after {epochs} epochs (loss ↓ / sim-time shown)",
        dataset.name()
    );

    let env = SharedEnv::new(&ExperimentConfig::paper_preset(dataset))?;
    let mut csv_rows: Vec<String> = vec!["algo,p,tau,train_loss,test_error,sim_time_s".into()];
    for &p in &ps {
        println!("\np = {p}");
        print!("{:>8}", "τ");
        for a in &algos {
            print!("  {:>22}", a.name());
        }
        println!();
        for &tau in &taus {
            print!("{tau:>8}");
            for &algo in &algos {
                let mut cfg = ExperimentConfig::paper_preset(dataset);
                cfg.algo = algo;
                cfg.p = p;
                cfg.tau = tau;
                cfg.m = cfg.m.min(tau);
                cfg.epochs = epochs;
                cfg.eval_every = usize::MAX / 2; // final record only
                cfg.eval_batches = 8;
                let out = env.run(&cfg)?;
                let r = out.log.records.last().unwrap();
                print!("  {:>12.4} @{:>7.2}s", r.train_loss, r.sim_time_s);
                csv_rows.push(format!(
                    "{},{p},{tau},{:.6},{:.6},{:.6}",
                    algo.name(),
                    r.train_loss,
                    r.test_error,
                    r.sim_time_s
                ));
            }
            println!();
        }
    }

    std::fs::create_dir_all(RESULTS_DIR)?;
    let path = format!("{RESULTS_DIR}/fig7_tau_sweep_{}.csv", dataset.name());
    std::fs::write(&path, csv_rows.join("\n") + "\n")?;
    println!("\nwrote {path}");
    Ok(())
}
