//! Fig. 4 — temperature sweep, T = 1/ã (DESIGN.md E2).
//!
//! For each T, WASGD+ runs 5 seeds × 1 epoch; the equally-weighted case
//! (ã = 0) is the baseline. Points are the paper's Eq. (47) mean
//! difference (positive = weighted case better) with error bars, on both
//! the loss and the error metric. Paper shape: a finite optimal T
//! (T*≈1 for MNIST/CIFAR-10, 10 for Fashion, 0.1 for CIFAR-100), decay
//! to baseline as T→∞, and collapse below baseline as T→0 (Property 2).
//!
//! ```bash
//! cargo run --release --bin bench_t_sweep -- [--dataset mnist]
//!     [--epochs 1.0] [--p 4] [--ts 0.001,0.01,0.1,1,10,100,1000]
//! ```

use anyhow::Result;
use wasgd::config::{AlgoKind, ExperimentConfig};
use wasgd::data::synth::DatasetKind;
use wasgd::harness::{eq47_point, print_sweep, write_sweep_csv, SharedEnv, RESULTS_DIR, SWEEP_SEEDS};
use wasgd::util::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()?;
    let dataset_s = args.str_flag("dataset", "mnist");
    let epochs = args.num_flag("epochs", 1.0f64)?;
    let p = args.num_flag("p", 4usize)?;
    let ts_s = args.str_flag("ts", "0.001,0.01,0.1,1,10,100,1000");
    let seeds_n = args.num_flag("seeds", 5usize)?;
    args.finish()?;

    let dataset = DatasetKind::parse(&dataset_s)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset_s:?}"))?;
    let ts: Vec<f64> = ts_s
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse())
        .collect::<Result<_, _>>()?;
    let seeds = &SWEEP_SEEDS[..seeds_n.min(SWEEP_SEEDS.len())];

    let mut base = ExperimentConfig::paper_preset(dataset);
    base.algo = AlgoKind::WasgdPlus;
    base.p = p;
    base.epochs = epochs;
    base.eval_every = (base.tau / 2).max(32);
    base.eval_batches = 6;
    let env = SharedEnv::new(&base)?;

    println!(
        "Fig. 4 T-sweep — {} (p={p}, {epochs} epochs, {} seeds); baseline = equal weights (ã=0)",
        dataset.name(),
        seeds.len()
    );

    // Baseline: equally weighted (ã = 0 ⇒ T = ∞).
    let mut eq = base.clone();
    eq.a_tilde = 0.0;
    let baseline: Vec<_> = env.run_seeds(&eq, seeds)?.into_iter().map(|o| o.log).collect();

    let mut loss_rows = Vec::new();
    let mut err_rows = Vec::new();
    for &t in &ts {
        let mut cfg = base.clone();
        cfg.a_tilde = (1.0 / t) as f32;
        let cand: Vec<_> = env.run_seeds(&cfg, seeds)?.into_iter().map(|o| o.log).collect();
        let (dl, el) = eq47_point(&baseline, &cand, |r| r.train_loss);
        let (de, ee) = eq47_point(&baseline, &cand, |r| r.train_error);
        loss_rows.push((format!("{t}"), dl, el));
        err_rows.push((format!("{t}"), de, ee));
    }

    print_sweep("Δ train loss vs equal-weight baseline (positive = weighted better)", "T", &loss_rows);
    print_sweep("Δ train error vs equal-weight baseline", "T", &err_rows);

    write_sweep_csv(
        &format!("{RESULTS_DIR}/fig4_t_sweep_{}_loss.csv", dataset.name()),
        "T,delta_loss,err",
        &loss_rows,
    )?;
    write_sweep_csv(
        &format!("{RESULTS_DIR}/fig4_t_sweep_{}_error.csv", dataset.name()),
        "T,delta_error,err",
        &err_rows,
    )?;

    // Shape summary.
    let best = loss_rows
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!("\noptimal T = {} (Δloss {:+.5}); paper expects a finite optimum", best.0, best.1);
    let tail = loss_rows.last().unwrap();
    println!(
        "T→∞ tail Δloss {:+.5} (should approach 0 — Property 2)",
        tail.1
    );
    Ok(())
}
